//! Figures 10-19 (Appendix G): layerwise norm-space series for the model
//! zoo at three resolutions (32^2 / 224^2 / 512^2) — the full hybridization
//! atlas. Emits one CSV per (model, resolution) and a summary table of
//! depth thresholds and totals.

use fastdp::arch::catalog::vision_model;
use fastdp::bench::emit;
use fastdp::complexity::{ghost_preferred, norm_space_ghost, norm_space_inst, norm_space_mixed};
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

const MODELS: [&str; 14] = [
    "resnet34",
    "resnet50",
    "resnet101",
    "resnet152",
    "vgg11",
    "vgg13",
    "vgg16",
    "vgg19",
    "densenet121",
    "densenet161",
    "densenet201",
    "convnext_base",
    "wide_resnet50",
    "beit_large",
];

fn main() {
    let mut summary = Table::new(
        "Figures 10-19 summary: hybridization by model x resolution",
        &["model", "img", "layers", "ghost-preferred", "mixed", "inst", "ghost"],
    );
    for name in MODELS {
        for img in [32u64, 224, 512] {
            let Some(arch) = vision_model(name, img) else { continue };
            let layers: Vec<_> = arch.gl_layers().cloned().collect();
            if layers.iter().any(|l| l.t == 0) {
                continue; // resolution too small for this depth
            }
            let mut series = Table::new(
                &format!("{name} @{img}^2"),
                &["layer_idx", "T", "ghost", "inst", "mixed"],
            );
            let mut n_ghost = 0usize;
            let (mut tot_g, mut tot_i, mut tot_m) = (0.0, 0.0, 0.0);
            for (i, l) in layers.iter().enumerate() {
                let g = norm_space_ghost(1.0, l);
                let inst = norm_space_inst(1.0, l);
                let m = norm_space_mixed(1.0, l);
                if ghost_preferred(l) {
                    n_ghost += 1;
                }
                tot_g += g;
                tot_i += inst;
                tot_m += m;
                series.row(&[
                    i.to_string(),
                    l.t.to_string(),
                    format!("{g:.0}"),
                    format!("{inst:.0}"),
                    format!("{m:.0}"),
                ]);
            }
            // CSV only (the atlas is large); summary row in the table
            let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
            let _ = std::fs::create_dir_all(&dir);
            let _ = std::fs::write(
                dir.join(format!("fig_atlas_{name}_{img}.csv")),
                series.csv(),
            );
            summary.row(&[
                name.into(),
                img.to_string(),
                layers.len().to_string(),
                n_ghost.to_string(),
                fmt_count(tot_m),
                fmt_count(tot_i),
                fmt_count(tot_g),
            ]);
        }
    }
    emit("fig10_19_summary", &summary, true);
    println!(
        "\nexpected shape (paper App. G): higher resolution pushes the \
         ghost/inst flip deeper (fewer ghost-preferred layers); transformers \
         (beit) prefer ghost everywhere at 224^2 but not at 512^2."
    );
}
