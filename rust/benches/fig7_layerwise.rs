//! Figure 7: layerwise space complexity of the per-sample gradient norm
//! — ResNet18 @224^2, ResNet18 @512^2, VGG11 @224^2, ViT-base @224^2.
//! Emits the CSV series behind each panel (layer index, ghost,
//! instantiation, mixed) plus the depth threshold where the decision
//! flips.

use fastdp::arch::catalog::vision_model;
use fastdp::bench::emit;
use fastdp::complexity::{ghost_preferred, norm_space_ghost, norm_space_inst};
use fastdp::util::table::Table;

fn main() {
    for (name, img) in [
        ("resnet18", 224u64),
        ("resnet18", 512),
        ("vgg11", 224),
        ("vit_base", 224),
    ] {
        let arch = vision_model(name, img).unwrap();
        let mut t = Table::new(
            &format!("Figure 7 series: {name} @{img}^2 (B=1, floats)"),
            &["layer_idx", "layer", "T", "ghost", "instantiation", "mixed", "choice"],
        );
        let mut flip = None;
        for (i, l) in arch.gl_layers().enumerate() {
            let g = norm_space_ghost(1.0, l);
            let inst = norm_space_inst(1.0, l);
            let ghost = ghost_preferred(l);
            if ghost && flip.is_none() {
                flip = Some(i);
            }
            t.row(&[
                i.to_string(),
                l.name.clone(),
                l.t.to_string(),
                format!("{g:.0}"),
                format!("{inst:.0}"),
                format!("{:.0}", g.min(inst)),
                if ghost { "ghost" } else { "inst" }.into(),
            ]);
        }
        emit(&format!("fig7_{name}_{img}"), &t, true);
        println!(
            "depth threshold (first ghost-preferred layer): {:?}\n",
            flip
        );
    }
    println!(
        "expected shape (paper Fig 7): the ghost/inst crossover moves deeper \
         as resolution grows (224^2: layer ~9 of ResNet18; 512^2: ~17); \
         ViT-base prefers ghost at every block."
    );
}
