//! Table 7: parameter census of the model zoo — weights in generalized
//! linear layers (BK-applicable) vs biases vs norm-layer parameters.

use fastdp::arch::catalog::{by_name, LANGUAGE_ZOO, VISION_ZOO};
use fastdp::bench::emit;
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

fn main() {
    let mut t = Table::new(
        "Table 7: % of trainable parameters applicable to BK",
        &["model", "GL weights", "GL bias", "other (norm)", "% BK"],
    );
    for name in VISION_ZOO.iter().chain(LANGUAGE_ZOO.iter()) {
        let a = by_name(name).unwrap();
        t.row(&[
            name.to_string(),
            fmt_count(a.gl_weight_params() as f64),
            a.gl_bias.to_string(),
            a.other_params.to_string(),
            format!("{:.2}%", 100.0 * a.bk_applicable_fraction()),
        ]);
    }
    emit("table7_param_fractions", &t, true);
    println!("\npaper: every model >= 98.9% applicable (Table 7)");
}
