//! Table 7: parameter census of the model zoo — weights in generalized
//! linear layers (BK-applicable) vs biases vs norm-layer parameters —
//! plus the same census for the native trainability plane: what
//! fraction of each registry model actually trains (and gets grads,
//! noise, and Adam state allocated) under each fine-tuning preset.

use fastdp::arch::catalog::{by_name, LANGUAGE_ZOO, VISION_ZOO};
use fastdp::bench::emit;
use fastdp::runtime::native::model::NativeSpec;
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

fn main() {
    let mut t = Table::new(
        "Table 7: % of trainable parameters applicable to BK",
        &["model", "GL weights", "GL bias", "other (norm)", "% BK"],
    );
    for name in VISION_ZOO.iter().chain(LANGUAGE_ZOO.iter()) {
        let a = by_name(name).unwrap();
        t.row(&[
            name.to_string(),
            fmt_count(a.gl_weight_params() as f64),
            a.gl_bias.to_string(),
            a.other_params.to_string(),
            format!("{:.2}%", 100.0 * a.bk_applicable_fraction()),
        ]);
    }
    emit("table7_param_fractions", &t, true);
    println!("\npaper: every model >= 98.9% applicable (Table 7)");

    // Native trainability census: the backend only allocates grad /
    // noise / optimizer buffers for the trainable slots, so this
    // fraction is also the fraction of BK book-keeping that survives.
    let mut n = Table::new(
        "native registry trainability census (§E.2 presets)",
        &["model", "preset", "trainable", "total", "fraction"],
    );
    // "" keeps the registry preset (the lora_bench variant ships its own)
    for (name, preset) in [
        ("gpt_nano_bench", "all"),
        ("gpt_nano_bench", "bias-only"),
        ("gpt_nano_bench", "lora:8"),
        ("gpt_nano_lora_bench", ""),
    ] {
        let mut spec = NativeSpec::by_name(name).unwrap();
        if !preset.is_empty() {
            spec.trainable = preset.into();
        }
        let (tr, total) = (spec.n_trainable_params(), spec.n_params());
        n.row(&[
            name.to_string(),
            spec.trainable.clone(),
            fmt_count(tr as f64),
            fmt_count(total as f64),
            format!("{:.2}%", 100.0 * tr as f64 / total as f64),
        ]);
    }
    println!();
    emit("table7_native_trainability", &n, true);
}
