//! Figure 5: language-model efficiency (GPT2 on E2E / RoBERTa on GLUE in
//! the paper) — measured across all implementations on the GPT artifact,
//! plus the sequence-length sweep (T = 16 / 64 / 256) that drives the
//! paper's T^2-vs-pd analysis.

use fastdp::bench::{artifacts_dir, emit, layers_of, maybe_run_child, measure_in_child};
use fastdp::complexity::{model_cost, Strategy};
use fastdp::runtime::Manifest;
use fastdp::util::stats::{fmt_bytes, fmt_duration};
use fastdp::util::table::Table;

fn main() {
    maybe_run_child();
    let manifest = Manifest::load(&artifacts_dir()).expect("manifest");
    let iters = 3;

    let mut t = Table::new(
        "Figure 5: GPT-mini, all implementations (measured)",
        &["strategy", "time/step", "vs nondp", "throughput", "peak RSS"],
    );
    let mut nondp_time = None;
    let mut rows = Vec::new();
    let mut order = vec!["nondp".to_string()];
    order.extend(
        manifest
            .strategies_for("gpt_bench")
            .into_iter()
            .filter(|s| s != "nondp"),
    );
    for strat in order {
        match measure_in_child("gpt_bench", &strat, iters) {
            Ok(r) => {
                if strat == "nondp" {
                    nondp_time = Some(r.mean_step_secs);
                }
                rows.push(r);
            }
            Err(e) => eprintln!("skip {strat}: {e}"),
        }
    }
    for r in rows {
        t.row(&[
            r.strategy.clone(),
            fmt_duration(r.mean_step_secs),
            nondp_time
                .map(|n| format!("{:.2}x", r.mean_step_secs / n))
                .unwrap_or_default(),
            format!("{:.1}/s", r.samples_per_sec),
            fmt_bytes(r.peak_rss as f64),
        ]);
    }
    emit("fig5_language", &t, true);

    // sequence-length sweep
    let mut ts = Table::new(
        "Figure 5 companion: sequence-length sweep (measured + analytic)",
        &["T", "strategy", "time/step", "peak RSS", "analytic time x nondp"],
    );
    for model in ["gpt_t16", "gpt_bench", "gpt_t256"] {
        let meta = &manifest.models[model];
        let layers = layers_of(meta);
        let b = meta.batch as f64;
        let t_seq = meta.spec.opt_i64("seq", 0);
        let nd = model_cost(Strategy::NonDp, b, &layers).time;
        for strat in ["nondp", "opacus", "ghostclip", "bk", "bk_mixopt"] {
            if !manifest.strategies_for(model).iter().any(|s| s == strat) {
                continue;
            }
            match measure_in_child(model, strat, iters) {
                Ok(r) => {
                    let s = Strategy::parse(strat).unwrap();
                    ts.row(&[
                        t_seq.to_string(),
                        strat.into(),
                        fmt_duration(r.mean_step_secs),
                        fmt_bytes(r.peak_rss as f64),
                        format!("{:.2}x", model_cost(s, b, &layers).time / nd),
                    ]);
                }
                Err(e) => eprintln!("skip {model}:{strat}: {e}"),
            }
        }
    }
    println!();
    emit("fig5_seq_sweep", &ts, true);
    println!(
        "\nexpected shape (paper Fig 5): DP-BK speed 0.86-0.89x of non-DP; \
         ghostclip ~1.6x slower than bk; opacus memory grows with model/batch."
    );
}
