//! Table 8: whole-model time and space complexity at B=100 for BK vs
//! non-DP / GhostClip / Opacus, over the language + vision lineup of the
//! paper (text T=256, GPT2 at T=100 and T=1000, vision at 224^2).

use fastdp::arch::catalog::{language_model, vision_model};
use fastdp::bench::emit;
use fastdp::complexity::{model_cost, Strategy};
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

fn main() {
    let b = 100.0;
    let rows: Vec<(String, Vec<fastdp::arch::LayerDims>, Strategy)> = vec![
        ("roberta-base T=256", language_model("roberta-base", 256), Strategy::Bk),
        ("roberta-large T=256", language_model("roberta-large", 256), Strategy::Bk),
        ("vit-base 224^2", vision_model("vit_base", 224), Strategy::BkMixOpt),
        ("vit-large 224^2", vision_model("vit_large", 224), Strategy::BkMixOpt),
        ("beit-large 224^2", vision_model("beit_large", 224), Strategy::BkMixOpt),
        ("gpt2 T=100", language_model("gpt2", 100), Strategy::Bk),
        ("gpt2-medium T=100", language_model("gpt2-medium", 100), Strategy::Bk),
        ("gpt2-large T=100", language_model("gpt2-large", 100), Strategy::Bk),
        ("gpt2 T=1000", language_model("gpt2", 1000), Strategy::Bk),
        ("gpt2-medium T=1000", language_model("gpt2-medium", 1000), Strategy::Bk),
        ("gpt2-large T=1000", language_model("gpt2-large", 1000), Strategy::Bk),
    ]
    .into_iter()
    .map(|(n, a, s)| (n.to_string(), a.unwrap().gl_layers().cloned().collect(), s))
    .collect();

    let mut t = Table::new(
        "Table 8: time complexity at B=100 (ratios vs BK in parens)",
        &["model", "BK", "non-DP", "GhostClip", "Opacus"],
    );
    let mut ts = Table::new(
        "Table 8: space complexity at B=100 (ratios vs BK in parens)",
        &["model", "BK", "non-DP", "GhostClip", "Opacus"],
    );
    for (name, layers, bk_variant) in &rows {
        let bk = model_cost(*bk_variant, b, layers);
        let fmt = |c: fastdp::complexity::ModelCost, base: f64, time: bool| {
            let v = if time { c.time } else { c.space };
            format!("{} ({:.2}x)", fmt_count(v), v / base)
        };
        let nd = model_cost(Strategy::NonDp, b, layers);
        let gc = model_cost(Strategy::GhostClip, b, layers);
        let op = model_cost(Strategy::Opacus, b, layers);
        t.row(&[
            name.clone(),
            fmt_count(bk.time),
            fmt(nd.clone(), bk.time, true),
            fmt(gc.clone(), bk.time, true),
            fmt(op.clone(), bk.time, true),
        ]);
        ts.row(&[
            name.clone(),
            fmt_count(bk.space),
            fmt(nd, bk.space, false),
            fmt(gc, bk.space, false),
            fmt(op, bk.space, false),
        ]);
    }
    emit("table8_time", &t, true);
    println!();
    emit("table8_space", &ts, true);
    println!(
        "\npaper reference (T=100/256): non-DP 0.86-0.97x, GhostClip 1.54-1.66x, \
         Opacus 1.01-1.30x time; Opacus 3.2-10.1x space"
    );
}
