//! Tables 2 & 5: per-layer complexity of every DP implementation —
//! the paper's symbolic coefficients evaluated on representative layers
//! in the small-T (language) and large-T (first-conv) regimes, plus the
//! qualitative Table 2 summary (backprops / instantiation flags).

use fastdp::arch::{LayerDims, LayerKind};
use fastdp::complexity::{layer_cost, ALL_STRATEGIES};
use fastdp::bench::emit;
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

fn layer(t: u64, d: u64, p: u64) -> LayerDims {
    LayerDims {
        kind: LayerKind::Linear,
        name: "rep".into(),
        t,
        d,
        p,
    }
}

fn main() {
    // Table 2 qualitative summary
    let mut t2 = Table::new(
        "Table 2: implementation properties",
        &["strategy", "backprops", "instantiates psg", "ghost norm"],
    );
    for s in ALL_STRATEGIES {
        t2.row(&[
            s.name().into(),
            s.backprops().to_string(),
            if s.instantiates_psg() { "yes" } else { "no" }.into(),
            match s.name() {
                "ghostclip" | "bk" => "always",
                "nondp" | "opacus" | "fastgradclip" => "never",
                _ => "layerwise",
            }
            .into(),
        ]);
    }
    emit("table2_properties", &t2, false);

    // Table 5 evaluated: one RoBERTa-like layer (T=256, d=p=1024) and the
    // VGG11 first conv (T=224^2, d=27, p=64), B=32.
    let b = 32.0;
    for (tag, l) in [
        ("language layer T=256 d=p=1024", layer(256, 1024, 1024)),
        (
            "vgg11 conv1 T=224^2 d=27 p=64",
            LayerDims {
                kind: LayerKind::Conv,
                name: "conv1".into(),
                t: 224 * 224,
                d: 27,
                p: 64,
            },
        ),
    ] {
        let mut t5 = Table::new(
            &format!("Table 5 evaluated: {tag} (B={b})"),
            &["strategy", "time", "vs nondp", "space overhead"],
        );
        let nondp = layer_cost(fastdp::complexity::Strategy::NonDp, b, &l).time;
        for s in ALL_STRATEGIES {
            let c = layer_cost(s, b, &l);
            t5.row(&[
                s.name().into(),
                fmt_count(c.time),
                format!("{:.3}x", c.time / nondp),
                fmt_count(c.space_overhead),
            ]);
        }
        emit(
            &format!(
                "table5_{}",
                if tag.starts_with("language") { "language" } else { "conv" }
            ),
            &t5,
            false,
        );
        println!();
    }
}
