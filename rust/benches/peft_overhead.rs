//! §E.2: DP parameter-efficient fine-tuning — BK on LoRA vs the
//! per-sample-instantiation (Opacus-style) implementation, measured on
//! the gptlora artifact, plus the analytic overhead formulas of §E.2.

use fastdp::bench::{artifacts_dir, emit, maybe_run_child, measure_in_child};
use fastdp::runtime::Manifest;
use fastdp::util::stats::{fmt_bytes, fmt_count, fmt_duration};
use fastdp::util::table::Table;

fn main() {
    maybe_run_child();
    let manifest = Manifest::load(&artifacts_dir()).expect("manifest");
    let iters = 3;

    let mut t = Table::new(
        "DP LoRA fine-tuning (measured, gpt-mini rank 8)",
        &["strategy", "time/step", "throughput", "peak RSS"],
    );
    for strat in manifest.strategies_for("gptlora") {
        match measure_in_child("gptlora", &strat, iters) {
            Ok(r) => t
                .row(&[
                    strat.clone(),
                    fmt_duration(r.mean_step_secs),
                    format!("{:.1}/s", r.samples_per_sec),
                    fmt_bytes(r.peak_rss as f64),
                ])
                .to_owned(),
            Err(e) => {
                eprintln!("skip {strat}: {e}");
                continue;
            }
        };
    }
    emit("peft_measured", &t, false);

    // Analytic §E.2 overheads for LoRA: instantiation Br(p+d) + 2BTr(p+d)
    // time vs BK 4BT^2 space + 2BT^2(p+d+2r) time.
    let mut a = Table::new(
        "§E.2 analytic LoRA overhead per layer (B=16, T=64, d=p=128)",
        &["rank", "inst space Br(p+d)", "BK space 4BT^2", "BK wins?"],
    );
    let (b, t_seq, d, p) = (16.0, 64.0, 128.0, 128.0);
    for r in [4.0, 16.0, 64.0, 256.0] {
        let inst = b * r * (p + d);
        let bk = 4.0 * b * t_seq * t_seq;
        a.row(&[
            format!("{r}"),
            fmt_count(inst),
            fmt_count(bk),
            if bk < inst { "yes" } else { "no (small rank)" }.into(),
        ]);
    }
    println!();
    emit("peft_analytic", &a, false);
}
