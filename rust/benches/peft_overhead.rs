//! §E.2: DP parameter-efficient fine-tuning — BK step time and memory
//! under the native trainability plane (full vs bias-only vs LoRA on
//! the gpt_nano bench model), plus the analytic overhead formulas of
//! §E.2. Frozen layers skip ghost norms, per-sample instantiation, and
//! clipped-sum accumulation, so bias-only must come in strictly below
//! the full fine-tune; the binary exits non-zero if it does not.

use fastdp::bench::{emit, maybe_run_native_child, measure_native_isolated};
use fastdp::util::stats::{fmt_bytes, fmt_count, fmt_duration};
use fastdp::util::table::Table;

fn main() {
    // this binary re-execs itself per row for peak-RSS isolation
    maybe_run_native_child();
    let (model, strategy) = ("gpt_nano_bench", "bk");
    let (warmup, iters, threads) = (3, 10, 0);

    let mut t = Table::new(
        "DP parameter-efficient fine-tuning (native BK, gpt_nano_bench)",
        &["preset", "trainable", "median/step", "vs full", "g-cache peak", "peak RSS"],
    );
    let mut rows = Vec::new();
    for preset in ["all", "bias-only", "lora:8"] {
        match measure_native_isolated(model, strategy, "all-layer", warmup, iters, threads, 1, preset)
        {
            Ok(r) => rows.push(r),
            Err(e) => {
                eprintln!("peft_overhead: {model}/{preset}: {e}");
                std::process::exit(1);
            }
        }
    }
    let full_median = rows[0].median_step_secs;
    for r in &rows {
        t.row(&[
            r.peft.clone(),
            format!("{:.2}%", 100.0 * r.trainable_frac),
            fmt_duration(r.median_step_secs),
            format!("{:.2}x", r.median_step_secs / full_median),
            fmt_count(r.peak_gcache_floats_measured as f64),
            fmt_bytes(r.peak_rss as f64),
        ]);
    }
    emit("peft_measured", &t, false);

    // Analytic §E.2 overheads for LoRA: instantiation Br(p+d) + 2BTr(p+d)
    // time vs BK 4BT^2 space + 2BT^2(p+d+2r) time.
    let mut a = Table::new(
        "§E.2 analytic LoRA overhead per layer (B=16, T=64, d=p=128)",
        &["rank", "inst space Br(p+d)", "BK space 4BT^2", "BK wins?"],
    );
    let (b, t_seq, d, p) = (16.0, 64.0, 128.0, 128.0);
    for r in [4.0, 16.0, 64.0, 256.0] {
        let inst = b * r * (p + d);
        let bk = 4.0 * b * t_seq * t_seq;
        a.row(&[
            format!("{r}"),
            fmt_count(inst),
            fmt_count(bk),
            if bk < inst { "yes" } else { "no (small rank)" }.into(),
        ]);
    }
    println!();
    emit("peft_analytic", &a, false);

    let bias_median = rows[1].median_step_secs;
    if bias_median < full_median {
        println!(
            "\nbias-only speedup over full fine-tune: {:.2}x",
            full_median / bias_median
        );
    } else {
        eprintln!(
            "\npeft_overhead: bias-only median {:.3}ms is not below full {:.3}ms — \
             frozen layers are not skipping work",
            bias_median * 1e3,
            full_median * 1e3
        );
        std::process::exit(1);
    }
}
