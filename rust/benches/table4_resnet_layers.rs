//! Table 4: layerwise space complexity of the per-sample gradient norm
//! (ghost vs instantiation, with the hybrid decision in bold — here
//! marked with '*') for ResNet-18/34/50 on ImageNet 224x224, B=1 —
//! plus the same per-layer decision over the native conv registry,
//! where a measured training step gates the fused g-cache peak against
//! the complexity engine's plan-walk prediction and the rows land in
//! `BENCH_table4_resnet.json` for the bench-regression gate.

use fastdp::arch::catalog::vision_model;
use fastdp::bench::{emit, measure_native, BenchResult};
use fastdp::complexity::{ghost_preferred, norm_space_ghost, norm_space_inst};
use fastdp::json::Value;
use fastdp::runtime::native::model::{registry_names, ModelKind, NativeSpec};
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

fn main() {
    for model in ["resnet18", "resnet34", "resnet50"] {
        let arch = vision_model(model, 224).unwrap();
        let mut t = Table::new(
            &format!("Table 4: {model} @224^2, B=1 ('*' = hybrid picks it)"),
            &["layer", "T", "ghost 2T^2", "inst pd", "decision"],
        );
        let mut total_ghost = 0.0;
        let mut total_inst = 0.0;
        let mut total_mixed = 0.0;
        for l in arch.gl_layers() {
            let g = norm_space_ghost(1.0, l);
            let i = norm_space_inst(1.0, l);
            let ghost = ghost_preferred(l);
            total_ghost += g;
            total_inst += i;
            total_mixed += g.min(i);
            t.row(&[
                l.name.clone(),
                l.t.to_string(),
                format!("{}{}", fmt_count(g), if ghost { "*" } else { "" }),
                format!("{}{}", fmt_count(i), if ghost { "" } else { "*" }),
                if ghost { "ghost" } else { "instantiate" }.into(),
            ]);
        }
        t.row(&[
            "TOTAL".into(),
            "".into(),
            fmt_count(total_ghost),
            fmt_count(total_inst),
            format!("mixed = {}", fmt_count(total_mixed)),
        ]);
        emit(&format!("table4_{model}"), &t, true);
        println!(
            "paper Table 4 reference totals: r18 ghost 399M / inst 11.5M / mixed 1.0M;\
             \n  r34 444M / 21.6M / 2.3M; r50 528M / 22.7M / 2.8M\n"
        );
    }

    // Native conv registry: the same layerwise decision, computed from
    // the executable plan's dims, and a measured step whose fused
    // g-cache peak must equal the plan-walk prediction exactly.
    let conv_models: Vec<String> = registry_names()
        .into_iter()
        .filter(|n| {
            matches!(
                NativeSpec::by_name(n).map(|s| s.model_kind()),
                Some(ModelKind::Conv { .. })
            )
        })
        .collect();
    assert!(!conv_models.is_empty(), "conv registry is empty");
    let mut rows: Vec<BenchResult> = Vec::new();
    let mut mismatches = 0usize;
    for model in &conv_models {
        let spec = NativeSpec::by_name(model).unwrap();
        let mut t = Table::new(
            &format!("Table 4 (native, {model}, B=1): ghost vs instantiation by layer"),
            &["layer", "T", "ghost 2T^2", "inst pd", "decision"],
        );
        for l in &spec.arch_layers() {
            let g = norm_space_ghost(1.0, l);
            let i = norm_space_inst(1.0, l);
            let ghost = ghost_preferred(l);
            t.row(&[
                l.name.clone(),
                l.t.to_string(),
                format!("{}{}", fmt_count(g), if ghost { "*" } else { "" }),
                format!("{}{}", fmt_count(i), if ghost { "" } else { "*" }),
                if ghost { "ghost" } else { "instantiate" }.into(),
            ]);
        }
        emit(&format!("table4_{model}_native"), &t, true);
        match measure_native(model, "bk", "all-layer", 1, 2, 0, 1, "") {
            Ok(r) => {
                let got = r.peak_gcache_floats_measured as f64;
                let want = r.peak_gcache_floats_predicted;
                if (got - want).abs() > 0.01 * want {
                    eprintln!(
                        "g-cache MISMATCH {model}: measured {got} vs plan-walk \
                         prediction {want}"
                    );
                    mismatches += 1;
                } else {
                    println!(
                        "{model}: measured fused g-cache peak {got} == plan-walk prediction\n"
                    );
                }
                rows.push(r);
            }
            Err(e) => {
                eprintln!("bench {model}: {e}");
                mismatches += 1;
            }
        }
    }

    let mut root = Value::obj();
    root.set("model", Value::from("table4_resnet_layers"))
        .set(
            "results",
            Value::Arr(rows.iter().map(BenchResult::to_json).collect()),
        );
    let path = "BENCH_table4_resnet.json";
    match std::fs::write(path, root.to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("cannot write {path}: {e}"),
    }
    if mismatches > 0 {
        eprintln!("\n{mismatches} conv model(s) failed the g-cache gate");
        std::process::exit(1);
    }
}
