//! Table 4: layerwise space complexity of the per-sample gradient norm
//! (ghost vs instantiation, with the hybrid decision in bold — here
//! marked with '*') for ResNet-18/34/50 on ImageNet 224x224, B=1.

use fastdp::arch::catalog::vision_model;
use fastdp::bench::emit;
use fastdp::complexity::{ghost_preferred, norm_space_ghost, norm_space_inst};
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

fn main() {
    for model in ["resnet18", "resnet34", "resnet50"] {
        let arch = vision_model(model, 224).unwrap();
        let mut t = Table::new(
            &format!("Table 4: {model} @224^2, B=1 ('*' = hybrid picks it)"),
            &["layer", "T", "ghost 2T^2", "inst pd", "decision"],
        );
        let mut total_ghost = 0.0;
        let mut total_inst = 0.0;
        let mut total_mixed = 0.0;
        for l in arch.gl_layers() {
            let g = norm_space_ghost(1.0, l);
            let i = norm_space_inst(1.0, l);
            let ghost = ghost_preferred(l);
            total_ghost += g;
            total_inst += i;
            total_mixed += g.min(i);
            t.row(&[
                l.name.clone(),
                l.t.to_string(),
                format!("{}{}", fmt_count(g), if ghost { "*" } else { "" }),
                format!("{}{}", fmt_count(i), if ghost { "" } else { "*" }),
                if ghost { "ghost" } else { "instantiate" }.into(),
            ]);
        }
        t.row(&[
            "TOTAL".into(),
            "".into(),
            fmt_count(total_ghost),
            fmt_count(total_inst),
            format!("mixed = {}", fmt_count(total_mixed)),
        ]);
        emit(&format!("table4_{model}"), &t, true);
        println!(
            "paper Table 4 reference totals: r18 ghost 399M / inst 11.5M / mixed 1.0M;\
             \n  r34 444M / 21.6M / 2.3M; r50 528M / 22.7M / 2.8M\n"
        );
    }
}
