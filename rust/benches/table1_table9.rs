//! Tables 1 & 9: wall-clock efficiency of each DP implementation vs
//! non-private training — time/step, max throughput, relative speed, and
//! memory (measured peak RSS per isolated child process + analytic).
//!
//! The paper measures GPT2/RoBERTa/BEiT on an A100; this testbed runs the
//! architecture-faithful scaled artifacts on XLA-CPU. Absolute numbers
//! differ; the *ordering and ratios* are the reproduction target:
//!   speed:  nondp > bk > ghostclip > opacus   (T small)
//!   memory: opacus >> bk ~ ghostclip ~ nondp

use fastdp::bench::{artifacts_dir, emit, maybe_run_child, measure_in_child};
use fastdp::complexity::{model_cost, Strategy};
use fastdp::runtime::Manifest;
use fastdp::util::stats::{fmt_bytes, fmt_duration};
use fastdp::util::table::Table;

fn main() {
    maybe_run_child();
    let manifest = Manifest::load(&artifacts_dir()).expect("manifest (run `make artifacts`)");
    let iters = std::env::var("FASTDP_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);

    let mut t = Table::new(
        "Table 1/9: per-implementation efficiency (measured, XLA-CPU)",
        &[
            "model", "strategy", "time/step", "throughput", "speedup by bk",
            "peak RSS", "analytic time x", "analytic space x",
        ],
    );
    for model in ["gpt_bench", "mlp_wide"] {
        let meta = &manifest.models[model];
        let layers = fastdp::bench::layers_of(meta);
        let b = meta.batch as f64;
        let bk_analytic = model_cost(Strategy::Bk, b, &layers);

        let mut bk_time = None;
        let strategies = manifest.strategies_for(model);
        // bk first so the speedup column is available
        let mut ordered = vec!["bk".to_string()];
        ordered.extend(strategies.iter().filter(|s| *s != "bk").cloned());
        let mut rows = Vec::new();
        for strat in &ordered {
            match measure_in_child(model, strat, iters) {
                Ok(r) => {
                    if strat == "bk" {
                        bk_time = Some(r.mean_step_secs);
                    }
                    rows.push(r);
                }
                Err(e) => eprintln!("skip {model}:{strat}: {e}"),
            }
        }
        for r in rows {
            let s = Strategy::parse(&r.strategy).unwrap();
            let c = model_cost(s, b, &layers);
            t.row(&[
                r.model.clone(),
                r.strategy.clone(),
                fmt_duration(r.mean_step_secs),
                format!("{:.1}/s", r.samples_per_sec),
                bk_time
                    .map(|bt| format!("{:.2}x", r.mean_step_secs / bt))
                    .unwrap_or_default(),
                fmt_bytes(r.peak_rss as f64),
                format!("{:.2}x", c.time / bk_analytic.time),
                format!("{:.2}x", c.space / bk_analytic.space),
            ]);
        }
    }
    emit("table1_table9", &t, false);

    // Max-batch estimate under a memory ceiling (the paper's 40GB A100):
    // argmax B s.t. analytic space(B) <= ceiling.
    let mut mb = Table::new(
        "Table 9 (max physical batch under 40GB, analytic, gpt2 T=100)",
        &["strategy", "max batch", "space at max"],
    );
    let gpt2 = fastdp::arch::catalog::language_model("gpt2", 100).unwrap();
    let layers: Vec<_> = gpt2.gl_layers().cloned().collect();
    let ceiling = 40e9 / 4.0; // floats
    for s in fastdp::complexity::ALL_STRATEGIES {
        let mut b = 1u64;
        while model_cost(s, (b * 2) as f64, &layers).space < ceiling && b < (1 << 20) {
            b *= 2;
        }
        // refine linearly
        let mut best = b;
        for cand in (b..=b * 2).step_by((b / 8).max(1) as usize) {
            if model_cost(s, cand as f64, &layers).space < ceiling {
                best = cand;
            }
        }
        mb.row(&[
            s.name().into(),
            best.to_string(),
            fmt_bytes(model_cost(s, best as f64, &layers).space * 4.0),
        ]);
    }
    emit("table9_maxbatch", &mb, false);
}
