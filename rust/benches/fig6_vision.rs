//! Figure 6: vision efficiency in the large-T regime.
//!
//! Measured on the native conv registry (`conv_mnist_e2e`,
//! `resnet_tiny_e2e`, `conv_bench` — at 32^2 the conv layers already
//! cross 2T^2 > pd, so the hybrid routes to instantiation where
//! ghost-norm-only implementations pay the Gram blow-up), and analytic
//! at the paper's true scale (VGG11 / BEiT-large @224^2) where the
//! ghost route explodes in memory.
//!
//! Every measured one-pass DP row is gated: the fused g-cache peak the
//! backend actually held must equal the complexity engine's plan-walk
//! prediction ([`bk_gcache_floats_layers`] over
//! [`NativeSpec::gcache_layers`]) — two independent codepaths. Any
//! mismatch exits non-zero. Rows are also written to
//! `BENCH_fig6_vision.json` in the `BENCH_native_kernels.json` schema
//! so the bench-regression gate can pin them.

use fastdp::bench::{emit, measure_native, BenchResult};
use fastdp::complexity::{model_cost, Strategy, ALL_STRATEGIES};
use fastdp::json::Value;
use fastdp::runtime::native::model::{registry_names, ModelKind, NativeSpec};
use fastdp::util::stats::{fmt_bytes, fmt_count, fmt_duration};
use fastdp::util::table::Table;

use fastdp::arch::catalog::vision_model;

fn main() {
    let iters = 3;
    let strategies = ["nondp", "opacus", "ghostclip", "bk", "bk_mixopt"];
    let conv_models: Vec<String> = registry_names()
        .into_iter()
        .filter(|n| {
            matches!(
                NativeSpec::by_name(n).map(|s| s.model_kind()),
                Some(ModelKind::Conv { .. })
            )
        })
        .collect();
    assert!(!conv_models.is_empty(), "conv registry is empty");

    let mut rows: Vec<BenchResult> = Vec::new();
    let mut mismatches = 0usize;
    for model in &conv_models {
        let spec = NativeSpec::by_name(model).unwrap();
        let layers = spec.arch_layers();
        let b = spec.batch as f64;
        let nondp_space = model_cost(Strategy::NonDp, b, &layers).space;
        let mut t = Table::new(
            &format!(
                "Figure 6 (measured, native {model}, B={}): hybrid wins where ghost can't",
                spec.batch
            ),
            &[
                "strategy",
                "time/step",
                "throughput",
                "peak RSS",
                "g-cache peak",
                "analytic space x nondp",
            ],
        );
        for strat in strategies {
            match measure_native(model, strat, "all-layer", 1, iters, 0, 1, "") {
                Ok(r) => {
                    let s = Strategy::parse(strat).unwrap();
                    // the acceptance gate: measured fused peak == plan-walk
                    // prediction, exactly (1% band absorbs f64 rounding)
                    if r.peak_gcache_floats_measured > 0 {
                        let want = r.peak_gcache_floats_predicted;
                        let got = r.peak_gcache_floats_measured as f64;
                        if (got - want).abs() > 0.01 * want {
                            eprintln!(
                                "g-cache MISMATCH {model}/{strat}: measured {got} vs \
                                 plan-walk prediction {want}"
                            );
                            mismatches += 1;
                        }
                    }
                    t.row(&[
                        strat.to_string(),
                        fmt_duration(r.mean_step_secs),
                        format!("{:.0}/s", r.samples_per_sec),
                        fmt_bytes(r.peak_rss as f64),
                        if r.peak_gcache_floats_measured > 0 {
                            fmt_count(r.peak_gcache_floats_measured as f64)
                        } else {
                            "-".into()
                        },
                        format!("{:.2}x", model_cost(s, b, &layers).space / nondp_space),
                    ]);
                    rows.push(r);
                }
                Err(e) => {
                    eprintln!("bench {model}/{strat}: {e}");
                    mismatches += 1;
                }
            }
        }
        emit(&format!("fig6_{model}_native"), &t, true);
        println!();
    }

    // analytic at paper scale
    for (name, img) in [("vgg11", 224u64), ("beit_large", 224)] {
        let arch = vision_model(name, img).unwrap();
        let l: Vec<_> = arch.gl_layers().cloned().collect();
        let mut ta = Table::new(
            &format!("Figure 6 (analytic, {name} @{img}^2, B=1): space by implementation"),
            &["strategy", "space (floats)", "x nondp"],
        );
        let nd = model_cost(Strategy::NonDp, 1.0, &l).space;
        for s in ALL_STRATEGIES {
            let c = model_cost(s, 1.0, &l);
            ta.row(&[
                s.name().into(),
                fmt_count(c.space),
                format!("{:.2}x", c.space / nd),
            ]);
        }
        println!();
        emit(&format!("fig6_{name}_analytic"), &ta, true);
    }

    // bench JSON in the BENCH_native_kernels.json schema, so CI can
    // feed these rows through `fastdp bench-check --current ...`
    let mut root = Value::obj();
    root.set("model", Value::from("fig6_vision"))
        .set("iters", Value::from(iters))
        .set(
            "results",
            Value::Arr(rows.iter().map(BenchResult::to_json).collect()),
        );
    let path = "BENCH_fig6_vision.json";
    match std::fs::write(path, root.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("cannot write {path}: {e}"),
    }

    println!(
        "\nexpected shape (paper Fig 6 + §3.1): ghostclip/bk explode on VGG11 \
         (first conv 2T^2 = 5e9 floats); hybrids track nondp; on BEiT \
         (transformer) ghost is fine and hybrids equal bk."
    );
    if mismatches > 0 {
        eprintln!("\n{mismatches} measured row(s) failed the g-cache gate");
        std::process::exit(1);
    }
}
