//! Figure 6: vision efficiency in the large-T regime. Measured on the
//! CNN artifact (32^2, where the conv layers already cross 2T^2 > pd),
//! and analytic at the paper's true scale (VGG11 / BEiT-large @224^2)
//! where ghost-norm-only implementations explode in memory.

use fastdp::arch::catalog::vision_model;
use fastdp::bench::{artifacts_dir, emit, layers_of, maybe_run_child, measure_in_child};
use fastdp::complexity::{model_cost, Strategy, ALL_STRATEGIES};
use fastdp::runtime::Manifest;
use fastdp::util::stats::{fmt_bytes, fmt_count, fmt_duration};
use fastdp::util::table::Table;

fn main() {
    maybe_run_child();
    let manifest = Manifest::load(&artifacts_dir()).expect("manifest");
    let iters = 3;

    let mut t = Table::new(
        "Figure 6 (measured, CNN 32^2): hybrid wins where ghost can't",
        &["strategy", "time/step", "throughput", "peak RSS", "analytic space x nondp"],
    );
    let meta = &manifest.models["conv_bench"];
    let layers = layers_of(meta);
    let b = meta.batch as f64;
    let nondp_space = model_cost(Strategy::NonDp, b, &layers).space;
    for strat in manifest.strategies_for("conv_bench") {
        match measure_in_child("conv_bench", &strat, iters) {
            Ok(r) => {
                let s = Strategy::parse(&strat).unwrap();
                t.row(&[
                    strat.clone(),
                    fmt_duration(r.mean_step_secs),
                    format!("{:.0}/s", r.samples_per_sec),
                    fmt_bytes(r.peak_rss as f64),
                    format!("{:.2}x", model_cost(s, b, &layers).space / nondp_space),
                ]);
            }
            Err(e) => eprintln!("skip {strat}: {e}"),
        }
    }
    emit("fig6_cnn_measured", &t, true);

    // analytic at paper scale
    for (name, img) in [("vgg11", 224u64), ("beit_large", 224)] {
        let arch = vision_model(name, img).unwrap();
        let l: Vec<_> = arch.gl_layers().cloned().collect();
        let mut ta = Table::new(
            &format!("Figure 6 (analytic, {name} @{img}^2, B=1): space by implementation"),
            &["strategy", "space (floats)", "x nondp"],
        );
        let nd = model_cost(Strategy::NonDp, 1.0, &l).space;
        for s in ALL_STRATEGIES {
            let c = model_cost(s, 1.0, &l);
            ta.row(&[
                s.name().into(),
                fmt_count(c.space),
                format!("{:.2}x", c.space / nd),
            ]);
        }
        println!();
        emit(&format!("fig6_{name}_analytic"), &ta, true);
    }
    println!(
        "\nexpected shape (paper Fig 6 + §3.1): ghostclip/bk explode on VGG11 \
         (first conv 2T^2 = 5e9 floats); hybrids track nondp; on BEiT \
         (transformer) ghost is fine and hybrids equal bk."
    );
}
