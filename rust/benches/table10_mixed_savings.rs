//! Table 10: space complexity of the per-sample gradient norm over the
//! vision zoo at 224^2 — mixed ghost norm vs pure instantiation vs pure
//! ghost, with the savings multipliers the paper headlines.

use fastdp::arch::catalog::{vision_model, VISION_ZOO};
use fastdp::bench::emit;
use fastdp::complexity::{norm_space_ghost, norm_space_inst, norm_space_mixed};
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

fn main() {
    let mut t = Table::new(
        "Table 10: per-sample-norm space @224^2 (B=1)",
        &["model", "mixed (MGN)", "instantiation", "saving", "ghost", "saving"],
    );
    for name in VISION_ZOO {
        let a = vision_model(name, 224).unwrap();
        let layers: Vec<_> = a.gl_layers().cloned().collect();
        let ghost: f64 = layers.iter().map(|l| norm_space_ghost(1.0, l)).sum();
        let inst: f64 = layers.iter().map(|l| norm_space_inst(1.0, l)).sum();
        let mixed: f64 = layers.iter().map(|l| norm_space_mixed(1.0, l)).sum();
        t.row(&[
            name.to_string(),
            fmt_count(mixed),
            fmt_count(inst),
            format!("{:.1}x", inst / mixed),
            fmt_count(ghost),
            format!("{:.1}x", ghost / mixed),
        ]);
    }
    emit("table10_mixed_savings", &t, true);
    println!(
        "\npaper reference rows: resnet18 1.0M/11.5M(11.5x)/399M(399x), \
         vit_base 3.8M/86.3M(22.7x)/3.8M(1.0x), beit_large 5.7M/303.8M(53.3x)/5.7M(1.0x)"
    );
}
