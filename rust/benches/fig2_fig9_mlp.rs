//! Figures 2 & 9: MLP speed/memory across implementations — deep,
//! shallow, and wide configurations plus the batch-size ablation on the
//! wide network (where the paper shows Opacus going OOM at B=1024).
//! Measured on the real artifacts, one child process per point.

use fastdp::bench::{artifacts_dir, emit, layers_of, maybe_run_child, measure_in_child};
use fastdp::complexity::{model_cost, Strategy};
use fastdp::runtime::Manifest;
use fastdp::util::stats::{fmt_bytes, fmt_duration};
use fastdp::util::table::Table;

fn main() {
    maybe_run_child();
    let manifest = Manifest::load(&artifacts_dir()).expect("manifest");
    let iters = 3;

    let mut t = Table::new(
        "Figure 2: MLP speed & memory by implementation (measured)",
        &["config", "strategy", "time/step", "throughput", "peak RSS", "analytic space x nondp"],
    );
    for model in ["mlp_deep", "mlp_shallow", "mlp_wide"] {
        let meta = &manifest.models[model];
        let layers = layers_of(meta);
        let b = meta.batch as f64;
        let nondp_space = model_cost(Strategy::NonDp, b, &layers).space;
        for strat in manifest.strategies_for(model) {
            match measure_in_child(model, &strat, iters) {
                Ok(r) => {
                    let s = Strategy::parse(&strat).unwrap();
                    let c = model_cost(s, b, &layers);
                    t.row(&[
                        model.into(),
                        strat.clone(),
                        fmt_duration(r.mean_step_secs),
                        format!("{:.0}/s", r.samples_per_sec),
                        fmt_bytes(r.peak_rss as f64),
                        format!("{:.2}x", c.space / nondp_space),
                    ]);
                }
                Err(e) => eprintln!("skip {model}:{strat}: {e}"),
            }
        }
    }
    emit("fig2_mlp", &t, true);

    // Figure 9 ablation: batch size on the wide config
    let mut t9 = Table::new(
        "Figure 9: batch-size ablation, wide MLP (measured)",
        &["batch", "strategy", "time/step", "throughput", "peak RSS"],
    );
    for model in ["mlp_wide_b16", "mlp_wide", "mlp_wide_b256"] {
        let meta = &manifest.models[model];
        for strat in manifest.strategies_for(model) {
            match measure_in_child(model, &strat, iters) {
                Ok(r) => {
                    t9.row(&[
                        meta.batch.to_string(),
                        strat.clone(),
                        fmt_duration(r.mean_step_secs),
                        format!("{:.0}/s", r.samples_per_sec),
                        fmt_bytes(r.peak_rss as f64),
                    ]);
                }
                Err(e) => eprintln!("skip {model}:{strat}: {e}"),
            }
        }
    }
    println!();
    emit("fig9_batch_ablation", &t9, true);
    println!(
        "\nexpected shape (paper Fig 2/9): opacus RSS grows ~linearly with B \
         (per-sample grads), bk/ghostclip stay near nondp; bk fastest among DP."
    );
}
