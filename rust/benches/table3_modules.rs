//! Table 3: time/space of the six computation modules, plus a *measured*
//! validation that the analytic model predicts real XLA-CPU ratios: the
//! BK-vs-GhostClip and BK-vs-Opacus step-time ratios on the gpt_bench
//! artifacts should land near the analytic prediction.

use fastdp::bench::{artifacts_dir, emit, layers_of, maybe_run_child, measure_in_child};
use fastdp::arch::{LayerDims, LayerKind};
use fastdp::complexity::{model_cost, module_space, module_time, Module, Strategy};
use fastdp::runtime::Manifest;
use fastdp::util::stats::fmt_count;
use fastdp::util::table::Table;

fn main() {
    maybe_run_child();

    let l = LayerDims {
        kind: LayerKind::Linear,
        name: "rep".into(),
        t: 64,
        d: 512,
        p: 512,
    };
    let b = 16.0;
    let mut t3 = Table::new(
        "Table 3: module costs on a T=64, d=p=512 layer (B=16)",
        &["module", "time", "space"],
    );
    for (name, m) in [
        ("(1) forward", Module::Forward),
        ("(2a) output grad", Module::OutputGrad),
        ("(2b) param grad", Module::ParamGrad),
        ("(3) ghost norm", Module::GhostNorm),
        ("(4) psg instantiation", Module::PsgInstantiation),
        ("(5) weighted sum", Module::WeightedSum),
    ] {
        t3.row(&[
            name.into(),
            fmt_count(module_time(m, b, &l)),
            fmt_count(module_space(m, b, &l)),
        ]);
    }
    emit("table3_modules", &t3, false);

    // measured validation on gpt_bench
    let manifest = Manifest::load(&artifacts_dir()).expect("manifest");
    let meta = &manifest.models["gpt_bench"];
    let layers = layers_of(meta);
    let bb = meta.batch as f64;
    let predict = |s: Strategy| model_cost(s, bb, &layers).time;

    let mut v = Table::new(
        "analytic vs measured step-time ratios (gpt_bench)",
        &["pair", "analytic", "measured"],
    );
    let iters = 3;
    let bk = measure_in_child("gpt_bench", "bk", iters).expect("bk");
    for other in ["nondp", "ghostclip", "opacus", "fastgradclip"] {
        match measure_in_child("gpt_bench", other, iters) {
            Ok(r) => {
                let s = Strategy::parse(other).unwrap();
                v.row(&[
                    format!("{other}/bk"),
                    format!("{:.2}x", predict(s) / predict(Strategy::Bk)),
                    format!("{:.2}x", r.mean_step_secs / bk.mean_step_secs),
                ]);
            }
            Err(e) => eprintln!("skip {other}: {e}"),
        }
    }
    println!();
    emit("table3_validation", &v, false);
}
