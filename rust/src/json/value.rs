//! JSON value tree + typed accessors + serializer.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path access: `v.get("a")` on objects.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        self.as_arr().and_then(|a| a.get(i))
    }

    /// Required typed getters (error messages name the key).
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| format!("missing/invalid string field '{key}'"))
    }

    pub fn req_i64(&self, key: &str) -> Result<i64, String> {
        self.get(key)
            .and_then(Value::as_i64)
            .ok_or_else(|| format!("missing/invalid int field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("missing/invalid number field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Value], String> {
        self.get(key)
            .and_then(Value::as_arr)
            .ok_or_else(|| format!("missing/invalid array field '{key}'"))
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn opt_i64(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Builder-style insert for Obj values.
    pub fn set(&mut self, key: &str, v: Value) -> &mut Value {
        if let Value::Obj(o) = self {
            o.insert(key.to_string(), v);
        } else {
            panic!("set() on non-object");
        }
        self
    }

    fn write_escaped(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Int(i) => out.push_str(&i.to_string()),
            Value::Num(f) => {
                if f.is_finite() {
                    out.push_str(&format!("{f}"));
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Value::Str(s) => Self::write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    Self::write_escaped(k, out);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<i64> for Value {
    fn from(i: i64) -> Self {
        Value::Int(i)
    }
}
impl From<usize> for Value {
    fn from(i: usize) -> Self {
        Value::Int(i as i64)
    }
}
impl From<f64> for Value {
    fn from(f: f64) -> Self {
        Value::Num(f)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let mut v = Value::obj();
        v.set("a", Value::Int(3))
            .set("b", Value::from("hi"))
            .set("c", Value::from(vec![1i64, 2, 3]));
        assert_eq!(v.req_i64("a").unwrap(), 3);
        assert_eq!(v.req_str("b").unwrap(), "hi");
        assert_eq!(v.req_arr("c").unwrap().len(), 3);
        assert!(v.req_str("zz").is_err());
        assert_eq!(v.opt_f64("a", 0.0), 3.0);
        assert_eq!(v.opt_str("missing", "dflt"), "dflt");
    }

    #[test]
    fn display_roundtrip_escapes() {
        let v = Value::Str("a\"b\\c\nd".to_string());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn int_vs_float() {
        assert_eq!(Value::Num(3.0).as_i64(), Some(3));
        assert_eq!(Value::Num(3.5).as_i64(), None);
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
    }
}
