//! Recursive-descent JSON parser with byte offsets in errors.

use super::Value;
use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // handle surrogate pairs
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i + 1..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    out.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                out.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        // self.i points at 'u'
        let start = self.i + 1;
        if start + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[start..start + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Value::Num)
                .map_err(|_| self.err("bad number"))
        } else {
            match text.parse::<i64>() {
                Ok(i) => Ok(Value::Int(i)),
                Err(_) => text
                    .parse::<f64>()
                    .map(Value::Num)
                    .map_err(|_| self.err("bad number")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::super::Value;
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("42").unwrap(), Value::Int(42));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x"], "c": 2.5}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().get("b"), Some(&Value::Null));
        assert_eq!(v.req_f64("c").unwrap(), 2.5);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = parse(r#""a\n\tA😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\tA😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo — ≤\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ≤");
    }

    #[test]
    fn errors_carry_offsets() {
        let e = parse("[1, 2,]").unwrap_err();
        assert!(e.offset > 0);
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("[1] tail").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::obj());
    }

    #[test]
    fn big_ints_fall_back_to_float() {
        let v = parse("123456789012345678901234567890").unwrap();
        assert!(matches!(v, Value::Num(_)));
    }
}
