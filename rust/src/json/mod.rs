//! Minimal JSON parser/serializer (the serde facade is unavailable
//! offline). Supports the full JSON grammar; numbers are f64 with an i64
//! fast path — all we need for configs and artifact manifests.

mod parse;
mod value;

pub use parse::{parse, ParseError};
pub use value::Value;

/// Convenience: parse a file.
pub fn from_file(path: &std::path::Path) -> Result<Value, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
}
