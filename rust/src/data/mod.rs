//! Synthetic data pipeline.
//!
//! The paper's efficiency experiments depend only on tensor *shapes*, and
//! the E2E/GLUE/CIFAR corpora are not redistributable here, so the
//! coordinator trains on synthetic workloads with realistic statistics:
//!
//!  * `TokenCorpus` — Markov bigram chains with Zipf-distributed
//!    marginals (language modeling has signal: the model can actually
//!    learn the bigram structure, so loss curves are meaningful).
//!  * `VectorDataset` — Gaussian-mixture classification (one mean per
//!    class), the MLP/CNN workload.
//!  * `PoissonSampler` — per-example inclusion with probability q, the
//!    sampling scheme the RDP accountant assumes.

use crate::util::rng::Xoshiro256;

/// Zipf-ish unigram sampler over [0, vocab) via inverse CDF.
#[derive(Clone)]
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(vocab: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(vocab);
        let mut acc = 0.0;
        for k in 1..=vocab {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let z = acc;
        for c in cdf.iter_mut() {
            *c /= z;
        }
        Self { cdf }
    }

    fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Markov bigram language: each token's successor distribution is a
/// deterministic permutation mixed with Zipf noise, so sequences have
/// learnable structure (a bigram model reaches well below unigram
/// entropy).
///
/// Draws are *counter-based*: every batch is generated from a fresh fork
/// of an immutable root RNG keyed by a draw cursor, so batch k is a pure
/// function of (seed, k). That makes the stream resumable — a run killed
/// after k draws restores `skip_to(k)` from a checkpoint and continues
/// bitwise-identically — and lets eval draw from a disjoint stream
/// (odd stream ids) without perturbing training data.
///
/// Because draws are counter-based, the stream also splits for free:
/// [`Self::sub_stream`] hands out a positioned clone, so a sharded step
/// can give shard `s` a sub-stream starting at its first global
/// micro-batch index and the per-shard draws concatenate to exactly the
/// 1-shard draw order.
#[derive(Clone)]
pub struct TokenCorpus {
    pub vocab: usize,
    pub seq: usize,
    zipf: Zipf,
    perm: Vec<usize>,
    /// Probability of following the deterministic successor.
    coherence: f64,
    root: Xoshiro256,
    cursor: u64,
    eval_cursor: u64,
}

impl TokenCorpus {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        // random permutation as the "grammar"
        let mut perm: Vec<usize> = (0..vocab).collect();
        for i in (1..vocab).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            perm.swap(i, j);
        }
        Self {
            vocab,
            seq,
            zipf: Zipf::new(vocab, 1.2),
            perm,
            coherence: 0.7,
            root: Xoshiro256::new(seed ^ 0xD1CE),
            cursor: 0,
            eval_cursor: 0,
        }
    }

    /// Training draws consumed so far (persisted in checkpoints).
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Position the training stream at draw `cursor` (checkpoint resume).
    pub fn skip_to(&mut self, cursor: u64) {
        self.cursor = cursor;
    }

    /// Positioned clone of the training stream starting at absolute
    /// draw `start`. Batch k is a pure function of (seed, k), so the
    /// sub-stream's draws are bitwise those the parent would make from
    /// the same cursor; the parent's own cursor is untouched.
    pub fn sub_stream(&self, start: u64) -> Self {
        let mut s = self.clone();
        s.cursor = start;
        s
    }

    fn sequence_from(&self, rng: &mut Xoshiro256) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(self.seq + 1);
        let mut cur = self.zipf.sample(rng);
        toks.push(cur);
        for _ in 0..self.seq {
            cur = if rng.next_f64() < self.coherence {
                self.perm[cur]
            } else {
                self.zipf.sample(rng)
            };
            toks.push(cur);
        }
        let x = toks[..self.seq].iter().map(|&t| t as i32).collect();
        let y = toks[1..=self.seq].iter().map(|&t| t as i32).collect();
        (x, y)
    }

    fn batch_from(&self, rng: &mut Xoshiro256, b: usize) -> (Vec<i32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(b * self.seq);
        let mut ys = Vec::with_capacity(b * self.seq);
        for _ in 0..b {
            let (x, y) = self.sequence_from(rng);
            xs.extend(x);
            ys.extend(y);
        }
        (xs, ys)
    }

    /// One (input, target) pair: x = tokens[0..seq], y = tokens[1..=seq].
    /// Consumes one training draw.
    pub fn sample_sequence(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut rng = self.root.fork(2 * self.cursor);
        self.cursor += 1;
        self.sequence_from(&mut rng)
    }

    /// Fill a flat training batch (B*seq each). Consumes one draw.
    pub fn sample_batch(&mut self, b: usize) -> (Vec<i32>, Vec<i32>) {
        let mut rng = self.root.fork(2 * self.cursor);
        self.cursor += 1;
        self.batch_from(&mut rng, b)
    }

    /// Fill a flat eval batch from the disjoint eval stream (odd stream
    /// ids); never advances the training cursor.
    pub fn sample_eval_batch(&mut self, b: usize) -> (Vec<i32>, Vec<i32>) {
        let mut rng = self.root.fork(2 * self.eval_cursor + 1);
        self.eval_cursor += 1;
        self.batch_from(&mut rng, b)
    }
}

/// Gaussian-mixture classification vectors: class means on a scaled
/// simplex, unit within-class noise.
///
/// Counter-based like [`TokenCorpus`]: batch k is a pure function of
/// (seed, k), with a disjoint eval stream, so checkpoints can persist
/// and restore the exact data position, and [`Self::sub_stream`] can
/// split the draw order across shards without perturbing it.
#[derive(Clone)]
pub struct VectorDataset {
    pub dim: usize,
    pub classes: usize,
    means: Vec<Vec<f32>>,
    root: Xoshiro256,
    cursor: u64,
    eval_cursor: u64,
}

impl VectorDataset {
    pub fn new(dim: usize, classes: usize, separation: f32, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let mut spare = None;
        let means = (0..classes)
            .map(|_| {
                (0..dim)
                    .map(|_| separation * rng.next_gaussian(&mut spare) as f32)
                    .collect()
            })
            .collect();
        Self {
            dim,
            classes,
            means,
            root: Xoshiro256::new(seed ^ 0xF00D),
            cursor: 0,
            eval_cursor: 0,
        }
    }

    /// Training draws consumed so far (persisted in checkpoints).
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Position the training stream at draw `cursor` (checkpoint resume).
    pub fn skip_to(&mut self, cursor: u64) {
        self.cursor = cursor;
    }

    /// Positioned clone of the training stream starting at absolute
    /// draw `start` (see [`TokenCorpus::sub_stream`]).
    pub fn sub_stream(&self, start: u64) -> Self {
        let mut s = self.clone();
        s.cursor = start;
        s
    }

    fn batch_from(&self, rng: &mut Xoshiro256, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(b * self.dim);
        let mut ys = Vec::with_capacity(b);
        let mut spare = None;
        for _ in 0..b {
            let c = rng.next_below(self.classes as u64) as usize;
            ys.push(c as i32);
            for j in 0..self.dim {
                xs.push(self.means[c][j] + rng.next_gaussian(&mut spare) as f32);
            }
        }
        (xs, ys)
    }

    /// One training batch. Consumes one draw.
    pub fn sample_batch(&mut self, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = self.root.fork(2 * self.cursor);
        self.cursor += 1;
        self.batch_from(&mut rng, b)
    }

    /// One eval batch from the disjoint eval stream; never advances the
    /// training cursor.
    pub fn sample_eval_batch(&mut self, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = self.root.fork(2 * self.eval_cursor + 1);
        self.eval_cursor += 1;
        self.batch_from(&mut rng, b)
    }

    /// Image-shaped variant (B, H, W, C) for the CNN model.
    pub fn sample_images(&mut self, b: usize, hw: usize, c: usize) -> (Vec<f32>, Vec<i32>) {
        assert_eq!(self.dim, hw * hw * c, "dim must equal hw*hw*c");
        self.sample_batch(b)
    }
}

/// Poisson subsampling: each of N examples enters the batch independently
/// with probability q — the scheme the RDP accountant models. Returns
/// sampled indices.
pub struct PoissonSampler {
    pub n: usize,
    pub q: f64,
    rng: Xoshiro256,
}

impl PoissonSampler {
    pub fn new(n: usize, q: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&q));
        Self {
            n,
            q,
            rng: Xoshiro256::new(seed),
        }
    }

    pub fn sample(&mut self) -> Vec<usize> {
        (0..self.n)
            .filter(|_| self.rng.next_f64() < self.q)
            .collect()
    }

    /// Sample then clamp/pad to exactly `b` indices (physical batches are
    /// fixed-shape for the AOT executables; the paper's logical batch is
    /// realized by accumulation).
    pub fn sample_fixed(&mut self, b: usize) -> Vec<usize> {
        let mut idx = self.sample();
        while idx.len() < b {
            idx.push(self.rng.next_below(self.n as u64) as usize);
        }
        if idx.len() > b {
            // uniformly thin
            while idx.len() > b {
                let k = self.rng.next_below(idx.len() as u64) as usize;
                idx.swap_remove(k);
            }
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shapes_and_structure() {
        let mut c = TokenCorpus::new(100, 16, 1);
        let (x, y) = c.sample_batch(4);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 64);
        assert!(x.iter().all(|&t| (0..100).contains(&t)));
        // y is x shifted by one within each sequence
        assert_eq!(x[1], y[0]);
        // bigram coherence: successor matches the grammar most of the time
        let mut hits = 0;
        let mut total = 0;
        let mut c2 = TokenCorpus::new(50, 128, 7);
        let perm = c2.perm.clone();
        let (x, y) = c2.sample_batch(8);
        for i in 0..x.len() {
            if perm[x[i] as usize] as i32 == y[i] {
                hits += 1;
            }
            total += 1;
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.55 && rate < 0.85, "coherence rate {rate}");
    }

    #[test]
    fn vectors_are_classifiable() {
        let mut d = VectorDataset::new(8, 3, 4.0, 2);
        let (xs, ys) = d.sample_batch(300);
        assert_eq!(xs.len(), 2400);
        // nearest-mean classification should beat chance easily
        let means = d.means.clone();
        let mut correct = 0;
        for i in 0..300 {
            let v = &xs[i * 8..(i + 1) * 8];
            let mut best = (f32::INFINITY, 0usize);
            for (ci, m) in means.iter().enumerate() {
                let dist: f32 = v.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, ci);
                }
            }
            if best.1 == ys[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 250, "nearest-mean acc {correct}/300");
    }

    #[test]
    fn draws_are_counter_based_and_resumable() {
        // batch k is a pure function of (seed, k): skipping to a cursor
        // reproduces the exact draws a fresh stream makes at it.
        let mut a = TokenCorpus::new(100, 8, 11);
        let _ = a.sample_batch(4);
        let second = a.sample_batch(4);
        let mut b = TokenCorpus::new(100, 8, 11);
        b.skip_to(1);
        assert_eq!(b.sample_batch(4), second);
        assert_eq!(b.cursor(), 2);

        let mut a = VectorDataset::new(8, 3, 4.0, 11);
        let _ = a.sample_batch(5);
        let second = a.sample_batch(5);
        let mut b = VectorDataset::new(8, 3, 4.0, 11);
        b.skip_to(1);
        assert_eq!(b.sample_batch(5), second);
    }

    #[test]
    fn sub_streams_concatenate_to_one_shard_draw_order() {
        // Split 7 draws over 3 shard sub-streams (balanced contiguous
        // ranges 3+2+2): concatenating their draws reproduces the
        // 1-shard sequence bitwise, and the parent cursor is untouched.
        let parent = TokenCorpus::new(64, 8, 9);
        let mut solo = TokenCorpus::new(64, 8, 9);
        let expect: Vec<_> = (0..7).map(|_| solo.sample_batch(4)).collect();
        let mut got = Vec::new();
        for (start, len) in [(0u64, 3usize), (3, 2), (5, 2)] {
            let mut sub = parent.sub_stream(start);
            for _ in 0..len {
                got.push(sub.sample_batch(4));
            }
            assert_eq!(sub.cursor(), start + len as u64);
        }
        assert_eq!(got, expect);
        assert_eq!(parent.cursor(), 0);

        let parent = VectorDataset::new(8, 3, 4.0, 9);
        let mut solo = VectorDataset::new(8, 3, 4.0, 9);
        let expect: Vec<_> = (0..5).map(|_| solo.sample_batch(6)).collect();
        let mut got = Vec::new();
        for (start, len) in [(0u64, 2usize), (2, 2), (4, 1)] {
            let mut sub = parent.sub_stream(start);
            for _ in 0..len {
                got.push(sub.sample_batch(6));
            }
        }
        assert_eq!(got, expect);
        assert_eq!(parent.cursor(), 0);
    }

    #[test]
    fn eval_stream_does_not_perturb_training() {
        let mut a = TokenCorpus::new(100, 8, 5);
        let mut b = TokenCorpus::new(100, 8, 5);
        let _ = b.sample_eval_batch(4);
        let _ = b.sample_eval_batch(4);
        assert_eq!(a.sample_batch(4), b.sample_batch(4));
        // and the streams are disjoint
        let mut c = TokenCorpus::new(100, 8, 5);
        assert_ne!(c.sample_eval_batch(4), a.sample_batch(4));

        let mut a = VectorDataset::new(8, 3, 4.0, 5);
        let mut b = VectorDataset::new(8, 3, 4.0, 5);
        let _ = b.sample_eval_batch(5);
        assert_eq!(a.sample_batch(5), b.sample_batch(5));
    }

    #[test]
    fn poisson_rate() {
        let mut s = PoissonSampler::new(10_000, 0.05, 3);
        let mut total = 0usize;
        for _ in 0..20 {
            total += s.sample().len();
        }
        let rate = total as f64 / (20.0 * 10_000.0);
        assert!((rate - 0.05).abs() < 0.005, "rate {rate}");
    }

    #[test]
    fn poisson_fixed_exact_size() {
        let mut s = PoissonSampler::new(1000, 0.01, 4);
        for _ in 0..10 {
            let idx = s.sample_fixed(32);
            assert_eq!(idx.len(), 32);
            assert!(idx.iter().all(|&i| i < 1000));
        }
    }
}
