//! Timing + summary statistics for the bench harness (criterion is not
//! available offline; this is the minimal honest replacement: warmup,
//! repeated timed runs, mean/median/stddev/min, and RSS sampling).

use std::time::Instant;

/// Online summary of a sample set.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn var(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Time `f` with warmup; returns per-iteration seconds.
pub fn time_iters<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// Current process resident set size in bytes (Linux, /proc/self/statm).
pub fn rss_bytes() -> u64 {
    if let Ok(statm) = std::fs::read_to_string("/proc/self/statm") {
        if let Some(pages) = statm.split_whitespace().nth(1) {
            if let Ok(p) = pages.parse::<u64>() {
                return p * 4096;
            }
        }
    }
    0
}

/// Peak RSS (VmHWM) in bytes from /proc/self/status.
pub fn peak_rss_bytes() -> u64 {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: u64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0);
                return kb * 1024;
            }
        }
    }
    0
}

/// Human formatting helpers used across bench tables.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

pub fn fmt_count(c: f64) -> String {
    if c >= 1e12 {
        format!("{:.1}T", c / 1e12)
    } else if c >= 1e9 {
        format!("{:.1}G", c / 1e9)
    } else if c >= 1e6 {
        format!("{:.1}M", c / 1e6)
    } else if c >= 1e3 {
        format!("{:.1}K", c / 1e3)
    } else {
        format!("{c:.0}")
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else {
        format!("{:.0}m{:02.0}s", (secs / 60.0).floor(), secs % 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.n(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.var() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn timing_positive() {
        let s = time_iters(
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
            2,
            5,
        );
        assert_eq!(s.n(), 5);
        assert!(s.min() > 0.0);
    }

    #[test]
    fn rss_nonzero_on_linux() {
        assert!(rss_bytes() > 0);
        assert!(peak_rss_bytes() >= rss_bytes() / 2);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(1536.0), "1.50KB");
        assert_eq!(fmt_count(2_500_000.0), "2.5M");
        assert_eq!(fmt_duration(0.0025), "2.5ms");
        assert_eq!(fmt_duration(125.0), "2m05s");
    }
}
