//! Tiny leveled logger (env `FASTDP_LOG` = error|warn|info|debug|trace).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        let l = std::env::var("FASTDP_LOG")
            .map(|s| Level::parse(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(l as u8, Ordering::Relaxed);
        return l;
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if l > level() {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {}] {args}", l.tag());
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::parse("DEBUG"), Level::Debug);
        assert_eq!(Level::parse("bogus"), Level::Info);
    }

    #[test]
    fn set_and_get() {
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Info);
    }
}
