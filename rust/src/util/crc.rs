//! CRC-32 (IEEE 802.3, polynomial 0xEDB88320) for checkpoint integrity.
//!
//! The offline build has no `crc32fast`, so the standard byte-table
//! implementation lives here. Checkpoint payloads are a few MB at most;
//! the table-driven loop does ~1 GB/s, far off any hot path.

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// Streaming CRC-32: start with [`Crc32::new`], feed bytes with
/// [`Crc32::update`], read the digest with [`Crc32::finish`].
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        for &b in bytes {
            self.state = t[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Canonical check value for the IEEE polynomial.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(37) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0xA5u8; 4096];
        let base = crc32(&data);
        data[1234] ^= 0x10;
        assert_ne!(crc32(&data), base);
    }
}
