//! Deterministic pseudo-random generation for the coordinator.
//!
//! The offline environment has no `rand` crate, so we implement
//! xoshiro256++ (Blackman & Vigna) seeded through splitmix64 — the
//! standard pairing — plus Gaussian sampling via the polar method.
//!
//! DP note: the *noise* stream used for the private gradient is owned by
//! the Rust coordinator (never by JAX), so the privacy-critical sampling
//! path is auditable in one place. xoshiro is not a CSPRNG; for a real
//! deployment swap `GaussianSource` for a DRBG — the trait boundary in
//! `coordinator::noise` exists precisely for that.

/// splitmix64: seeds the main generator and is a fine standalone PRNG.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. one per parameter tensor) by
    /// re-seeding through splitmix with a stream id mixed in.
    pub fn fork(&self, stream: u64) -> Self {
        let mut sm = SplitMix64::new(self.s[0] ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / ((1u64 << 53) as f64))
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / ((1u64 << 24) as f32))
    }

    /// Uniform integer in [0, n) (Lemire's rejection-free-ish method with
    /// a widening multiply; unbiased via rejection on the low word).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via the Marsaglia polar method (cached spare).
    pub fn next_gaussian(&mut self, spare: &mut Option<f64>) -> f64 {
        if let Some(v) = spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let mul = (-2.0 * s.ln() / s).sqrt();
                *spare = Some(v * mul);
                return u * mul;
            }
        }
    }
}

/// Buffered Gaussian stream for filling noise tensors.
#[derive(Clone, Debug)]
pub struct GaussianSource {
    rng: Xoshiro256,
    spare: Option<f64>,
}

impl GaussianSource {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            spare: None,
        }
    }

    pub fn from_rng(rng: Xoshiro256) -> Self {
        Self { rng, spare: None }
    }

    #[inline]
    pub fn sample(&mut self) -> f64 {
        self.rng.next_gaussian(&mut self.spare)
    }

    /// Fill a f32 buffer with i.i.d. N(0, 1).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): the bulk path runs the polar
    /// method in f32 (one u64 draw yields both uniforms; f32 ln/sqrt),
    /// which measured ~2.3x faster than the original f64 pair loop while
    /// remaining an exact polar-method Gaussian at f32 granularity — the
    /// output precision the artifacts consume anyway.
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        const SCALE: f32 = 1.0 / ((1u64 << 31) as f32);
        let mut i = 0;
        while i + 1 < out.len() {
            loop {
                // one u64 -> two signed 31-bit uniforms in (-1, 1)
                let bits = self.rng.next_u64();
                let u = (bits >> 33) as i64 as f32 * SCALE * 2.0 - 1.0;
                let v = ((bits << 31) >> 33) as i64 as f32 * SCALE * 2.0 - 1.0;
                let s = u * u + v * v;
                if s > 1e-12 && s < 1.0 {
                    let mul = (-2.0 * s.ln() / s).sqrt();
                    out[i] = u * mul;
                    out[i + 1] = v * mul;
                    break;
                }
            }
            i += 2;
        }
        if i < out.len() {
            out[i] = self.sample() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 1234567 from the canonical C impl.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_forks_differ() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut f1 = r1.fork(1);
        let mut f2 = r1.fork(2);
        let same = (0..100).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert!(same < 3, "forked streams should not collide");
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Xoshiro256::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn gaussian_moments() {
        let mut g = GaussianSource::new(3);
        let n = 50_000;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = g.sample();
            s1 += x;
            s2 += x * x;
            s4 += x * x * x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        let kurt = s4 / n as f64 / (var * var);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
        assert!((kurt - 3.0).abs() < 0.15, "kurtosis {kurt}");
    }

    #[test]
    fn fill_f32_matches_moments() {
        let mut g = GaussianSource::new(11);
        let mut buf = vec![0.0f32; 30_001]; // odd length hits the tail path
        g.fill_f32(&mut buf);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.03);
    }
}
