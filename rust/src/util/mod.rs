//! Small self-contained utilities. The environment is offline (no rand /
//! criterion / statistical crates), so the usual helpers are
//! reimplemented here with tests: PRNG + Gaussian sampling, special
//! functions for the accountant, timing/summary stats, table rendering,
//! and a tiny leveled logger.

pub mod crc;
pub mod log;
pub mod math;
pub mod rng;
pub mod stats;
pub mod table;
