//! Special functions needed by the privacy accountant (no libm-extras
//! offline): erf/erfc, standard normal CDF, log-sum-exp, log binomial.

/// Abramowitz & Stegun 7.1.26-style erf via the Numerical-Recipes erfc
/// approximation; |error| < 1.2e-7 — ample for accounting (we binary
/// search over it, so only monotonicity + ~1e-6 accuracy matter).
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal CDF Phi(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// log(Gamma(x)) via Lanczos (g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// log C(n, k) for real-valued RDP order interpolation.
pub fn ln_binom(n: f64, k: f64) -> f64 {
    ln_gamma(n + 1.0) - ln_gamma(k + 1.0) - ln_gamma(n - k + 1.0)
}

/// Numerically stable log(sum(exp(xs))).
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// Stable log(exp(a) + exp(b)).
pub fn log_add_exp(a: f64, b: f64) -> f64 {
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    if hi == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    hi + (lo - hi).exp().ln_1p()
}

/// Binary search for the root of a monotone-increasing `f` on [lo, hi].
pub fn bisect<F: Fn(f64) -> f64>(f: F, mut lo: f64, mut hi: f64, iters: usize) -> f64 {
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        if f(mid) > 0.0 {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_points() {
        // Reference values from tables.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x})");
        }
    }

    #[test]
    fn norm_cdf_symmetry_and_points() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 5e-7);
        assert!((norm_cdf(1.96) - 0.9750021).abs() < 1e-5);
        // symmetry holds to the accuracy of the erfc approximation (~1e-7)
        for x in [-3.0, -1.0, 0.3, 2.5] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 5e-7);
        }
    }

    #[test]
    fn ln_gamma_factorials() {
        for n in 1..15u64 {
            let fact: f64 = (1..n).map(|k| k as f64).product::<f64>().ln();
            assert!(
                (ln_gamma(n as f64) - fact).abs() < 1e-8,
                "ln_gamma({n}) = {} want {}",
                ln_gamma(n as f64),
                fact
            );
        }
    }

    #[test]
    fn ln_binom_pascal() {
        // C(10,3) = 120
        assert!((ln_binom(10.0, 3.0) - 120f64.ln()).abs() < 1e-8);
        // C(52,5) = 2598960
        assert!((ln_binom(52.0, 5.0) - 2598960f64.ln()).abs() < 1e-7);
    }

    #[test]
    fn lse_basics() {
        assert!((log_sum_exp(&[0.0, 0.0]) - 2f64.ln()).abs() < 1e-12);
        assert!((log_add_exp(1000.0, 1000.0) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(log_sum_exp(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn bisect_sqrt2() {
        let r = bisect(|x| x * x - 2.0, 0.0, 2.0, 80);
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-12);
    }
}
