//! Aligned text/markdown table printer for bench output — every paper
//! table is regenerated through this so EXPERIMENTS.md rows are uniform.

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowv(&mut self, cells: Vec<String>) -> &mut Self {
        self.row(&cells)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Terminal rendering with aligned columns.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored markdown rendering (for EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// CSV rendering (for figure series).
    pub fn csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["alpha".into(), "1".into()]);
        t.row(&["b".into(), "22222".into()]);
        t
    }

    #[test]
    fn render_aligns() {
        let r = sample().render();
        assert!(r.contains("== demo =="));
        let lines: Vec<&str> = r.lines().collect();
        // header and rows padded to same column start
        // lines: [0] title, [1] header, [2] rule, [3] row alpha, [4] row b
        assert_eq!(lines[1].find("value"), lines[4].find("22222"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().markdown();
        assert!(md.contains("| name | value |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| alpha | 1 |"));
    }

    #[test]
    fn csv_shape() {
        let c = sample().csv();
        assert_eq!(c.lines().count(), 3);
        assert_eq!(c.lines().next().unwrap(), "name,value");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        sample().row(&["only-one".into()]);
    }
}
