//! Language architectures: GPT2, BERT/RoBERTa (+distil), Longformer, T5.
//! T (sequence length) is a free parameter — the paper evaluates GPT2 at
//! T = 100 and T = 1000, RoBERTa at T = 256, Longformer at T = 4096.

use super::Arch;

/// GPT2 family (Conv1D layers in HF are linears; c_attn fuses qkv).
pub fn gpt2(name: &str, t: u64, dm: u64, depth: u64) -> Arch {
    let mut a = Arch::new(name);
    let vocab = 50257;
    a.embedding("wte", t, vocab, dm);
    a.embedding("wpe", t, 1024, dm);
    for i in 0..depth {
        a.norm(&format!("h{i}.ln1"), t, dm);
        a.linear(&format!("h{i}.attn.c_attn"), t, dm, 3 * dm, true);
        a.linear(&format!("h{i}.attn.c_proj"), t, dm, dm, true);
        a.norm(&format!("h{i}.ln2"), t, dm);
        a.linear(&format!("h{i}.mlp.c_fc"), t, dm, 4 * dm, true);
        a.linear(&format!("h{i}.mlp.c_proj"), t, 4 * dm, dm, true);
    }
    a.norm("ln_f", t, dm);
    // lm_head is tied to wte: a TiedLinear layer carries the head's
    // full forward/backward/ghost-norm compute but zero new parameters
    // (the native registry's tied gpt models follow the same accounting;
    // see runtime::native::model::NativeSpec::arch).
    a.tied_linear("lm_head", t, dm, vocab);
    a
}

/// BERT/RoBERTa encoder (separate q,k,v,o projections).
pub fn bert_like(name: &str, t: u64, dm: u64, depth: u64, vocab: u64, max_pos: u64) -> Arch {
    let mut a = Arch::new(name);
    a.embedding("word_emb", t, vocab, dm);
    a.embedding("pos_emb", t, max_pos, dm);
    a.embedding("type_emb", t, 2, dm);
    a.norm("emb_ln", t, dm);
    for i in 0..depth {
        for nm in ["q", "k", "v", "o"] {
            a.linear(&format!("l{i}.attn.{nm}"), t, dm, dm, true);
        }
        a.norm(&format!("l{i}.attn_ln"), t, dm);
        a.linear(&format!("l{i}.fc1"), t, dm, 4 * dm, true);
        a.linear(&format!("l{i}.fc2"), t, 4 * dm, dm, true);
        a.norm(&format!("l{i}.out_ln"), t, dm);
    }
    a.linear("pooler", 1, dm, dm, true);
    a
}

pub fn roberta(name: &str, t: u64, dm: u64, depth: u64) -> Arch {
    bert_like(name, t, dm, depth, 50265, 514)
}

pub fn bert(name: &str, t: u64, dm: u64, depth: u64, vocab: u64) -> Arch {
    bert_like(name, t, dm, depth, vocab, 512)
}

/// Longformer: RoBERTa weights + extra global-attention q,k,v per layer.
pub fn longformer(name: &str, t: u64, dm: u64, depth: u64) -> Arch {
    let mut a = bert_like(name, t, dm, depth, 50265, 4098);
    for i in 0..depth {
        for nm in ["q_global", "k_global", "v_global"] {
            a.linear(&format!("l{i}.attn.{nm}"), t, dm, dm, true);
        }
    }
    a
}

/// T5 encoder-decoder; no biases anywhere (paper Table 7: bias = 0),
/// RMSNorm has a single scale vector per layer.
pub fn t5(name: &str, t: u64, dm: u64, ff: u64, enc: u64, dec: u64) -> Arch {
    let mut a = Arch::new(name);
    let vocab = 32128;
    a.embedding("shared_emb", t, vocab, dm);
    for i in 0..enc {
        for nm in ["q", "k", "v", "o"] {
            a.linear(&format!("enc{i}.attn.{nm}"), t, dm, dm, false);
        }
        a.linear(&format!("enc{i}.wi"), t, dm, ff, false);
        a.linear(&format!("enc{i}.wo"), t, ff, dm, false);
        // two RMSNorms: scale only (p params each) — count as other
        a.other_params += 2 * dm;
    }
    for i in 0..dec {
        for nm in ["q", "k", "v", "o", "xq", "xk", "xv", "xo"] {
            a.linear(&format!("dec{i}.attn.{nm}"), t, dm, dm, false);
        }
        a.linear(&format!("dec{i}.wi"), t, dm, ff, false);
        a.linear(&format!("dec{i}.wo"), t, ff, dm, false);
        a.other_params += 3 * dm;
    }
    a.other_params += 2 * dm; // final norms
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_small_params() {
        let a = gpt2("gpt2", 100, 768, 12);
        // HF gpt2: 124.4M total
        let total = a.total_params();
        assert!(
            (total as f64 - 124.4e6).abs() / 124.4e6 < 0.01,
            "gpt2 params {total}"
        );
        // paper Table 7: GL weights 124.3M (includes embeddings), other 38400
        assert!((a.gl_weight_params() as f64 - 124.3e6).abs() / 124.3e6 < 0.01);
        assert_eq!(a.other_params, 2 * 768 * 25);
    }

    #[test]
    fn gpt2_large_params() {
        let a = gpt2("gpt2-large", 100, 1280, 36);
        let total = a.total_params();
        assert!(
            (total as f64 - 774.0e6).abs() / 774.0e6 < 0.01,
            "gpt2-large params {total}"
        );
    }

    #[test]
    fn roberta_base_params() {
        let a = roberta("roberta-base", 256, 768, 12);
        let total = a.total_params();
        // HF roberta-base: ~124.6M (sans LM head)
        assert!(
            (total as f64 - 124.6e6).abs() / 124.6e6 < 0.02,
            "roberta-base params {total}"
        );
        assert!(a.bk_applicable_fraction() > 0.998);
    }

    #[test]
    fn roberta_large_params() {
        let a = roberta("roberta-large", 256, 1024, 24);
        let total = a.total_params();
        assert!(
            (total as f64 - 355.0e6).abs() / 355.0e6 < 0.02,
            "roberta-large params {total}"
        );
    }

    #[test]
    fn bert_base_params() {
        let a = bert("bert-base-uncased", 256, 768, 12, 30522);
        let total = a.total_params();
        assert!(
            (total as f64 - 109.5e6).abs() / 109.5e6 < 0.02,
            "bert-base params {total}"
        );
    }

    #[test]
    fn t5_base_params() {
        let a = t5("t5-base", 256, 768, 3072, 12, 12);
        let total = a.total_params();
        // paper Table 7: 222.9M GL weights, zero bias
        assert!(
            (total as f64 - 222.9e6).abs() / 222.9e6 < 0.02,
            "t5-base params {total}"
        );
        assert_eq!(a.gl_bias, 0);
    }

    #[test]
    fn gpt2_lm_head_is_tied_and_param_free() {
        // The tied head is an explicit layer (its ghost-norm and
        // backward costs are real) but contributes zero parameters —
        // the same accounting the native tied gpt models use.
        let a = gpt2("gpt2", 100, 768, 12);
        let head = a.layers.last().unwrap();
        assert_eq!(head.kind, super::super::LayerKind::TiedLinear);
        assert_eq!((head.d, head.p), (768, 50257));
        assert_eq!(head.weight_params(), 0);
        // and it participates in the complexity tables as a GL layer
        assert!(a.gl_layers().any(|l| l.name == "lm_head"));
    }

    #[test]
    fn sequence_length_is_free() {
        let short = gpt2("g", 100, 768, 12);
        let long = gpt2("g", 1000, 768, 12);
        assert_eq!(short.total_params(), long.total_params());
        assert_eq!(long.layers[2].t, 1000);
    }
}
