//! Architecture catalog: per-layer (T, d, p) dims for the model zoo the
//! paper analyzes (Tables 4, 7, 8, 10; Figures 7, 10-19).
//!
//! Dimension conventions (paper Appendix B):
//!  * linear     — d = in features, p = out features, T = tokens (1 if none)
//!  * conv       — d = C_in * k_h * k_w, p = C_out, T = H_out * W_out
//!  * embedding  — d = vocab, p = dim, T = sequence length
//!  * norm       — p = normalized dim (gamma + beta = 2p params)
//!
//! These are *shape calculators*, not weights: they let the complexity
//! engine evaluate full-size GPT2 / ResNet / ViT on ImageNet dims even
//! though the CPU testbed executes only the scaled-down artifacts.

pub mod catalog;
pub mod language;
pub mod vision;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Linear,
    Conv,
    Embedding,
    Norm,
    /// Causal multi-head self-attention. Dims convention: `d` = model
    /// width, `p` = head count, `t` = sequence length. The complexity
    /// engine decomposes it into its two generalized-linear sublayers
    /// (fused QKV `d -> 3d`, output projection `d -> d`) plus the
    /// parameter-free softmax core — see
    /// [`crate::complexity::attention_sublayers`].
    Attention,
    /// A `(d, p)` linear whose weight is a *view* of another layer's
    /// tensor (the GPT-2 `lm_head = wte^T` tie): compute and activation
    /// costs are exactly a bias-free Linear's, but its weights are
    /// counted at the owning layer (`weight_params() == 0`) and its
    /// per-sample norm needs the tied ghost cross term on top of its own
    /// Grams (see `complexity::module_time`).
    TiedLinear,
    /// Learned positional-embedding table added row-wise to the
    /// sequence (GPT-2 `wpe`). Dims convention: `t` = sequence length
    /// (= table rows), `d = p` = embedding dim. Unlike a token
    /// embedding, its rows never collide across positions, so the
    /// per-sample norm is the plain gradient Frobenius norm (no
    /// token-equality Gram) and backward to the layer below is the
    /// identity.
    PosEmbedding,
    /// LoRA-adapted linear: a frozen `(d, p)` base (weight + bias) with
    /// trainable rank-`rank` adapters `A (d, r)` and `B (r, p)` —
    /// `out = x·W + b + (x·A)·B`. The census counts base + adapters;
    /// only the adapters ever take gradients, so norm/sum costs come
    /// from the two skinny sublayers (see `complexity::lora_sublayers`).
    Lora { rank: u64 },
}

#[derive(Clone, Debug)]
pub struct LayerDims {
    pub kind: LayerKind,
    pub name: String,
    pub t: u64,
    pub d: u64,
    pub p: u64,
}

impl LayerDims {
    pub fn weight_params(&self) -> u64 {
        match self.kind {
            LayerKind::Norm => 0,
            // QKV (d, 3d) + output projection (d, d); p is the head count
            LayerKind::Attention => 4 * self.d * self.d,
            // the weight is an alias of another layer's tensor
            LayerKind::TiedLinear => 0,
            // the (t, p) position table
            LayerKind::PosEmbedding => self.t * self.p,
            // frozen (d, p) base plus the rank-r adapter pair
            LayerKind::Lora { rank } => self.d * self.p + rank * (self.d + self.p),
            _ => self.d * self.p,
        }
    }
}

/// A named architecture: ordered layers plus bias/norm bookkeeping for
/// the Table 7 parameter census.
#[derive(Clone, Debug)]
pub struct Arch {
    pub name: String,
    pub layers: Vec<LayerDims>,
    /// Bias parameter count over generalized linear layers.
    pub gl_bias: u64,
    /// Weight+bias parameters in non-GL layers (norms).
    pub other_params: u64,
}

impl Arch {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            layers: Vec::new(),
            gl_bias: 0,
            other_params: 0,
        }
    }

    pub fn linear(&mut self, name: &str, t: u64, d: u64, p: u64, bias: bool) -> &mut Self {
        self.layers.push(LayerDims {
            kind: LayerKind::Linear,
            name: name.into(),
            t,
            d,
            p,
        });
        if bias {
            self.gl_bias += p;
        }
        self
    }

    /// Conv with explicit output spatial size.
    pub fn conv_dims(
        &mut self,
        name: &str,
        t_out: u64,
        cin: u64,
        cout: u64,
        k: u64,
        bias: bool,
    ) -> &mut Self {
        self.layers.push(LayerDims {
            kind: LayerKind::Conv,
            name: name.into(),
            t: t_out,
            d: cin * k * k,
            p: cout,
        });
        if bias {
            self.gl_bias += cout;
        }
        self
    }

    pub fn embedding(&mut self, name: &str, t: u64, vocab: u64, dim: u64) -> &mut Self {
        self.layers.push(LayerDims {
            kind: LayerKind::Embedding,
            name: name.into(),
            t,
            d: vocab,
            p: dim,
        });
        self
    }

    /// Causal self-attention over model width `d` with `heads` heads
    /// (fused QKV + output projection, 4 d^2 weights + 4 d biases).
    pub fn attention(&mut self, name: &str, t: u64, d: u64, heads: u64) -> &mut Self {
        self.layers.push(LayerDims {
            kind: LayerKind::Attention,
            name: name.into(),
            t,
            d,
            p: heads,
        });
        self.gl_bias += 4 * d;
        self
    }

    /// A `(d, p)` head tied to an earlier layer's `(p, d)` tensor
    /// (GPT-2 `lm_head = wte^T`): full generalized-linear compute, zero
    /// *new* parameters and no bias — the weights stay counted at the
    /// owning embedding.
    pub fn tied_linear(&mut self, name: &str, t: u64, d: u64, p: u64) -> &mut Self {
        self.layers.push(LayerDims {
            kind: LayerKind::TiedLinear,
            name: name.into(),
            t,
            d,
            p,
        });
        self
    }

    /// Learned positional-embedding table over `t` positions of width
    /// `dim` (GPT-2 `wpe`): `t * dim` weights, no bias.
    pub fn pos_embedding(&mut self, name: &str, t: u64, dim: u64) -> &mut Self {
        self.layers.push(LayerDims {
            kind: LayerKind::PosEmbedding,
            name: name.into(),
            t,
            d: dim,
            p: dim,
        });
        self
    }

    /// LoRA-adapted `(d, p)` linear: frozen base (weights + optional
    /// bias) plus trainable rank-`rank` adapters.
    pub fn lora_linear(
        &mut self,
        name: &str,
        t: u64,
        d: u64,
        p: u64,
        rank: u64,
        bias: bool,
    ) -> &mut Self {
        self.layers.push(LayerDims {
            kind: LayerKind::Lora { rank },
            name: name.into(),
            t,
            d,
            p,
        });
        if bias {
            self.gl_bias += p;
        }
        self
    }

    pub fn norm(&mut self, name: &str, t: u64, dim: u64) -> &mut Self {
        self.layers.push(LayerDims {
            kind: LayerKind::Norm,
            name: name.into(),
            t,
            d: dim,
            p: dim,
        });
        self.other_params += 2 * dim;
        self
    }

    /// Weight parameters in generalized linear layers (Table 7 col 1).
    pub fn gl_weight_params(&self) -> u64 {
        self.layers.iter().map(LayerDims::weight_params).sum()
    }

    pub fn total_params(&self) -> u64 {
        self.gl_weight_params() + self.gl_bias + self.other_params
    }

    /// Fraction of trainable parameters BK applies to (Table 7 last col).
    pub fn bk_applicable_fraction(&self) -> f64 {
        self.gl_weight_params() as f64 / self.total_params() as f64
    }

    /// Only the generalized linear layers (complexity tables skip norms).
    pub fn gl_layers(&self) -> impl Iterator<Item = &LayerDims> {
        self.layers.iter().filter(|l| l.kind != LayerKind::Norm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_builder_counts() {
        let mut a = Arch::new("toy");
        a.linear("fc1", 1, 10, 20, true)
            .norm("ln", 1, 20)
            .conv_dims("c1", 64, 3, 8, 3, true)
            .embedding("emb", 16, 100, 32);
        assert_eq!(a.gl_weight_params(), 10 * 20 + 27 * 8 + 100 * 32);
        assert_eq!(a.gl_bias, 20 + 8);
        assert_eq!(a.other_params, 40);
        assert_eq!(a.gl_layers().count(), 3);
        assert!(a.bk_applicable_fraction() > 0.95);
    }

    #[test]
    fn attention_builder_counts() {
        let mut a = Arch::new("tfm");
        a.attention("attn", 16, 32, 4);
        // fused QKV (32, 96) + out proj (32, 32) weights, 96 + 32 biases
        assert_eq!(a.gl_weight_params(), 4 * 32 * 32);
        assert_eq!(a.gl_bias, 4 * 32);
        assert_eq!(a.gl_layers().count(), 1);
    }

    #[test]
    fn tied_linear_adds_no_params_but_is_a_gl_layer() {
        let mut a = Arch::new("tied");
        a.embedding("wte", 16, 100, 32).tied_linear("lm_head", 16, 32, 100);
        // the head's weights are the embedding's — counted once
        assert_eq!(a.gl_weight_params(), 100 * 32);
        assert_eq!(a.gl_bias, 0);
        // but it is a real generalized-linear layer for compute costs
        assert_eq!(a.gl_layers().count(), 2);
        assert_eq!(a.layers[1].weight_params(), 0);
    }
}
