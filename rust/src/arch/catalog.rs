//! Named catalog: every model the paper's tables/figures mention,
//! constructible at any input resolution / sequence length.

use super::{language, vision, Arch};

/// Vision models at a given square image size.
pub fn vision_model(name: &str, img: u64) -> Option<Arch> {
    let a = match name {
        "resnet18" => vision::resnet(name, img, [2, 2, 2, 2], false, false),
        "resnet34" => vision::resnet(name, img, [3, 4, 6, 3], false, false),
        "resnet50" => vision::resnet(name, img, [3, 4, 6, 3], true, false),
        "resnet101" => vision::resnet(name, img, [3, 4, 23, 3], true, false),
        "resnet152" => vision::resnet(name, img, [3, 8, 36, 3], true, false),
        "wide_resnet50" => vision::resnet(name, img, [3, 4, 6, 3], true, true),
        "wide_resnet101" => vision::resnet(name, img, [3, 4, 23, 3], true, true),
        "vgg11" => vision::vgg(name, img, &vision::VGG11),
        "vgg13" => vision::vgg(name, img, &vision::VGG13),
        "vgg16" => vision::vgg(name, img, &vision::VGG16),
        "vgg19" => vision::vgg(name, img, &vision::VGG19),
        "densenet121" => vision::densenet(name, img, [6, 12, 24, 16], 32, 64),
        "densenet161" => vision::densenet(name, img, [6, 12, 36, 24], 48, 96),
        "densenet201" => vision::densenet(name, img, [6, 12, 48, 32], 32, 64),
        "vit_tiny" => vision::vit(name, img, 16, 192, 12, true),
        "vit_small" => vision::vit(name, img, 16, 384, 12, true),
        "vit_base" => vision::vit(name, img, 16, 768, 12, true),
        "vit_large" => vision::vit(name, img, 16, 1024, 24, true),
        "deit_tiny" => vision::vit(name, img, 16, 192, 12, true),
        "deit_small" => vision::vit(name, img, 16, 384, 12, true),
        "deit_base" => vision::vit(name, img, 16, 768, 12, true),
        "beit_base" => vision::vit(name, img, 16, 768, 12, true),
        "beit_large" => vision::vit(name, img, 16, 1024, 24, true),
        "crossvit_tiny" => vision::crossvit(name, 240, 96, 192, 9),
        "crossvit_small" => vision::crossvit(name, 240, 192, 384, 9),
        "crossvit_base" => vision::crossvit(name, 240, 384, 768, 9),
        "convnext_small" => vision::convnext(name, img, [96, 192, 384, 768], [3, 3, 27, 3]),
        "convnext_base" => vision::convnext(name, img, [128, 256, 512, 1024], [3, 3, 27, 3]),
        "convnext_large" => vision::convnext(name, img, [192, 384, 768, 1536], [3, 3, 27, 3]),
        _ => return None,
    };
    Some(a)
}

/// Language models at a given sequence length.
pub fn language_model(name: &str, t: u64) -> Option<Arch> {
    let a = match name {
        "gpt2" => language::gpt2(name, t, 768, 12),
        "gpt2-medium" => language::gpt2(name, t, 1024, 24),
        "gpt2-large" => language::gpt2(name, t, 1280, 36),
        "roberta-base" => language::roberta(name, t, 768, 12),
        "roberta-large" => language::roberta(name, t, 1024, 24),
        "distilroberta-base" => language::roberta(name, t, 768, 6),
        "bert-base" => language::bert(name, t, 768, 12, 30522),
        "bert-large" => language::bert(name, t, 1024, 24, 30522),
        "longformer-base" => language::longformer(name, t, 768, 12),
        "longformer-large" => language::longformer(name, t, 1024, 24),
        "t5-small" => language::t5(name, t, 512, 2048, 6, 6),
        "t5-base" => language::t5(name, t, 768, 3072, 12, 12),
        "t5-large" => language::t5(name, t, 1024, 4096, 24, 24),
        _ => return None,
    };
    Some(a)
}

/// Any model with the paper's default dims (224^2 images, T = 256 text).
pub fn by_name(name: &str) -> Option<Arch> {
    vision_model(name, 224).or_else(|| language_model(name, 256))
}

/// The Table 7 / Table 10 model zoo, in paper order.
pub const VISION_ZOO: [&str; 25] = [
    "resnet18",
    "resnet34",
    "resnet50",
    "resnet101",
    "resnet152",
    "densenet121",
    "densenet161",
    "densenet201",
    "wide_resnet50",
    "wide_resnet101",
    "vit_tiny",
    "vit_small",
    "vit_base",
    "vit_large",
    "crossvit_tiny",
    "crossvit_small",
    "crossvit_base",
    "convnext_small",
    "convnext_base",
    "convnext_large",
    "deit_tiny",
    "deit_small",
    "deit_base",
    "beit_base",
    "beit_large",
];

pub const LANGUAGE_ZOO: [&str; 13] = [
    "roberta-base",
    "roberta-large",
    "distilroberta-base",
    "bert-base",
    "bert-large",
    "longformer-base",
    "longformer-large",
    "t5-small",
    "t5-base",
    "t5-large",
    "gpt2",
    "gpt2-medium",
    "gpt2-large",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_zoo_constructs() {
        for name in VISION_ZOO {
            let a = vision_model(name, 224).unwrap_or_else(|| panic!("{name}"));
            assert!(a.total_params() > 1_000_000, "{name} too small");
            assert!(!a.layers.is_empty());
        }
        for name in LANGUAGE_ZOO {
            let a = language_model(name, 256).unwrap_or_else(|| panic!("{name}"));
            assert!(a.total_params() > 10_000_000, "{name} too small");
        }
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn table7_fractions_above_98_percent() {
        // Paper Table 7: every zoo model has >= 98.9% of trainable params
        // in generalized linear weights.
        for name in VISION_ZOO.iter().chain(LANGUAGE_ZOO.iter()) {
            let a = by_name(name).unwrap();
            let f = a.bk_applicable_fraction();
            assert!(f > 0.975, "{name}: BK fraction {f:.4}");
        }
    }

    #[test]
    fn resolution_scales_t_not_params() {
        let lo = vision_model("resnet18", 224).unwrap();
        let hi = vision_model("resnet18", 512).unwrap();
        assert_eq!(lo.total_params(), hi.total_params());
        assert!(hi.layers[0].t > lo.layers[0].t);
    }
}
