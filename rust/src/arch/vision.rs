//! Vision architectures: ResNet / WideResNet, VGG, DenseNet, ViT / DeiT /
//! BEiT, CrossViT, ConvNeXt — at arbitrary input resolution (the paper
//! sweeps 32^2 / 224^2 / 512^2 in Figures 7 and 10-19).

use super::Arch;

/// Spatial tracker: square feature maps through convs/pools.
#[derive(Clone, Copy)]
struct Hw(u64);

impl Hw {
    fn conv(&mut self, k: u64, stride: u64, pad: u64) -> u64 {
        self.0 = (self.0 + 2 * pad - k) / stride + 1;
        self.0
    }

    fn t(&self) -> u64 {
        self.0 * self.0
    }
}

// ---------------------------------------------------------------------------
// ResNet family

fn basic_block(a: &mut Arch, hw: &mut Hw, idx: &mut u32, cin: u64, cout: u64, stride: u64) {
    let t_in = hw.t();
    hw.conv(3, stride, 1);
    a.conv_dims(&format!("layer{idx}.conv1"), hw.t(), cin, cout, 3, false);
    a.norm(&format!("layer{idx}.bn1"), hw.t(), cout);
    a.conv_dims(&format!("layer{idx}.conv2"), hw.t(), cout, cout, 3, false);
    a.norm(&format!("layer{idx}.bn2"), hw.t(), cout);
    if stride != 1 || cin != cout {
        // 1x1 downsample on the residual path
        let _ = t_in;
        a.conv_dims(&format!("layer{idx}.down"), hw.t(), cin, cout, 1, false);
        a.norm(&format!("layer{idx}.bn_down"), hw.t(), cout);
    }
    *idx += 1;
}

fn bottleneck(
    a: &mut Arch,
    hw: &mut Hw,
    idx: &mut u32,
    cin: u64,
    width: u64,
    cout: u64,
    stride: u64,
) {
    a.conv_dims(&format!("layer{idx}.conv1"), hw.t(), cin, width, 1, false);
    a.norm(&format!("layer{idx}.bn1"), hw.t(), width);
    hw.conv(3, stride, 1);
    a.conv_dims(&format!("layer{idx}.conv2"), hw.t(), width, width, 3, false);
    a.norm(&format!("layer{idx}.bn2"), hw.t(), width);
    a.conv_dims(&format!("layer{idx}.conv3"), hw.t(), width, cout, 1, false);
    a.norm(&format!("layer{idx}.bn3"), hw.t(), cout);
    if stride != 1 || cin != cout {
        a.conv_dims(&format!("layer{idx}.down"), hw.t(), cin, cout, 1, false);
        a.norm(&format!("layer{idx}.bn_down"), hw.t(), cout);
    }
    *idx += 1;
}

/// blocks: per-stage block counts; `wide` doubles the bottleneck width.
pub fn resnet(name: &str, img: u64, blocks: [u64; 4], bottle: bool, wide: bool) -> Arch {
    let mut a = Arch::new(name);
    let mut hw = Hw(img);
    hw.conv(7, 2, 3);
    a.conv_dims("conv1", hw.t(), 3, 64, 7, false);
    a.norm("bn1", hw.t(), 64);
    hw.conv(3, 2, 1); // maxpool

    let expansion = if bottle { 4 } else { 1 };
    let mut cin = 64u64;
    let mut idx = 0u32;
    for (stage, &n) in blocks.iter().enumerate() {
        let base = 64 << stage;
        // torchvision "wide" doubles the bottleneck's inner 3x3 width
        // (width_per_group = 128); the block output stays base * 4.
        let width = if wide { base * 2 } else { base };
        for b in 0..n {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            if bottle {
                bottleneck(&mut a, &mut hw, &mut idx, cin, width, base * 4, stride);
                cin = base * 4;
            } else {
                basic_block(&mut a, &mut hw, &mut idx, cin, base, stride);
                cin = base;
            }
        }
    }
    a.linear("fc", 1, 512 * expansion, 1000, true);
    a
}

// ---------------------------------------------------------------------------
// VGG

pub fn vgg(name: &str, img: u64, cfg: &[i64]) -> Arch {
    // cfg entries: channel count, or -1 for maxpool.
    let mut a = Arch::new(name);
    let mut hw = Hw(img);
    let mut cin = 3u64;
    let mut i = 0;
    for &c in cfg {
        if c < 0 {
            hw.conv(2, 2, 0);
        } else {
            hw.conv(3, 1, 1);
            a.conv_dims(&format!("conv{i}"), hw.t(), cin, c as u64, 3, true);
            cin = c as u64;
            i += 1;
        }
    }
    // classifier expects 7x7 after adaptive pool at 224; scale with input
    let pool = 7u64;
    a.linear("fc1", 1, cin * pool * pool, 4096, true);
    a.linear("fc2", 1, 4096, 4096, true);
    a.linear("fc3", 1, 4096, 1000, true);
    a
}

pub const VGG11: [i64; 13] = [64, -1, 128, -1, 256, 256, -1, 512, 512, -1, 512, 512, -1];
pub const VGG13: [i64; 15] = [64, 64, -1, 128, 128, -1, 256, 256, -1, 512, 512, -1, 512, 512, -1];
pub const VGG16: [i64; 18] = [
    64, 64, -1, 128, 128, -1, 256, 256, 256, -1, 512, 512, 512, -1, 512, 512, 512, -1,
];
pub const VGG19: [i64; 21] = [
    64, 64, -1, 128, 128, -1, 256, 256, 256, 256, -1, 512, 512, 512, 512, -1, 512, 512, 512, 512,
    -1,
];

// ---------------------------------------------------------------------------
// DenseNet

pub fn densenet(name: &str, img: u64, blocks: [u64; 4], growth: u64, init: u64) -> Arch {
    let mut a = Arch::new(name);
    let mut hw = Hw(img);
    hw.conv(7, 2, 3);
    a.conv_dims("conv0", hw.t(), 3, init, 7, false);
    a.norm("bn0", hw.t(), init);
    hw.conv(3, 2, 1);
    let mut c = init;
    for (bi, &n) in blocks.iter().enumerate() {
        for li in 0..n {
            // dense layer: bn + 1x1 -> 4k, bn + 3x3 -> k
            a.norm(&format!("b{bi}l{li}.bn1"), hw.t(), c);
            a.conv_dims(&format!("b{bi}l{li}.conv1"), hw.t(), c, 4 * growth, 1, false);
            a.norm(&format!("b{bi}l{li}.bn2"), hw.t(), 4 * growth);
            a.conv_dims(&format!("b{bi}l{li}.conv2"), hw.t(), 4 * growth, growth, 3, false);
            c += growth;
        }
        if bi < 3 {
            // transition: 1x1 halving + avgpool/2
            a.norm(&format!("t{bi}.bn"), hw.t(), c);
            a.conv_dims(&format!("t{bi}.conv"), hw.t(), c, c / 2, 1, false);
            c /= 2;
            hw.conv(2, 2, 0);
        }
    }
    a.norm("bn_final", hw.t(), c);
    a.linear("classifier", 1, c, 1000, true);
    a
}

// ---------------------------------------------------------------------------
// ViT / DeiT / BEiT (isotropic transformers on patches)

pub fn vit(name: &str, img: u64, patch: u64, dm: u64, depth: u64, cls_token: bool) -> Arch {
    let mut a = Arch::new(name);
    let grid = img / patch;
    let t_patch = grid * grid;
    let t = t_patch + if cls_token { 1 } else { 0 };
    // patch embedding as conv: d = 3*patch^2
    a.conv_dims("patch_embed", t_patch, 3, dm, patch, true);
    for i in 0..depth {
        a.norm(&format!("blk{i}.ln1"), t, dm);
        a.linear(&format!("blk{i}.qkv"), t, dm, 3 * dm, true);
        a.linear(&format!("blk{i}.proj"), t, dm, dm, true);
        a.norm(&format!("blk{i}.ln2"), t, dm);
        a.linear(&format!("blk{i}.fc1"), t, dm, 4 * dm, true);
        a.linear(&format!("blk{i}.fc2"), t, 4 * dm, dm, true);
    }
    a.norm("ln_f", t, dm);
    a.linear("head", 1, dm, 1000, true);
    a
}

/// CrossViT: two patch branches (12 & 16 on 240px) with cross-attention.
/// Multi-scale dims follow the timm configs; cross-attention projection
/// layers between branches are included at their token counts.
pub fn crossvit(name: &str, img: u64, dm_s: u64, dm_l: u64, depth: u64) -> Arch {
    let mut a = Arch::new(name);
    let t_s = (img / 12) * (img / 12) + 1;
    let t_l = (img / 16) * (img / 16) + 1;
    a.conv_dims("patch_s", t_s - 1, 3, dm_s, 12, true);
    a.conv_dims("patch_l", t_l - 1, 3, dm_l, 16, true);
    for i in 0..depth {
        for (tag, t, dm) in [("s", t_s, dm_s), ("l", t_l, dm_l)] {
            a.norm(&format!("blk{i}{tag}.ln1"), t, dm);
            a.linear(&format!("blk{i}{tag}.qkv"), t, dm, 3 * dm, true);
            a.linear(&format!("blk{i}{tag}.proj"), t, dm, dm, true);
            a.norm(&format!("blk{i}{tag}.ln2"), t, dm);
            a.linear(&format!("blk{i}{tag}.fc1"), t, dm, 3 * dm, true);
            a.linear(&format!("blk{i}{tag}.fc2"), t, 3 * dm, dm, true);
        }
        // cross-branch fusion projections
        a.linear(&format!("fuse{i}.s2l"), 1, dm_s, dm_l, true);
        a.linear(&format!("fuse{i}.l2s"), 1, dm_l, dm_s, true);
    }
    a.linear("head_s", 1, dm_s, 1000, true);
    a.linear("head_l", 1, dm_l, 1000, true);
    a
}

// ---------------------------------------------------------------------------
// ConvNeXt

pub fn convnext(name: &str, img: u64, dims: [u64; 4], depths: [u64; 4]) -> Arch {
    let mut a = Arch::new(name);
    let mut hw = Hw(img);
    hw.conv(4, 4, 0);
    a.conv_dims("stem", hw.t(), 3, dims[0], 4, true);
    a.norm("stem_ln", hw.t(), dims[0]);
    for s in 0..4 {
        if s > 0 {
            a.norm(&format!("down{s}.ln"), hw.t(), dims[s - 1]);
            hw.conv(2, 2, 0);
            a.conv_dims(&format!("down{s}.conv"), hw.t(), dims[s - 1], dims[s], 2, true);
        }
        let c = dims[s];
        for b in 0..depths[s] {
            // depthwise 7x7: model as d = k^2 per output channel
            a.conv_dims(&format!("st{s}b{b}.dw"), hw.t(), 1, c * 49 / 49, 7, true);
            // (d = 49, p = c) — depthwise weight is (49, c)
            let last = a.layers.last_mut().unwrap();
            last.d = 49;
            last.p = c;
            a.norm(&format!("st{s}b{b}.ln"), hw.t(), c);
            a.linear(&format!("st{s}b{b}.pw1"), hw.t(), c, 4 * c, true);
            a.linear(&format!("st{s}b{b}.pw2"), hw.t(), 4 * c, c, true);
        }
    }
    a.norm("ln_f", 1, dims[3]);
    a.linear("head", 1, dims[3], 1000, true);
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_structure_matches_paper_table4() {
        let a = resnet("resnet18", 224, [2, 2, 2, 2], false, false);
        // conv1: T = 112^2, pd = 147*64 = 9408 (paper 9.4e3)
        let c1 = &a.layers[0];
        assert_eq!(c1.t, 112 * 112);
        assert_eq!(c1.d * c1.p, 9408);
        // conv2_x: four 3x3 convs at 56^2 with pd = 36864 (paper 3.7e4 x4)
        let c2: Vec<_> = a
            .layers
            .iter()
            .filter(|l| l.t == 56 * 56 && l.kind == super::super::LayerKind::Conv)
            .collect();
        assert_eq!(c2.len(), 4);
        assert!(c2.iter().all(|l| l.d * l.p == 36864));
        // total params ~11.7M (torchvision: 11.69M)
        let total = a.total_params();
        assert!(
            (total as f64 - 11.7e6).abs() / 11.7e6 < 0.02,
            "resnet18 params {total}"
        );
        // BK applicability ~99.9% (paper Table 7)
        assert!(a.bk_applicable_fraction() > 0.985);
    }

    #[test]
    fn resnet50_param_count() {
        let a = resnet("resnet50", 224, [3, 4, 6, 3], true, false);
        let total = a.total_params();
        assert!(
            (total as f64 - 25.5e6).abs() / 25.5e6 < 0.02,
            "resnet50 params {total}"
        );
    }

    #[test]
    fn wide_resnet50_param_count() {
        let a = resnet("wide_resnet50", 224, [3, 4, 6, 3], true, true);
        let total = a.total_params();
        assert!(
            (total as f64 - 68.9e6).abs() / 68.9e6 < 0.02,
            "wide_resnet50 params {total}"
        );
    }

    #[test]
    fn vgg11_param_count() {
        let a = vgg("vgg11", 224, &VGG11);
        let total = a.total_params();
        // torchvision vgg11: 132.86M
        assert!(
            (total as f64 - 132.9e6).abs() / 132.9e6 < 0.01,
            "vgg11 params {total}"
        );
        // first conv: T = 224^2, pd = 27*64 = 1728 (paper §3.1: 1.7e3)
        let c0 = &a.layers[0];
        assert_eq!(c0.t, 224 * 224);
        assert_eq!(c0.d * c0.p, 1728);
    }

    #[test]
    fn vit_base_matches_paper() {
        let a = vit("vit_base", 224, 16, 768, 12, true);
        // paper Table 7: 86.3M GL weights
        let glw = a.gl_weight_params();
        assert!(
            (glw as f64 - 86.3e6).abs() / 86.3e6 < 0.02,
            "vit_base GL weights {glw}"
        );
        // paper Table 10: ghost norm total 2 sum T^2 = 3.8M
        let ghost: f64 = a
            .gl_layers()
            .map(|l| 2.0 * (l.t as f64) * (l.t as f64))
            .sum();
        assert!(
            (ghost - 3.8e6).abs() / 3.8e6 < 0.05,
            "vit_base ghost space {ghost}"
        );
    }

    #[test]
    fn vit_large_matches_paper() {
        let a = vit("vit_large", 224, 16, 1024, 24, true);
        let glw = a.gl_weight_params();
        assert!(
            (glw as f64 - 303.8e6).abs() / 303.8e6 < 0.02,
            "vit_large GL weights {glw}"
        );
    }

    #[test]
    fn densenet121_param_count() {
        let a = densenet("densenet121", 224, [6, 12, 24, 16], 32, 64);
        let total = a.total_params();
        // torchvision densenet121: 7.98M
        assert!(
            (total as f64 - 7.98e6).abs() / 7.98e6 < 0.03,
            "densenet121 params {total}"
        );
    }

    #[test]
    fn spatial_tracker() {
        let mut hw = Hw(224);
        assert_eq!(hw.conv(7, 2, 3), 112);
        assert_eq!(hw.conv(3, 2, 1), 56);
        assert_eq!(hw.conv(3, 1, 1), 56);
        assert_eq!(hw.conv(2, 2, 0), 28);
    }
}
