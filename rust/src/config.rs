//! Typed run configuration: JSON file + CLI overrides -> validated config.
//!
//! A config names a model from `artifacts/manifest.json`, a DP
//! implementation strategy, optimizer hyperparameters, and the privacy
//! target. `sigma` may be given directly or calibrated from
//! (epsilon, delta, q, steps) by the accountant.

use crate::cli::Args;
use crate::json::Value;
use std::path::PathBuf;

#[derive(Clone, Debug)]
pub struct PrivacyConfig {
    /// Target (epsilon, delta); sigma is calibrated if not set explicitly.
    pub target_epsilon: f64,
    pub target_delta: f64,
    /// Explicit noise multiplier (sigma); overrides calibration if > 0.
    pub sigma: f64,
    /// Training-set size N (for the sampling rate q = B/N).
    pub dataset_size: usize,
    /// Hard stop when the spent epsilon exceeds the target.
    pub strict_budget: bool,
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Execution backend: "native" (default, pure Rust kernels) or
    /// "pjrt" (AOT artifacts; needs the `xla-runtime` feature).
    pub backend: String,
    /// Worker threads for the native kernels (0 = one per core).
    pub threads: usize,
    /// Data-parallel worker shards per logical step (native backend
    /// only). Each shard runs whole micro-batches through the fused
    /// schedule on its own replica; rank 0 merges the per-micro-batch
    /// clipped sums in fixed global order and stays authoritative for
    /// the noise draw and the privacy accountant, so an N-shard step is
    /// bitwise identical to the 1-shard step at equal global batch.
    pub shards: usize,
    /// Ghost-vs-instantiation route decision for the mixed strategies:
    /// "formula" (the paper's `2T^2 < pd` rule, default) or "measured"
    /// (per-machine cost model calibrated by a startup microbenchmark,
    /// cached in `dispatch_profile`; corrupt/stale caches fall back to
    /// the formula with a warning).
    pub dispatch: String,
    /// Cache file for the measured dispatch profile.
    pub dispatch_profile: PathBuf,
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub strategy: String,
    /// Per-sample clipping granularity: "all-layer" (flat, default),
    /// "layer-wise", or "group-wise[:k]" (native backend only).
    pub clipping_style: String,
    /// Trainability preset (native backend only): "" inherits the
    /// model's own preset; otherwise "all", "bias-only", "lora:<rank>",
    /// or "mask:<layer,...>" override it. Frozen tensors skip norms,
    /// clipped sums, noise, and optimizer state but keep the forward
    /// and `backward_data` flow.
    pub trainable: String,
    pub steps: usize,
    pub lr: f64,
    pub clip: f64,
    pub logical_batch: usize,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub checkpoint_dir: Option<PathBuf>,
    pub checkpoint_every: usize,
    /// Retention cap: prune to this many newest checkpoints after each
    /// successful publish (0 = keep everything).
    pub checkpoint_keep_last: usize,
    /// Require a checkpoint to resume from: error out instead of
    /// silently starting fresh when `checkpoint_dir` holds none.
    /// (Resume itself is automatic whenever the dir has a usable
    /// checkpoint — this flag only upgrades "none found" to an error.)
    pub resume: bool,
    /// Policy when a step produces NaN/Inf in the loss, gradients, or
    /// updated parameters: "abort" (fail loudly, default), "skip"
    /// (discard the update but burn the noise draw and accountant step —
    /// the data was touched, the budget is spent), or "rollback"
    /// (restore parameters from the last checkpoint; streams and ledger
    /// keep advancing).
    pub on_nonfinite: String,
    pub privacy: PrivacyConfig,
    /// Disable DP entirely (strategy must be "nondp").
    pub disable_dp: bool,
}

impl Default for PrivacyConfig {
    fn default() -> Self {
        Self {
            target_epsilon: 3.0,
            target_delta: 1e-5,
            sigma: 0.0,
            dataset_size: 50_000,
            strict_budget: true,
        }
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            backend: "native".to_string(),
            threads: 0,
            shards: 1,
            dispatch: "formula".to_string(),
            dispatch_profile: PathBuf::from("fastdp_dispatch.json"),
            artifacts_dir: PathBuf::from("artifacts"),
            model: "mlp_e2e".to_string(),
            strategy: "bk".to_string(),
            clipping_style: "all-layer".to_string(),
            trainable: String::new(),
            steps: 100,
            lr: 1e-3,
            clip: 1.0,
            logical_batch: 0, // 0 = physical batch from manifest
            seed: 0,
            log_every: 10,
            eval_every: 0,
            checkpoint_dir: None,
            checkpoint_every: 0,
            checkpoint_keep_last: 0,
            resume: false,
            on_nonfinite: "abort".to_string(),
            privacy: PrivacyConfig::default(),
            disable_dp: false,
        }
    }
}

impl TrainConfig {
    pub fn from_json(v: &Value) -> Result<Self, String> {
        let mut c = TrainConfig::default();
        c.backend = v.opt_str("backend", &c.backend).to_string();
        c.threads = v.opt_i64("threads", 0) as usize;
        c.shards = v.opt_i64("shards", 1) as usize;
        c.dispatch = v.opt_str("dispatch", &c.dispatch).to_string();
        if let Some(p) = v.get("dispatch_profile").and_then(Value::as_str) {
            c.dispatch_profile = PathBuf::from(p);
        }
        c.model = v.opt_str("model", &c.model).to_string();
        c.strategy = v.opt_str("strategy", &c.strategy).to_string();
        c.clipping_style = v.opt_str("clipping_style", &c.clipping_style).to_string();
        c.trainable = v.opt_str("trainable", &c.trainable).to_string();
        c.artifacts_dir = PathBuf::from(v.opt_str("artifacts_dir", "artifacts"));
        c.steps = v.opt_i64("steps", c.steps as i64) as usize;
        c.lr = v.opt_f64("lr", c.lr);
        c.clip = v.opt_f64("clip", c.clip);
        c.logical_batch = v.opt_i64("logical_batch", 0) as usize;
        c.seed = v.opt_i64("seed", 0) as u64;
        c.log_every = v.opt_i64("log_every", c.log_every as i64) as usize;
        c.eval_every = v.opt_i64("eval_every", 0) as usize;
        c.checkpoint_every = v.opt_i64("checkpoint_every", 0) as usize;
        c.checkpoint_keep_last = v.opt_i64("checkpoint_keep_last", 0) as usize;
        c.resume = v.opt_bool("resume", false);
        c.on_nonfinite = v.opt_str("on_nonfinite", &c.on_nonfinite).to_string();
        if let Some(d) = v.get("checkpoint_dir").and_then(Value::as_str) {
            c.checkpoint_dir = Some(PathBuf::from(d));
        }
        if let Some(p) = v.get("privacy") {
            c.privacy.target_epsilon = p.opt_f64("target_epsilon", 3.0);
            c.privacy.target_delta = p.opt_f64("target_delta", 1e-5);
            c.privacy.sigma = p.opt_f64("sigma", 0.0);
            c.privacy.dataset_size = p.opt_i64("dataset_size", 50_000) as usize;
            c.privacy.strict_budget = p.opt_bool("strict_budget", true);
        }
        c.disable_dp = v.opt_bool("disable_dp", false);
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let v = crate::json::from_file(path)?;
        Self::from_json(&v)
    }

    /// Apply `--key value` CLI overrides on top of the file config.
    pub fn apply_cli(&mut self, args: &Args) -> Result<(), String> {
        if let Some(b) = args.get("backend") {
            self.backend = b.to_string();
        }
        self.threads = args.get_usize("threads", self.threads);
        self.shards = args.get_usize("shards", self.shards);
        if let Some(d) = args.get("dispatch") {
            self.dispatch = d.to_string();
        }
        if let Some(p) = args.get("dispatch-profile") {
            self.dispatch_profile = PathBuf::from(p);
        }
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(s) = args.get("strategy") {
            self.strategy = s.to_string();
        }
        if let Some(s) = args.get("clipping-style") {
            self.clipping_style = s.to_string();
        }
        if let Some(s) = args.get("trainable") {
            self.trainable = s.to_string();
        }
        if let Some(d) = args.get("artifacts-dir") {
            self.artifacts_dir = PathBuf::from(d);
        }
        self.steps = args.get_usize("steps", self.steps);
        self.lr = args.get_f64("lr", self.lr);
        self.clip = args.get_f64("clip", self.clip);
        self.seed = args.get_u64("seed", self.seed);
        self.logical_batch = args.get_usize("logical-batch", self.logical_batch);
        self.log_every = args.get_usize("log-every", self.log_every);
        self.eval_every = args.get_usize("eval-every", self.eval_every);
        if let Some(d) = args.get("checkpoint-dir") {
            self.checkpoint_dir = Some(PathBuf::from(d));
        }
        self.checkpoint_every = args.get_usize("checkpoint-every", self.checkpoint_every);
        self.checkpoint_keep_last = args.get_usize("keep-last", self.checkpoint_keep_last);
        if args.has_flag("resume") {
            self.resume = true;
        }
        if let Some(p) = args.get("on-nonfinite") {
            self.on_nonfinite = p.to_string();
        }
        self.privacy.target_epsilon = args.get_f64("epsilon", self.privacy.target_epsilon);
        self.privacy.target_delta = args.get_f64("delta", self.privacy.target_delta);
        self.privacy.sigma = args.get_f64("sigma", self.privacy.sigma);
        self.privacy.dataset_size = args.get_usize("dataset-size", self.privacy.dataset_size);
        if args.has_flag("no-dp") {
            self.disable_dp = true;
            self.strategy = "nondp".to_string();
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<(), String> {
        const STRATEGIES: [&str; 8] = [
            "nondp",
            "opacus",
            "fastgradclip",
            "ghostclip",
            "mixghostclip",
            "bk",
            "bk_mixghostclip",
            "bk_mixopt",
        ];
        if !STRATEGIES.contains(&self.strategy.as_str()) {
            return Err(format!(
                "unknown strategy '{}', expected one of {STRATEGIES:?}",
                self.strategy
            ));
        }
        if self.backend != "native" && self.backend != "pjrt" {
            return Err(format!(
                "unknown backend '{}', expected 'native' or 'pjrt'",
                self.backend
            ));
        }
        if self.dispatch != "formula" && self.dispatch != "measured" {
            return Err(format!(
                "unknown dispatch '{}', expected 'formula' or 'measured'",
                self.dispatch
            ));
        }
        if self.shards == 0 {
            return Err("shards must be >= 1".into());
        }
        if self.shards > 1 && self.backend != "native" {
            return Err(format!(
                "--shards {} requires the native backend (pjrt artifacts are single-worker)",
                self.shards
            ));
        }
        if crate::complexity::ClippingStyle::parse(&self.clipping_style).is_none() {
            return Err(format!(
                "unknown clipping_style '{}', expected all-layer, layer-wise, or group-wise[:k]",
                self.clipping_style
            ));
        }
        if !self.trainable.is_empty() {
            if self.backend != "native" {
                return Err(format!(
                    "trainable = '{}' requires the native backend (pjrt artifacts are \
                     compiled fully trainable)",
                    self.trainable
                ));
            }
            // syntax only here; mask layer names are checked against the
            // model's plan when the backend is built
            crate::runtime::native::model::Trainable::parse(&self.trainable)
                .map_err(|e| e.to_string())?;
        }
        if self.steps == 0 {
            return Err("steps must be > 0".into());
        }
        if !["abort", "skip", "rollback"].contains(&self.on_nonfinite.as_str()) {
            return Err(format!(
                "unknown on_nonfinite policy '{}', expected abort, skip, or rollback",
                self.on_nonfinite
            ));
        }
        if self.on_nonfinite == "rollback" && self.checkpoint_dir.is_none() {
            return Err("on_nonfinite=rollback requires checkpoint_dir".into());
        }
        if self.resume && self.checkpoint_dir.is_none() {
            return Err("resume requires checkpoint_dir".into());
        }
        if self.lr <= 0.0 {
            return Err("lr must be > 0".into());
        }
        if self.clip <= 0.0 {
            return Err("clip must be > 0".into());
        }
        if !self.disable_dp && self.strategy != "nondp" {
            let p = &self.privacy;
            if p.sigma == 0.0 && (p.target_epsilon <= 0.0 || p.target_delta <= 0.0) {
                return Err("privacy: need sigma > 0 or a positive (epsilon, delta) target".into());
            }
            if p.dataset_size == 0 {
                return Err("privacy.dataset_size must be > 0".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn from_json_full() {
        let v = parse(
            r#"{
          "model": "mlp_e2e", "strategy": "bk_mixopt", "steps": 7,
          "lr": 0.5, "clip": 2.0, "seed": 9,
          "privacy": {"target_epsilon": 8, "target_delta": 1e-6,
                      "dataset_size": 1000}
        }"#,
        )
        .unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.model, "mlp_e2e");
        assert_eq!(c.strategy, "bk_mixopt");
        assert_eq!(c.steps, 7);
        assert_eq!(c.privacy.dataset_size, 1000);
        assert!((c.privacy.target_delta - 1e-6).abs() < 1e-18);
    }

    #[test]
    fn backend_parse_and_reject() {
        let v = parse(r#"{"backend": "native", "threads": 4}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.backend, "native");
        assert_eq!(c.threads, 4);
        let v = parse(r#"{"backend": "tpu"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let mut c = TrainConfig::default();
        let args = crate::cli::Args::parse(
            "train --backend pjrt --threads 2".split_whitespace().map(String::from),
        );
        c.apply_cli(&args).unwrap();
        assert_eq!(c.backend, "pjrt");
        assert_eq!(c.threads, 2);
    }

    #[test]
    fn dispatch_parse_and_reject() {
        let v = parse(r#"{"dispatch": "measured", "dispatch_profile": "/tmp/prof.json"}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.dispatch, "measured");
        assert_eq!(
            c.dispatch_profile,
            std::path::Path::new("/tmp/prof.json")
        );
        let v = parse(r#"{"dispatch": "vibes"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let mut c = TrainConfig::default();
        assert_eq!(c.dispatch, "formula");
        let args = crate::cli::Args::parse(
            "train --dispatch measured --dispatch-profile prof.json"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args).unwrap();
        assert_eq!(c.dispatch, "measured");
        assert_eq!(c.dispatch_profile, std::path::Path::new("prof.json"));
    }

    #[test]
    fn shards_parse_and_reject() {
        let v = parse(r#"{"shards": 4}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.shards, 4);
        // legacy configs without the field default to a single worker
        let v = parse(r#"{"model": "mlp_e2e"}"#).unwrap();
        assert_eq!(TrainConfig::from_json(&v).unwrap().shards, 1);
        // zero shards and non-native sharding are rejected
        let v = parse(r#"{"shards": 0}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let v = parse(r#"{"backend": "pjrt", "shards": 2}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let mut c = TrainConfig::default();
        let args = crate::cli::Args::parse(
            "train --shards 3".split_whitespace().map(String::from),
        );
        c.apply_cli(&args).unwrap();
        assert_eq!(c.shards, 3);
    }

    #[test]
    fn trainable_parse_and_reject() {
        let v = parse(r#"{"trainable": "bias-only"}"#).unwrap();
        assert_eq!(TrainConfig::from_json(&v).unwrap().trainable, "bias-only");
        // legacy configs without the field inherit the model's preset
        let v = parse(r#"{"model": "mlp_e2e"}"#).unwrap();
        assert_eq!(TrainConfig::from_json(&v).unwrap().trainable, "");
        let v = parse(r#"{"trainable": "half"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let v = parse(r#"{"trainable": "lora:0"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let v = parse(r#"{"backend": "pjrt", "trainable": "bias-only"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let mut c = TrainConfig::default();
        let args = crate::cli::Args::parse(
            "train --trainable lora:4".split_whitespace().map(String::from),
        );
        c.apply_cli(&args).unwrap();
        assert_eq!(c.trainable, "lora:4");
    }

    #[test]
    fn rejects_bad_strategy() {
        let v = parse(r#"{"strategy": "warpspeed"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }

    #[test]
    fn clipping_style_parse_and_reject() {
        let v = parse(r#"{"clipping_style": "group-wise:4"}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.clipping_style, "group-wise:4");
        let v = parse(r#"{"clipping_style": "per-tensor"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let mut c = TrainConfig::default();
        assert_eq!(c.clipping_style, "all-layer");
        let args = crate::cli::Args::parse(
            "train --clipping-style layer-wise".split_whitespace().map(String::from),
        );
        c.apply_cli(&args).unwrap();
        assert_eq!(c.clipping_style, "layer-wise");
    }

    #[test]
    fn rejects_missing_privacy() {
        let v = parse(r#"{"strategy": "bk", "privacy": {"target_epsilon": 0, "sigma": 0}}"#)
            .unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }

    #[test]
    fn checkpoint_and_nonfinite_options() {
        let v = parse(
            r#"{"checkpoint_dir": "/tmp/ck", "checkpoint_every": 5,
                "checkpoint_keep_last": 3, "on_nonfinite": "skip", "resume": true}"#,
        )
        .unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.checkpoint_dir.as_deref(), Some(std::path::Path::new("/tmp/ck")));
        assert_eq!(c.checkpoint_every, 5);
        assert_eq!(c.checkpoint_keep_last, 3);
        assert_eq!(c.on_nonfinite, "skip");
        assert!(c.resume);

        // unknown policy and dir-less rollback/resume are rejected
        let v = parse(r#"{"on_nonfinite": "retry"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let v = parse(r#"{"on_nonfinite": "rollback"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        let v = parse(r#"{"resume": true}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());

        let mut c = TrainConfig::default();
        let args = crate::cli::Args::parse(
            "train --checkpoint-dir /tmp/ck2 --checkpoint-every 4 --keep-last 2 \
             --on-nonfinite rollback --resume"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args).unwrap();
        assert_eq!(c.checkpoint_dir.as_deref(), Some(std::path::Path::new("/tmp/ck2")));
        assert_eq!(c.checkpoint_every, 4);
        assert_eq!(c.checkpoint_keep_last, 2);
        assert_eq!(c.on_nonfinite, "rollback");
        assert!(c.resume);
    }

    #[test]
    fn cli_overrides() {
        let mut c = TrainConfig::default();
        let args = crate::cli::Args::parse(
            "train --strategy opacus --steps 3 --sigma 1.1"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_cli(&args).unwrap();
        assert_eq!(c.strategy, "opacus");
        assert_eq!(c.steps, 3);
        assert!((c.privacy.sigma - 1.1).abs() < 1e-12);
    }
}
