//! fastdp CLI — launcher for DP training runs, benches and analysis.
//!
//! Subcommands:
//!   train       — run DP training per a JSON config (+ CLI overrides);
//!                 `--clipping-style all-layer|layer-wise|group-wise[:k]`
//!                 picks the per-sample clipping granularity
//!   bench       — time native-kernel steps per strategy (`--styles` adds
//!                 clipping-style rows; `--json` writes
//!                 BENCH_native_kernels.json with measured fused
//!                 g-cache peaks)
//!   bench-check — compare bench JSON against a committed baseline
//!                 (ci/bench_baseline.json): exact on floats held,
//!                 banded on time; exit non-zero on regression
//!   complexity  — print the paper's complexity tables for a model,
//!                 including per-clipping-style cost reporting
//!                 (`--trainable bias-only|lora:<rank>|mask:<layers>`
//!                 masks the predictions to the trainable set)
//!                 (`--gcache-md` emits the fused-vs-legacy g-cache
//!                 markdown rows for the CI step summary) and the
//!                 per-layer ghost/inst route under both the formula
//!                 and the active `--dispatch` mode
//!   calibrate   — solve sigma for a (epsilon, delta, q, steps) target
//!   calibrate-dispatch — run the ghost-vs-instantiation microbenchmark
//!                 and cache the measured dispatch profile
//!   ckpt        — inspect / list checkpoint files: format version,
//!                 integrity (CRC), privacy fingerprint, stream cursors
//!   list        — list native models (and PJRT artifacts if present)
//!   version

use fastdp::cli::Args;
use fastdp::complexity::{self, ALL_STRATEGIES};
use fastdp::config::TrainConfig;
use fastdp::coordinator::Trainer;
use fastdp::privacy;
use fastdp::runtime::native::model::NativeSpec;
use fastdp::util::stats::{fmt_bytes, fmt_count};
use fastdp::util::table::Table;

fn main() {
    // Bench child processes short-circuit before argument parsing.
    fastdp::bench::maybe_run_native_child();
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("bench") => fastdp::bench::run_native_bench(&args),
        Some("bench-check") => fastdp::bench::run_bench_check(&args),
        Some("complexity") => cmd_complexity(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("calibrate-dispatch") => cmd_calibrate_dispatch(&args),
        Some("ckpt") => cmd_ckpt(&args),
        Some("list") => cmd_list(&args),
        Some("version") | None => {
            println!("fastdp 0.2.0 — Book-Keeping DP optimization (Bu et al., ICML 2023)");
            println!(
                "usage: fastdp <train|bench|bench-check|complexity|calibrate|\
                 calibrate-dispatch|ckpt|list|version> [--opts]"
            );
            println!(
                "       train --model <m> --strategy <s> [--threads <n>] [--shards <n>] \
                 [--clipping-style all-layer|layer-wise|group-wise[:k]] \
                 [--trainable all|bias-only|lora:<rank>|mask:<layers>] \
                 [--dispatch formula|measured] [--dispatch-profile <file>] \
                 [--checkpoint-dir <d> --checkpoint-every <k> --keep-last <n>] \
                 [--on-nonfinite abort|skip|rollback] [--resume]"
            );
            println!("       ckpt inspect <checkpoint.fdp|dir> | ckpt list <dir>");
            println!(
                "       bench [--model <m>] [--strategy a,b,...] [--styles a,b,...] \
                 [--threads <n>] [--shards <n>] [--trainable <preset>] [--json]"
            );
            println!(
                "       complexity [--model <m>] [--batch <b>] [--trainable <preset>] \
                 [--shards <n> [--micro-batches <k>]] \
                 [--dispatch formula|measured] [--dispatch-profile <file>]"
            );
            println!("       calibrate-dispatch [--threads <n>] [--dispatch-profile <file>]");
            println!(
                "       bench-check [--current a.json,b.json] [--baseline ci/bench_baseline.json] \
                 [--time-tolerance 1.0] [--summary out.md]"
            );
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_train(args: &Args) -> i32 {
    let mut cfg = match args.get("config") {
        Some(path) => match TrainConfig::load(std::path::Path::new(path)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        },
        None => TrainConfig::default(),
    };
    if let Err(e) = cfg.apply_cli(args) {
        eprintln!("config error: {e}");
        return 2;
    }
    let mut trainer = match Trainer::new(cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("init error: {e}");
            return 1;
        }
    };
    match trainer.run() {
        Ok(report) => {
            println!(
                "done: {} steps, loss {:.4} -> {:.4}, eps = {:.3}, {:.1} samples/s \
                 (mean step {:.1} ms, backend {}, peak RSS {})",
                report.steps,
                report.initial_loss,
                report.final_loss,
                report.final_epsilon,
                report.throughput_samples_per_sec,
                report.mean_step_secs * 1e3,
                report.backend,
                fmt_bytes(report.peak_rss_bytes as f64),
            );
            0
        }
        Err(e) => {
            eprintln!("training error: {e}");
            1
        }
    }
}

fn cmd_complexity(args: &Args) -> i32 {
    let model = args.get_or("model", "resnet18");
    let img = args.get_usize("image", 224) as u64;
    let seq = args.get_usize("seq", 256) as u64;
    let arch = fastdp::arch::catalog::vision_model(model, img)
        .or_else(|| fastdp::arch::catalog::language_model(model, seq));
    // catalog first, then the native registry (gpt_nano_*, mlp_*, ...),
    // so the complexity report covers the natively executable
    // transformers with their attention terms
    let mut native_spec = NativeSpec::by_name(model);
    // `--trainable all|bias-only|lora:<rank>|mask:<layers>` overrides the
    // registry preset: predictions below (param census, LoRA layer
    // rewrite, masked g-cache peaks) all follow the override
    if let Some(preset) = args.get("trainable") {
        match native_spec.as_mut() {
            Some(spec) => {
                spec.trainable = preset.to_string();
                if let Err(e) = spec.trainable_preset() {
                    eprintln!("trainable error: {e}");
                    return 2;
                }
            }
            None => {
                eprintln!(
                    "--trainable needs a native registry model (catalog \
                     architectures carry no trainability plan)"
                );
                return 2;
            }
        }
    }
    let (layers, default_b): (Vec<_>, f64) = match (&arch, &native_spec) {
        (Some(arch), _) => (arch.gl_layers().cloned().collect(), 100.0),
        (None, Some(spec)) => (
            spec.arch_layers()
                .into_iter()
                .filter(|l| l.kind != fastdp::arch::LayerKind::Norm)
                .collect(),
            spec.batch as f64,
        ),
        (None, None) => {
            eprintln!(
                "unknown model '{model}' (try resnet18, vit_base, gpt2, roberta-base, \
                 or a native registry model like gpt_nano_e2e)"
            );
            return 2;
        }
    };
    // Native models: the complexity-side parameter census (canonical
    // tensors — tied heads counted once) must agree with the spec the
    // tape executes. A mismatch means the g-cache / sensitivity /
    // noise accounting is wrong for this model, so fail loudly — the CI
    // smoke step runs this over the whole registry.
    if let Some(spec) = &native_spec {
        let arch_total = spec.arch().total_params() as usize;
        if arch_total != spec.n_params() {
            eprintln!(
                "param census mismatch for '{model}': arch counts {arch_total}, \
                 native spec counts {} — canonical-tensor accounting has drifted",
                spec.n_params()
            );
            return 1;
        }
        println!(
            "params: {} canonical floats{} (arch census and native spec agree)",
            fmt_count(spec.n_params() as f64),
            if spec.tied { ", vocab head tied to the embedding" } else { "" },
        );
        if spec.trainable != "all" {
            let trainable = spec.n_trainable_params();
            println!(
                "trainable: preset '{}' trains {} of {} floats ({:.2}%)",
                spec.trainable,
                fmt_count(trainable as f64),
                fmt_count(spec.n_params() as f64),
                100.0 * trainable as f64 / spec.n_params() as f64,
            );
        }
    }
    let b = args.get_f64("batch", default_b);
    // g-cache reporting walks the FULL trainable stack (LayerNorm
    // output gradients are book-kept too, so their caches count); the
    // per-strategy table keeps the generalized-linear view above.
    let gcache_layers = match &native_spec {
        Some(spec) if arch.is_none() => spec.arch_layers(),
        _ => layers.clone(),
    };
    // trainability mask, index-parallel to `gcache_layers`: frozen
    // layers book-keep nothing, so the fused peak treats them as pure
    // frontier transitions (`bk_gcache_floats_masked`)
    let gcache_mask: Vec<bool> = match &native_spec {
        Some(spec) if arch.is_none() => spec.arch_layer_trainable(),
        _ => vec![true; gcache_layers.len()],
    };
    use fastdp::complexity::ClippingStyle;
    // native specs predict through the plan-derived entry walk —
    // conv/pool/flatten activation widths are invisible to the (T,d,p)
    // dims view; a `--batch` override scales the whole-batch element
    // counts linearly
    let native_entries: Option<Vec<fastdp::complexity::GcacheLayer>> = match &native_spec {
        Some(spec) if arch.is_none() => {
            let mut e = spec.gcache_layers();
            let scale = b / spec.batch as f64;
            for l in &mut e {
                l.cache *= scale;
                l.frontier *= scale;
            }
            Some(e)
        }
        _ => None,
    };
    let fused_peak = |style: ClippingStyle| match &native_entries {
        Some(entries) => complexity::bk_gcache_floats_layers(style, entries),
        None => complexity::bk_gcache_floats_masked(style, b, &gcache_layers, &gcache_mask),
    };
    let gcache_styles = [
        ClippingStyle::AllLayer,
        ClippingStyle::LayerWise,
        ClippingStyle::GroupWise(2),
        ClippingStyle::GroupWise(4),
    ];
    // `--gcache-md`: emit only the fused-vs-legacy markdown rows (the
    // CI registry loop appends them to $GITHUB_STEP_SUMMARY; the table
    // header lives in ci.yml so the rows concatenate across models)
    if args.has_flag("gcache-md") {
        let legacy = complexity::bk_gcache_floats_unfused(b, &gcache_layers);
        for style in gcache_styles {
            let fused = fused_peak(style);
            println!(
                "| {model} | {} | {} | {} | {:.1}% |",
                style.name(),
                fmt_count(fused),
                fmt_count(legacy),
                if legacy > 0.0 { 100.0 * (1.0 - fused / legacy) } else { 0.0 },
            );
        }
        return 0;
    }
    let mut t = Table::new(
        &format!("{model}: per-strategy complexity (B={b})"),
        &["strategy", "time", "time-vs-nondp", "space", "space-vs-nondp"],
    );
    for s in ALL_STRATEGIES {
        let c = complexity::model_cost(s, b, &layers);
        t.row(&[
            s.name().into(),
            fmt_count(c.time),
            format!("{:.2}x", c.time_ratio()),
            fmt_count(c.space),
            format!("{:.2}x", c.space_ratio()),
        ]);
    }
    print!("{}", t.render());

    // layerwise decision summary (Table 4 style)
    let ghost: f64 = layers.iter().map(|l| complexity::norm_space_ghost(1.0, l)).sum();
    let inst: f64 = layers.iter().map(|l| complexity::norm_space_inst(1.0, l)).sum();
    let mixed: f64 = layers.iter().map(|l| complexity::norm_space_mixed(1.0, l)).sum();
    println!(
        "\nper-sample-norm space (B=1): ghost {} | instantiation {} | mixed {} \
         (saves {:.1}x vs inst, {:.1}x vs ghost)",
        fmt_count(ghost),
        fmt_count(inst),
        fmt_count(mixed),
        inst / mixed,
        ghost / mixed
    );
    let n_ghost = layers.iter().filter(|l| complexity::ghost_preferred(l)).count();
    println!(
        "layerwise decision: {n_ghost}/{} layers prefer ghost norm \
         (2T^2 < pd; attention: 2T^2 < d^2)",
        layers.len()
    );

    // per-layer route report under the active dispatch: `--dispatch
    // measured [--dispatch-profile f]` shows exactly which layers a
    // measured cost profile flips relative to the paper's formula
    let dispatch = match fastdp::runtime::native::autotune::resolve_dispatch(
        args.get_or("dispatch", "formula"),
        std::path::Path::new(args.get_or("dispatch-profile", "fastdp_dispatch.json")),
        args.get_usize("threads", 0),
    ) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("dispatch error: {e}");
            return 2;
        }
    };
    let route = |ghost: bool| if ghost { "ghost" } else { "inst" };
    let mut t = Table::new(
        &format!("per-layer norm route (active dispatch: {})", dispatch.name()),
        &["layer", "T", "d", "p", "formula", "active"],
    );
    let mut flips = 0usize;
    for l in &layers {
        let f = complexity::ghost_preferred(l);
        let m = dispatch.ghost_preferred(l);
        if f != m {
            flips += 1;
        }
        t.row(&[
            l.name.clone(),
            l.t.to_string(),
            l.d.to_string(),
            l.p.to_string(),
            route(f).into(),
            format!("{}{}", route(m), if f != m { " *" } else { "" }),
        ]);
    }
    print!("{}", t.render());
    if dispatch.name() == "measured" {
        println!(
            "measured dispatch flips {flips}/{} layer route(s) vs the formula",
            layers.len()
        );
    }

    // clipping-style cost reporting: the fused schedule frees each
    // group's book-kept output-gradient cache at its group boundary
    // (He et al. / Bu et al. group-wise clipping); the legacy column is
    // the pre-fusion hold-everything peak the saving is measured
    // against
    let mut styles = gcache_styles.to_vec();
    if let Some(s) = args.get("clipping-style") {
        match ClippingStyle::parse(s) {
            Some(cs) => {
                if !styles.contains(&cs) {
                    styles.push(cs);
                }
            }
            None => {
                eprintln!("unknown clipping style '{s}'");
                return 2;
            }
        }
    }
    let legacy = complexity::bk_gcache_floats_unfused(b, &gcache_layers);
    // clipping groups form over trainable owner layers only (the
    // backend's rule); frozen layers mint no group
    let n_own = gcache_layers
        .iter()
        .zip(&gcache_mask)
        .filter(|(l, &m)| m && l.kind != fastdp::arch::LayerKind::TiedLinear)
        .count();
    let mut t = Table::new(
        &format!("clipping styles (B={b}): fused BK g-cache peak vs legacy, + clip state (floats)"),
        &["style", "groups", "g-cache (fused)", "g-cache (legacy)", "saved", "clip state"],
    );
    for style in &styles {
        let fused = fused_peak(*style);
        t.row(&[
            style.name(),
            style.n_groups(n_own).to_string(),
            fmt_count(fused),
            fmt_count(legacy),
            if legacy > 0.0 {
                format!("{:.1}%", 100.0 * (1.0 - fused / legacy))
            } else {
                "-".into()
            },
            fmt_count(complexity::clip_state_floats(*style, n_own, b)),
        ]);
    }
    print!("{}", t.render());

    // `--shards N` (>1): predicted sharded-execution memory. Per-shard
    // g-cache peaks equal the 1-shard figure (shards take whole physical
    // micro-batches, never slices); totals scale with the N replicas
    // plus the rank-0 reduction's in-flight micro-batch grad sets.
    let shards = args.get_usize("shards", 1);
    if shards > 1 {
        let param_floats = match &native_spec {
            Some(spec) => spec.n_params() as f64,
            None => layers.iter().map(|l| l.p as f64).sum(),
        };
        let adam = native_spec
            .as_ref()
            .map(|s| s.optimizer == "adam")
            .unwrap_or(false);
        let micro = args.get_usize("micro-batches", shards);
        let mut t = Table::new(
            &format!(
                "sharded execution (N={shards} shards, K={micro} micro-batches/step): \
                 predicted peak floats"
            ),
            &["style", "replica state", "per-shard g-cache", "reduction in-flight", "total"],
        );
        for style in &styles {
            let g = fused_peak(*style);
            let sp = complexity::sharded_space(shards, micro, param_floats, adam, g);
            t.row(&[
                style.name(),
                fmt_count(sp.replica_state_floats),
                fmt_count(sp.per_shard_gcache_floats),
                fmt_count(sp.reduction_inflight_floats),
                fmt_count(sp.total_floats),
            ]);
        }
        print!("{}", t.render());
        println!(
            "per-shard g-cache peak is shard-count independent (each shard runs whole \
             physical micro-batches); replica state and g-cache scale with N"
        );
    }
    0
}

fn cmd_ckpt(args: &Args) -> i32 {
    use fastdp::coordinator::checkpoint;
    let action = args.positional.first().map(String::as_str).unwrap_or("");
    let Some(target) = args.positional.get(1) else {
        eprintln!("usage: fastdp ckpt <inspect|list> <checkpoint.fdp|dir>");
        return 2;
    };
    let path = std::path::PathBuf::from(target);
    match action {
        "list" => {
            let files = checkpoint::list_desc(&path);
            if files.is_empty() {
                println!("no checkpoints in {}", path.display());
                return 0;
            }
            for p in files {
                match checkpoint::read(&p) {
                    Ok(ck) => println!(
                        "{}  v{} step {:>6} model {} ({} tensors, {} floats)",
                        p.display(),
                        ck.version,
                        ck.step,
                        ck.model,
                        ck.tensors.len(),
                        fmt_count(ck.total_floats() as f64),
                    ),
                    Err(e) => println!("{}  CORRUPT: {e}", p.display()),
                }
            }
            0
        }
        "inspect" => {
            let file = if path.is_dir() {
                match checkpoint::latest(&path) {
                    Some(p) => p,
                    None => {
                        eprintln!("no checkpoints in {}", path.display());
                        return 1;
                    }
                }
            } else {
                path
            };
            match checkpoint::read(&file) {
                Ok(ck) => {
                    println!("checkpoint : {}", file.display());
                    println!("format     : v{}", ck.version);
                    println!("model      : {} (optimizer {})", ck.model, ck.optimizer);
                    println!("step       : {}", ck.step);
                    println!(
                        "tensors    : {} ({} floats, CRC OK)",
                        ck.tensors.len(),
                        fmt_count(ck.total_floats() as f64),
                    );
                    match &ck.fingerprint {
                        Some(fp) => println!(
                            "fingerprint: strategy={} clipping={}/{} clip={} sigma={} \
                             seed={} logical_batch={} trainable={}",
                            fp.strategy,
                            fp.clipping_style,
                            fp.clip_fn,
                            fp.clip,
                            fp.sigma,
                            fp.seed,
                            fp.logical_batch,
                            fp.trainable,
                        ),
                        None => println!("fingerprint: none (v1 checkpoint)"),
                    }
                    match ck.cursors {
                        Some(c) => println!(
                            "cursors    : noise_step={} data_cursor={} accountant_steps={}",
                            c.noise_step, c.data_cursor, c.accountant_steps,
                        ),
                        None => println!("cursors    : none (v1 — derived from step on resume)"),
                    }
                    0
                }
                Err(e) => {
                    eprintln!("corrupt checkpoint: {e}");
                    1
                }
            }
        }
        other => {
            eprintln!("unknown ckpt action '{other}' (expected inspect or list)");
            2
        }
    }
}

fn cmd_calibrate(args: &Args) -> i32 {
    let eps = args.get_f64("epsilon", 3.0);
    let delta = args.get_f64("delta", 1e-5);
    let n = args.get_usize("dataset-size", 50_000);
    let batch = args.get_usize("batch", 1024);
    let steps = args.get_u64("steps", 1000);
    let q = batch as f64 / n as f64;
    let sigma = privacy::calibrate_sigma(q, steps, eps, delta);
    let achieved = privacy::epsilon_for(q, sigma, steps, delta);
    println!(
        "q = {q:.5} (B={batch}, N={n}), steps = {steps}\n\
         sigma = {sigma:.4} achieves eps = {achieved:.4} at delta = {delta:e} \
         (target {eps})"
    );
    // epsilon trajectory
    let mut t = Table::new("epsilon trajectory", &["step", "epsilon"]);
    for frac in [0.1, 0.25, 0.5, 0.75, 1.0] {
        let s = ((steps as f64) * frac) as u64;
        t.row(&[s.to_string(), format!("{:.4}", privacy::epsilon_for(q, sigma, s, delta))]);
    }
    print!("{}", t.render());
    0
}

fn cmd_calibrate_dispatch(args: &Args) -> i32 {
    use fastdp::runtime::native::autotune;
    let threads = args.get_usize("threads", 0);
    let path =
        std::path::PathBuf::from(args.get_or("dispatch-profile", "fastdp_dispatch.json"));
    let profile = autotune::calibrate(threads);
    println!(
        "calibrated ghost-vs-instantiation dispatch on {} thread(s), isa {}:",
        profile.threads, profile.isa
    );
    println!(
        "  ghost norm     : {:.3e} s/FLOP\n  instantiation  : {:.3e} s/FLOP\n  \
         ghost/inst cost: {:.3}x",
        profile.ghost_secs_per_flop,
        profile.inst_secs_per_flop,
        profile.ghost_secs_per_flop / profile.inst_secs_per_flop,
    );
    if let Err(e) = autotune::save_profile(&path, &profile) {
        eprintln!("profile write error: {e}");
        return 1;
    }
    println!(
        "profile cached to {} (pass --dispatch measured to use it)",
        path.display()
    );
    0
}

fn cmd_list(args: &Args) -> i32 {
    // `--names`: bare registry names, one per line — scripting surface
    // for the CI complexity smoke loop.
    if args.has_flag("names") {
        for name in fastdp::runtime::native::model::registry_names() {
            println!("{name}");
        }
        return 0;
    }
    // Native registry (always available).
    let mut t = Table::new(
        "native models (backend=native, no artifacts needed)",
        &["model", "kind", "B", "T", "dims", "params", "optimizer", "clip", "trainable"],
    );
    for spec in NativeSpec::registry() {
        let info = spec.info();
        let dims: Vec<String> = std::iter::once(spec.d_in)
            .chain(spec.hidden.iter().copied())
            .chain(std::iter::once(spec.n_classes))
            .map(|d| d.to_string())
            .collect();
        t.row(&[
            spec.name.clone(),
            if spec.tied { format!("{} tied", info.kind) } else { info.kind.clone() },
            spec.batch.to_string(),
            spec.seq.to_string(),
            dims.join("-"),
            fmt_count(info.n_params as f64),
            spec.optimizer.clone(),
            spec.clip_fn.clone(),
            info.trainable_preset.clone(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "strategies: {}",
        ALL_STRATEGIES.iter().map(|s| s.name()).collect::<Vec<_>>().join(", ")
    );
    println!("clipping styles: all-layer (default), layer-wise, group-wise[:k]");

    // PJRT artifacts, when a manifest exists on disk.
    let dir = args.get_or("artifacts-dir", "artifacts");
    match fastdp::runtime::Manifest::load(std::path::Path::new(dir)) {
        Ok(m) => {
            let mut t = Table::new(
                &format!("PJRT artifacts in {dir} (kernel_impl={})", m.kernel_impl),
                &["model", "group", "params", "batch", "optimizer", "strategies"],
            );
            for (name, meta) in &m.models {
                t.row(&[
                    name.clone(),
                    meta.group.clone(),
                    fmt_count(meta.n_params as f64),
                    meta.batch.to_string(),
                    meta.optimizer.clone(),
                    m.strategies_for(name).join(","),
                ]);
            }
            print!("{}", t.render());
        }
        Err(_) => {
            println!(
                "no PJRT artifacts in '{dir}' (native backend needs none; \
                 run `make artifacts` + --features xla-runtime for the PJRT path)"
            );
        }
    }
    0
}
