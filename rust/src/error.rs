//! Minimal error handling for an offline, zero-dependency build.
//!
//! The seed code leaned on the `anyhow` crate; the default build must
//! compile with no network and no vendored registry, so this module
//! provides the small slice of that API the codebase actually uses:
//! a string-backed [`Error`], the [`anyhow!`](crate::anyhow) /
//! [`bail!`](crate::bail) macros, and a [`Context`] extension trait for
//! `Result` / `Option`. Error chains are flattened into one message with
//! `context: cause` nesting, which is exactly what the CLI prints.

use std::fmt;

/// A flattened, display-oriented error.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    /// Prepend a higher-level context message.
    pub fn wrap(self, ctx: impl fmt::Display) -> Self {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::new(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::new(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::new(e.to_string())
    }
}

impl From<std::str::Utf8Error> for Error {
    fn from(e: std::str::Utf8Error) -> Self {
        Error::new(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::new(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::new(e.to_string())
    }
}

/// Crate-wide result alias (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for results and options (drop-in for
/// `anyhow::Context`): annotates the error with a message.
pub trait Context<T> {
    fn context<D: fmt::Display>(self, ctx: D) -> Result<T>;
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<D: fmt::Display>(self, ctx: D) -> Result<T> {
        self.map_err(|e| Error::new(format!("{ctx}: {e}")))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, ctx: D) -> Result<T> {
        self.ok_or_else(|| Error::new(ctx.to_string()))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (drop-in for
/// `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::error::Error::new(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::error::Error::new(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::error::Error::new(format!("{}", $err))
    };
}

/// Early-return with an [`Error`] (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<u8> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn macros_and_context_compose() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        let s = String::from("wrapped");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "wrapped");

        let r: Result<u8> = io_fail().context("opening file");
        assert_eq!(r.unwrap_err().to_string(), "opening file: gone");
        let r: Result<u8> = io_fail().with_context(|| format!("attempt {}", 2));
        assert_eq!(r.unwrap_err().to_string(), "attempt 2: gone");

        let n: Option<u8> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u8> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 7");
    }

    #[test]
    fn question_mark_conversions() {
        fn g() -> Result<u8> {
            let _: i64 = "12".parse()?;
            let _ = std::str::from_utf8(b"ok")?;
            io_fail()?;
            Ok(0)
        }
        assert_eq!(g().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn wrap_nests() {
        let e = Error::new("inner").wrap("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
