//! Strategy composition — Table 2 / Table 5 of the paper, encoded as
//! module sums with the layerwise mixed decision for hybrids.

use super::{ghost_preferred, module_space, module_time, Cost, Module};
use crate::arch::{LayerDims, LayerKind};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    NonDp,
    Opacus,
    FastGradClip,
    GhostClip,
    MixGhostClip,
    Bk,
    BkMixGhostClip,
    BkMixOpt,
}

pub const ALL_STRATEGIES: [Strategy; 8] = [
    Strategy::NonDp,
    Strategy::Opacus,
    Strategy::FastGradClip,
    Strategy::GhostClip,
    Strategy::MixGhostClip,
    Strategy::Bk,
    Strategy::BkMixGhostClip,
    Strategy::BkMixOpt,
];

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NonDp => "nondp",
            Strategy::Opacus => "opacus",
            Strategy::FastGradClip => "fastgradclip",
            Strategy::GhostClip => "ghostclip",
            Strategy::MixGhostClip => "mixghostclip",
            Strategy::Bk => "bk",
            Strategy::BkMixGhostClip => "bk_mixghostclip",
            Strategy::BkMixOpt => "bk_mixopt",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        ALL_STRATEGIES.iter().copied().find(|x| x.name() == s)
    }

    /// Number of back-propagations (paper Table 2).
    pub fn backprops(&self) -> u32 {
        match self {
            Strategy::NonDp | Strategy::Opacus | Strategy::Bk
            | Strategy::BkMixGhostClip | Strategy::BkMixOpt => 1,
            Strategy::FastGradClip | Strategy::GhostClip | Strategy::MixGhostClip => 2,
        }
    }

    pub fn instantiates_psg(&self) -> bool {
        matches!(self, Strategy::Opacus | Strategy::FastGradClip)
    }
}

/// Per-layer cost of one training step under `strategy` (Table 5).
///
/// Norm layers (LayerNorm etc.) are treated uniformly: every DP
/// implementation instantiates their (tiny) per-sample grads; their time
/// is the standard 6BTp and their overhead Bp — negligible next to
/// generalized linear layers, but included for honesty.
pub fn layer_cost(strategy: Strategy, b: f64, l: &LayerDims) -> Cost {
    if l.kind == LayerKind::Norm {
        let t = module_time(Module::Forward, b, l) / (l.d as f64).max(1.0) * 3.0;
        let over = if strategy == Strategy::NonDp {
            0.0
        } else {
            b * (l.p as f64)
        };
        return Cost {
            time: t,
            space_overhead: over,
        };
    }

    let fwd = module_time(Module::Forward, b, l);
    let og = module_time(Module::OutputGrad, b, l);
    let pg = module_time(Module::ParamGrad, b, l);
    let gn = module_time(Module::GhostNorm, b, l);
    let psg = module_time(Module::PsgInstantiation, b, l);
    let ws = module_time(Module::WeightedSum, b, l);
    let sp_gn = module_space(Module::GhostNorm, b, l);
    let sp_psg = module_space(Module::PsgInstantiation, b, l);
    let ghost = ghost_preferred(l);

    match strategy {
        // (1) + (2a) + (2b)
        Strategy::NonDp => Cost {
            time: fwd + og + pg,
            space_overhead: 0.0,
        },
        // (1) + (2a) + (2b) + (4) + (5)
        Strategy::Opacus => Cost {
            time: fwd + og + pg + psg + ws,
            space_overhead: sp_psg,
        },
        // (1) + (2a) + (4 norms) + 2nd-pass param grads.
        // The paper's own module equation (§2.2) sums to 10BTpd, but its
        // Tables 2/5 list 8BTpd — the second pass's output-gradient
        // recomputation is attributed to the clipping norm pass. We
        // follow the tables, which are the reproduction target.
        Strategy::FastGradClip => Cost {
            time: fwd + og + psg + pg,
            space_overhead: sp_psg,
        },
        // (1) + (2a) + (2b) + (3) + (2a) + (2b)
        Strategy::GhostClip => Cost {
            time: fwd + og + pg + gn + og + pg,
            space_overhead: sp_gn,
        },
        // Table 5: 8BTpd + <2BTpd, 2BT^2(p+d)> (same 8-vs-10 convention
        // as FastGradClip above).
        Strategy::MixGhostClip => Cost {
            time: fwd + og + pg + pg + if ghost { gn } else { psg },
            space_overhead: sp_gn.min(sp_psg),
        },
        // (1) + (2a) + (3) + (2b')
        Strategy::Bk => Cost {
            time: fwd + og + gn + pg,
            space_overhead: sp_gn,
        },
        // (1) + (2a) + min{(3),(4)} + (2b')
        Strategy::BkMixGhostClip => Cost {
            time: fwd + og + if ghost { gn } else { psg } + pg,
            space_overhead: sp_gn.min(sp_psg),
        },
        // (1) + (2a) + min{(3)+(2b'), (4)+(5)}
        Strategy::BkMixOpt => Cost {
            time: fwd + og + if ghost { gn + pg } else { psg + ws },
            space_overhead: sp_gn.min(sp_psg),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{LayerDims, LayerKind};

    fn lin(t: u64, d: u64, p: u64) -> LayerDims {
        LayerDims {
            kind: LayerKind::Linear,
            name: "l".into(),
            t,
            d,
            p,
        }
    }

    /// Table 2: with T small (ghost regime), time orders as
    /// nondp ~ bk < fastgradclip ~ opacus < ghostclip,
    /// and space as nondp < bk ~ ghostclip << opacus.
    #[test]
    fn table2_orderings_small_t() {
        let l = lin(100, 1024, 1024); // 2T^2 = 2e4 << pd = 1e6
        let b = 32.0;
        let t = |s| layer_cost(s, b, &l).time;
        let sp = |s| layer_cost(s, b, &l).space_overhead;
        assert!(t(Strategy::Bk) < t(Strategy::FastGradClip));
        assert!(t(Strategy::Bk) < t(Strategy::Opacus));
        assert!(t(Strategy::Opacus) < t(Strategy::GhostClip));
        // both 8BTpd up to the cubic weighted-sum term
        assert!((t(Strategy::FastGradClip) - t(Strategy::Opacus)).abs() / t(Strategy::Opacus) < 0.01);
        // bk time = 6BTpd + 2BT^2(p+d): within 3.5% of nondp here
        assert!(t(Strategy::Bk) / t(Strategy::NonDp) < 1.07);
        assert!(sp(Strategy::Bk) < sp(Strategy::Opacus));
        assert_eq!(sp(Strategy::Bk), sp(Strategy::GhostClip));
        assert_eq!(sp(Strategy::NonDp), 0.0);
    }

    /// Large T: ghost norm explodes; hybrids must beat both bases.
    #[test]
    fn hybrids_dominate_large_t() {
        let l = lin(224 * 224, 147, 64); // ResNet conv1 shape
        let b = 8.0;
        let sp = |s| layer_cost(s, b, &l).space_overhead;
        assert!(sp(Strategy::BkMixOpt) <= sp(Strategy::Bk));
        assert!(sp(Strategy::BkMixOpt) <= sp(Strategy::Opacus));
        let t = |s| layer_cost(s, b, &l).time;
        assert!(t(Strategy::BkMixOpt) < t(Strategy::GhostClip));
        assert!(t(Strategy::BkMixOpt) < t(Strategy::Bk));
    }

    /// In the ghost regime hybrids degenerate to their base (paper §3.2).
    #[test]
    fn hybrids_equal_base_small_t() {
        let l = lin(64, 512, 512);
        let b = 16.0;
        assert_eq!(
            layer_cost(Strategy::BkMixOpt, b, &l),
            layer_cost(Strategy::Bk, b, &l)
        );
        // MixGhostClip degenerates to the ghost-norm choice (same space;
        // time follows the Table 5 8-vs-10 convention, see layer_cost).
        assert_eq!(
            layer_cost(Strategy::MixGhostClip, b, &l).space_overhead,
            layer_cost(Strategy::GhostClip, b, &l).space_overhead
        );
    }

    /// Table 2 exact coefficients on a representative layer.
    #[test]
    fn exact_coefficients() {
        let l = lin(10, 20, 30);
        let b = 2.0;
        let btpd = 2.0 * 10.0 * 30.0 * 20.0;
        let bt2pd = 2.0 * 100.0 * 50.0;
        assert_eq!(layer_cost(Strategy::NonDp, b, &l).time, 6.0 * btpd);
        assert_eq!(layer_cost(Strategy::Opacus, b, &l).time, 8.0 * btpd + 2.0 * 2.0 * 600.0);
        assert_eq!(
            layer_cost(Strategy::GhostClip, b, &l).time,
            10.0 * btpd + 2.0 * bt2pd
        );
        assert_eq!(layer_cost(Strategy::Bk, b, &l).time, 6.0 * btpd + 2.0 * bt2pd);
    }

    #[test]
    fn parse_roundtrip() {
        for s in ALL_STRATEGIES {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn backprop_counts_match_table2() {
        assert_eq!(Strategy::NonDp.backprops(), 1);
        assert_eq!(Strategy::Opacus.backprops(), 1);
        assert_eq!(Strategy::FastGradClip.backprops(), 2);
        assert_eq!(Strategy::GhostClip.backprops(), 2);
        assert_eq!(Strategy::Bk.backprops(), 1);
    }
}
