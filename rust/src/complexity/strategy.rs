//! Strategy composition — Table 2 / Table 5 of the paper, encoded as
//! module sums with the layerwise mixed decision for hybrids.

use super::{
    attention_sublayers, ghost_preferred, lora_sublayers, module_space, module_time, Cost, Module,
};
use crate::arch::{LayerDims, LayerKind};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    NonDp,
    Opacus,
    FastGradClip,
    GhostClip,
    MixGhostClip,
    Bk,
    BkMixGhostClip,
    BkMixOpt,
}

pub const ALL_STRATEGIES: [Strategy; 8] = [
    Strategy::NonDp,
    Strategy::Opacus,
    Strategy::FastGradClip,
    Strategy::GhostClip,
    Strategy::MixGhostClip,
    Strategy::Bk,
    Strategy::BkMixGhostClip,
    Strategy::BkMixOpt,
];

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NonDp => "nondp",
            Strategy::Opacus => "opacus",
            Strategy::FastGradClip => "fastgradclip",
            Strategy::GhostClip => "ghostclip",
            Strategy::MixGhostClip => "mixghostclip",
            Strategy::Bk => "bk",
            Strategy::BkMixGhostClip => "bk_mixghostclip",
            Strategy::BkMixOpt => "bk_mixopt",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        ALL_STRATEGIES.iter().copied().find(|x| x.name() == s)
    }

    /// Number of back-propagations (paper Table 2).
    pub fn backprops(&self) -> u32 {
        match self {
            Strategy::NonDp | Strategy::Opacus | Strategy::Bk
            | Strategy::BkMixGhostClip | Strategy::BkMixOpt => 1,
            Strategy::FastGradClip | Strategy::GhostClip | Strategy::MixGhostClip => 2,
        }
    }

    pub fn instantiates_psg(&self) -> bool {
        matches!(self, Strategy::Opacus | Strategy::FastGradClip)
    }
}

/// Per-sample clipping granularity (He et al. 2023; Bu et al. 2023 on
/// group-wise clipping): which trainable layers share one clip factor.
///
/// Sensitivity bookkeeping: with `G` groups each group is clipped to
/// `R_g = R / sqrt(G)`, so a sample's total clipped contribution has
/// norm at most `sqrt(sum_g R_g^2) = R` — the noise multiplier and the
/// accountant are style-independent. `AllLayer` (G = 1) is the paper's
/// flat clipping and is bitwise-identical to the pre-style behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ClippingStyle {
    /// One norm over all layers per sample (flat clipping; default).
    AllLayer,
    /// One clip factor per trainable layer.
    LayerWise,
    /// `k` contiguous groups of trainable layers.
    GroupWise(usize),
}

impl ClippingStyle {
    /// Parse `"all-layer"`, `"layer-wise"`, `"group-wise"` (2 groups),
    /// or `"group-wise:<k>"`.
    pub fn parse(s: &str) -> Option<ClippingStyle> {
        match s {
            "all-layer" => Some(ClippingStyle::AllLayer),
            "layer-wise" => Some(ClippingStyle::LayerWise),
            "group-wise" => Some(ClippingStyle::GroupWise(2)),
            _ => s
                .strip_prefix("group-wise:")?
                .parse::<usize>()
                .ok()
                .filter(|&k| k >= 1)
                .map(ClippingStyle::GroupWise),
        }
    }

    /// Canonical display name (round-trips through [`Self::parse`]).
    pub fn name(&self) -> String {
        match self {
            ClippingStyle::AllLayer => "all-layer".to_string(),
            ClippingStyle::LayerWise => "layer-wise".to_string(),
            ClippingStyle::GroupWise(k) => format!("group-wise:{k}"),
        }
    }

    /// Number of clipping groups over `n` trainable layers.
    pub fn n_groups(&self, n: usize) -> usize {
        match self {
            ClippingStyle::AllLayer => 1,
            ClippingStyle::LayerWise => n.max(1),
            ClippingStyle::GroupWise(k) => (*k).clamp(1, n.max(1)),
        }
    }

    /// Group id of trainable layer `i` (0-based) among `n` layers:
    /// balanced contiguous blocks, every group non-empty.
    pub fn group_of(&self, i: usize, n: usize) -> usize {
        let g = self.n_groups(n);
        if n == 0 {
            return 0;
        }
        i * g / n
    }
}

/// Clip-state bookkeeping of a style: one squared-norm accumulator and
/// one clip factor per (group, sample) — `2 * G * B` floats.
pub fn clip_state_floats(style: ClippingStyle, n_layers: usize, b: f64) -> f64 {
    2.0 * style.n_groups(n_layers) as f64 * b
}

/// Book-kept output-gradient width of a layer (floats per activation
/// row): attention book-keeps `dL/d out` at the model width `d` (its
/// `p` encodes the head count); every other kind at `p`.
fn gcache_width(l: &LayerDims) -> f64 {
    match l.kind {
        LayerKind::Attention => l.d as f64,
        _ => l.p as f64,
    }
}

/// Frontier-gradient width below a layer (`dL/d input` rows): `d` for
/// every feature-consuming kind; 0 for an embedding (token input,
/// nothing to back-propagate into).
fn frontier_width(l: &LayerDims) -> f64 {
    match l.kind {
        LayerKind::Embedding => 0.0,
        _ => l.d as f64,
    }
}

/// Peak g-cache floats of the **legacy unfused** one-pass schedule:
/// every trainable layer's `B*T*width` book-kept output gradient is
/// stashed until the clipped-sum sweep at the end of the walk, so the
/// peak is the plain sum regardless of clipping style. Kept as the
/// baseline the fused schedule ([`bk_gcache_floats`]) is measured
/// against (`fastdp complexity` prints both; CI diffs them per model).
pub fn bk_gcache_floats_unfused(b: f64, layers: &[LayerDims]) -> f64 {
    layers.iter().map(|l| b * l.t as f64 * gcache_width(l)).sum()
}

/// Peak g-cache floats of the **fused** BK one-pass schedule under a
/// clipping style: a group's clip factor is finalized — and its
/// members' book-kept caches released — the moment the backward walk
/// crosses the group boundary, so the peak is the maximum over walk
/// positions of (live book-kept caches of unfinalized groups) + (the
/// propagating frontier gradient), not the sum over all layers.
///
/// This simulates the exact walk `StackRun::fused_pass` runs, over the
/// trainable layers in plan order: groups are balanced contiguous
/// blocks over *owner* layers; a `TiedLinear` head aliases the
/// embedding and inherits its group, so its cache stays live until the
/// shared group finalizes at the bottom of the walk. The native
/// backend's measured gauge (`AllocStats::peak_gcache_floats`) counts
/// the same quantity, and the fused-schedule tests pin measured ==
/// predicted on the registry models.
pub fn bk_gcache_floats(style: ClippingStyle, b: f64, layers: &[LayerDims]) -> f64 {
    bk_gcache_floats_masked(style, b, layers, &vec![true; layers.len()])
}

/// [`bk_gcache_floats`] under a per-layer trainability mask: frozen
/// layers keep no book-kept cache and join no clipping group — in the
/// walk they are pure frontier transitions (`backward_data` still runs,
/// so the frontier gradient flows through them at their input width),
/// exactly matching the fused gauge's stateless-layer accounting.
/// Groups are balanced contiguous blocks over *trainable* owner layers,
/// mirroring the native backend's group assignment. Note a layer whose
/// bias alone trains still book-keeps its full-width output gradient
/// (the bias sum reads it), so bias-only masks shrink the peak only via
/// the layers that are frozen outright.
pub fn bk_gcache_floats_masked(
    style: ClippingStyle,
    b: f64,
    layers: &[LayerDims],
    trainable: &[bool],
) -> f64 {
    debug_assert_eq!(trainable.len(), layers.len());
    let emb = layers.iter().position(|l| l.kind == LayerKind::Embedding);
    let entries: Vec<GcacheLayer> = layers
        .iter()
        .zip(trainable)
        .map(|(l, &tr)| GcacheLayer {
            cache: b * l.t as f64 * gcache_width(l),
            frontier: b * l.t as f64 * frontier_width(l),
            trainable: tr,
            alias_of: if l.kind == LayerKind::TiedLinear { emb } else { None },
        })
        .collect();
    bk_gcache_floats_layers(style, &entries)
}

/// One layer of the fused g-cache walk, as whole-batch element counts.
///
/// [`bk_gcache_floats_masked`] derives these from `(T, d, p)` dims — a
/// view that cannot represent stacks whose activation width changes
/// *between* parameterized layers (a conv's frontier gradient is
/// `B·cin·h·w`, not `B·T_out·cin·k²`, and pooling/flatten transitions
/// are invisible to `LayerDims`). The executable plan can:
/// `NativeSpec::gcache_layers` emits one entry per plan layer,
/// stateless ops included, and [`bk_gcache_floats_layers`] runs the
/// same walk over raw element counts.
#[derive(Clone, Debug, PartialEq)]
pub struct GcacheLayer {
    /// Book-kept output-gradient floats (`B·T·out-width`) if this layer
    /// trains; also the walk's init when it is the head layer (the loss
    /// gradient is the first frontier).
    pub cache: f64,
    /// Frontier-gradient floats below this layer (`B·T·in-width`); 0
    /// for a token-consuming front (embedding). Ignored for layer 0.
    pub frontier: f64,
    /// Whether any of the layer's tensors train (a bias-only layer
    /// still book-keeps its full-width output gradient). Stateless ops
    /// (ReLU, pooling, flatten) are `false` — pure frontier transitions.
    pub trainable: bool,
    /// Tied-alias link: `Some(i)` means this layer views layer `i`'s
    /// tensor (the GPT-2 tied head over its embedding) and inherits
    /// that owner's clipping group instead of minting one.
    pub alias_of: Option<usize>,
}

/// The fused-walk simulation of [`bk_gcache_floats_masked`] over
/// plan-derived element counts — the same walk, but correct for stacks
/// with non-uniform activation widths (conv/pool/flatten trunks).
/// `StackRun::fused_pass`'s gauge measures exactly this quantity.
pub fn bk_gcache_floats_layers(style: ClippingStyle, layers: &[GcacheLayer]) -> f64 {
    let n = layers.len();
    if n == 0 || !layers.iter().any(|l| l.trainable) {
        return 0.0;
    }
    // group ids: trainable owners positionally; frozen/stateless layers
    // carry a sentinel (no cache, no group); a trainable alias inherits
    // the group of the owner whose tensor it views
    const FROZEN: usize = usize::MAX;
    let n_own = layers.iter().filter(|l| l.trainable && l.alias_of.is_none()).count();
    let mut groups = vec![FROZEN; n];
    let mut oi = 0usize;
    for (i, l) in layers.iter().enumerate() {
        if l.trainable && l.alias_of.is_none() {
            groups[i] = style.group_of(oi, n_own);
            oi += 1;
        }
    }
    for i in 0..n {
        if layers[i].trainable {
            if let Some(j) = layers[i].alias_of {
                // a shared tensor has exactly one trainability state, so
                // an alias cannot train over a frozen owner
                debug_assert_ne!(groups[j], FROZEN, "trainable alias over a frozen owner");
                groups[i] = groups[j];
            }
        }
    }
    // each group finalizes at its lowest-index (trainable) member
    let g = style.n_groups(n_own);
    let finalize_at: Vec<usize> = (0..g)
        .map(|gi| (0..n).find(|&i| groups[i] == gi).expect("non-empty group"))
        .collect();
    // walk top-down: keep trainable caches, advance the frontier,
    // release at group boundaries — mirroring StackRun::fused_pass's
    // gauge (which subtracts a stateless layer's old frontier before
    // sampling the peak)
    let mut kept = vec![0.0f64; g];
    let mut kept_total = 0.0f64;
    let mut peak = layers[n - 1].cache;
    for i in (0..n).rev() {
        let l = &layers[i];
        if l.trainable {
            kept[groups[i]] += l.cache;
            kept_total += l.cache;
        }
        let frontier = if i > 0 { l.frontier } else { 0.0 };
        peak = peak.max(kept_total + frontier);
        if l.trainable && finalize_at[groups[i]] == i {
            kept_total -= kept[groups[i]];
            kept[groups[i]] = 0.0;
        }
    }
    peak
}

/// Per-layer cost of one training step under `strategy` (Table 5).
///
/// Norm layers (LayerNorm etc.) are treated uniformly: every DP
/// implementation instantiates their (tiny) per-sample grads; their time
/// is the standard 6BTp and their overhead Bp — negligible next to
/// generalized linear layers, but included for honesty.
pub fn layer_cost(strategy: Strategy, b: f64, l: &LayerDims) -> Cost {
    if l.kind == LayerKind::Attention {
        // Attention = two generalized-linear sublayers (fused QKV
        // d -> 3d, output projection d -> d) costed per strategy, plus
        // the parameter-free causal-softmax core: 4BT^2 d per forward
        // (scores + probs @ v, with H*hd = d head-independent) and
        // ~8BT^2 d per backward recompute (g_v, the two g_prob dot
        // sweeps, g_q, g_k), once per backprop of the strategy.
        let [qkv, out] = attention_sublayers(l);
        let mut c = layer_cost(strategy, b, &qkv);
        c.add(layer_cost(strategy, b, &out));
        let (t, d) = (l.t as f64, l.d as f64);
        c.time += 4.0 * b * t * t * d + 8.0 * b * t * t * d * strategy.backprops() as f64;
        return c;
    }
    if l.kind == LayerKind::Norm {
        let t = module_time(Module::Forward, b, l) / (l.d as f64).max(1.0) * 3.0;
        let over = if strategy == Strategy::NonDp {
            0.0
        } else {
            b * (l.p as f64)
        };
        return Cost {
            time: t,
            space_overhead: over,
        };
    }
    if matches!(l.kind, LayerKind::Lora { .. }) {
        // Frozen base + two trainable skinny adapters: the adapters are
        // ordinary generalized-linear layers costed per strategy (the
        // gA = g·B^T recompute is sublayer B's output gradient); the
        // base pays only its forward and the backward-data flow g·W^T,
        // once per backprop — it never norms, instantiates, or sums.
        let [a, ad_b] = lora_sublayers(l);
        let mut c = layer_cost(strategy, b, &a);
        c.add(layer_cost(strategy, b, &ad_b));
        let mut base = l.clone();
        base.kind = LayerKind::Linear;
        c.time += module_time(Module::Forward, b, &base)
            + module_time(Module::OutputGrad, b, &base) * strategy.backprops() as f64;
        return c;
    }
    if l.kind == LayerKind::PosEmbedding {
        // row-add forward (identity backward) + Frobenius norm +
        // position-wise scatter; both norm routes are the same O(BTp)
        // reduction, so every DP strategy pays the same time and no
        // extra space
        let fwd = module_time(Module::Forward, b, l);
        let gn = module_time(Module::GhostNorm, b, l);
        let ws = module_time(Module::ParamGrad, b, l);
        let time = if strategy == Strategy::NonDp { fwd + ws } else { fwd + gn + ws };
        return Cost {
            time,
            space_overhead: 0.0,
        };
    }

    let fwd = module_time(Module::Forward, b, l);
    let og = module_time(Module::OutputGrad, b, l);
    let pg = module_time(Module::ParamGrad, b, l);
    let gn = module_time(Module::GhostNorm, b, l);
    let psg = module_time(Module::PsgInstantiation, b, l);
    let ws = module_time(Module::WeightedSum, b, l);
    let sp_gn = module_space(Module::GhostNorm, b, l);
    let sp_psg = module_space(Module::PsgInstantiation, b, l);
    let ghost = ghost_preferred(l);

    match strategy {
        // (1) + (2a) + (2b)
        Strategy::NonDp => Cost {
            time: fwd + og + pg,
            space_overhead: 0.0,
        },
        // (1) + (2a) + (2b) + (4) + (5)
        Strategy::Opacus => Cost {
            time: fwd + og + pg + psg + ws,
            space_overhead: sp_psg,
        },
        // (1) + (2a) + (4 norms) + 2nd-pass param grads.
        // The paper's own module equation (§2.2) sums to 10BTpd, but its
        // Tables 2/5 list 8BTpd — the second pass's output-gradient
        // recomputation is attributed to the clipping norm pass. We
        // follow the tables, which are the reproduction target.
        Strategy::FastGradClip => Cost {
            time: fwd + og + psg + pg,
            space_overhead: sp_psg,
        },
        // (1) + (2a) + (2b) + (3) + (2a) + (2b)
        Strategy::GhostClip => Cost {
            time: fwd + og + pg + gn + og + pg,
            space_overhead: sp_gn,
        },
        // Table 5: 8BTpd + <2BTpd, 2BT^2(p+d)> (same 8-vs-10 convention
        // as FastGradClip above).
        Strategy::MixGhostClip => Cost {
            time: fwd + og + pg + pg + if ghost { gn } else { psg },
            space_overhead: sp_gn.min(sp_psg),
        },
        // (1) + (2a) + (3) + (2b')
        Strategy::Bk => Cost {
            time: fwd + og + gn + pg,
            space_overhead: sp_gn,
        },
        // (1) + (2a) + min{(3),(4)} + (2b')
        Strategy::BkMixGhostClip => Cost {
            time: fwd + og + if ghost { gn } else { psg } + pg,
            space_overhead: sp_gn.min(sp_psg),
        },
        // (1) + (2a) + min{(3)+(2b'), (4)+(5)}
        Strategy::BkMixOpt => Cost {
            time: fwd + og + if ghost { gn + pg } else { psg + ws },
            space_overhead: sp_gn.min(sp_psg),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{LayerDims, LayerKind};

    fn lin(t: u64, d: u64, p: u64) -> LayerDims {
        LayerDims {
            kind: LayerKind::Linear,
            name: "l".into(),
            t,
            d,
            p,
        }
    }

    /// Table 2: with T small (ghost regime), time orders as
    /// nondp ~ bk < fastgradclip ~ opacus < ghostclip,
    /// and space as nondp < bk ~ ghostclip << opacus.
    #[test]
    fn table2_orderings_small_t() {
        let l = lin(100, 1024, 1024); // 2T^2 = 2e4 << pd = 1e6
        let b = 32.0;
        let t = |s| layer_cost(s, b, &l).time;
        let sp = |s| layer_cost(s, b, &l).space_overhead;
        assert!(t(Strategy::Bk) < t(Strategy::FastGradClip));
        assert!(t(Strategy::Bk) < t(Strategy::Opacus));
        assert!(t(Strategy::Opacus) < t(Strategy::GhostClip));
        // both 8BTpd up to the cubic weighted-sum term
        assert!((t(Strategy::FastGradClip) - t(Strategy::Opacus)).abs() / t(Strategy::Opacus) < 0.01);
        // bk time = 6BTpd + 2BT^2(p+d): within 3.5% of nondp here
        assert!(t(Strategy::Bk) / t(Strategy::NonDp) < 1.07);
        assert!(sp(Strategy::Bk) < sp(Strategy::Opacus));
        assert_eq!(sp(Strategy::Bk), sp(Strategy::GhostClip));
        assert_eq!(sp(Strategy::NonDp), 0.0);
    }

    /// Large T: ghost norm explodes; hybrids must beat both bases.
    #[test]
    fn hybrids_dominate_large_t() {
        let l = lin(224 * 224, 147, 64); // ResNet conv1 shape
        let b = 8.0;
        let sp = |s| layer_cost(s, b, &l).space_overhead;
        assert!(sp(Strategy::BkMixOpt) <= sp(Strategy::Bk));
        assert!(sp(Strategy::BkMixOpt) <= sp(Strategy::Opacus));
        let t = |s| layer_cost(s, b, &l).time;
        assert!(t(Strategy::BkMixOpt) < t(Strategy::GhostClip));
        assert!(t(Strategy::BkMixOpt) < t(Strategy::Bk));
    }

    /// In the ghost regime hybrids degenerate to their base (paper §3.2).
    #[test]
    fn hybrids_equal_base_small_t() {
        let l = lin(64, 512, 512);
        let b = 16.0;
        assert_eq!(
            layer_cost(Strategy::BkMixOpt, b, &l),
            layer_cost(Strategy::Bk, b, &l)
        );
        // MixGhostClip degenerates to the ghost-norm choice (same space;
        // time follows the Table 5 8-vs-10 convention, see layer_cost).
        assert_eq!(
            layer_cost(Strategy::MixGhostClip, b, &l).space_overhead,
            layer_cost(Strategy::GhostClip, b, &l).space_overhead
        );
    }

    /// Table 2 exact coefficients on a representative layer.
    #[test]
    fn exact_coefficients() {
        let l = lin(10, 20, 30);
        let b = 2.0;
        let btpd = 2.0 * 10.0 * 30.0 * 20.0;
        let bt2pd = 2.0 * 100.0 * 50.0;
        assert_eq!(layer_cost(Strategy::NonDp, b, &l).time, 6.0 * btpd);
        assert_eq!(layer_cost(Strategy::Opacus, b, &l).time, 8.0 * btpd + 2.0 * 2.0 * 600.0);
        assert_eq!(
            layer_cost(Strategy::GhostClip, b, &l).time,
            10.0 * btpd + 2.0 * bt2pd
        );
        assert_eq!(layer_cost(Strategy::Bk, b, &l).time, 6.0 * btpd + 2.0 * bt2pd);
    }

    #[test]
    fn parse_roundtrip() {
        for s in ALL_STRATEGIES {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn clipping_style_parse_and_groups() {
        for style in [
            ClippingStyle::AllLayer,
            ClippingStyle::LayerWise,
            ClippingStyle::GroupWise(2),
            ClippingStyle::GroupWise(7),
        ] {
            assert_eq!(ClippingStyle::parse(&style.name()), Some(style));
        }
        assert_eq!(ClippingStyle::parse("group-wise"), Some(ClippingStyle::GroupWise(2)));
        assert_eq!(ClippingStyle::parse("group-wise:0"), None);
        assert_eq!(ClippingStyle::parse("per-layer"), None);

        let n = 5;
        assert_eq!(ClippingStyle::AllLayer.n_groups(n), 1);
        assert_eq!(ClippingStyle::LayerWise.n_groups(n), n);
        assert_eq!(ClippingStyle::GroupWise(2).n_groups(n), 2);
        // more groups than layers clamps
        assert_eq!(ClippingStyle::GroupWise(9).n_groups(n), n);

        // contiguous, surjective, monotone partition
        for style in [ClippingStyle::LayerWise, ClippingStyle::GroupWise(2), ClippingStyle::GroupWise(3)] {
            let g = style.n_groups(n);
            let ids: Vec<usize> = (0..n).map(|i| style.group_of(i, n)).collect();
            assert!(ids.windows(2).all(|w| w[0] <= w[1]), "{ids:?}");
            assert_eq!(ids[0], 0);
            assert_eq!(*ids.last().unwrap(), g - 1);
            let mut seen: Vec<usize> = ids.clone();
            seen.dedup();
            assert_eq!(seen.len(), g, "every group non-empty: {ids:?}");
        }
    }

    #[test]
    fn style_cost_reporting() {
        // Stack: (d=64, p=32/64/128/256), b=16, t=8 => rows = 128.
        // Walk-simulated fused peaks (kept caches of unfinalized groups
        // + the propagating frontier at every step), worked by hand:
        //   all-layer:  max at i=1: 128*(256+128+64) + 128*64 = 65536
        //   layer-wise: max at i=3: 128*256 + 128*64          = 40960
        //   group-wise:2 (groups {0,1}{2,3}): max at i=2:
        //               128*(256+128) + 128*64                = 57344
        let layers: Vec<LayerDims> = (0..4).map(|i| lin(8, 64, 32 << i)).collect();
        let b = 16.0;
        let all = bk_gcache_floats(ClippingStyle::AllLayer, b, &layers);
        let lw = bk_gcache_floats(ClippingStyle::LayerWise, b, &layers);
        let gw = bk_gcache_floats(ClippingStyle::GroupWise(2), b, &layers);
        assert_eq!(all, 65536.0);
        assert_eq!(lw, 40960.0);
        assert_eq!(gw, 57344.0);
        // finer styles release earlier, never later
        assert!(lw <= gw && gw <= all);
        // the legacy unfused schedule holds every cache to the end,
        // style-independent: the plain sum
        let total: f64 = layers.iter().map(|l| b * l.t as f64 * l.p as f64).sum();
        assert_eq!(bk_gcache_floats_unfused(b, &layers), total);
        assert_eq!(total, 61440.0);
        // every fused peak is bounded by legacy + the widest frontier
        assert!(all <= total + b * 8.0 * 64.0);
        // clip state scales with group count
        assert_eq!(clip_state_floats(ClippingStyle::AllLayer, 4, b), 2.0 * b);
        assert_eq!(clip_state_floats(ClippingStyle::LayerWise, 4, b), 8.0 * b);
    }

    #[test]
    fn gcache_simulation_handles_tied_heads() {
        // Embedding (vocab=7, dim=4) -> Linear (4,4) -> TiedLinear
        // (d=4, p=7), b=1, t=2. Layer-wise: 2 owner groups; the tied
        // head inherits the embedding's group 0, so its 2*7=14-float
        // cache stays live to the bottom of the walk:
        //   i=2 tied(g0): kept 14, frontier 8  -> 22
        //   i=1 lin(g1):  kept 22, frontier 8  -> 30, g1 releases 8
        //   i=0 emb(g0):  kept 22, frontier 0  -> 22
        let mk = |kind, d: u64, p: u64| LayerDims {
            kind,
            name: "l".into(),
            t: 2,
            d,
            p,
        };
        let layers = vec![
            mk(LayerKind::Embedding, 7, 4),
            mk(LayerKind::Linear, 4, 4),
            mk(LayerKind::TiedLinear, 4, 7),
        ];
        let lw = bk_gcache_floats(ClippingStyle::LayerWise, 1.0, &layers);
        assert_eq!(lw, 30.0);
        let all = bk_gcache_floats(ClippingStyle::AllLayer, 1.0, &layers);
        assert_eq!(all, 30.0);
        assert_eq!(bk_gcache_floats_unfused(1.0, &layers), 30.0);
        assert!(lw <= all);
        // layer-wise groups count owners only: with the tied head in
        // the embedding's group the walk still drains to zero (the
        // asserts inside the simulation would panic otherwise)
        assert!(bk_gcache_floats(ClippingStyle::GroupWise(2), 1.0, &layers) <= all);
    }

    #[test]
    fn masked_gcache_all_true_is_unmasked() {
        let layers: Vec<LayerDims> = (0..4).map(|i| lin(8, 64, 32 << i)).collect();
        let b = 16.0;
        for style in [
            ClippingStyle::AllLayer,
            ClippingStyle::LayerWise,
            ClippingStyle::GroupWise(2),
        ] {
            assert_eq!(
                bk_gcache_floats_masked(style, b, &layers, &[true; 4]),
                bk_gcache_floats(style, b, &layers)
            );
        }
        // no trainable layers: nothing is ever book-kept
        assert_eq!(
            bk_gcache_floats_masked(ClippingStyle::AllLayer, b, &layers, &[false; 4]),
            0.0
        );
    }

    #[test]
    fn masked_gcache_frozen_layers_are_frontier_transitions() {
        // Same stack as style_cost_reporting (p = 32/64/128/256, b=16,
        // t=8, rows=128) with layer 2 (p=128) frozen. All-layer walk by
        // hand: init 32768 (loss grad); i=3 kept 32768 + frontier 8192
        // -> 40960; i=2 frozen, 32768 + 8192 -> 40960; i=1 kept 40960 +
        // 8192 -> 49152; i=0 kept 45056, frontier 0. Peak 49152 — the
        // frozen layer's 16384-float cache never joins the gauge
        // (full-stack peak is 65536).
        let layers: Vec<LayerDims> = (0..4).map(|i| lin(8, 64, 32 << i)).collect();
        let b = 16.0;
        let mask = [true, true, false, true];
        let all = bk_gcache_floats_masked(ClippingStyle::AllLayer, b, &layers, &mask);
        assert_eq!(all, 49152.0);
        assert!(all < bk_gcache_floats(ClippingStyle::AllLayer, b, &layers));
        // layer-wise releases each cache immediately; the frozen layer
        // changes nothing about the peak (which full layer-wise also hits)
        let lw = bk_gcache_floats_masked(ClippingStyle::LayerWise, b, &layers, &mask);
        assert_eq!(lw, bk_gcache_floats(ClippingStyle::LayerWise, b, &layers));
    }

    #[test]
    fn masked_gcache_frozen_tied_stack() {
        // Embedding (7,4) -> Linear (4,4) -> TiedLinear (4,7), t=2,
        // b=1, embedding + tied head frozen (a lora-style mask). Walk:
        // init 14 (loss grad over the head); i=2 frozen -> 14 vs 8;
        // i=1 kept 8 + frontier 8 -> 16, finalize releases; i=0 frozen,
        // 0. Peak 16 vs 30 fully trainable.
        let mk = |kind, d: u64, p: u64| LayerDims {
            kind,
            name: "l".into(),
            t: 2,
            d,
            p,
        };
        let layers = vec![
            mk(LayerKind::Embedding, 7, 4),
            mk(LayerKind::Linear, 4, 4),
            mk(LayerKind::TiedLinear, 4, 7),
        ];
        let mask = [false, true, false];
        for style in [ClippingStyle::AllLayer, ClippingStyle::LayerWise] {
            let m = bk_gcache_floats_masked(style, 1.0, &layers, &mask);
            assert_eq!(m, 16.0, "{style:?}");
            assert!(m < bk_gcache_floats(style, 1.0, &layers));
        }
    }

    #[test]
    fn entry_walk_reproduces_dims_walk_pins() {
        // The same 4-layer stack style_cost_reporting pins (t=8, d=64,
        // p = 32<<i, b=16), expressed as raw element counts: the entry
        // walk must land on the identical 65536 / 40960 / 57344 peaks.
        let b = 16.0;
        let rows = b * 8.0;
        let entries: Vec<GcacheLayer> = (0..4)
            .map(|i| GcacheLayer {
                cache: rows * (32 << i) as f64,
                frontier: rows * 64.0,
                trainable: true,
                alias_of: None,
            })
            .collect();
        assert_eq!(bk_gcache_floats_layers(ClippingStyle::AllLayer, &entries), 65536.0);
        assert_eq!(bk_gcache_floats_layers(ClippingStyle::LayerWise, &entries), 40960.0);
        assert_eq!(bk_gcache_floats_layers(ClippingStyle::GroupWise(2), &entries), 57344.0);
        // and the masked delegation is literally this walk
        let layers: Vec<LayerDims> = (0..4).map(|i| lin(8, 64, 32 << i)).collect();
        for style in [
            ClippingStyle::AllLayer,
            ClippingStyle::LayerWise,
            ClippingStyle::GroupWise(2),
        ] {
            assert_eq!(
                bk_gcache_floats_layers(style, &entries),
                bk_gcache_floats_masked(style, b, &layers, &[true; 4])
            );
        }
    }

    #[test]
    fn entry_walk_counts_conv_trunk_frontiers() {
        // conv(1x16x16 -> 4x16x16) -> avgpool/2 -> flatten -> linear
        // (256 -> 10), b=2. The frontier below the pool is the conv's
        // FULL output activation (B·4·16·16 = 2048 floats) — a width no
        // LayerDims view can express (the conv's t·d would give
        // B·256·9 = 4608) — and the pool/flatten transitions must
        // participate in the walk as stateless entries.
        let b = 2.0;
        let entries = vec![
            GcacheLayer {
                cache: b * 1024.0, // B·cout·ho·wo
                frontier: b * 256.0,
                trainable: true,
                alias_of: None,
            },
            GcacheLayer {
                cache: b * 256.0,
                frontier: b * 1024.0, // the conv's output activation
                trainable: false,
                alias_of: None,
            },
            GcacheLayer {
                cache: b * 256.0,
                frontier: b * 256.0,
                trainable: false,
                alias_of: None,
            },
            GcacheLayer {
                cache: b * 10.0,
                frontier: b * 256.0,
                trainable: true,
                alias_of: None,
            },
        ];
        // all-layer walk by hand: init 20; linear kept 20 + frontier 512
        // -> 532; flatten 20 + 512; pool 20 + 2048 -> 2068; conv kept
        // 2048 more, frontier 0 -> 2068. Peak 2068.
        assert_eq!(bk_gcache_floats_layers(ClippingStyle::AllLayer, &entries), 2068.0);
        // layer-wise finalizes the linear at its own index, so only the
        // conv's cache survives to the bottom: peak 2048 at the conv.
        assert_eq!(bk_gcache_floats_layers(ClippingStyle::LayerWise, &entries), 2048.0);
        // frozen conv: the linear (sole group member) finalizes at its
        // own index, so the pool frontier alone dominates
        let mut frozen = entries.clone();
        frozen[0].trainable = false;
        assert_eq!(bk_gcache_floats_layers(ClippingStyle::AllLayer, &frozen), 2048.0);
        // nothing trainable: nothing book-kept
        let dead: Vec<GcacheLayer> =
            entries.iter().cloned().map(|mut e| { e.trainable = false; e }).collect();
        assert_eq!(bk_gcache_floats_layers(ClippingStyle::AllLayer, &dead), 0.0);
    }

    #[test]
    fn lora_cost_is_adapters_plus_frozen_base_flow() {
        let l = LayerDims {
            kind: LayerKind::Lora { rank: 4 },
            name: "fc".into(),
            t: 16,
            d: 32,
            p: 64,
        };
        let b = 8.0;
        let [a, ad_b] = lora_sublayers(&l);
        let mut base = l.clone();
        base.kind = LayerKind::Linear;
        for s in ALL_STRATEGIES {
            let c = layer_cost(s, b, &l);
            let sub = layer_cost(s, b, &a).time + layer_cost(s, b, &ad_b).time;
            let flow = module_time(Module::Forward, b, &base)
                + module_time(Module::OutputGrad, b, &base) * s.backprops() as f64;
            assert_eq!(c.time, sub + flow, "{s:?}");
            // DP overhead comes only from the adapters — far below the
            // full layer's (Bpd psg / 2BT^2-per-factor Gram) overheads
            assert_eq!(
                c.space_overhead,
                layer_cost(s, b, &a).space_overhead + layer_cost(s, b, &ad_b).space_overhead,
                "{s:?}"
            );
            assert!(c.space_overhead <= layer_cost(s, b, &base).space_overhead, "{s:?}");
        }
        // DP-LoRA time stays well under full DP fine-tuning of the base
        let lora_bk = layer_cost(Strategy::Bk, b, &l).time;
        let full_bk = layer_cost(Strategy::Bk, b, &base).time;
        assert!(lora_bk < full_bk, "{lora_bk} vs {full_bk}");
    }

    #[test]
    fn pos_embedding_cost_is_linear_and_route_free() {
        let l = LayerDims {
            kind: LayerKind::PosEmbedding,
            name: "wpe".into(),
            t: 16,
            d: 32,
            p: 32,
        };
        let b = 8.0;
        let btp = b * 16.0 * 32.0;
        assert_eq!(layer_cost(Strategy::NonDp, b, &l).time, btp + 2.0 * btp);
        for s in ALL_STRATEGIES {
            let c = layer_cost(s, b, &l);
            if s != Strategy::NonDp {
                // fwd + frobenius norm + scatter, identical across DP
                // strategies (the norm has one route)
                assert_eq!(c.time, btp + btp + 2.0 * btp, "{s:?}");
            }
            assert_eq!(c.space_overhead, 0.0, "{s:?}");
        }
    }

    #[test]
    fn attention_cost_decomposes_into_sublayers_plus_core() {
        let l = LayerDims {
            kind: LayerKind::Attention,
            name: "attn".into(),
            t: 64,
            d: 256,
            p: 8, // heads
        };
        let b = 16.0;
        let [qkv, out] = super::attention_sublayers(&l);
        assert_eq!((qkv.d, qkv.p), (256, 768));
        assert_eq!((out.d, out.p), (256, 256));
        for s in ALL_STRATEGIES {
            let c = layer_cost(s, b, &l);
            let sub = layer_cost(s, b, &qkv).time + layer_cost(s, b, &out).time;
            let (t, d) = (64f64, 256f64);
            let core = 4.0 * b * t * t * d + 8.0 * b * t * t * d * s.backprops() as f64;
            assert_eq!(c.time, sub + core, "{s:?}");
            // DP space overhead comes only from the projections
            assert_eq!(
                c.space_overhead,
                layer_cost(s, b, &qkv).space_overhead + layer_cost(s, b, &out).space_overhead,
                "{s:?}"
            );
        }
        // BK on attention stays near non-DP, the headline 1.0x-ish claim
        let ratio = layer_cost(Strategy::Bk, b, &l).time / layer_cost(Strategy::NonDp, b, &l).time;
        assert!(ratio < 1.15, "bk/nondp attention time ratio {ratio}");
    }

    #[test]
    fn attention_gcache_uses_model_width() {
        let attn = LayerDims {
            kind: LayerKind::Attention,
            name: "attn".into(),
            t: 8,
            d: 32,
            p: 4,
        };
        // book-kept output gradient of attention is B*T*d, not B*T*heads
        // (single layer: the frontier is 0 at the bottom of the walk,
        // so fused peak == the one cache == legacy)
        assert_eq!(
            bk_gcache_floats(ClippingStyle::AllLayer, 2.0, std::slice::from_ref(&attn)),
            2.0 * 8.0 * 32.0
        );
        assert_eq!(
            bk_gcache_floats_unfused(2.0, std::slice::from_ref(&attn)),
            2.0 * 8.0 * 32.0
        );
    }

    #[test]
    fn backprop_counts_match_table2() {
        assert_eq!(Strategy::NonDp.backprops(), 1);
        assert_eq!(Strategy::Opacus.backprops(), 1);
        assert_eq!(Strategy::FastGradClip.backprops(), 2);
        assert_eq!(Strategy::GhostClip.backprops(), 2);
        assert_eq!(Strategy::Bk.backprops(), 1);
    }
}
