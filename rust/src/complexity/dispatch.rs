//! Ghost-vs-instantiation dispatch: formula or measured.
//!
//! The paper's mixed strategies pick, per layer, between the ghost-norm
//! route (`O(BT^2(p+d))`, no per-sample gradient) and per-sample
//! instantiation (`O(BTpd)`). The closed-form rule `2T^2 < p*d`
//! compares FLOP counts — but FLOPs are not seconds: the two routes
//! have different arithmetic intensity and memory traffic, so on a real
//! machine the crossover can sit well away from the formula's. A
//! [`DispatchProfile`] holds *measured* seconds-per-FLOP coefficients
//! for each route (calibrated by `runtime::native::autotune` and cached
//! to a JSON profile file), and [`Dispatch::Measured`] weighs the
//! per-layer FLOP counts by them, picking the route that is actually
//! faster on this hardware.
//!
//! Embedding and Norm layers are *not* up for debate in either mode:
//! embeddings always ghost (instantiation is `vocab * p` floats per
//! sample) and norm layers always instantiate their `O(p)` gradients —
//! the same forced routes the backend applies.

use crate::arch::{LayerDims, LayerKind};
use crate::json::Value;

/// Bump when the profile file schema or the calibration workload
/// changes; stale files fall back to the formula with a warning.
pub const PROFILE_VERSION: i64 = 1;

/// Measured per-route cost coefficients (seconds per FLOP), as
/// calibrated on one machine at one thread count.
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchProfile {
    /// Seconds per ghost-norm FLOP (Gram build + Gram dot).
    pub ghost_secs_per_flop: f64,
    /// Seconds per instantiation FLOP (streamed `a^T g` + norm).
    pub inst_secs_per_flop: f64,
    /// Thread count the calibration ran with (informational).
    pub threads: usize,
    /// SIMD ISA the calibration ran with (informational).
    pub isa: String,
}

impl DispatchProfile {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("version", Value::Int(PROFILE_VERSION));
        v.set("ghost_secs_per_flop", Value::Num(self.ghost_secs_per_flop));
        v.set("inst_secs_per_flop", Value::Num(self.inst_secs_per_flop));
        v.set("threads", Value::Int(self.threads as i64));
        v.set("isa", Value::Str(self.isa.clone()));
        v
    }

    /// Parse a cached profile. Errors distinguish a stale version from
    /// a corrupt file only in the message; both mean "do not trust it".
    pub fn from_json(v: &Value) -> Result<DispatchProfile, String> {
        let version = v.req_i64("version")?;
        if version != PROFILE_VERSION {
            return Err(format!(
                "stale dispatch profile (version {version}, expected {PROFILE_VERSION})"
            ));
        }
        let ghost = v.req_f64("ghost_secs_per_flop")?;
        let inst = v.req_f64("inst_secs_per_flop")?;
        if !(ghost.is_finite() && ghost > 0.0 && inst.is_finite() && inst > 0.0) {
            return Err(format!(
                "corrupt dispatch profile (ghost {ghost}, inst {inst}; both must be positive)"
            ));
        }
        Ok(DispatchProfile {
            ghost_secs_per_flop: ghost,
            inst_secs_per_flop: inst,
            threads: v.opt_i64("threads", 0).max(0) as usize,
            isa: v.opt_str("isa", "unknown").to_string(),
        })
    }
}

/// How the mixed strategies route each layer's per-sample norm.
#[derive(Clone, Debug, PartialEq)]
pub enum Dispatch {
    /// The paper's closed-form rule (`ghost_preferred`: `2T^2 < pd`,
    /// attention `2T^2 < d^2`).
    Formula,
    /// Measured per-machine cost model: route = argmin of
    /// coefficient-weighted per-layer module times.
    Measured(DispatchProfile),
}

impl Dispatch {
    /// Route decision for one layer. The batch size cancels from both
    /// sides, so the decision is batch-independent (like the formula).
    pub fn ghost_preferred(&self, l: &LayerDims) -> bool {
        match self {
            Dispatch::Formula => super::ghost_preferred(l),
            Dispatch::Measured(p) => match l.kind {
                LayerKind::Embedding => true,
                LayerKind::Norm => false,
                _ => {
                    let ghost = p.ghost_secs_per_flop
                        * super::module_time(super::Module::GhostNorm, 1.0, l);
                    let inst = p.inst_secs_per_flop
                        * super::module_time(super::Module::PsgInstantiation, 1.0, l);
                    ghost < inst
                }
            },
        }
    }

    /// Short mode name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Dispatch::Formula => "formula",
            Dispatch::Measured(_) => "measured",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear(t: u64, d: u64, p: u64) -> LayerDims {
        LayerDims {
            kind: LayerKind::Linear,
            name: "lin".to_string(),
            t,
            d,
            p,
        }
    }

    #[test]
    fn profile_round_trips_through_json() {
        let p = DispatchProfile {
            ghost_secs_per_flop: 2.5e-10,
            inst_secs_per_flop: 4.0e-10,
            threads: 8,
            isa: "avx2+fma".to_string(),
        };
        let back = DispatchProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn stale_or_corrupt_profiles_are_rejected() {
        let mut stale = DispatchProfile {
            ghost_secs_per_flop: 1e-10,
            inst_secs_per_flop: 1e-10,
            threads: 1,
            isa: "portable".to_string(),
        }
        .to_json();
        stale.set("version", Value::Int(PROFILE_VERSION + 1));
        assert!(DispatchProfile::from_json(&stale).unwrap_err().contains("stale"));

        let mut corrupt = DispatchProfile {
            ghost_secs_per_flop: 1e-10,
            inst_secs_per_flop: 1e-10,
            threads: 1,
            isa: "portable".to_string(),
        }
        .to_json();
        corrupt.set("inst_secs_per_flop", Value::Num(-1.0));
        assert!(DispatchProfile::from_json(&corrupt)
            .unwrap_err()
            .contains("corrupt"));
        assert!(DispatchProfile::from_json(&Value::obj()).is_err());
    }

    #[test]
    fn formula_mode_matches_ghost_preferred() {
        let d = Dispatch::Formula;
        for l in [linear(4, 16, 16), linear(64, 8, 8), linear(1, 100, 100)] {
            assert_eq!(d.ghost_preferred(&l), crate::complexity::ghost_preferred(&l));
        }
    }

    #[test]
    fn measured_profile_can_flip_the_formula_route() {
        // t=4, d=p=16: 2T^2 = 32 < 256 = pd, so the formula says ghost.
        let l = linear(4, 16, 16);
        assert!(crate::complexity::ghost_preferred(&l));
        // A machine where ghost FLOPs are 100x more expensive than
        // instantiation FLOPs flips the route...
        let slow_ghost = Dispatch::Measured(DispatchProfile {
            ghost_secs_per_flop: 1e-8,
            inst_secs_per_flop: 1e-10,
            threads: 1,
            isa: "portable".to_string(),
        });
        assert!(!slow_ghost.ghost_preferred(&l));
        // ...while equal coefficients reduce to the FLOP comparison,
        // which agrees with the formula here.
        let neutral = Dispatch::Measured(DispatchProfile {
            ghost_secs_per_flop: 1e-10,
            inst_secs_per_flop: 1e-10,
            threads: 1,
            isa: "portable".to_string(),
        });
        assert!(neutral.ghost_preferred(&l));
    }

    #[test]
    fn measured_keeps_the_forced_routes() {
        let inst_biased = Dispatch::Measured(DispatchProfile {
            ghost_secs_per_flop: 1e-6,
            inst_secs_per_flop: 1e-12,
            threads: 1,
            isa: "portable".to_string(),
        });
        let emb = LayerDims {
            kind: LayerKind::Embedding,
            name: "emb".to_string(),
            t: 8,
            d: 1,
            p: 32,
        };
        let norm = LayerDims {
            kind: LayerKind::Norm,
            name: "ln".to_string(),
            t: 8,
            d: 32,
            p: 64,
        };
        assert!(inst_biased.ghost_preferred(&emb));
        assert!(!inst_biased.ghost_preferred(&norm));
    }
}
