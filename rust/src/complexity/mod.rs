//! The paper's modular complexity analysis (Section 2.2, Tables 2/3/5/8).
//!
//! Every DP implementation decomposes into the modules of Table 3:
//!   (1)  forward pass                 time 2BTpd   space pd + BTd
//!   (2a) output gradient              time 2BTpd   space BT(p+d)
//!   (2b) parameter gradient           time 2BTpd   space pd
//!   (3)  ghost norm                   time 2BT^2(p+d)  space 2BT^2
//!   (4)  per-sample grad instantiation time 2BTpd  space Bpd
//!   (5)  weighted sum of psg          time 2Bpd    space 0
//!
//! The engine evaluates those formulas per layer, applies the paper's
//! layerwise decision (ghost iff 2T^2 < pd) for the hybrid algorithms,
//! and aggregates over a model — exactly regenerating Tables 2, 3, 4, 5,
//! 8, 10 and the layerwise series behind Figures 7 and 10-19.

pub mod dispatch;
pub mod strategy;

use crate::arch::{LayerDims, LayerKind};

pub use dispatch::{Dispatch, DispatchProfile};
pub use strategy::{
    bk_gcache_floats, bk_gcache_floats_layers, bk_gcache_floats_masked, bk_gcache_floats_unfused,
    clip_state_floats, layer_cost, ClippingStyle, GcacheLayer, Strategy, ALL_STRATEGIES,
};

/// Time cost (multiply-accumulate*2, matching the paper's 2BTpd counting)
/// of one module on one layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Module {
    Forward,
    OutputGrad,
    ParamGrad,
    GhostNorm,
    PsgInstantiation,
    WeightedSum,
}

/// The two generalized-linear sublayers of a causal self-attention
/// layer (`LayerKind::Attention`, dims d = model width, p = heads): the
/// fused QKV projection `d -> 3d` and the output projection `d -> d`.
/// Module formulas sum over them; the parameter-free softmax core is
/// costed separately in [`strategy::layer_cost`].
pub fn attention_sublayers(l: &LayerDims) -> [LayerDims; 2] {
    [
        LayerDims {
            kind: LayerKind::Linear,
            name: format!("{}.qkv", l.name),
            t: l.t,
            d: l.d,
            p: 3 * l.d,
        },
        LayerDims {
            kind: LayerKind::Linear,
            name: format!("{}.out", l.name),
            t: l.t,
            d: l.d,
            p: l.d,
        },
    ]
}

/// The two trainable adapter sublayers of a LoRA linear
/// (`LayerKind::Lora`, dims d/p = the base projection, rank r from the
/// kind): `A: d -> r` fed by the layer input and `B: r -> p` fed by the
/// cached `h = x·A`. Module formulas sum over them; the frozen base
/// contributes only forward + output-gradient time, added in
/// [`strategy::layer_cost`].
pub fn lora_sublayers(l: &LayerDims) -> [LayerDims; 2] {
    let LayerKind::Lora { rank } = l.kind else {
        unreachable!("lora_sublayers on {:?}", l.kind);
    };
    [
        LayerDims {
            kind: LayerKind::Linear,
            name: format!("{}.lora_a", l.name),
            t: l.t,
            d: l.d,
            p: rank,
        },
        LayerDims {
            kind: LayerKind::Linear,
            name: format!("{}.lora_b", l.name),
            t: l.t,
            d: rank,
            p: l.p,
        },
    ]
}

/// f64 everywhere: counts overflow u64 at ImageNet scale (2BT^2 with
/// T = 224^2 and B = 100 is ~5e14 per layer).
pub fn module_time(m: Module, b: f64, l: &LayerDims) -> f64 {
    if l.kind == LayerKind::Attention {
        return attention_sublayers(l).iter().map(|s| module_time(m, b, s)).sum();
    }
    if matches!(l.kind, LayerKind::Lora { .. }) {
        return lora_sublayers(l).iter().map(|s| module_time(m, b, s)).sum();
    }
    if l.kind == LayerKind::PosEmbedding {
        // row-add forward, identity backward, plain Frobenius norm,
        // position-wise scatter sum: every module is O(BTp) — the table
        // rows never collide, so there are no Grams and nothing to
        // instantiate beyond the gradient already in hand
        let (t, p) = (l.t as f64, l.p as f64);
        return match m {
            Module::Forward | Module::GhostNorm | Module::PsgInstantiation => b * t * p,
            Module::OutputGrad => 0.0,
            Module::ParamGrad | Module::WeightedSum => 2.0 * b * t * p,
        };
    }
    let (t, d, p) = (l.t as f64, l.d as f64, l.p as f64);
    match m {
        Module::Forward | Module::OutputGrad | Module::ParamGrad | Module::PsgInstantiation => {
            2.0 * b * t * p * d
        }
        Module::GhostNorm => match l.kind {
            // embedding ghost norm has no activation Gram (token equality
            // mask): 2BT^2 p + BT^2
            LayerKind::Embedding => 2.0 * b * t * t * p + b * t * t,
            // tied head: its own Grams plus the O(T^2 d) ghost cross
            // term against the owning embedding (2<G_emb, G_head>)
            LayerKind::TiedLinear => 2.0 * b * t * t * (p + d) + 2.0 * b * t * t * d,
            _ => 2.0 * b * t * t * (p + d),
        },
        Module::WeightedSum => 2.0 * b * p * d,
    }
}

pub fn module_space(m: Module, b: f64, l: &LayerDims) -> f64 {
    if l.kind == LayerKind::Attention {
        return attention_sublayers(l).iter().map(|s| module_space(m, b, s)).sum();
    }
    if matches!(l.kind, LayerKind::Lora { .. }) {
        return lora_sublayers(l).iter().map(|s| module_space(m, b, s)).sum();
    }
    if l.kind == LayerKind::PosEmbedding {
        let (t, d, p) = (l.t as f64, l.d as f64, l.p as f64);
        return match m {
            Module::Forward => t * p + b * t * d,
            Module::OutputGrad => b * t * (p + d),
            Module::ParamGrad => t * p,
            // the norm is an in-place Frobenius reduction and the sum a
            // scatter into the grad table: no Grams, no per-sample slabs
            Module::GhostNorm | Module::PsgInstantiation | Module::WeightedSum => 0.0,
        };
    }
    let (t, d, p) = (l.t as f64, l.d as f64, l.p as f64);
    match m {
        Module::Forward => p * d + b * t * d,
        Module::OutputGrad => b * t * (p + d),
        Module::ParamGrad => p * d,
        Module::GhostNorm => 2.0 * b * t * t,
        Module::PsgInstantiation => b * p * d,
        Module::WeightedSum => 0.0,
    }
}

/// The paper's layerwise decision (Section 3.2): ghost norm iff
/// 2T^2 < p*d. Norm layers always instantiate (tiny params); embeddings
/// always ghost (instantiation is V*p per sample).
pub fn ghost_preferred(l: &LayerDims) -> bool {
    match l.kind {
        LayerKind::Embedding => true,
        LayerKind::Norm => false,
        // both routes are the same Frobenius reduction (rows never
        // collide); call it ghost so measured dispatch never "learns"
        // a preference from noise
        LayerKind::PosEmbedding => true,
        // one route for the whole layer; the narrowest trainable factor
        // — the rank-r adapter against min(d, p) — decides
        LayerKind::Lora { rank } => {
            2.0 * (l.t as f64) * (l.t as f64) < (rank as f64) * (l.d.min(l.p) as f64)
        }
        // one route for the whole attention layer; the narrower output
        // projection (pd = d^2) decides, so instantiation is never
        // picked while a sublayer would still prefer ghost by a wide
        // margin (the QKV sublayer's pd is only 3x larger)
        LayerKind::Attention => 2.0 * (l.t as f64) * (l.t as f64) < (l.d as f64) * (l.d as f64),
        _ => 2.0 * (l.t as f64) * (l.t as f64) < (l.p as f64) * (l.d as f64),
    }
}

/// Space complexity of computing ONE layer's per-sample grad norm under
/// the mixed ghost norm (Table 4 / Table 10 / Figures 7, 10-19).
pub fn norm_space_ghost(b: f64, l: &LayerDims) -> f64 {
    module_space(Module::GhostNorm, b, l)
}

pub fn norm_space_inst(b: f64, l: &LayerDims) -> f64 {
    module_space(Module::PsgInstantiation, b, l)
}

pub fn norm_space_mixed(b: f64, l: &LayerDims) -> f64 {
    norm_space_ghost(b, l).min(norm_space_inst(b, l))
}

/// Per-layer time/space of a full DP implementation (Table 5 row).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub time: f64,
    /// Extra space on top of non-DP training (the paper's convention).
    pub space_overhead: f64,
}

impl Cost {
    pub fn add(&mut self, other: Cost) {
        self.time += other.time;
        self.space_overhead += other.space_overhead;
    }
}

/// Whole-model cost under a strategy (Table 8).
#[derive(Clone, Debug, Default)]
pub struct ModelCost {
    pub time: f64,
    /// Peak space including weights + activations (Table 8 lower half).
    pub space: f64,
    /// Non-DP baseline for ratio reporting.
    pub nondp_time: f64,
    pub nondp_space: f64,
}

impl ModelCost {
    pub fn time_ratio(&self) -> f64 {
        self.time / self.nondp_time
    }

    pub fn space_ratio(&self) -> f64 {
        self.space / self.nondp_space
    }
}

/// Activation/weight space shared by every implementation (Table 8:
/// sum_l pd + B sum_l T(3d + p); the B-independent pd term is the weights).
pub fn base_space(b: f64, layers: &[LayerDims]) -> f64 {
    let weights: f64 = layers
        .iter()
        .map(|l| match l.kind {
            LayerKind::Attention => 4.0 * (l.d * l.d) as f64,
            // keyed on canonical tensors: a tied head's weight slab is
            // the owning embedding's, already counted there
            LayerKind::TiedLinear => 0.0,
            // the (t, p) position table
            LayerKind::PosEmbedding => (l.t * l.p) as f64,
            // frozen base W (d, p) + the two adapters (biases are not
            // counted anywhere in this table)
            LayerKind::Lora { rank } => {
                (l.d * l.p) as f64 + (rank * (l.d + l.p)) as f64
            }
            _ => (l.p * l.d) as f64,
        })
        .sum();
    let acts: f64 = layers
        .iter()
        .map(|l| {
            let (t, d, p) = (l.t as f64, l.d as f64, l.p as f64);
            match l.kind {
                // qkv (3d) + ao (d) activations plus the B*H*T^2
                // softmax cache every implementation keeps
                LayerKind::Attention => b * t * (3.0 * d + d) + b * p * t * t,
                // plain linear activations plus the cached h = x·A and
                // the adapter-path forward temp
                LayerKind::Lora { rank } => {
                    b * t * (3.0 * d + p) + b * t * (rank as f64 + p)
                }
                _ => b * t * (3.0 * d + p),
            }
        })
        .sum();
    weights + acts
}

/// Evaluate a strategy over a whole model (Table 8 rows).
pub fn model_cost(strategy: Strategy, b: f64, layers: &[LayerDims]) -> ModelCost {
    let mut time = 0.0;
    let mut overhead = 0.0;
    for l in layers {
        let c = strategy::layer_cost(strategy, b, l);
        time += c.time;
        overhead += c.space_overhead;
    }
    let nondp_time: f64 = layers
        .iter()
        .map(|l| strategy::layer_cost(Strategy::NonDp, b, l).time)
        .sum();
    let base = base_space(b, layers);
    ModelCost {
        time,
        space: base + overhead,
        nondp_time,
        nondp_space: base,
    }
}

/// Memory prediction for the data-parallel sharded driver (`--shards`).
///
/// Sharding is at micro-batch granularity, so *per-shard* peaks are
/// unchanged from the 1-shard run: each shard runs the same fused
/// schedule over whole physical micro-batches, and its peak g-cache is
/// exactly the 1-shard `bk_gcache_floats` prediction (same for the
/// arena peak). What scales with N is replica state — every shard owns
/// a full copy of the parameters (+ Adam moments) and its own arena —
/// plus the rank-0 reduction's in-flight gradient sets.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ShardedSpace {
    pub shards: usize,
    /// Model + optimizer state floats of ONE replica (P, or 3P under
    /// Adam).
    pub replica_state_floats: f64,
    /// Peak g-cache floats of ONE shard — identical to the 1-shard
    /// prediction, because the physical micro-batch is unchanged.
    pub per_shard_gcache_floats: f64,
    /// Worst-case in-flight floats of the rank-0 reduction: the fold
    /// accumulator plus every not-yet-merged micro-batch gradient set
    /// (each P floats). Bounded by `(K + 1) * P` for K micro-batches;
    /// `2 * P` when the fold is sequential (N == 1 or K == 1), matching
    /// the plain gradient-accumulation path.
    pub reduction_inflight_floats: f64,
    /// Predicted total: `N * (state + g-cache)` + reduction in-flight.
    pub total_floats: f64,
}

/// Predict sharded-run memory from the per-replica numbers.
/// `param_floats` is the trainable-parameter float count P (one
/// gradient set is P floats), `per_shard_gcache` the 1-shard
/// `bk_gcache_floats` prediction for the model/strategy/style.
pub fn sharded_space(
    shards: usize,
    micro_batches: usize,
    param_floats: f64,
    adam: bool,
    per_shard_gcache: f64,
) -> ShardedSpace {
    let n = shards.max(1);
    let k = micro_batches.max(1);
    let state = if adam { 3.0 * param_floats } else { param_floats };
    let inflight = if n == 1 || k == 1 {
        2.0 * param_floats
    } else {
        (k as f64 + 1.0) * param_floats
    };
    ShardedSpace {
        shards: n,
        replica_state_floats: state,
        per_shard_gcache_floats: per_shard_gcache,
        reduction_inflight_floats: inflight,
        total_floats: n as f64 * (state + per_shard_gcache) + inflight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{LayerDims, LayerKind};

    fn lin(t: u64, d: u64, p: u64) -> LayerDims {
        LayerDims {
            kind: LayerKind::Linear,
            name: "l".into(),
            t,
            d,
            p,
        }
    }

    #[test]
    fn module_formulas_match_table3() {
        let l = lin(10, 20, 30);
        let b = 4.0;
        assert_eq!(module_time(Module::Forward, b, &l), 2.0 * 4.0 * 10.0 * 30.0 * 20.0);
        assert_eq!(module_time(Module::GhostNorm, b, &l), 2.0 * 4.0 * 100.0 * 50.0);
        assert_eq!(module_time(Module::WeightedSum, b, &l), 2.0 * 4.0 * 600.0);
        assert_eq!(module_space(Module::GhostNorm, b, &l), 2.0 * 4.0 * 100.0);
        assert_eq!(module_space(Module::PsgInstantiation, b, &l), 4.0 * 600.0);
    }

    #[test]
    fn decision_threshold() {
        // 2T^2 < pd: T=10 -> 200 < 600 => ghost
        assert!(ghost_preferred(&lin(10, 20, 30)));
        // T=100 -> 20000 > 600 => instantiate
        assert!(!ghost_preferred(&lin(100, 20, 30)));
        // embedding always ghost even with huge T
        let emb = LayerDims {
            kind: LayerKind::Embedding,
            name: "e".into(),
            t: 10_000,
            d: 50_000,
            p: 768,
        };
        assert!(ghost_preferred(&emb));
    }

    #[test]
    fn mixed_is_min() {
        for l in [lin(1, 512, 512), lin(3136, 576, 64)] {
            let m = norm_space_mixed(8.0, &l);
            assert_eq!(m, norm_space_ghost(8.0, &l).min(norm_space_inst(8.0, &l)));
            assert!(m <= norm_space_ghost(8.0, &l));
            assert!(m <= norm_space_inst(8.0, &l));
        }
    }

    #[test]
    fn attention_modules_sum_over_projections() {
        let l = LayerDims {
            kind: LayerKind::Attention,
            name: "attn".into(),
            t: 16,
            d: 32,
            p: 4, // heads
        };
        let b = 4.0;
        // forward time: QKV 2BTd(3d) + out 2BTdd = 8BTd^2
        assert_eq!(
            module_time(Module::Forward, b, &l),
            8.0 * b * 16.0 * 32.0 * 32.0
        );
        // ghost norm: 2BT^2(d + 3d) + 2BT^2(d + d) = 12 BT^2 d
        assert_eq!(
            module_time(Module::GhostNorm, b, &l),
            12.0 * b * 256.0 * 32.0
        );
        // per-sample instantiation space: B(3d^2 + d^2)
        assert_eq!(
            module_space(Module::PsgInstantiation, b, &l),
            4.0 * b * 32.0 * 32.0
        );
        // short sequences ghost (2T^2 = 512 < d^2 = 1024), long don't
        assert!(ghost_preferred(&l));
        let mut long = l.clone();
        long.t = 64;
        assert!(!ghost_preferred(&long));
        // base space counts 4d^2 weights + qkv/ao acts + the probs cache
        let base = base_space(b, std::slice::from_ref(&l));
        assert_eq!(
            base,
            4.0 * 1024.0 + b * 16.0 * 4.0 * 32.0 + b * 4.0 * 256.0
        );
    }

    #[test]
    fn tied_linear_counts_weights_once_but_costs_like_linear() {
        let tied = LayerDims {
            kind: LayerKind::TiedLinear,
            name: "lm_head".into(),
            t: 16,
            d: 32,
            p: 64, // vocab
        };
        let mut plain = tied.clone();
        plain.kind = LayerKind::Linear;
        let b = 4.0;
        // identical forward/psg/weighted-sum costs...
        for m in [Module::Forward, Module::OutputGrad, Module::ParamGrad,
                  Module::PsgInstantiation, Module::WeightedSum] {
            assert_eq!(module_time(m, b, &tied), module_time(m, b, &plain));
            assert_eq!(module_space(m, b, &tied), module_space(m, b, &plain));
        }
        // ...plus the 2BT^2 d ghost cross term against the embedding
        assert_eq!(
            module_time(Module::GhostNorm, b, &tied),
            module_time(Module::GhostNorm, b, &plain) + 2.0 * b * 256.0 * 32.0
        );
        assert_eq!(ghost_preferred(&tied), ghost_preferred(&plain));
        // base space: the weight slab is the embedding's, counted once
        let base_tied = base_space(b, std::slice::from_ref(&tied));
        let base_plain = base_space(b, std::slice::from_ref(&plain));
        assert_eq!(base_plain - base_tied, (32 * 64) as f64);
    }

    #[test]
    fn pos_embedding_is_linear_time_no_grams() {
        let l = LayerDims {
            kind: LayerKind::PosEmbedding,
            name: "wpe".into(),
            t: 16,
            d: 32,
            p: 32,
        };
        let b = 4.0;
        // every module is O(BTp); the norm has no Gram space at all
        assert_eq!(module_time(Module::Forward, b, &l), b * 16.0 * 32.0);
        assert_eq!(module_time(Module::GhostNorm, b, &l), b * 16.0 * 32.0);
        assert_eq!(module_time(Module::WeightedSum, b, &l), 2.0 * b * 16.0 * 32.0);
        assert_eq!(module_time(Module::OutputGrad, b, &l), 0.0);
        assert_eq!(module_space(Module::GhostNorm, b, &l), 0.0);
        assert_eq!(module_space(Module::PsgInstantiation, b, &l), 0.0);
        assert!(ghost_preferred(&l));
        // weights in base_space are the (t, p) table
        let base = base_space(b, std::slice::from_ref(&l));
        assert_eq!(base, (16 * 32) as f64 + b * 16.0 * (3.0 * 32.0 + 32.0));
    }

    #[test]
    fn lora_modules_sum_over_adapters() {
        let l = LayerDims {
            kind: LayerKind::Lora { rank: 4 },
            name: "fc".into(),
            t: 16,
            d: 32,
            p: 64,
        };
        let b = 4.0;
        let [a, bb] = lora_sublayers(&l);
        assert_eq!((a.d, a.p), (32, 4));
        assert_eq!((bb.d, bb.p), (4, 64));
        for m in [Module::Forward, Module::GhostNorm, Module::PsgInstantiation,
                  Module::WeightedSum] {
            assert_eq!(
                module_time(m, b, &l),
                module_time(m, b, &a) + module_time(m, b, &bb)
            );
        }
        // skinny adapters: ghost wins only below 2T^2 = rank*min(d,p)
        assert!(!ghost_preferred(&l)); // 512 > 4*32
        let mut short = l.clone();
        short.t = 4;
        assert!(ghost_preferred(&short)); // 32 < 128
        // weights: frozen base d*p + adapters r*(d+p), counted once
        let base = base_space(b, std::slice::from_ref(&l));
        let weights = (32 * 64 + 4 * (32 + 64)) as f64;
        let acts = b * 16.0 * (3.0 * 32.0 + 64.0) + b * 16.0 * (4.0 + 64.0);
        assert_eq!(base, weights + acts);
    }

    #[test]
    fn resnet_conv1_matches_paper_table4() {
        // conv1 of ResNet @224^2: T = 112^2, d = 3*7*7, p = 64
        let l = LayerDims {
            kind: LayerKind::Conv,
            name: "conv1".into(),
            t: 112 * 112,
            d: 147,
            p: 64,
        };
        // paper: 2T^2 = 3.1e8, pd = 9.4e3 (B = 1)
        assert!((norm_space_ghost(1.0, &l) - 3.148e8).abs() / 3.148e8 < 0.01);
        assert_eq!(norm_space_inst(1.0, &l), 9408.0);
        assert!(!ghost_preferred(&l));
    }

    #[test]
    fn sharded_space_per_shard_peaks_are_shard_independent() {
        // The per-shard g-cache prediction never changes with N — the
        // physical micro-batch is unchanged; only replica count scales.
        let p = 1000.0;
        let g = 250.0;
        for n in [1usize, 2, 4, 7] {
            let s = sharded_space(n, 8, p, false, g);
            assert_eq!(s.per_shard_gcache_floats, g);
            assert_eq!(s.replica_state_floats, p);
        }
        let adam = sharded_space(2, 8, p, true, g);
        assert_eq!(adam.replica_state_floats, 3.0 * p);
    }

    #[test]
    fn sharded_space_totals_scale_with_replicas() {
        let p = 1000.0;
        let g = 250.0;
        // N = 1 reduces to the plain accumulation bound: state + cache
        // + a 2P fold.
        let one = sharded_space(1, 8, p, false, g);
        assert_eq!(one.reduction_inflight_floats, 2.0 * p);
        assert_eq!(one.total_floats, p + g + 2.0 * p);
        // N > 1, K micro-batches: (K+1)*P in-flight worst case, N
        // replicas of state + cache.
        let four = sharded_space(4, 8, p, false, g);
        assert_eq!(four.reduction_inflight_floats, 9.0 * p);
        assert_eq!(four.total_floats, 4.0 * (p + g) + 9.0 * p);
        // K = 1 is sequential on rank 0 even with many shards.
        let idle = sharded_space(4, 1, p, false, g);
        assert_eq!(idle.reduction_inflight_floats, 2.0 * p);
        // monotone in N
        assert!(four.total_floats > one.total_floats);
        // shards = 0 clamps to 1
        assert_eq!(sharded_space(0, 8, p, false, g), one);
    }
}
