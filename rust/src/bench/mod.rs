//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations of training steps, with *per-process* peak-RSS isolation.
//!
//! Memory attribution problem: allocators retain arenas, so measuring
//! several strategies in one process smears their footprints. Solution:
//! the CLI re-execs itself once per (model, strategy, style) with
//! `FASTDP_BENCH_CHILD=<model>:<strategy>:<warmup>:<iters>:<threads>:<shards>:<style>`
//! (plus `FASTDP_BENCH_TRAINABLE=<preset>` when a trainability
//! override is in play); the child measures, prints one JSON line, and
//! exits; the parent
//! aggregates into the paper-style table and (with `--json`) writes
//! `BENCH_native_kernels.json` so the perf trajectory is tracked across
//! PRs.
//!
//! The native measurement additionally reports the arena's steady-state
//! allocation count — 0 once warm, the flat-memory invariant.

use crate::complexity::{ClippingStyle, Strategy};
use crate::data;
use crate::error::Result;
use crate::json::Value;
use crate::runtime::native::{model::NativeSpec, par, NativeBackend};
use crate::runtime::{Backend, BatchX, StepHyper};
use crate::util::stats::{fmt_bytes, fmt_count, fmt_duration, peak_rss_bytes, Summary};
use crate::util::table::Table;
use crate::{anyhow, bail};
use std::time::Instant;

pub const CHILD_ENV: &str = "FASTDP_BENCH_CHILD";
/// Trainability preset for the bench child ("" / unset = the registry
/// default). A separate env var because preset syntax (`lora:4`,
/// `mask:a,b`) would collide with the `:`-separated `CHILD_ENV` spec.
pub const CHILD_TRAINABLE_ENV: &str = "FASTDP_BENCH_TRAINABLE";

/// Result of benchmarking one (model, strategy, clipping style) triple.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub model: String,
    pub strategy: String,
    /// Clipping style ("all-layer" unless overridden via `--styles`).
    pub style: String,
    pub batch: usize,
    /// Tokens per sample (the paper's T) — disambiguates transformer
    /// rows whose cost is quadratic in T.
    pub seq_len: usize,
    /// Attention heads (0 for models without attention layers).
    pub heads: usize,
    /// Vocab head tied to the embedding (`lm_head = wte^T`); rows from
    /// JSON written before the field existed parse as untied.
    pub tied: bool,
    pub threads: usize,
    /// Data-parallel worker shards per logical step (1 = the plain
    /// single-worker backend). Sharded rows time one logical step of
    /// `shards` micro-batches — one per shard — so the fan-out and the
    /// rank-0 reduction are on the measured path. Rows from JSON
    /// written before the field existed parse as `shards: 1`.
    pub shards: usize,
    pub mean_step_secs: f64,
    /// Median step time — the statistic the regression gate bands
    /// against (robust to scheduler spikes on shared CI runners). Rows
    /// from JSON written before the field existed parse as 0.0 =
    /// unpinned, which falls back to the legacy mean band.
    pub median_step_secs: f64,
    pub min_step_secs: f64,
    /// Useful-arithmetic throughput: the complexity engine's analytic
    /// FLOP count for this (strategy, model) divided by the median step
    /// time. 0.0 when unmeasured (legacy rows, PJRT).
    pub gflops: f64,
    pub samples_per_sec: f64,
    pub peak_rss: u64,
    /// Arena pool misses in the last warm step (0 = flat memory).
    pub steady_allocs: usize,
    /// Measured peak g-cache floats of the fused BK walk (frontier +
    /// live book-kept output gradients); 0 for two-pass / nondp rows.
    pub peak_gcache_floats_measured: usize,
    /// `complexity::bk_gcache_floats` prediction for the same
    /// (model, style) — the fused-schedule walk simulation. Must match
    /// the measured value (the bench-regression CI gate enforces it).
    pub peak_gcache_floats_predicted: f64,
    /// Legacy hold-everything peak (`bk_gcache_floats_unfused`) — the
    /// baseline the fused saving is reported against.
    pub peak_gcache_floats_unfused: f64,
    /// Arena high-water mark (floats checked out) of the last step.
    pub arena_peak_floats: usize,
    /// Canonical trainability preset of the measured run ("all",
    /// "bias-only", "lora:<rank>", "mask:<layers>"). Rows from JSON
    /// written before the trainability plane parse as fully trainable.
    pub peft: String,
    /// Trainable fraction of the canonical parameter census (1.0 for
    /// full fine-tuning). Legacy rows parse as 1.0.
    pub trainable_frac: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("model", Value::from(self.model.as_str()))
            .set("strategy", Value::from(self.strategy.as_str()))
            .set("style", Value::from(self.style.as_str()))
            .set("batch", Value::from(self.batch))
            .set("seq_len", Value::from(self.seq_len))
            .set("heads", Value::from(self.heads))
            .set("tied", Value::from(self.tied))
            .set("threads", Value::from(self.threads))
            .set("shards", Value::from(self.shards))
            .set("mean_step_secs", Value::from(self.mean_step_secs))
            .set("median_step_secs", Value::from(self.median_step_secs))
            .set("min_step_secs", Value::from(self.min_step_secs))
            .set("gflops", Value::from(self.gflops))
            .set("samples_per_sec", Value::from(self.samples_per_sec))
            .set("peak_rss", Value::from(self.peak_rss as f64))
            .set("steady_allocs", Value::from(self.steady_allocs))
            .set(
                "peak_gcache_floats_measured",
                Value::from(self.peak_gcache_floats_measured),
            )
            .set(
                "peak_gcache_floats_predicted",
                Value::from(self.peak_gcache_floats_predicted),
            )
            .set(
                "peak_gcache_floats_unfused",
                Value::from(self.peak_gcache_floats_unfused),
            )
            .set("arena_peak_floats", Value::from(self.arena_peak_floats))
            .set("peft", Value::from(self.peft.as_str()))
            .set("trainable_frac", Value::from(self.trainable_frac));
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(BenchResult {
            model: v.req_str("model").map_err(|e| anyhow!(e))?.to_string(),
            strategy: v.req_str("strategy").map_err(|e| anyhow!(e))?.to_string(),
            style: v.opt_str("style", "all-layer").to_string(),
            batch: v.req_i64("batch").map_err(|e| anyhow!(e))? as usize,
            // pre-attention JSON (no seq_len/heads) defaults to T = 1, no heads
            seq_len: v.opt_i64("seq_len", 1) as usize,
            heads: v.opt_i64("heads", 0) as usize,
            // pre-tying JSON (no tied field) defaults to untied
            tied: v.opt_bool("tied", false),
            threads: v.opt_i64("threads", 1) as usize,
            // pre-sharding JSON (no shards field) parses as single-worker
            shards: v.opt_i64("shards", 1) as usize,
            mean_step_secs: v.req_f64("mean_step_secs").map_err(|e| anyhow!(e))?,
            // pre-statistical-gate JSON (no median/gflops) parses as
            // unpinned median + unmeasured throughput
            median_step_secs: v.opt_f64("median_step_secs", 0.0),
            min_step_secs: v.req_f64("min_step_secs").map_err(|e| anyhow!(e))?,
            gflops: v.opt_f64("gflops", 0.0),
            samples_per_sec: v.req_f64("samples_per_sec").map_err(|e| anyhow!(e))?,
            peak_rss: v.req_f64("peak_rss").map_err(|e| anyhow!(e))? as u64,
            steady_allocs: v.opt_i64("steady_allocs", 0) as usize,
            // pre-fusion JSON (no peak fields) defaults to 0 = unmeasured
            peak_gcache_floats_measured: v.opt_i64("peak_gcache_floats_measured", 0) as usize,
            peak_gcache_floats_predicted: v.opt_f64("peak_gcache_floats_predicted", 0.0),
            peak_gcache_floats_unfused: v.opt_f64("peak_gcache_floats_unfused", 0.0),
            arena_peak_floats: v.opt_i64("arena_peak_floats", 0) as usize,
            // pre-trainability JSON (no peft fields) parses as a full
            // fine-tune, so old baselines keep their row identity
            peft: v.opt_str("peft", "all").to_string(),
            trainable_frac: v.opt_f64("trainable_frac", 1.0),
        })
    }
}

/// Measure one (model, strategy, clipping style, shards) native step in
/// THIS process. `shards == 1` times the fused single-worker step;
/// `shards > 1` times one logical step of `shards` micro-batches (one
/// per shard) through the `ShardedRun` fan-out + rank-0 reduction +
/// broadcast update — the reduction is on the measured path.
/// `trainable` overrides the registry trainability preset ("" keeps
/// it, so LoRA registry variants bench their own adapters by default).
#[allow(clippy::too_many_arguments)]
pub fn measure_native(
    model: &str,
    strategy: &str,
    style: &str,
    warmup: usize,
    iters: usize,
    threads: usize,
    shards: usize,
    trainable: &str,
) -> Result<BenchResult> {
    let mut spec = NativeSpec::by_name(model)
        .ok_or_else(|| anyhow!("model '{model}' not in the native registry"))?;
    if !trainable.is_empty() {
        spec.trainable = trainable.to_string();
    }
    // validate the preset up front (backend construction would refuse
    // it too, but with less context in a bench child's stderr)
    let preset = spec
        .trainable_preset()
        .map_err(|e| anyhow!("model '{model}': {e}"))?
        .canonical();
    let strat = Strategy::parse(strategy).ok_or_else(|| anyhow!("unknown strategy '{strategy}'"))?;
    let cstyle = ClippingStyle::parse(style)
        .ok_or_else(|| anyhow!("unknown clipping style '{style}'"))?;
    let threads = if threads == 0 { par::default_threads() } else { threads };
    let shards = shards.max(1);
    let mut be: Box<dyn Backend> = if shards > 1 {
        Box::new(crate::runtime::native::shard::ShardedRun::new(
            spec.clone(),
            strat,
            cstyle,
            threads,
            &crate::complexity::Dispatch::Formula,
            shards,
        )?)
    } else {
        Box::new(NativeBackend::builder(spec.clone(), strat).style(cstyle).threads(threads).build()?)
    };
    be.init(0)?;

    // one micro-batch per shard, so every replica computes each step
    let micro = shards;
    let rows = spec.batch * spec.seq;
    let batches: Vec<(BatchX, Vec<i32>)> = if spec.vocab > 0 {
        let mut corpus = data::TokenCorpus::new(spec.vocab, spec.seq, 11);
        (0..micro)
            .map(|_| {
                let (xs, ys) = corpus.sample_batch(spec.batch);
                (BatchX::I32(xs), ys)
            })
            .collect()
    } else {
        let mut ds = data::VectorDataset::new(spec.d_in, spec.n_classes, 2.0, 11);
        (0..micro)
            .map(|_| {
                let (xs, ys) = ds.sample_batch(rows);
                (BatchX::F32(xs), ys)
            })
            .collect()
    };
    let dp = strat != Strategy::NonDp;
    let noise: Vec<Vec<f32>> = if dp {
        let mut ns = crate::coordinator::noise::NoiseSource::new(5);
        ns.tensors(be.info())
    } else {
        Vec::new()
    };
    let h = StepHyper {
        lr: 1e-3,
        clip: 1.0,
        sigma_r: if dp { 0.5 } else { 0.0 },
        logical_batch: (spec.batch * micro) as f32,
        step: 1.0,
    };
    let mut run_step = |be: &mut Box<dyn Backend>| -> Result<f32> {
        if shards == 1 {
            let (x, y) = &batches[0];
            Ok(be.step(x, y, &noise, &h)?.loss)
        } else {
            let (grads, out) = be.sharded_grads(&batches, h.clip)?;
            be.apply_update(&grads, &noise, &h)?;
            Ok(out.loss)
        }
    };

    for _ in 0..warmup.max(1) {
        run_step(&mut be)?;
    }
    let mut s = Summary::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        let loss = run_step(&mut be)?;
        s.push(t0.elapsed().as_secs_f64());
        if !loss.is_finite() {
            bail!("{model}/{strategy}: loss diverged during bench");
        }
    }
    // Read after the timed loop: even with warmup == 1 (the cold step),
    // the last timed iteration ran against a saturated arena pool.
    let stats = be.alloc_stats();
    let steady_allocs = stats.fresh_allocs_last_step;
    // g-cache accounting: measured by the fused walk's gauge, predicted
    // by the complexity engine's walk simulation over the plan-derived
    // element counts (frozen/stateless layers are pure frontier
    // transitions; conv trunks carry their real activation widths) —
    // only the one-pass DP strategies book-keep output gradients
    let (predicted, unfused) = if strat != Strategy::NonDp && strat.backprops() == 1 {
        (
            crate::complexity::bk_gcache_floats_layers(cstyle, &spec.gcache_layers()),
            crate::complexity::bk_gcache_floats_unfused(spec.batch as f64, &spec.arch_layers()),
        )
    } else {
        (0.0, 0.0)
    };
    // useful-arithmetic throughput: analytic FLOPs of this strategy on
    // the generalized-linear stack (LayerNorm excluded, matching the
    // complexity tables) over the median step time
    let flop_layers: Vec<_> = spec
        .arch_layers()
        .into_iter()
        .filter(|l| l.kind != crate::arch::LayerKind::Norm)
        .collect();
    // per-micro-batch FLOPs times micro-batches per timed logical step
    let step_flops =
        crate::complexity::model_cost(strat, spec.batch as f64, &flop_layers).time * micro as f64;
    let median = s.median();
    Ok(BenchResult {
        model: model.to_string(),
        strategy: strategy.to_string(),
        style: style.to_string(),
        batch: spec.batch,
        seq_len: spec.seq,
        heads: spec.attn_heads,
        tied: spec.tied,
        threads,
        shards,
        mean_step_secs: s.mean(),
        median_step_secs: median,
        min_step_secs: s.min(),
        gflops: if median > 0.0 { step_flops / median / 1e9 } else { 0.0 },
        samples_per_sec: (spec.batch * micro) as f64 / s.mean(),
        peak_rss: peak_rss_bytes(),
        steady_allocs,
        peak_gcache_floats_measured: stats.peak_gcache_floats,
        peak_gcache_floats_predicted: predicted,
        peak_gcache_floats_unfused: unfused,
        arena_peak_floats: stats.arena_peak_floats,
        peft: preset,
        trainable_frac: spec.n_trainable_params() as f64 / spec.n_params().max(1) as f64,
    })
}

/// Shared child protocol, spawn half: re-exec the current binary with
/// the `CHILD_ENV` spec (`model:strategy:warmup:iters:threads`). The
/// child side is [`maybe_run_native_child`] (or the PJRT benches'
/// `maybe_run_child`).
fn spawn_child_raw(spec: &str, trainable: &str) -> std::io::Result<std::process::Output> {
    let exe = std::env::current_exe()?;
    let mut cmd = std::process::Command::new(exe);
    cmd.env(CHILD_ENV, spec).env("FASTDP_LOG", "error");
    if !trainable.is_empty() {
        cmd.env(CHILD_TRAINABLE_ENV, trainable);
    }
    cmd.output()
}

/// Shared child protocol, parse half: the child prints exactly one
/// JSON result line; protocol violations are hard errors.
fn parse_child_output(spec: &str, out: std::process::Output) -> Result<BenchResult> {
    if !out.status.success() {
        bail!(
            "bench child {spec} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .rev()
        .find(|l| l.starts_with('{'))
        .ok_or_else(|| anyhow!("bench child {spec}: no JSON line in output: {stdout}"))?;
    BenchResult::from_json(&crate::json::parse(line).map_err(|e| anyhow!("{e}"))?)
}

/// Parent side: re-exec self per (model, strategy, style) for RSS
/// isolation. Falls back to in-process measurement only when the
/// *spawn itself* fails (no exe handle, exotic sandbox) — a child that
/// ran but broke the protocol is a hard error, because silently
/// re-measuring in the parent would smear peak-RSS attribution across
/// strategies.
#[allow(clippy::too_many_arguments)]
pub fn measure_native_isolated(
    model: &str,
    strategy: &str,
    style: &str,
    warmup: usize,
    iters: usize,
    threads: usize,
    shards: usize,
    trainable: &str,
) -> Result<BenchResult> {
    // NOTE: style is LAST because it may itself contain ':'
    // ("group-wise:4"); every numeric field sits before it. The
    // trainability preset travels in its own env var for the same
    // reason ("lora:4", "mask:a,b").
    let spec = format!("{model}:{strategy}:{warmup}:{iters}:{threads}:{shards}:{style}");
    match spawn_child_raw(&spec, trainable) {
        Ok(out) => parse_child_output(&spec, out),
        Err(_) => measure_native(model, strategy, style, warmup, iters, threads, shards, trainable),
    }
}

/// Call at the top of the CLI main(): if we are a bench child, run the
/// one measurement, print JSON, and exit.
pub fn maybe_run_native_child() {
    if let Ok(spec) = std::env::var(CHILD_ENV) {
        let parts: Vec<&str> = spec.split(':').collect();
        if parts.len() < 2 {
            eprintln!("bad {CHILD_ENV} spec '{spec}'");
            std::process::exit(1);
        }
        let warmup = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(3);
        let iters = parts.get(3).and_then(|s| s.parse().ok()).unwrap_or(10);
        let threads = parts.get(4).and_then(|s| s.parse().ok()).unwrap_or(0);
        let shards = parts.get(5).and_then(|s| s.parse().ok()).unwrap_or(1);
        // NOTE: the style field rejoins on ':' so "group-wise:4" survives
        // the split.
        let style = if parts.len() > 6 { parts[6..].join(":") } else { "all-layer".to_string() };
        let trainable = std::env::var(CHILD_TRAINABLE_ENV).unwrap_or_default();
        match measure_native(parts[0], parts[1], &style, warmup, iters, threads, shards, &trainable)
        {
            Ok(r) => {
                println!("{}", r.to_json());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("child error: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// The `fastdp bench` subcommand: measure a strategy list (crossed with
/// a clipping-style list) on one native model, print the paper-style
/// table, optionally write `BENCH_native_kernels.json`
/// (machine-readable perf trajectory).
pub fn run_native_bench(args: &crate::cli::Args) -> i32 {
    let model = args.get_or("model", "mlp_e2e").to_string();
    let strategies: Vec<String> = args
        .get_or("strategy", "bk,nondp")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let mut styles: Vec<String> = args
        .get_or("styles", "all-layer")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if styles.is_empty() {
        styles.push("all-layer".to_string());
    }
    let warmup = args.get_usize("warmup", 5);
    let iters = args.get_usize("iters", 20);
    let threads = args.get_usize("threads", 0);
    let shards = args.get_usize("shards", 1);
    // "" keeps the registry preset (LoRA variants bench their adapters)
    let trainable = args.get_or("trainable", "").to_string();
    let isolate = !args.has_flag("no-isolate");

    let mut results: Vec<BenchResult> = Vec::new();
    for strat in &strategies {
        for style in &styles {
            // clipping styles only differ for DP strategies; bench
            // nondp once under the default style
            if strat == "nondp" && style != &styles[0] {
                continue;
            }
            let r = if isolate {
                measure_native_isolated(
                    &model, strat, style, warmup, iters, threads, shards, &trainable,
                )
            } else {
                measure_native(&model, strat, style, warmup, iters, threads, shards, &trainable)
            };
            match r {
                Ok(r) => results.push(r),
                Err(e) => {
                    eprintln!("bench {model}/{strat}/{style}: {e}");
                    return 1;
                }
            }
        }
    }

    let shard_note = if shards > 1 { format!(", shards={shards}") } else { String::new() };
    let mut t = Table::new(
        &format!("native kernel bench: {model} (warmup={warmup}, iters={iters}{shard_note})"),
        &[
            "strategy",
            "style",
            "peft",
            "mean/step",
            "median/step",
            "min/step",
            "GFLOP/s",
            "samples/s",
            "peak RSS",
            "g-cache peak",
            "steady allocs",
        ],
    );
    for r in &results {
        t.row(&[
            r.strategy.clone(),
            r.style.clone(),
            r.peft.clone(),
            fmt_duration(r.mean_step_secs),
            fmt_duration(r.median_step_secs),
            fmt_duration(r.min_step_secs),
            if r.gflops > 0.0 { format!("{:.2}", r.gflops) } else { "-".into() },
            format!("{:.0}", r.samples_per_sec),
            fmt_bytes(r.peak_rss as f64),
            if r.peak_gcache_floats_measured > 0 {
                fmt_count(r.peak_gcache_floats_measured as f64)
            } else {
                "-".to_string()
            },
            r.steady_allocs.to_string(),
        ]);
    }
    print!("{}", t.render());

    let find = |name: &str| {
        results
            .iter()
            .find(|r| r.strategy == name && r.style == styles[0])
    };
    let ratio = match (find("bk"), find("nondp")) {
        (Some(bk), Some(nd)) if nd.mean_step_secs > 0.0 => {
            let ratio = bk.mean_step_secs / nd.mean_step_secs;
            println!(
                "bk/nondp step-time ratio: {ratio:.2}x (paper: 1.03x time complexity on GPT2)"
            );
            Some(ratio)
        }
        _ => None,
    };
    if results.iter().all(|r| r.steady_allocs == 0) {
        println!("steady-state allocations: flat (0 arena misses per step) across all strategies");
    } else {
        for r in results.iter().filter(|r| r.steady_allocs > 0) {
            eprintln!(
                "warning: {} had {} steady-state allocations per step",
                r.strategy, r.steady_allocs
            );
        }
    }

    if args.has_flag("json") {
        let mut root = Value::obj();
        root.set("model", Value::from(model.as_str()))
            .set("warmup", Value::from(warmup))
            .set("iters", Value::from(iters))
            .set(
                "results",
                Value::Arr(results.iter().map(BenchResult::to_json).collect()),
            );
        if let Some(r) = ratio {
            root.set("bk_vs_nondp_time_ratio", Value::from(r));
        }
        let path = "BENCH_native_kernels.json";
        match std::fs::write(path, root.to_string_pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                return 1;
            }
        }
    }
    0
}

// ---- bench-regression gate (`fastdp bench-check`) ------------------------

/// One baseline-vs-current comparison verdict.
#[derive(Clone, Debug)]
pub struct CheckRow {
    pub key: String,
    /// Human-readable failure reasons; empty = the row passed.
    pub failures: Vec<String>,
    pub fused: usize,
    pub unfused: f64,
    pub time_secs: f64,
    pub baseline_time_secs: f64,
    /// Useful-arithmetic throughput of the current row (0 = unmeasured).
    pub gflops: f64,
}

/// Compare current bench rows against a committed baseline.
///
/// Contract (the CI `bench-regression` job enforces it per PR):
/// * every baseline (model, strategy, style) row must be present;
/// * `steady_allocs` must be 0 (flat memory once warm);
/// * `peak_gcache_floats_measured` must equal the baseline **exactly**
///   — floats held are deterministic, so any drift is a real schedule
///   regression;
/// * measured must agree with the row's own complexity prediction to
///   within 1% (they are exact in practice; the band absorbs f64
///   rounding of the prediction);
/// * step time is banded **statistically**: when the baseline pins a
///   median (`median_step_secs` > 0, from ≥ 5 timed reps per row), the
///   current median must stay within `(1 + time_tolerance) *` baseline
///   median — medians are robust to the scheduler spikes that make
///   single-rep means flaky on shared CI runners. Baselines written
///   before the median field existed (median 0) fall back to the old
///   mean band; the committed baseline leaves both at 0 = unpinned,
///   because CI machines vary — the bands exist for locally
///   regenerated baselines;
/// * symmetrically, a current one-pass DP row absent from the baseline
///   fails — growing the CI matrix requires regenerating the baseline
///   so the new rows are actually pinned.
pub fn check_against_baseline(
    current: &[BenchResult],
    baseline: &[BenchResult],
    time_tolerance: f64,
) -> Vec<CheckRow> {
    // Row identity is (model, strategy, style, shards, peft): a
    // shards-2 row and its single-worker sibling are distinct pins, and
    // so are a bias-only leg and the full fine-tune of the same triple.
    // Legacy rows parse as shards 1 / peft "all", so old baselines keep
    // matching; the key only grows a suffix for the non-default values.
    let row_key = |r: &BenchResult| {
        let mut key = format!("{}/{}/{}", r.model, r.strategy, r.style);
        if r.shards > 1 {
            key.push_str(&format!("/shards{}", r.shards));
        }
        if r.peft != "all" {
            key.push_str(&format!("/{}", r.peft));
        }
        key
    };
    let same_row = |a: &BenchResult, b: &BenchResult| {
        a.model == b.model
            && a.strategy == b.strategy
            && a.style == b.style
            && a.shards == b.shards
            && a.peft == b.peft
    };
    let mut out = Vec::new();
    for base in baseline {
        let key = row_key(base);
        let cur = current.iter().find(|r| same_row(r, base));
        let mut failures = Vec::new();
        let Some(cur) = cur else {
            out.push(CheckRow {
                key,
                failures: vec!["row missing from the current bench output".into()],
                fused: 0,
                unfused: base.peak_gcache_floats_unfused,
                time_secs: 0.0,
                baseline_time_secs: base.mean_step_secs,
                gflops: 0.0,
            });
            continue;
        };
        if cur.steady_allocs != 0 {
            failures.push(format!(
                "steady-state allocations regressed: {} per step (expected 0)",
                cur.steady_allocs
            ));
        }
        if cur.peak_gcache_floats_measured != base.peak_gcache_floats_measured {
            failures.push(format!(
                "peak g-cache floats changed: measured {} vs baseline {} (exact pin)",
                cur.peak_gcache_floats_measured, base.peak_gcache_floats_measured
            ));
        }
        let predicted = cur.peak_gcache_floats_predicted;
        if predicted > 0.0 {
            let diff = (cur.peak_gcache_floats_measured as f64 - predicted).abs();
            if diff > 0.01 * predicted {
                failures.push(format!(
                    "measured g-cache peak {} is >1% off its own prediction {:.0}",
                    cur.peak_gcache_floats_measured, predicted
                ));
            }
        }
        // statistical time gate: prefer the median band (robust to CI
        // scheduler spikes); mean band only for pre-median baselines
        if base.median_step_secs > 0.0 {
            if cur.median_step_secs > base.median_step_secs * (1.0 + time_tolerance) {
                failures.push(format!(
                    "median step time regressed: {:.2}ms vs baseline {:.2}ms (+{:.0}% band)",
                    cur.median_step_secs * 1e3,
                    base.median_step_secs * 1e3,
                    time_tolerance * 100.0
                ));
            }
        } else if base.mean_step_secs > 0.0
            && cur.mean_step_secs > base.mean_step_secs * (1.0 + time_tolerance)
        {
            failures.push(format!(
                "step time regressed: {:.2}ms vs baseline {:.2}ms (+{:.0}% band)",
                cur.mean_step_secs * 1e3,
                base.mean_step_secs * 1e3,
                time_tolerance * 100.0
            ));
        }
        out.push(CheckRow {
            key,
            failures,
            fused: cur.peak_gcache_floats_measured,
            unfused: cur.peak_gcache_floats_unfused,
            time_secs: if cur.median_step_secs > 0.0 {
                cur.median_step_secs
            } else {
                cur.mean_step_secs
            },
            baseline_time_secs: if base.median_step_secs > 0.0 {
                base.median_step_secs
            } else {
                base.mean_step_secs
            },
            gflops: cur.gflops,
        });
    }
    // Symmetric guard: a current row with no baseline counterpart means
    // the CI matrix grew without regenerating the baseline — that row's
    // floats-held pin would otherwise never be checked, so it fails too
    // (DP one-pass rows only; nondp/two-pass rows carry no g-cache pin).
    for cur in current {
        let known = baseline.iter().any(|b| same_row(b, cur));
        if !known && cur.peak_gcache_floats_measured > 0 {
            out.push(CheckRow {
                key: row_key(cur),
                failures: vec![
                    "row not pinned in the baseline — regenerate it \
                     (python3 python/tools/gen_gcache_baseline.py)"
                        .into(),
                ],
                fused: cur.peak_gcache_floats_measured,
                unfused: cur.peak_gcache_floats_unfused,
                time_secs: cur.mean_step_secs,
                baseline_time_secs: 0.0,
                gflops: cur.gflops,
            });
        }
    }
    out
}

/// Render the comparison as a markdown savings table (goes to the CI
/// step summary, so the memory win is visible per PR).
pub fn check_summary_markdown(rows: &[CheckRow]) -> String {
    let mut s = String::from(
        "### bench regression gate: fused g-cache peaks vs baseline\n\n\
         | model/strategy/style | fused peak (floats) | legacy (unfused) | saved | median/step | GFLOP/s | status |\n\
         |---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let saved = if r.unfused > 0.0 {
            format!("{:.1}%", 100.0 * (1.0 - r.fused as f64 / r.unfused))
        } else {
            "-".to_string()
        };
        s.push_str(&format!(
            "| {} | {} | {:.0} | {} | {} | {} | {} |\n",
            r.key,
            r.fused,
            r.unfused,
            saved,
            if r.time_secs > 0.0 {
                fmt_duration(r.time_secs)
            } else {
                "-".to_string()
            },
            if r.gflops > 0.0 {
                format!("{:.2}", r.gflops)
            } else {
                "-".to_string()
            },
            if r.failures.is_empty() {
                "ok".to_string()
            } else {
                format!("FAIL: {}", r.failures.join("; "))
            },
        ));
    }
    s
}

fn load_results(path: &str) -> Result<Vec<BenchResult>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read bench JSON '{path}': {e}"))?;
    let v = crate::json::parse(&text).map_err(|e| anyhow!("bad JSON in '{path}': {e}"))?;
    let rows = v.req_arr("results").map_err(|e| anyhow!("{path}: {e}"))?;
    rows.iter().map(BenchResult::from_json).collect()
}

/// The `fastdp bench-check` subcommand: compare current bench JSON
/// (comma-separated list of files, results concatenated) against the
/// committed baseline; exit non-zero on any regression.
pub fn run_bench_check(args: &crate::cli::Args) -> i32 {
    let current_paths = args.get_or("current", "BENCH_native_kernels.json").to_string();
    let baseline_path = args.get_or("baseline", "ci/bench_baseline.json").to_string();
    let tol = args.get_f64("time-tolerance", 1.0);
    let mut current: Vec<BenchResult> = Vec::new();
    for path in current_paths.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        match load_results(path) {
            Ok(mut rows) => current.append(&mut rows),
            Err(e) => {
                eprintln!("bench-check: {e}");
                return 2;
            }
        }
    }
    let baseline = match load_results(&baseline_path) {
        Ok(rows) => rows,
        Err(e) => {
            eprintln!("bench-check: {e}");
            return 2;
        }
    };
    if baseline.is_empty() {
        eprintln!("bench-check: baseline '{baseline_path}' has no rows");
        return 2;
    }
    let rows = check_against_baseline(&current, &baseline, tol);
    let md = check_summary_markdown(&rows);
    match args.get("summary") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &md) {
                eprintln!("bench-check: cannot write summary '{path}': {e}");
                return 2;
            }
            print!("{md}");
        }
        None => print!("{md}"),
    }
    let failed: Vec<&CheckRow> = rows.iter().filter(|r| !r.failures.is_empty()).collect();
    if failed.is_empty() {
        println!(
            "\nbench-check: {} row(s) ok against {baseline_path}",
            rows.len()
        );
        0
    } else {
        for r in &failed {
            eprintln!("bench-check FAIL {}: {}", r.key, r.failures.join("; "));
        }
        1
    }
}

/// Convert manifest layer metadata to complexity-engine layer dims.
pub fn layers_of(meta: &crate::runtime::ModelMeta) -> Vec<crate::arch::LayerDims> {
    meta.layer_meta
        .iter()
        .map(|l| crate::arch::LayerDims {
            kind: match l.kind.as_str() {
                "conv2d" => crate::arch::LayerKind::Conv,
                "embedding" => crate::arch::LayerKind::Embedding,
                "layernorm" => crate::arch::LayerKind::Norm,
                "attention" => crate::arch::LayerKind::Attention,
                _ => crate::arch::LayerKind::Linear,
            },
            name: l.name.clone(),
            t: l.t as u64,
            d: l.d as u64,
            p: l.p as u64,
        })
        .collect()
}

pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Write a rendered table to bench_results/<name>.<ext> and stdout.
pub fn emit(name: &str, table: &crate::util::table::Table, csv: bool) {
    print!("{}", table.render());
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let _ = std::fs::write(dir.join(format!("{name}.md")), table.markdown());
    if csv {
        let _ = std::fs::write(dir.join(format!("{name}.csv")), table.csv());
    }
}

// ---- PJRT (artifact) measurement, feature-gated --------------------------

/// Measure one (model, strategy) step executable in THIS process on the
/// PJRT runtime. Used by the artifact-driven bench targets.
#[cfg(feature = "xla-runtime")]
pub fn measure_step(
    rt: &crate::runtime::pjrt::Runtime,
    model: &str,
    strategy: &str,
    warmup: usize,
    iters: usize,
) -> Result<BenchResult> {
    use crate::runtime::pjrt::{literal_f32, literal_i32, scalar_f32, scalar_i32, scalar_of};
    use crate::util::rng::{GaussianSource, Xoshiro256};

    let meta = rt.model(model)?.clone();
    let art = rt.artifact(model, "step", Some(strategy))?.clone();
    let b = meta.batch;

    // params from init
    let init = rt.artifact(model, "init", None)?.clone();
    let seed = scalar_i32(0);
    let all_params = rt.execute(&init, &[&seed])?;
    let n_tr = meta.param_names.len();
    let params = &all_params[..n_tr];
    let frozen = &all_params[n_tr..];

    // synthetic inputs straight from the artifact descriptors
    let (xd, yd) = (
        art.inputs[art.input_index("x").unwrap()].clone(),
        art.inputs[art.input_index("y").unwrap()].clone(),
    );
    let mut rng = Xoshiro256::new(11);
    let xl = match xd.dtype {
        crate::runtime::Dtype::F32 => {
            let data: Vec<f32> = (0..xd.elements()).map(|_| rng.next_f32() - 0.5).collect();
            literal_f32(&data, &xd.shape)?
        }
        _ => {
            let vocab = meta.spec.opt_i64("vocab", 512) as u64;
            let data: Vec<i32> = (0..xd.elements())
                .map(|_| rng.next_below(vocab) as i32)
                .collect();
            literal_i32(&data, &xd.shape)?
        }
    };
    let classes = meta
        .spec
        .get("n_classes")
        .and_then(Value::as_i64)
        .or_else(|| meta.spec.get("vocab").and_then(Value::as_i64))
        .unwrap_or(10) as u64;
    let ydata: Vec<i32> = (0..yd.elements())
        .map(|_| rng.next_below(classes) as i32)
        .collect();
    let yl = literal_i32(&ydata, &yd.shape)?;

    let with_noise = strategy != "nondp";
    let mut gs = GaussianSource::new(5);
    let noise: Vec<xla::Literal> = if with_noise {
        meta.param_names
            .iter()
            .map(|name| {
                let shape = meta.param_shape(name).unwrap();
                let n: usize = shape.iter().product();
                let mut buf = vec![0f32; n];
                gs.fill_f32(&mut buf);
                literal_f32(&buf, shape).unwrap()
            })
            .collect()
    } else {
        Vec::new()
    };
    let opt_state: Vec<xla::Literal> = if meta.is_adam() {
        meta.param_names
            .iter()
            .map(|name| {
                let shape = meta.param_shape(name).unwrap();
                let n: usize = shape.iter().product();
                literal_f32(&vec![0f32; n], shape).unwrap()
            })
            .collect()
    } else {
        Vec::new()
    };
    let scalars = [
        scalar_f32(1e-3),
        scalar_f32(1.0),
        scalar_f32(0.5),
        scalar_f32(b as f32),
        scalar_f32(1.0),
    ];

    let run_once = |rt: &crate::runtime::pjrt::Runtime| -> Result<f32> {
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.extend(frozen.iter());
        if meta.is_adam() {
            args.extend(opt_state.iter()); // m
            args.extend(opt_state.iter()); // v (zeros again)
        }
        args.push(&xl);
        args.push(&yl);
        args.extend(noise.iter());
        args.extend(scalars.iter());
        let outs = rt.execute(&art, &args)?;
        scalar_of(&outs[art.output_index("metric:loss").unwrap()])
    };

    for _ in 0..warmup {
        run_once(rt)?;
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        let loss = run_once(rt)?;
        s.push(t0.elapsed().as_secs_f64());
        if !loss.is_finite() {
            bail!("{model}/{strategy}: loss diverged during bench");
        }
    }
    Ok(BenchResult {
        model: model.to_string(),
        strategy: strategy.to_string(),
        style: "all-layer".to_string(),
        batch: b,
        seq_len: meta.spec.opt_i64("seq", 1) as usize,
        heads: meta.spec.opt_i64("heads", 0) as usize,
        tied: meta.spec.opt_bool("tied", false),
        threads: 1,
        shards: 1,
        mean_step_secs: s.mean(),
        median_step_secs: s.median(),
        min_step_secs: s.min(),
        // no analytic FLOP census for artifact-driven rows
        gflops: 0.0,
        samples_per_sec: b as f64 / s.mean(),
        peak_rss: peak_rss_bytes(),
        steady_allocs: 0,
        // the PJRT runtime has no arena / fused-walk gauge
        peak_gcache_floats_measured: 0,
        peak_gcache_floats_predicted: 0.0,
        peak_gcache_floats_unfused: 0.0,
        arena_peak_floats: 0,
        // PJRT artifacts are compiled fully trainable
        peft: "all".to_string(),
        trainable_frac: 1.0,
    })
}

/// Parent side of the PJRT bench: spawn self as a child per
/// (model, strategy). The child must call [`maybe_run_child`].
#[cfg(feature = "xla-runtime")]
pub fn measure_in_child(model: &str, strategy: &str, iters: usize) -> Result<BenchResult> {
    let spec = format!("{model}:{strategy}:1:{iters}:0");
    let out = spawn_child_raw(&spec, "").map_err(|e| anyhow!("spawning bench child: {e}"))?;
    parse_child_output(&spec, out)
}

/// Call at the top of every PJRT bench main(): if we are a child, run
/// the one measurement against the artifacts, print JSON, and exit.
#[cfg(feature = "xla-runtime")]
pub fn maybe_run_child() {
    if let Ok(spec) = std::env::var(CHILD_ENV) {
        let parts: Vec<&str> = spec.split(':').collect();
        let (model, strategy) = (parts[0], parts[1]);
        let iters = parts.get(3).and_then(|s| s.parse().ok()).unwrap_or(3);
        let rt = crate::runtime::pjrt::Runtime::load(artifacts_dir()).expect("runtime");
        match measure_step(&rt, model, strategy, 1, iters) {
            Ok(r) => {
                println!("{}", r.to_json());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("child error: {e}");
                std::process::exit(1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_result() -> BenchResult {
        BenchResult {
            model: "m".into(),
            strategy: "bk".into(),
            style: "layer-wise".into(),
            batch: 8,
            seq_len: 32,
            heads: 4,
            tied: true,
            threads: 4,
            shards: 1,
            mean_step_secs: 0.25,
            median_step_secs: 0.24,
            min_step_secs: 0.2,
            gflops: 1.5,
            samples_per_sec: 32.0,
            peak_rss: 1024,
            steady_allocs: 0,
            peak_gcache_floats_measured: 4096,
            peak_gcache_floats_predicted: 4096.0,
            peak_gcache_floats_unfused: 8192.0,
            arena_peak_floats: 50_000,
            peft: "all".into(),
            trainable_frac: 1.0,
        }
    }

    #[test]
    fn bench_result_json_roundtrip() {
        let r = sample_result();
        let v = r.to_json();
        let r2 = BenchResult::from_json(&crate::json::parse(&v.to_string()).unwrap()).unwrap();
        assert_eq!(r2.model, "m");
        assert_eq!(r2.style, "layer-wise");
        assert_eq!(r2.batch, 8);
        assert_eq!(r2.seq_len, 32);
        assert_eq!(r2.heads, 4);
        assert!(r2.tied, "tied flag must round-trip");
        assert_eq!(r2.threads, 4);
        assert_eq!(r2.shards, 1);
        // sharded rows round-trip their worker count
        let mut sharded = sample_result();
        sharded.shards = 3;
        let sv = sharded.to_json();
        let s2 = BenchResult::from_json(&crate::json::parse(&sv.to_string()).unwrap()).unwrap();
        assert_eq!(s2.shards, 3, "shards field must round-trip");
        assert_eq!(r2.median_step_secs, 0.24);
        assert_eq!(r2.gflops, 1.5);
        assert!((r2.samples_per_sec - 32.0).abs() < 1e-12);
        assert_eq!(r2.steady_allocs, 0);
        assert_eq!(r2.peak_gcache_floats_measured, 4096);
        assert_eq!(r2.peak_gcache_floats_predicted, 4096.0);
        assert_eq!(r2.peak_gcache_floats_unfused, 8192.0);
        assert_eq!(r2.arena_peak_floats, 50_000);
        assert_eq!(r2.peft, "all");
        assert_eq!(r2.trainable_frac, 1.0);
        // peft rows round-trip their preset + trainable fraction
        let mut peft = sample_result();
        peft.peft = "bias-only".into();
        peft.trainable_frac = 0.01;
        let pv = peft.to_json();
        let p2 = BenchResult::from_json(&crate::json::parse(&pv.to_string()).unwrap()).unwrap();
        assert_eq!(p2.peft, "bias-only", "peft preset must round-trip");
        assert_eq!(p2.trainable_frac, 0.01, "trainable fraction must round-trip");
        // pre-style/pre-attention/pre-tying JSON defaults: all-layer,
        // T = 1, no heads, untied
        let legacy = crate::json::parse(
            r#"{"model":"m","strategy":"bk","batch":4,"mean_step_secs":0.1,
                "min_step_secs":0.1,"samples_per_sec":40.0,"peak_rss":1.0}"#,
        )
        .unwrap();
        let lr = BenchResult::from_json(&legacy).unwrap();
        assert_eq!(lr.style, "all-layer");
        assert_eq!(lr.seq_len, 1);
        assert_eq!(lr.heads, 0);
        assert!(!lr.tied, "legacy rows default to untied");
        assert_eq!(lr.threads, 1, "pre-threads rows parse with the old default");
        assert_eq!(lr.shards, 1, "pre-sharding rows parse as single-worker");
        assert_eq!(lr.median_step_secs, 0.0, "pre-median rows parse as unpinned");
        assert_eq!(lr.gflops, 0.0);
        assert_eq!(lr.peak_gcache_floats_measured, 0, "pre-fusion rows parse as unmeasured");
        assert_eq!(lr.peak_gcache_floats_unfused, 0.0);
        assert_eq!(lr.arena_peak_floats, 0);
        assert_eq!(lr.peft, "all", "pre-trainability rows parse as fully trainable");
        assert_eq!(lr.trainable_frac, 1.0);
        // a row with seq/heads but no tied field (PR 3 era) is untied too
        let pr3 = crate::json::parse(
            r#"{"model":"m","strategy":"bk","batch":4,"seq_len":16,"heads":4,
                "mean_step_secs":0.1,"min_step_secs":0.1,"samples_per_sec":40.0,
                "peak_rss":1.0}"#,
        )
        .unwrap();
        assert!(!BenchResult::from_json(&pr3).unwrap().tied);
    }

    #[test]
    fn measure_native_reports_steady_state() {
        // Tiny in-process measurement: BK on the seed MLP reaches a warm
        // arena (no steady-state allocations) and finite throughput.
        let r = measure_native("mlp_e2e", "bk", "all-layer", 2, 2, 2, 1, "").unwrap();
        assert_eq!(r.steady_allocs, 0, "arena must be warm after warmup");
        assert!(r.mean_step_secs > 0.0);
        assert!(r.median_step_secs > 0.0);
        assert!(r.gflops > 0.0, "analytic throughput must be measured");
        assert!(r.samples_per_sec > 0.0);
        assert_eq!(r.batch, 32);
        assert_eq!(r.threads, 2, "the requested thread count lands in the row");
    }

    #[test]
    fn measure_native_covers_styles_and_token_models() {
        // layer-wise clipping on the seed MLP, and the token+LayerNorm
        // model end-to-end — both stay allocation-free once warm.
        let r = measure_native("mlp_e2e", "bk", "layer-wise", 2, 2, 2, 1, "").unwrap();
        assert_eq!(r.steady_allocs, 0);
        assert_eq!(r.style, "layer-wise");
        let r = measure_native("seq_tok_e2e", "bk", "group-wise:2", 2, 2, 2, 1, "").unwrap();
        assert_eq!(r.steady_allocs, 0, "token model arena must be warm");
        assert!(r.samples_per_sec > 0.0);
    }

    #[test]
    fn measure_native_reports_transformer_dims() {
        // gpt_nano rows must carry seq_len + heads so transformer rows
        // in BENCH_native_kernels.json are unambiguous.
        let r = measure_native("gpt_nano_e2e", "bk", "all-layer", 1, 2, 2, 1, "").unwrap();
        assert_eq!(r.seq_len, 16);
        assert_eq!(r.heads, 4);
        assert_eq!(r.steady_allocs, 0, "gpt arena must be warm after warmup");
        let v = r.to_json().to_string();
        assert!(v.contains("seq_len"), "{v}");
        assert!(v.contains("heads"), "{v}");
    }

    #[test]
    fn measure_native_covers_tied_models() {
        // the tied gpt model benches end-to-end (cross-term kernel in
        // the norm pass) and stays allocation-free once warm
        let r = measure_native("gpt_nano_tied_e2e", "bk", "all-layer", 1, 2, 2, 1, "").unwrap();
        assert!(r.tied, "registry tied model must report tied");
        assert_eq!(r.seq_len, 16);
        assert_eq!(r.heads, 4);
        assert_eq!(r.steady_allocs, 0, "tied gpt arena must be warm after warmup");
        let v = r.to_json().to_string();
        assert!(v.contains("\"tied\":true"), "{v}");
        // untied sibling reports untied
        let r = measure_native("gpt_nano_e2e", "bk", "all-layer", 1, 1, 2, 1, "").unwrap();
        assert!(!r.tied);
    }

    #[test]
    fn measure_native_reports_gcache_peaks() {
        // One-pass DP rows carry the fused g-cache gauge, and the
        // measured value equals the complexity-engine prediction (walk
        // simulation) exactly; nondp rows are unmeasured by definition.
        let r = measure_native("mlp_ln", "bk", "group-wise:2", 2, 2, 2, 1, "").unwrap();
        assert!(r.peak_gcache_floats_measured > 0);
        assert_eq!(r.peak_gcache_floats_measured as f64, r.peak_gcache_floats_predicted);
        assert!(r.peak_gcache_floats_unfused > r.peak_gcache_floats_predicted);
        assert!(r.arena_peak_floats >= r.peak_gcache_floats_measured);
        let nd = measure_native("mlp_ln", "nondp", "all-layer", 1, 1, 2, 1, "").unwrap();
        assert_eq!(nd.peak_gcache_floats_measured, 0);
        assert_eq!(nd.peak_gcache_floats_predicted, 0.0);
    }

    #[test]
    fn bench_check_passes_and_fails_correctly() {
        let base = sample_result();
        let mut cur = base.clone();
        // clean pass
        let rows = check_against_baseline(
            std::slice::from_ref(&cur),
            std::slice::from_ref(&base),
            0.5,
        );
        assert_eq!(rows.len(), 1);
        assert!(rows[0].failures.is_empty(), "{:?}", rows[0].failures);
        let md = check_summary_markdown(&rows);
        assert!(md.contains("m/bk/layer-wise"), "{md}");
        assert!(md.contains("50.0%"), "savings column: {md}");
        assert!(md.contains("GFLOP/s"), "throughput column header: {md}");
        assert!(md.contains("| 1.50 |"), "throughput column value: {md}");
        assert!(md.contains("| ok |"), "{md}");

        // injected floats-held regression: exact pin must fail
        let mut perturbed = base.clone();
        perturbed.peak_gcache_floats_measured += 1;
        let rows = check_against_baseline(
            std::slice::from_ref(&cur),
            std::slice::from_ref(&perturbed),
            0.5,
        );
        assert_eq!(rows[0].failures.len(), 1, "{:?}", rows[0].failures);
        assert!(rows[0].failures[0].contains("peak g-cache floats changed"));
        assert!(check_summary_markdown(&rows).contains("FAIL"));

        // measured drifting >1% off its own prediction fails
        let mut drifted = base.clone();
        drifted.peak_gcache_floats_measured = 5000;
        let rows = check_against_baseline(
            std::slice::from_ref(&drifted),
            std::slice::from_ref(&drifted),
            0.5,
        );
        assert!(rows[0].failures.iter().any(|f| f.contains("off its own prediction")));

        // statistical time gate: the median band fires when the current
        // median drifts beyond it — a blown *mean* alone (one scheduler
        // spike) does not fail a median-pinned baseline
        cur.mean_step_secs = base.mean_step_secs * 2.0;
        let rows = check_against_baseline(
            std::slice::from_ref(&cur),
            std::slice::from_ref(&base),
            0.5,
        );
        assert!(rows[0].failures.is_empty(), "mean spike alone: {:?}", rows[0].failures);
        cur.median_step_secs = base.median_step_secs * 2.0;
        let rows = check_against_baseline(
            std::slice::from_ref(&cur),
            std::slice::from_ref(&base),
            0.5,
        );
        assert!(rows[0].failures.iter().any(|f| f.contains("median step time regressed")));
        // pre-median baselines (median 0, mean pinned) fall back to the
        // legacy mean band; fully unpinned baselines skip it
        let mut mean_only = base.clone();
        mean_only.median_step_secs = 0.0;
        let rows = check_against_baseline(
            std::slice::from_ref(&cur),
            std::slice::from_ref(&mean_only),
            0.5,
        );
        assert!(rows[0].failures.iter().any(|f| f.contains("step time regressed")));
        let mut unpinned = base.clone();
        unpinned.mean_step_secs = 0.0;
        unpinned.median_step_secs = 0.0;
        let rows = check_against_baseline(
            std::slice::from_ref(&cur),
            std::slice::from_ref(&unpinned),
            0.5,
        );
        assert!(rows[0].failures.is_empty(), "{:?}", rows[0].failures);

        // steady-state allocations must stay flat
        let mut leaky = base.clone();
        leaky.steady_allocs = 3;
        let rows = check_against_baseline(
            std::slice::from_ref(&leaky),
            std::slice::from_ref(&base),
            0.5,
        );
        assert!(rows[0].failures.iter().any(|f| f.contains("steady-state allocations")));

        // a baseline row missing from the current output fails
        let rows = check_against_baseline(&[], std::slice::from_ref(&base), 0.5);
        assert!(rows[0].failures.iter().any(|f| f.contains("missing")));

        // ...and so does a measured current row the baseline never
        // pinned (grown CI matrix without a regenerated baseline);
        // unmeasured rows (nondp/two-pass, gauge 0) stay exempt
        let mut unpinned_cur = base.clone();
        unpinned_cur.style = "group-wise:7".into();
        let rows = check_against_baseline(
            std::slice::from_ref(&unpinned_cur),
            std::slice::from_ref(&base),
            0.5,
        );
        assert_eq!(rows.len(), 2, "{rows:?}");
        assert!(rows[1].failures.iter().any(|f| f.contains("not pinned")), "{rows:?}");
        let mut nondp_cur = base.clone();
        nondp_cur.strategy = "nondp".into();
        nondp_cur.peak_gcache_floats_measured = 0;
        nondp_cur.peak_gcache_floats_predicted = 0.0;
        let rows = check_against_baseline(
            std::slice::from_ref(&nondp_cur),
            std::slice::from_ref(&base),
            0.5,
        );
        assert_eq!(rows.len(), 1, "unmeasured extra rows are not flagged: {rows:?}");
    }

    #[test]
    fn measure_native_sharded_row() {
        // A shards-2 measurement runs the fan-out + rank-0 reduction
        // path: arena stays warm in every replica, the rank-0 g-cache
        // gauge still equals the (shard-count-independent) prediction,
        // and the row carries the shard count.
        let r = measure_native("mlp_ln", "bk", "all-layer", 2, 2, 2, 2, "").unwrap();
        assert_eq!(r.shards, 2);
        assert_eq!(r.steady_allocs, 0, "replica arenas must be warm after warmup");
        assert!(r.peak_gcache_floats_measured > 0);
        assert_eq!(r.peak_gcache_floats_measured as f64, r.peak_gcache_floats_predicted);
        let solo = measure_native("mlp_ln", "bk", "all-layer", 2, 2, 2, 1, "").unwrap();
        assert_eq!(
            r.peak_gcache_floats_measured, solo.peak_gcache_floats_measured,
            "per-shard g-cache peak must not depend on the shard count"
        );
        assert!(r.to_json().to_string().contains("\"shards\":2"));
    }

    #[test]
    fn bench_check_keys_sharded_rows_separately() {
        // A shards-2 row and its single-worker sibling are distinct
        // pins: same (model, strategy, style) but different shard
        // counts must not match each other.
        let base = sample_result();
        let mut sharded = sample_result();
        sharded.shards = 2;
        let rows = check_against_baseline(
            std::slice::from_ref(&sharded),
            std::slice::from_ref(&base),
            0.5,
        );
        // base row is missing (no shards-1 current), sharded row is
        // unpinned (no shards-2 baseline)
        assert_eq!(rows.len(), 2, "{rows:?}");
        assert_eq!(rows[0].key, "m/bk/layer-wise");
        assert!(rows[0].failures.iter().any(|f| f.contains("missing")), "{rows:?}");
        assert_eq!(rows[1].key, "m/bk/layer-wise/shards2");
        assert!(rows[1].failures.iter().any(|f| f.contains("not pinned")), "{rows:?}");
        // with both pinned, both pass
        let rows = check_against_baseline(
            &[base.clone(), sharded.clone()],
            &[base.clone(), sharded.clone()],
            0.5,
        );
        assert_eq!(rows.len(), 2, "{rows:?}");
        assert!(rows.iter().all(|r| r.failures.is_empty()), "{rows:?}");
    }

    #[test]
    fn measure_native_reports_peft_rows() {
        // A bias-only override lands in the row (canonical preset +
        // trainable fraction) and the masked g-cache prediction still
        // matches the measured fused peak exactly — otherwise the
        // bench-check ">1% off its own prediction" gate would fail every
        // peft row.
        let r = measure_native("mlp_ln", "bk", "layer-wise", 1, 2, 2, 1, "bias-only").unwrap();
        assert_eq!(r.peft, "bias-only");
        assert!(
            r.trainable_frac > 0.0 && r.trainable_frac < 0.5,
            "bias census must be a small fraction: {}",
            r.trainable_frac
        );
        assert!(r.peak_gcache_floats_measured > 0);
        assert_eq!(r.peak_gcache_floats_measured as f64, r.peak_gcache_floats_predicted);
        // a LoRA registry variant benches its own adapters by default
        let r = measure_native("gpt_nano_lora_e2e", "bk", "all-layer", 1, 1, 2, 1, "").unwrap();
        assert_eq!(r.peft, "lora:4");
        assert!(r.trainable_frac < 1.0);
        assert_eq!(r.peak_gcache_floats_measured as f64, r.peak_gcache_floats_predicted);
        // ...and an invalid override is refused up front
        assert!(measure_native("mlp_ln", "bk", "all-layer", 1, 1, 1, 1, "lora:0").is_err());
    }

    #[test]
    fn bench_check_keys_peft_rows_separately() {
        // A bias-only leg and the full fine-tune of the same
        // (model, strategy, style) are distinct pins; legacy baselines
        // (peft parses as "all") keep matching full rows only.
        let base = sample_result();
        let mut bias = sample_result();
        bias.peft = "bias-only".into();
        bias.trainable_frac = 0.02;
        let rows = check_against_baseline(
            std::slice::from_ref(&bias),
            std::slice::from_ref(&base),
            0.5,
        );
        assert_eq!(rows.len(), 2, "{rows:?}");
        assert_eq!(rows[0].key, "m/bk/layer-wise");
        assert!(rows[0].failures.iter().any(|f| f.contains("missing")), "{rows:?}");
        assert_eq!(rows[1].key, "m/bk/layer-wise/bias-only");
        assert!(rows[1].failures.iter().any(|f| f.contains("not pinned")), "{rows:?}");
        // with both pinned, both pass
        let rows = check_against_baseline(
            &[base.clone(), bias.clone()],
            &[base.clone(), bias.clone()],
            0.5,
        );
        assert_eq!(rows.len(), 2, "{rows:?}");
        assert!(rows.iter().all(|r| r.failures.is_empty()), "{rows:?}");
    }

    #[test]
    fn measure_native_rejects_unknowns() {
        assert!(measure_native("nope", "bk", "all-layer", 1, 1, 1, 1, "").is_err());
        assert!(measure_native("mlp_e2e", "warp", "all-layer", 1, 1, 1, 1, "").is_err());
        assert!(measure_native("mlp_e2e", "bk", "per-tensor", 1, 1, 1, 1, "").is_err());
    }
}
