//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations of step executables, with *per-process* peak-RSS isolation.
//!
//! Memory attribution problem: XLA's CPU allocator retains arenas, so
//! measuring several strategies in one process smears their footprints.
//! Solution: the bench binary re-execs itself once per (model, strategy)
//! with `FASTDP_BENCH_CHILD=<model>:<strategy>:<iters>`; the child runs
//! the measurement and prints one JSON line; the parent aggregates into
//! the paper-style table. Results are also written to `bench_results/`.

use crate::json::Value;
use crate::runtime::{literal_f32, literal_i32, scalar_f32, scalar_i32, scalar_of, Runtime};
use crate::util::rng::{GaussianSource, Xoshiro256};
use crate::util::stats::{peak_rss_bytes, Summary};
use anyhow::{anyhow, Context, Result};
use std::time::Instant;

pub const CHILD_ENV: &str = "FASTDP_BENCH_CHILD";

/// Result of benchmarking one (model, strategy) pair.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub model: String,
    pub strategy: String,
    pub batch: usize,
    pub mean_step_secs: f64,
    pub min_step_secs: f64,
    pub peak_rss: u64,
    pub compile_secs: f64,
    pub throughput: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("model", Value::from(self.model.as_str()))
            .set("strategy", Value::from(self.strategy.as_str()))
            .set("batch", Value::from(self.batch))
            .set("mean_step_secs", Value::from(self.mean_step_secs))
            .set("min_step_secs", Value::from(self.min_step_secs))
            .set("peak_rss", Value::from(self.peak_rss as f64))
            .set("compile_secs", Value::from(self.compile_secs))
            .set("throughput", Value::from(self.throughput));
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(BenchResult {
            model: v.req_str("model").map_err(|e| anyhow!(e))?.to_string(),
            strategy: v.req_str("strategy").map_err(|e| anyhow!(e))?.to_string(),
            batch: v.req_i64("batch").map_err(|e| anyhow!(e))? as usize,
            mean_step_secs: v.req_f64("mean_step_secs").map_err(|e| anyhow!(e))?,
            min_step_secs: v.req_f64("min_step_secs").map_err(|e| anyhow!(e))?,
            peak_rss: v.req_f64("peak_rss").map_err(|e| anyhow!(e))? as u64,
            compile_secs: v.req_f64("compile_secs").map_err(|e| anyhow!(e))?,
            throughput: v.req_f64("throughput").map_err(|e| anyhow!(e))?,
        })
    }
}

/// Measure one (model, strategy) step executable in THIS process.
pub fn measure_step(rt: &Runtime, model: &str, strategy: &str, warmup: usize, iters: usize)
    -> Result<BenchResult> {
    let meta = rt.model(model)?.clone();
    let art = rt.artifact(model, "step", Some(strategy))?.clone();
    let b = meta.batch;

    // params from init
    let init = rt.artifact(model, "init", None)?.clone();
    let seed = scalar_i32(0);
    let all_params = rt.execute(&init, &[&seed])?;
    let n_tr = meta.param_names.len();
    let params = &all_params[..n_tr];
    let frozen = &all_params[n_tr..];

    // synthetic inputs straight from the artifact descriptors
    let (xd, yd) = (
        art.inputs[art.input_index("x").unwrap()].clone(),
        art.inputs[art.input_index("y").unwrap()].clone(),
    );
    let mut rng = Xoshiro256::new(11);
    let xl = match xd.dtype {
        crate::runtime::Dtype::F32 => {
            let data: Vec<f32> = (0..xd.elements()).map(|_| rng.next_f32() - 0.5).collect();
            literal_f32(&data, &xd.shape)?
        }
        _ => {
            let vocab = meta.spec.opt_i64("vocab", 512) as u64;
            let data: Vec<i32> = (0..xd.elements())
                .map(|_| rng.next_below(vocab) as i32)
                .collect();
            literal_i32(&data, &xd.shape)?
        }
    };
    let classes = meta
        .spec
        .get("n_classes")
        .and_then(Value::as_i64)
        .or_else(|| meta.spec.get("vocab").and_then(Value::as_i64))
        .unwrap_or(10) as u64;
    let ydata: Vec<i32> = (0..yd.elements())
        .map(|_| rng.next_below(classes) as i32)
        .collect();
    let yl = literal_i32(&ydata, &yd.shape)?;

    let with_noise = strategy != "nondp";
    let mut gs = GaussianSource::new(5);
    let noise: Vec<xla::Literal> = if with_noise {
        meta.param_names
            .iter()
            .map(|name| {
                let shape = meta.param_shape(name).unwrap();
                let n: usize = shape.iter().product();
                let mut buf = vec![0f32; n];
                gs.fill_f32(&mut buf);
                literal_f32(&buf, shape).unwrap()
            })
            .collect()
    } else {
        Vec::new()
    };
    let opt_state: Vec<xla::Literal> = if meta.is_adam() {
        meta.param_names
            .iter()
            .flat_map(|name| {
                let shape = meta.param_shape(name).unwrap();
                let n: usize = shape.iter().product();
                vec![literal_f32(&vec![0f32; n], shape).unwrap()]
            })
            .collect()
    } else {
        Vec::new()
    };
    let scalars = [
        scalar_f32(1e-3),
        scalar_f32(1.0),
        scalar_f32(0.5),
        scalar_f32(b as f32),
        scalar_f32(1.0),
    ];

    let run_once = |rt: &Runtime| -> Result<f32> {
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.extend(frozen.iter());
        if meta.is_adam() {
            args.extend(opt_state.iter()); // m
            args.extend(opt_state.iter()); // v (zeros again)
        }
        args.push(&xl);
        args.push(&yl);
        args.extend(noise.iter());
        args.extend(scalars.iter());
        let outs = rt.execute(&art, &args)?;
        scalar_of(&outs[art.output_index("metric:loss").unwrap()])
    };

    for _ in 0..warmup {
        run_once(rt)?;
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        let loss = run_once(rt)?;
        s.push(t0.elapsed().as_secs_f64());
        assert!(loss.is_finite());
    }
    Ok(BenchResult {
        model: model.to_string(),
        strategy: strategy.to_string(),
        batch: b,
        mean_step_secs: s.mean(),
        min_step_secs: s.min(),
        peak_rss: peak_rss_bytes(),
        compile_secs: *rt.compile_secs.borrow(),
        throughput: b as f64 / s.mean(),
    })
}

/// Parent side: spawn self as a child per (model, strategy).
pub fn measure_in_child(model: &str, strategy: &str, iters: usize) -> Result<BenchResult> {
    let exe = std::env::current_exe()?;
    let out = std::process::Command::new(exe)
        .env(CHILD_ENV, format!("{model}:{strategy}:{iters}"))
        .env("FASTDP_LOG", "error")
        .output()
        .context("spawning bench child")?;
    if !out.status.success() {
        anyhow::bail!(
            "bench child {model}:{strategy} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .rev()
        .find(|l| l.starts_with('{'))
        .ok_or_else(|| anyhow!("no JSON line from child: {stdout}"))?;
    BenchResult::from_json(&crate::json::parse(line).map_err(|e| anyhow!("{e}"))?)
}

/// Call at the top of every bench main(): if we are a child, run the one
/// measurement, print JSON, and exit.
pub fn maybe_run_child() {
    if let Ok(spec) = std::env::var(CHILD_ENV) {
        let parts: Vec<&str> = spec.split(':').collect();
        let (model, strategy, iters) = (parts[0], parts[1], parts[2].parse().unwrap_or(3));
        let rt = Runtime::load(artifacts_dir()).expect("runtime");
        match measure_step(&rt, model, strategy, 1, iters) {
            Ok(r) => {
                println!("{}", r.to_json());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("child error: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

/// Convert manifest layer metadata to complexity-engine layer dims.
pub fn layers_of(meta: &crate::runtime::ModelMeta) -> Vec<crate::arch::LayerDims> {
    meta.layer_meta
        .iter()
        .map(|l| crate::arch::LayerDims {
            kind: match l.kind.as_str() {
                "conv2d" => crate::arch::LayerKind::Conv,
                "embedding" => crate::arch::LayerKind::Embedding,
                "layernorm" => crate::arch::LayerKind::Norm,
                _ => crate::arch::LayerKind::Linear,
            },
            name: l.name.clone(),
            t: l.t as u64,
            d: l.d as u64,
            p: l.p as u64,
        })
        .collect()
}

pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Write a rendered table to bench_results/<name>.<ext> and stdout.
pub fn emit(name: &str, table: &crate::util::table::Table, csv: bool) {
    print!("{}", table.render());
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let _ = std::fs::write(dir.join(format!("{name}.md")), table.markdown());
    if csv {
        let _ = std::fs::write(dir.join(format!("{name}.csv")), table.csv());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_result_json_roundtrip() {
        let r = BenchResult {
            model: "m".into(),
            strategy: "bk".into(),
            batch: 8,
            mean_step_secs: 0.25,
            min_step_secs: 0.2,
            peak_rss: 1024,
            compile_secs: 1.5,
            throughput: 32.0,
        };
        let v = r.to_json();
        let r2 = BenchResult::from_json(&crate::json::parse(&v.to_string()).unwrap()).unwrap();
        assert_eq!(r2.model, "m");
        assert_eq!(r2.batch, 8);
        assert!((r2.throughput - 32.0).abs() < 1e-12);
    }
}
