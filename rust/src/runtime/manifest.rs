//! Typed view of artifacts/manifest.json (written by python/compile/aot.py).

use crate::json::{self, Value};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u32" => Ok(Dtype::U32),
            other => Err(format!("unknown dtype '{other}'")),
        }
    }

    pub fn size(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug)]
pub struct TensorDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorDesc {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size()
    }

    fn from_json(v: &Value) -> Result<Self, String> {
        Ok(TensorDesc {
            name: v.req_str("name")?.to_string(),
            shape: v
                .req_arr("shape")?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| "bad dim".to_string()))
                .collect::<Result<_, _>>()?,
            dtype: Dtype::parse(v.req_str("dtype")?)?,
        })
    }
}

/// Per-layer dims for the complexity-engine cross-check (paper (T, d, p)).
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub kind: String,
    pub name: String,
    pub t: usize,
    pub d: usize,
    pub p: usize,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub group: String,
    pub batch: usize,
    pub optimizer: String,
    pub clip_fn: String,
    pub kernel_impl: String,
    pub param_names: Vec<String>,
    pub frozen_names: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub layer_meta: Vec<LayerMeta>,
    pub n_params: usize,
    pub spec: Value,
}

impl ModelMeta {
    pub fn param_shape(&self, name: &str) -> Result<&[usize], String> {
        self.param_shapes
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| format!("no shape for param '{name}'"))
    }

    pub fn param_elems(&self, name: &str) -> usize {
        self.param_shapes
            .get(name)
            .map(|s| s.iter().product())
            .unwrap_or(0)
    }

    pub fn is_adam(&self) -> bool {
        self.optimizer == "adam"
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub model: String,
    pub kind: String,
    pub strategy: Option<String>,
    pub file: String,
    pub inputs: Vec<TensorDesc>,
    pub outputs: Vec<TensorDesc>,
}

impl ArtifactMeta {
    /// Index of the named output (e.g. "metric:loss").
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|d| d.name == name)
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|d| d.name == name)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub source_hash: String,
    pub kernel_impl: String,
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self, String> {
        let v = json::from_file(&dir.join("manifest.json"))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Value) -> Result<Self, String> {
        let mut models = BTreeMap::new();
        for (name, m) in v
            .get("models")
            .and_then(Value::as_obj)
            .ok_or("manifest: missing models")?
        {
            let mut shapes = BTreeMap::new();
            for (p, s) in m
                .get("param_shapes")
                .and_then(Value::as_obj)
                .ok_or("manifest: missing param_shapes")?
            {
                shapes.insert(
                    p.clone(),
                    s.as_arr()
                        .ok_or("bad shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                );
            }
            let layer_meta = m
                .req_arr("layer_meta")?
                .iter()
                .map(|l| {
                    Ok(LayerMeta {
                        kind: l.req_str("kind")?.to_string(),
                        name: l.req_str("name")?.to_string(),
                        t: l.req_i64("T")? as usize,
                        d: l.req_i64("d")? as usize,
                        p: l.req_i64("p")? as usize,
                    })
                })
                .collect::<Result<Vec<_>, String>>()?;
            let str_list = |key: &str| -> Vec<String> {
                m.get(key)
                    .and_then(Value::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(|x| x.as_str().map(String::from))
                            .collect()
                    })
                    .unwrap_or_default()
            };
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    group: m.opt_str("group", "").to_string(),
                    batch: m.req_i64("batch")? as usize,
                    optimizer: m.req_str("optimizer")?.to_string(),
                    clip_fn: m.req_str("clip_fn")?.to_string(),
                    kernel_impl: m.opt_str("kernel_impl", "jnp").to_string(),
                    param_names: str_list("param_names"),
                    frozen_names: str_list("frozen_names"),
                    param_shapes: shapes,
                    layer_meta,
                    n_params: m.req_i64("n_params")? as usize,
                    spec: m.get("spec").cloned().unwrap_or(Value::Null),
                },
            );
        }
        let artifacts = v
            .req_arr("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    model: a.req_str("model")?.to_string(),
                    kind: a.req_str("kind")?.to_string(),
                    strategy: a
                        .get("strategy")
                        .and_then(Value::as_str)
                        .map(String::from),
                    file: a.req_str("file")?.to_string(),
                    inputs: a
                        .req_arr("inputs")?
                        .iter()
                        .map(TensorDesc::from_json)
                        .collect::<Result<_, _>>()?,
                    outputs: a
                        .req_arr("outputs")?
                        .iter()
                        .map(TensorDesc::from_json)
                        .collect::<Result<_, _>>()?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Manifest {
            source_hash: v.opt_str("source_hash", "").to_string(),
            kernel_impl: v.opt_str("kernel_impl", "jnp").to_string(),
            models,
            artifacts,
        })
    }

    /// Strategies available for a model's step artifacts.
    pub fn strategies_for(&self, model: &str) -> Vec<String> {
        self.artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == "step")
            .filter_map(|a| a.strategy.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn sample() -> Value {
        parse(
            r#"{
          "version": 1, "source_hash": "abc", "kernel_impl": "jnp",
          "models": {"m1": {
            "spec": {"kind": "mlp"}, "batch": 8, "optimizer": "adam",
            "clip_fn": "automatic", "group": "bench",
            "param_names": ["w"], "frozen_names": [],
            "param_shapes": {"w": [3, 4]},
            "layer_meta": [{"kind": "linear", "name": "w", "T": 1, "d": 3, "p": 4}],
            "n_params": 12, "kernel_impl": "jnp"
          }},
          "artifacts": [{
            "model": "m1", "kind": "step", "strategy": "bk",
            "file": "m1__step_bk.hlo.txt",
            "inputs": [{"name": "w", "shape": [3, 4], "dtype": "f32"}],
            "outputs": [{"name": "w", "shape": [3, 4], "dtype": "f32"},
                        {"name": "metric:loss", "shape": [], "dtype": "f32"}]
          }]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.models["m1"].batch, 8);
        assert!(m.models["m1"].is_adam());
        assert_eq!(m.models["m1"].param_shape("w").unwrap(), &[3, 4]);
        assert_eq!(m.models["m1"].layer_meta[0].d, 3);
        let a = &m.artifacts[0];
        assert_eq!(a.strategy.as_deref(), Some("bk"));
        assert_eq!(a.output_index("metric:loss"), Some(1));
        assert_eq!(a.inputs[0].elements(), 12);
        assert_eq!(a.inputs[0].bytes(), 48);
        assert_eq!(m.strategies_for("m1"), vec!["bk"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::from_json(&parse("{}").unwrap()).is_err());
        assert!(Dtype::parse("f64").is_err());
    }
}
