//! PJRT artifact executor — the original XLA-backed runtime, demoted
//! behind the `xla-runtime` feature (the `xla` crate is not buildable
//! offline; see DESIGN.md "Re-enabling the PJRT backend").
//!
//! Loads AOT artifacts (HLO text + manifest.json, written by
//! `python/compile/aot.py`) and executes them on the PJRT CPU client:
//!  * `<model>__init.hlo.txt`            — seed -> params
//!  * `<model>__eval.hlo.txt`            — params, x, y -> loss
//!  * `<model>__step_<strategy>.hlo.txt` — params, [m, v], x, y,
//!                                         [noise...], scalars -> params',
//!                                         [m', v'], metrics
//!  * `<model>__clipgrad_<strategy>`     — params, x, y, R -> clipped sums
//!  * `<model>__apply`                   — params, [m, v], grads, noise,
//!                                         scalars -> params', [m', v']
//! All computations are lowered with return_tuple=True; the output tuple
//! is decomposed by the manifest's descriptors. [`PjrtBackend`] adapts
//! this executor to the [`Backend`](super::Backend) trait.

use super::manifest::{ArtifactMeta, Manifest, ModelMeta};
use super::{AllocStats, Backend, BatchX, ModelInfo, StepHyper, StepOut};
use crate::error::{Context, Result};
use crate::{anyhow, bail};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

/// A compiled-executable cache keyed by artifact file name.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative compile seconds (reported by the coordinator).
    pub compile_secs: RefCell<f64>,
}

impl Runtime {
    /// Load the manifest and create a CPU PJRT client.
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)
            .map_err(|e| anyhow!("loading manifest from {}: {e}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_secs: RefCell::new(0.0),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.manifest.models.get(name).ok_or_else(|| {
            anyhow!(
                "model '{name}' not in manifest (have: {:?})",
                self.manifest.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact(&self, model: &str, kind: &str, strategy: Option<&str>) -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.model == model && a.kind == kind && a.strategy.as_deref() == strategy)
            .ok_or_else(|| {
                anyhow!(
                    "artifact model={model} kind={kind} strategy={strategy:?} not found \
                     (re-run `make artifacts`?)"
                )
            })
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn executable(&self, art: &ArtifactMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&art.file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(&art.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", art.file))?,
        );
        *self.compile_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(art.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs; returns the decomposed
    /// output tuple, validated against the manifest.
    pub fn execute(&self, art: &ArtifactMeta, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != art.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                art.file,
                art.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.executable(art)?;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", art.file))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = tuple.to_tuple().context("decomposing result tuple")?;
        if outs.len() != art.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                art.file,
                art.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// Build a f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal_f32: {} elements for shape {:?}", data.len(), shape);
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).context("reshaping f32 literal")
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal_i32: {} elements for shape {:?}", data.len(), shape);
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).context("reshaping i32 literal")
}

/// Scalar literals (0-d).
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Read back a f32 literal as a host vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("reading f32 literal")
}

/// Read a scalar f32 output.
pub fn scalar_of(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().context("reading f32 scalar")
}

/// Backend-neutral view of a manifest model.
pub fn model_info(meta: &ModelMeta) -> ModelInfo {
    let spec = &meta.spec;
    let kind = spec.opt_str("kind", "mlp").to_string();
    // Conv specs describe images (hw, c_in); flatten for the vector
    // data pipeline like the pre-Backend coordinator did.
    let d_in = if kind == "conv" {
        let hw = spec.opt_i64("hw", 32) as usize;
        let c = spec.opt_i64("c_in", 3) as usize;
        hw * hw * c
    } else {
        spec.opt_i64("d_in", 0) as usize
    };
    ModelInfo {
        name: meta.name.clone(),
        kind,
        batch: meta.batch,
        seq: spec.opt_i64("seq", 1) as usize,
        d_in,
        n_classes: spec.opt_i64("n_classes", spec.opt_i64("vocab", 10)) as usize,
        optimizer: meta.optimizer.clone(),
        clip_fn: meta.clip_fn.clone(),
        param_names: meta.param_names.clone(),
        param_shapes: meta.param_shapes.clone().into_iter().collect(),
        n_params: meta.n_params,
        // pjrt artifacts predate the trainability plane: fully trainable
        trainable: vec![true; meta.param_names.len()],
        trainable_preset: "all".into(),
    }
}

/// [`Backend`] adapter over the artifact executor: owns the runtime,
/// host-resident parameter/optimizer literals, and the frozen tensors.
pub struct PjrtBackend {
    rt: Runtime,
    meta: ModelMeta,
    info: ModelInfo,
    strategy: String,
    params: Vec<xla::Literal>,
    frozen: Vec<xla::Literal>,
    opt_m: Vec<xla::Literal>,
    opt_v: Vec<xla::Literal>,
}

impl PjrtBackend {
    pub fn load(cfg: &crate::config::TrainConfig) -> Result<Self> {
        let rt = Runtime::load(cfg.artifacts_dir.clone())?;
        let meta = rt.model(&cfg.model)?.clone();
        let info = model_info(&meta);
        Ok(Self {
            rt,
            meta,
            info,
            strategy: cfg.strategy.clone(),
            params: Vec::new(),
            frozen: Vec::new(),
            opt_m: Vec::new(),
            opt_v: Vec::new(),
        })
    }

    fn zeros_like_params(&self) -> Result<Vec<xla::Literal>> {
        self.meta
            .param_names
            .iter()
            .map(|name| {
                let shape = self.meta.param_shape(name).map_err(|e| anyhow!(e))?;
                let n: usize = shape.iter().product();
                literal_f32(&vec![0f32; n], shape)
            })
            .collect()
    }

    fn noise_literals(&self, noise: &[Vec<f32>]) -> Result<Vec<xla::Literal>> {
        if noise.len() != self.meta.param_names.len() {
            bail!(
                "got {} noise tensors, expected {}",
                noise.len(),
                self.meta.param_names.len()
            );
        }
        noise
            .iter()
            .zip(&self.meta.param_names)
            .map(|(z, name)| literal_f32(z, self.meta.param_shape(name).map_err(|e| anyhow!(e))?))
            .collect()
    }

    fn batch_literals(&self, art: &ArtifactMeta, x: &BatchX, y: &[i32])
        -> Result<(xla::Literal, xla::Literal)> {
        let xi = art.input_index("x").context("artifact missing x input")?;
        let yi = art.input_index("y").context("artifact missing y input")?;
        let xs = &art.inputs[xi].shape;
        let ys = &art.inputs[yi].shape;
        let xl = match x {
            BatchX::F32(v) => literal_f32(v, xs)?,
            BatchX::I32(v) => literal_i32(v, xs)?,
        };
        Ok((xl, literal_i32(y, ys)?))
    }
}

impl Backend for PjrtBackend {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn strategy(&self) -> &str {
        &self.strategy
    }

    fn init(&mut self, seed: u64) -> Result<()> {
        let init = self.rt.artifact(&self.meta.name, "init", None)?.clone();
        let seed = scalar_i32(seed as i32);
        let outs = self.rt.execute(&init, &[&seed])?;
        let n_tr = self.meta.param_names.len();
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n_tr).collect();
        self.frozen = it.collect();
        if self.meta.is_adam() {
            self.opt_m = self.zeros_like_params()?;
            self.opt_v = self.zeros_like_params()?;
        }
        Ok(())
    }

    fn eval_loss(&mut self, x: &BatchX, y: &[i32]) -> Result<f32> {
        let eval = self.rt.artifact(&self.meta.name, "eval", None)?.clone();
        let (xl, yl) = self.batch_literals(&eval, x, y)?;
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.extend(self.frozen.iter());
        args.push(&xl);
        args.push(&yl);
        scalar_of(&self.rt.execute(&eval, &args)?[0])
    }

    fn step(&mut self, x: &BatchX, y: &[i32], noise: &[Vec<f32>], h: &StepHyper) -> Result<StepOut> {
        let art = self
            .rt
            .artifact(&self.meta.name, "step", Some(&self.strategy))?
            .clone();
        let (xl, yl) = self.batch_literals(&art, x, y)?;
        let noise_lits = if noise.is_empty() {
            Vec::new()
        } else {
            self.noise_literals(noise)?
        };
        let scalars = [
            scalar_f32(h.lr),
            scalar_f32(h.clip),
            scalar_f32(h.sigma_r),
            scalar_f32(h.logical_batch),
            scalar_f32(h.step),
        ];
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.extend(self.frozen.iter());
        if self.meta.is_adam() {
            args.extend(self.opt_m.iter());
            args.extend(self.opt_v.iter());
        }
        args.push(&xl);
        args.push(&yl);
        args.extend(noise_lits.iter());
        args.extend(scalars.iter());

        let outs = self.rt.execute(&art, &args)?;
        let loss = scalar_of(&outs[art.output_index("metric:loss").context("loss output")?])?;
        let mean_clip = art
            .output_index("metric:mean_clip")
            .map(|i| scalar_of(&outs[i]).unwrap_or(1.0))
            .unwrap_or(1.0);
        let n_tr = self.meta.param_names.len();
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n_tr).collect();
        if self.meta.is_adam() {
            self.opt_m = (&mut it).take(n_tr).collect();
            self.opt_v = (&mut it).take(n_tr).collect();
        }
        Ok(StepOut {
            loss,
            mean_clip,
            group_clip: vec![mean_clip],
        })
    }

    fn clipped_grads(&mut self, x: &BatchX, y: &[i32], clip: f32)
        -> Result<(Vec<Vec<f32>>, StepOut)> {
        let cg = self
            .rt
            .artifact(&self.meta.name, "clipgrad", Some(&self.strategy))?
            .clone();
        let (xl, yl) = self.batch_literals(&cg, x, y)?;
        let clip_lit = scalar_f32(clip);
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.extend(self.frozen.iter());
        args.push(&xl);
        args.push(&yl);
        args.push(&clip_lit);
        let outs = self.rt.execute(&cg, &args)?;
        let loss = scalar_of(&outs[cg.output_index("metric:loss").context("loss output")?])?;
        let mean_clip = scalar_of(&outs[cg.output_index("metric:mean_clip").context("clip output")?])?;
        let n_tr = self.meta.param_names.len();
        let grads: Vec<Vec<f32>> = outs[..n_tr]
            .iter()
            .map(to_vec_f32)
            .collect::<Result<_>>()?;
        Ok((
            grads,
            StepOut {
                loss,
                mean_clip,
                group_clip: vec![mean_clip],
            },
        ))
    }

    fn apply_update(&mut self, grads: &[Vec<f32>], noise: &[Vec<f32>], h: &StepHyper) -> Result<()> {
        let apply = self.rt.artifact(&self.meta.name, "apply", None)?.clone();
        let n_tr = self.meta.param_names.len();
        if grads.len() != n_tr {
            bail!("apply got {} grad tensors, expected {n_tr}", grads.len());
        }
        let grad_lits: Vec<xla::Literal> = grads
            .iter()
            .enumerate()
            .map(|(i, g)| {
                literal_f32(
                    g,
                    self.meta
                        .param_shape(&self.meta.param_names[i])
                        .map_err(|e| anyhow!(e))?,
                )
            })
            .collect::<Result<_>>()?;
        let noise_lits = if noise.is_empty() {
            self.zeros_like_params()?
        } else {
            self.noise_literals(noise)?
        };
        let scalars = [
            scalar_f32(h.lr),
            scalar_f32(h.sigma_r),
            scalar_f32(h.logical_batch),
            scalar_f32(h.step),
        ];
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        if self.meta.is_adam() {
            args.extend(self.opt_m.iter());
            args.extend(self.opt_v.iter());
        }
        args.extend(grad_lits.iter());
        args.extend(noise_lits.iter());
        args.extend(scalars.iter());
        let outs = self.rt.execute(&apply, &args)?;
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n_tr).collect();
        if self.meta.is_adam() {
            self.opt_m = (&mut it).take(n_tr).collect();
            self.opt_v = (&mut it).take(n_tr).collect();
        }
        Ok(())
    }

    fn state(&self) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::new();
        for lit in self.params.iter().chain(self.opt_m.iter()).chain(self.opt_v.iter()) {
            out.push(to_vec_f32(lit)?);
        }
        Ok(out)
    }

    fn load_state(&mut self, tensors: Vec<Vec<f32>>) -> Result<()> {
        let n_tr = self.meta.param_names.len();
        let mut lits = Vec::with_capacity(tensors.len());
        for (i, data) in tensors.iter().enumerate() {
            let name = &self.meta.param_names[i % n_tr];
            lits.push(literal_f32(
                data,
                self.meta.param_shape(name).map_err(|e| anyhow!(e))?,
            )?);
        }
        let mut it = lits.into_iter();
        self.params = (&mut it).take(n_tr).collect();
        if self.meta.is_adam() {
            self.opt_m = (&mut it).take(n_tr).collect();
            self.opt_v = (&mut it).take(n_tr).collect();
        }
        Ok(())
    }

    fn compile_secs(&self) -> f64 {
        *self.rt.compile_secs.borrow()
    }

    fn alloc_stats(&self) -> AllocStats {
        AllocStats::default()
    }
}
