//! Vocab head tied to the embedding table: `out = x · W^T` where `W` is
//! the **owning embedding's** `(vocab, d)` tensor (the GPT-2
//! `lm_head = wte^T` convention), no bias.
//!
//! The layer holds no tensor of its own — its single param slot is a
//! canonical-tensor alias resolved by the backend's parameter-slot
//! indirection (see `NativeBackend::builder`), so `params[0]` here
//! *is* the embedding table. Both norm routes work off the same
//! generalized-linear structure as [`super::Linear`], with the roles of
//! `a`/`g` swapped in the weighted sum so the clipped gradient lands in
//! the canonical `(vocab, d)` orientation — accumulated (`+=`) into the
//! very tensor-slot the embedding's scatter-add fills, which is exactly
//! how the combined `G_emb + G_head` gradient of a shared tensor is
//! assembled. The `2<G_emb, G_head>` norm cross term is the *owner's*
//! job ([`super::DpLayer::accum_tied_cross_sq_norms`] on `Embedding`),
//! driven by the tape.
//!
//! Under the fused schedule the head finalizes with the **owner's**
//! clipping group (shared tensors must share a group, so the alias's
//! [`super::DpLayer::finalize_group`] — the default dispatch — runs at
//! the bottom of the walk, right before the embedding's, preserving
//! the alias-then-owner accumulation order of the unfused sweep). Its
//! book-kept output gradient therefore lives for the whole walk, where
//! it doubles as the owner's cross-term input — the fused walk takes
//! no separate `B*T*vocab` stash copy.
//!
//! The stored-psg route is deliberately unsupported (`psg_len() == 0`):
//! `psg_instantiate` materializes `a^T g` in `(d, vocab)` order, the
//! transpose of the canonical tensor, so reusing it for the weighted
//! sum would need a transposing kernel for a path the mixed dispatch
//! essentially never picks for a `d x vocab` head.

#![allow(clippy::too_many_arguments)]

use super::super::kernels;
use super::{Ctx, DpLayer, LayerIn, NormRoute, Scratch};
use crate::arch::{LayerDims, LayerKind};

/// `out[r, v] = x[r, :] · table[v, :]` over a `(vocab, d)` alias tensor.
pub struct TiedLinear {
    name: String,
    d: usize,
    vocab: usize,
}

impl TiedLinear {
    /// Build a `d -> vocab` head viewing a `(vocab, d)` canonical tensor.
    pub fn new(name: String, d: usize, vocab: usize) -> Self {
        Self { name, d, vocab }
    }
}

impl DpLayer for TiedLinear {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.d
    }

    fn out_width(&self) -> usize {
        self.vocab
    }

    fn n_param_tensors(&self) -> usize {
        1
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        // the canonical (owner's) shape, not the transposed view
        vec![vec![self.vocab, self.d]]
    }

    fn dims(&self, t: usize) -> Option<LayerDims> {
        Some(LayerDims {
            kind: LayerKind::TiedLinear,
            name: self.name.clone(),
            t: t as u64,
            d: self.d as u64,
            p: self.vocab as u64,
        })
    }

    // init: intentionally the default no-op — the owning embedding
    // initializes the shared tensor.

    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        _cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        // out[r, v] = x[r, :] · W[v, :] — exactly the backward_data
        // contraction with (d, p) read as (vocab, d_in)
        kernels::backward_data(
            x.feat(),
            &params[0],
            out,
            ctx.rows(),
            self.vocab,
            self.d,
            ctx.threads,
        );
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        _out: &[f32],
        params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        ctx: Ctx,
    ) {
        // dL/dx = g · W, a plain forward matmul through (vocab, d)
        kernels::linear_forward(
            g_out,
            &params[0],
            None,
            g_in,
            ctx.rows(),
            self.vocab,
            self.d,
            ctx.threads,
        );
    }

    fn accum_sq_norms(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        route: NormRoute,
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        // ||G_head_i||^2 = sum_{t,s} (x_t·x_s)(g_t·g_s): the transpose
        // shares its Frobenius norm, so both routes are verbatim Linear
        let (b, t) = (ctx.b, ctx.t);
        match route {
            NormRoute::Ghost => kernels::ghost_norm(
                x.feat(),
                g_out,
                b,
                t,
                self.d,
                self.vocab,
                scratch.gram_a,
                scratch.gram_g,
                sq,
                ctx.threads,
            ),
            NormRoute::Inst => kernels::psg_norms_streaming(
                x.feat(),
                g_out,
                b,
                t,
                self.d,
                self.vocab,
                scratch.stream,
                sq,
                ctx.threads,
            ),
        }
    }

    fn clipped_grads(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        // grads[0] is the canonical (vocab, d) tensor's accumulator:
        // out[v, j] += sum_i c_i sum_t g_i[t, v] x_i[t, j] — weighted_grad
        // with the a/g roles swapped lands the transposed-view gradient
        // in canonical orientation directly.
        kernels::weighted_grad(
            g_out,
            x.feat(),
            c,
            ctx.b,
            ctx.t,
            self.vocab,
            self.d,
            scratch.partials,
            &mut grads[0],
            ctx.threads,
        );
    }
}
