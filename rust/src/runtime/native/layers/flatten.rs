//! Stateless flatten: the HWC-per-sample activation layout means a
//! sample's `(h*w, c)` spatial block is already one contiguous run of
//! `n = c*h*w` floats, so flatten is a pure identity copy — it exists
//! in the plan as the explicit shape transition from the conv trunk's
//! spatial geometry to the linear tail's feature rows, and as the
//! marker the complexity walks use to stop interpreting widths
//! spatially. Backward is the same identity.

use super::{Ctx, DpLayer, LayerIn, Scratch};
use crate::arch::LayerDims;

/// Identity shape transition over `n` features per sample.
pub struct Flatten {
    name: String,
    n: usize,
}

impl Flatten {
    /// Build a flatten over `n = c*h*w` features.
    pub fn new(name: String, n: usize) -> Self {
        Self { name, n }
    }
}

impl DpLayer for Flatten {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.n
    }

    fn out_width(&self) -> usize {
        self.n
    }

    fn n_param_tensors(&self) -> usize {
        0
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        Vec::new()
    }

    fn dims(&self, _t: usize) -> Option<LayerDims> {
        None
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        _params: &[Vec<f32>],
        out: &mut [f32],
        _cache: &mut [Vec<f32>],
        _ctx: Ctx,
    ) {
        out.copy_from_slice(x.feat());
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        _out: &[f32],
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        _ctx: Ctx,
    ) {
        g_in.copy_from_slice(g_out);
    }
}
