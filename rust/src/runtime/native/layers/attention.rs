//! Causal multi-head self-attention: the transformer's core as one
//! composable [`DpLayer`].
//!
//! The layer is a composite of three stages —
//!
//! ```text
//! x (rows, d) --W_qkv-> qkv (rows, 3d) --softmax core-> ao (rows, d) --W_o-> out (rows, d)
//! ```
//!
//! — where only the fused QKV projection `(d, 3d)` and the output
//! projection `(d, d)` carry parameters, and both are *generalized
//! linear* in the paper's sense: their per-sample gradients are
//! `x_i^T g_qkv_i` and `ao_i^T g_out_i`, so their ghost norms come from
//! the very same `{B, T, T}` Gram kernels the plain `Linear` layer uses.
//! The softmax core is parameter-free; its backward is **recomputed**
//! from the cached attention probabilities whenever a walk needs the
//! internal gradients (`g_ao`, `g_qkv`), rather than stored per sample —
//! recompute costs `O(B T^2 d)` time per walk while storing softmax
//! gradients would add `B*H*T^2` state per backward stage (see
//! DESIGN.md, "Causal self-attention").
//!
//! Forward caches (in [`DpLayer::cache_lens`] order): `qkv` (rows, 3d),
//! `probs` (B, H, T, T — the causal softmax weights), and `ao`
//! (rows, d — the input of the output projection). The recompute
//! scratch `[g_ao | g_qkv]` lives in [`Scratch::attn`].

#![allow(clippy::too_many_arguments)]

use super::super::kernels;
use super::{Ctx, DpLayer, LayerIn, NormRoute, Scratch};
use crate::arch::{LayerDims, LayerKind};
use crate::util::rng::{GaussianSource, Xoshiro256};

/// `out = CausalMHA(x)` with a fused QKV projection and an output
/// projection; `heads` must divide the model width `d`.
pub struct Attention {
    name: String,
    d: usize,
    heads: usize,
    /// Per-tensor trainability `[w_qkv, b_qkv, w_o, b_o]` (bias-only
    /// fine-tuning freezes both projections). A fully frozen layer
    /// still flows `backward_data` — see the note there.
    train: [bool; 4],
}

impl Attention {
    /// Build a causal self-attention layer over width `d` with `heads`
    /// heads (`d % heads == 0`, validated by `build_stack`), fully
    /// trainable.
    pub fn new(name: String, d: usize, heads: usize) -> Self {
        debug_assert!(heads > 0 && d % heads == 0);
        Self {
            name,
            d,
            heads,
            train: [true; 4],
        }
    }

    /// Set the `[w_qkv, b_qkv, w_o, b_o]` trainability mask.
    pub fn with_trainable(mut self, train: [bool; 4]) -> Self {
        self.train = train;
        self
    }

    /// Number of attention heads.
    pub fn heads(&self) -> usize {
        self.heads
    }

    /// Recompute the internal backward chain
    /// `g_out -> g_ao -> (softmax backward) -> g_qkv` from the forward
    /// caches into the `attn` scratch (`[g_ao | g_qkv]` layout).
    /// Returns views of the two freshly written slices.
    fn recompute_core<'s>(
        &self,
        g_out: &[f32],
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        attn: &'s mut [f32],
        ctx: Ctx,
    ) -> (&'s [f32], &'s [f32]) {
        let rows = ctx.rows();
        let dm = self.d;
        let (g_ao, rest) = attn.split_at_mut(rows * dm);
        let (g_qkv, _) = rest.split_at_mut(rows * 3 * dm);
        kernels::backward_data(g_out, &params[2], g_ao, rows, dm, dm, ctx.threads);
        kernels::attention_backward(
            &cache[0], &cache[1], g_ao, g_qkv, ctx.b, ctx.t, dm, self.heads, ctx.threads,
        );
        (&*g_ao, &*g_qkv)
    }
}

impl DpLayer for Attention {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.d
    }

    fn out_width(&self) -> usize {
        self.d
    }

    fn n_param_tensors(&self) -> usize {
        4
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![
            vec![self.d, 3 * self.d],
            vec![3 * self.d],
            vec![self.d, self.d],
            vec![self.d],
        ]
    }

    fn dims(&self, t: usize) -> Option<LayerDims> {
        // Attention dims convention: d = model width, p = head count
        // (the complexity engine decomposes into the two generalized
        // linear sublayers; see `complexity::attention_sublayers`).
        Some(LayerDims {
            kind: LayerKind::Attention,
            name: self.name.clone(),
            t: t as u64,
            d: self.d as u64,
            p: self.heads as u64,
        })
    }

    fn cache_lens(&self, ctx: Ctx) -> Vec<usize> {
        // qkv (rows, 3d) + probs (B, H, T, T) + ao (rows, d)
        vec![
            ctx.rows() * 3 * self.d,
            ctx.b * self.heads * ctx.t * ctx.t,
            ctx.rows() * self.d,
        ]
    }

    fn init(&self, rng: Xoshiro256, params: &mut [Vec<f32>], _is_head: bool) {
        // GPT-style N(0, 1/d) for both projections, zero biases.
        let scale = (1.0 / self.d as f32).sqrt();
        let mut gs = GaussianSource::from_rng(rng);
        gs.fill_f32(&mut params[0]);
        for v in params[0].iter_mut() {
            *v *= scale;
        }
        for v in params[1].iter_mut() {
            *v = 0.0;
        }
        gs.fill_f32(&mut params[2]);
        for v in params[2].iter_mut() {
            *v *= scale;
        }
        for v in params[3].iter_mut() {
            *v = 0.0;
        }
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let rows = ctx.rows();
        let (qkv_c, rest) = cache.split_at_mut(1);
        let (probs_c, ao_c) = rest.split_at_mut(1);
        kernels::linear_forward(
            x.feat(),
            &params[0],
            Some(&params[1]),
            &mut qkv_c[0],
            rows,
            self.d,
            3 * self.d,
            ctx.threads,
        );
        kernels::attention_forward(
            &qkv_c[0],
            &mut probs_c[0],
            &mut ao_c[0],
            ctx.b,
            ctx.t,
            self.d,
            self.heads,
            ctx.threads,
        );
        kernels::linear_forward(
            &ao_c[0],
            &params[2],
            Some(&params[3]),
            out,
            rows,
            self.d,
            self.d,
            ctx.threads,
        );
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        _out: &[f32],
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        ctx: Ctx,
    ) {
        // Tape invariant: attention always has parameters, so every walk
        // calls this layer's `accum_sq_norms` or `clipped_grads` with
        // the *same* output gradient immediately before `backward_data`
        // (see `StackRun::norm_pass` / `clipped_recompute` /
        // `fused_pass`). That call left `[g_ao | g_qkv]` for this layer
        // in `Scratch::attn`, so the O(B T^2 d) softmax backward is NOT
        // run a second time here — only the final projection through
        // W_qkv remains. The fused schedule preserves the invariant by
        // finalizing a clipping group only *after* the boundary layer's
        // `backward_data`: a group finalize may refill `Scratch::attn`
        // for another attention layer (each `finalize_group` recomputes
        // its own core), but never between one layer's norm hook and
        // its `backward_data`. The differential harness and the
        // full-stack FD tests pin this invariant; breaking the call
        // order produces garbage gradients they catch immediately.
        //
        // Exception: a fully *frozen* attention layer gets no norm/sum
        // hook at all (the tape skips it), so nothing filled
        // `Scratch::attn` — recompute the core here instead. This is
        // the one softmax backward the frozen layer pays per walk;
        // partially frozen (bias-only) layers still hook and keep the
        // shared recompute.
        if self.train == [false; 4] {
            self.recompute_core(g_out, params, cache, scratch.attn, ctx);
        }
        let rows = ctx.rows();
        let dm = self.d;
        let g_qkv = &scratch.attn[rows * dm..rows * 4 * dm];
        kernels::backward_data(g_qkv, &params[0], g_in, rows, dm, 3 * dm, ctx.threads);
    }

    fn accum_sq_norms(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        route: NormRoute,
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let (b, t) = (ctx.b, ctx.t);
        let dm = self.d;
        // recompute the internal gradients from the forward caches
        // (backward_data reuses them — see the invariant there)
        let (_g_ao, g_qkv) = self.recompute_core(g_out, params, cache, scratch.attn, ctx);
        // both projections are generalized linear: the same ghost /
        // streamed-instantiation dispatch as `Linear`, each gated on
        // its tensor's trainability
        if self.train[0] {
            match route {
                NormRoute::Ghost => kernels::ghost_norm(
                    x.feat(),
                    g_qkv,
                    b,
                    t,
                    dm,
                    3 * dm,
                    scratch.gram_a,
                    scratch.gram_g,
                    sq,
                    ctx.threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    x.feat(),
                    g_qkv,
                    b,
                    t,
                    dm,
                    3 * dm,
                    scratch.stream,
                    sq,
                    ctx.threads,
                ),
            }
        }
        if self.train[2] {
            match route {
                NormRoute::Ghost => kernels::ghost_norm(
                    &cache[2],
                    g_out,
                    b,
                    t,
                    dm,
                    dm,
                    scratch.gram_a,
                    scratch.gram_g,
                    sq,
                    ctx.threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    &cache[2],
                    g_out,
                    b,
                    t,
                    dm,
                    dm,
                    scratch.stream,
                    sq,
                    ctx.threads,
                ),
            }
        }
        if self.train[1] {
            kernels::bias_sq_norms(g_qkv, b, t, 3 * dm, scratch.small, sq, ctx.threads);
        }
        if self.train[3] {
            kernels::bias_sq_norms(g_out, b, t, dm, scratch.small, sq, ctx.threads);
        }
    }

    fn clipped_grads(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let (b, t) = (ctx.b, ctx.t);
        let dm = self.d;
        let (_g_ao, g_qkv) = self.recompute_core(g_out, params, cache, scratch.attn, ctx);
        let [gw_qkv, gb_qkv, gw_o, gb_o] = grads else {
            unreachable!("{}: attention has exactly 4 param tensors", self.name);
        };
        if self.train[0] {
            kernels::weighted_grad(
                x.feat(),
                g_qkv,
                c,
                b,
                t,
                dm,
                3 * dm,
                scratch.partials,
                gw_qkv,
                ctx.threads,
            );
        }
        if self.train[1] {
            kernels::bias_grad(g_qkv, c, b, t, 3 * dm, gb_qkv);
        }
        if self.train[2] {
            kernels::weighted_grad(
                &cache[2],
                g_out,
                c,
                b,
                t,
                dm,
                dm,
                scratch.partials,
                gw_o,
                ctx.threads,
            );
        }
        if self.train[3] {
            kernels::bias_grad(g_out, c, b, t, dm, gb_o);
        }
    }
}
