//! LayerNorm over the feature axis with affine `(gamma, beta)`.
//!
//! The forward pass caches `xhat` (normalized input) and `inv_std` per
//! row — the two tensors the backward and the per-sample (gamma, beta)
//! gradients need. Norm layers always take the instantiation route
//! (their per-sample grads are `O(p)`, trivially small — paper
//! Section 2.2's "norm layers" convention). Note the book-kept output
//! gradient is still a full `B*T*width` buffer, so LayerNorms count in
//! the fused schedule's g-cache gauge like any other trainable layer
//! (the per-group finalize is the default dispatch to
//! `ln_weighted_grads`).

#![allow(clippy::too_many_arguments)]

use super::super::kernels;
use super::{Ctx, DpLayer, LayerIn, NormRoute, Scratch};
use crate::arch::{LayerDims, LayerKind};
use crate::util::rng::Xoshiro256;

/// `out = gamma * (x - mu) / sqrt(var + eps) + beta`, per row.
pub struct LayerNorm {
    name: String,
    width: usize,
}

impl LayerNorm {
    /// Build a LayerNorm over `width` features.
    pub fn new(name: String, width: usize) -> Self {
        Self { name, width }
    }
}

impl DpLayer for LayerNorm {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.width
    }

    fn out_width(&self) -> usize {
        self.width
    }

    fn n_param_tensors(&self) -> usize {
        2
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![vec![self.width], vec![self.width]]
    }

    fn dims(&self, t: usize) -> Option<LayerDims> {
        Some(LayerDims {
            kind: LayerKind::Norm,
            name: self.name.clone(),
            t: t as u64,
            d: self.width as u64,
            p: self.width as u64,
        })
    }

    fn cache_lens(&self, ctx: Ctx) -> Vec<usize> {
        // xhat (rows, width) + inv_std (rows,)
        vec![ctx.rows() * self.width, ctx.rows()]
    }

    fn init(&self, _rng: Xoshiro256, params: &mut [Vec<f32>], _is_head: bool) {
        for v in params[0].iter_mut() {
            *v = 1.0;
        }
        for v in params[1].iter_mut() {
            *v = 0.0;
        }
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let (xhat, inv_std) = cache.split_at_mut(1);
        kernels::layernorm_forward(
            x.feat(),
            &params[0],
            &params[1],
            out,
            &mut xhat[0],
            &mut inv_std[0],
            ctx.rows(),
            self.width,
        );
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        _out: &[f32],
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        ctx: Ctx,
    ) {
        kernels::layernorm_backward_data(
            g_out,
            &params[0],
            &cache[0],
            &cache[1],
            g_in,
            ctx.rows(),
            self.width,
        );
    }

    fn accum_sq_norms(
        &self,
        _x: LayerIn<'_>,
        g_out: &[f32],
        _route: NormRoute,
        _params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        kernels::ln_sq_norms(
            g_out,
            &cache[0],
            ctx.b,
            ctx.t,
            self.width,
            scratch.small,
            sq,
            ctx.threads,
        );
    }

    fn clipped_grads(
        &self,
        _x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        _params: &[Vec<f32>],
        cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let (gg, gb) = grads.split_at_mut(1);
        kernels::ln_weighted_grads(
            g_out,
            &cache[0],
            c,
            ctx.b,
            ctx.t,
            self.width,
            &mut gg[0],
            &mut gb[0],
        );
    }
}
