//! 2-D convolution as a `DpLayer` via im2col/unfold: the forward pass
//! unfolds each sample's `(h, w, cin)` HWC activation into
//! `(t_out, cin*k*k)` patch rows (cached for backward), after which the
//! convolution *is* a linear layer over `t_out = ho*wo` "tokens" of
//! width `d = cin*k*k` — exactly the `{B,T,T}` generalized-linear shape
//! attention routes through. Ghost norms, streamed/stored per-sample
//! instantiation, and clipped weighted sums therefore reuse the
//! existing SIMD kernels verbatim with `T = t_out`; the only
//! conv-specific kernels are `unfold`/`fold` (exact transposes of each
//! other), so backward-to-data is `backward_data` into the unfolded
//! gradient followed by a `fold` scatter-add.
//!
//! Layout contract: activations are HWC per sample (spatial position
//! major, channels innermost), so the `(b, t_out, cout)` output
//! gradient handed down by the tape is *directly* the right operand of
//! every norm/sum kernel — no transposes anywhere. The weight is stored
//! `(cin*k*k, cout)` row-major with patch element order
//! `(ky*k + kx)*cin + ci`, matching `unfold`'s column order and the
//! linear kernels' `(d, p)` convention.

#![allow(clippy::too_many_arguments)]

use super::super::kernels;
use super::super::model::conv_out;
use super::{Ctx, DpLayer, LayerIn, NormRoute, Scratch};
use crate::arch::{LayerDims, LayerKind};
use crate::util::rng::{GaussianSource, Xoshiro256};

/// `out[b, t, co] = sum_{ky,kx,ci} x[b, patch(t,ky,kx), ci] * W[(ky,kx,ci), co] + bias[co]`.
pub struct Conv2d {
    name: String,
    cin: usize,
    h: usize,
    w: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    /// Per-tensor trainability `[weight, bias]` (same contract as
    /// `Linear`): frozen tensors skip their norm/sum kernels, while
    /// forward and `backward_data` always run.
    train: [bool; 2],
}

impl Conv2d {
    /// Build a conv layer over `(cin, h, w)` HWC input, fully trainable.
    pub fn new(
        name: String,
        cin: usize,
        h: usize,
        w: usize,
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Self {
            name,
            cin,
            h,
            w,
            cout,
            k,
            stride,
            pad,
            train: [true, true],
        }
    }

    /// Set the `[weight, bias]` trainability mask.
    pub fn with_trainable(mut self, train: [bool; 2]) -> Self {
        self.train = train;
        self
    }

    /// Output spatial positions `ho * wo` — the conv layer's own T,
    /// independent of the spec-level `ctx.t` (conv models run at
    /// `seq = 1`; each conv layer carries its per-layer token count).
    fn t_out(&self) -> usize {
        conv_out(self.h, self.k, self.stride, self.pad) * conv_out(self.w, self.k, self.stride, self.pad)
    }

    /// Patch width `cin * k * k` — the unfolded d.
    fn d(&self) -> usize {
        self.cin * self.k * self.k
    }
}

impl DpLayer for Conv2d {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.cin * self.h * self.w
    }

    fn out_width(&self) -> usize {
        self.cout * self.t_out()
    }

    fn n_param_tensors(&self) -> usize {
        2
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![vec![self.d(), self.cout], vec![self.cout]]
    }

    fn dims(&self, _t: usize) -> Option<LayerDims> {
        // the conv layer's T is its own output spatial count, not the
        // spec-level token count the tape passes in
        Some(LayerDims {
            kind: LayerKind::Conv,
            name: self.name.clone(),
            t: self.t_out() as u64,
            d: self.d() as u64,
            p: self.cout as u64,
        })
    }

    fn psg_len(&self) -> usize {
        if self.train[0] {
            self.d() * self.cout
        } else {
            0
        }
    }

    fn cache_lens(&self, ctx: Ctx) -> Vec<usize> {
        // the unfolded patches: backward's norm/sum kernels read them as
        // the "input activation" of the equivalent linear layer
        vec![ctx.b * self.t_out() * self.d()]
    }

    fn init(&self, rng: Xoshiro256, params: &mut [Vec<f32>], _is_head: bool) {
        // He init over the patch fan-in (conv layers feed ReLUs; a conv
        // is never the damped head)
        let scale = (2.0 / self.d() as f32).sqrt();
        let mut gs = GaussianSource::from_rng(rng);
        gs.fill_f32(&mut params[0]);
        for v in params[0].iter_mut() {
            *v *= scale;
        }
        for v in params[1].iter_mut() {
            *v = 0.0;
        }
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        kernels::unfold(
            x.feat(),
            ctx.b,
            self.cin,
            self.h,
            self.w,
            self.k,
            self.stride,
            self.pad,
            &mut cache[0],
            ctx.threads,
        );
        kernels::linear_forward(
            &cache[0],
            &params[0],
            Some(&params[1]),
            out,
            ctx.b * self.t_out(),
            self.d(),
            self.cout,
            ctx.threads,
        );
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        _out: &[f32],
        params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        ctx: Ctx,
    ) {
        // unfolded gradient in the composite-layer scratch, then fold
        // (the exact transpose of unfold) scatter-adds it back onto the
        // input's HWC geometry
        let n_unf = ctx.b * self.t_out() * self.d();
        let g_unf = &mut scratch.attn[..n_unf];
        kernels::backward_data(
            g_out,
            &params[0],
            g_unf,
            ctx.b * self.t_out(),
            self.d(),
            self.cout,
            ctx.threads,
        );
        kernels::fold(
            g_unf,
            ctx.b,
            self.cin,
            self.h,
            self.w,
            self.k,
            self.stride,
            self.pad,
            g_in,
            ctx.threads,
        );
    }

    fn accum_sq_norms(
        &self,
        _x: LayerIn<'_>,
        g_out: &[f32],
        route: NormRoute,
        _params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let (b, t) = (ctx.b, self.t_out());
        if self.train[0] {
            match route {
                NormRoute::Ghost => kernels::ghost_norm(
                    &cache[0],
                    g_out,
                    b,
                    t,
                    self.d(),
                    self.cout,
                    scratch.gram_a,
                    scratch.gram_g,
                    sq,
                    ctx.threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    &cache[0],
                    g_out,
                    b,
                    t,
                    self.d(),
                    self.cout,
                    scratch.stream,
                    sq,
                    ctx.threads,
                ),
            }
        }
        if self.train[1] {
            kernels::bias_sq_norms(g_out, b, t, self.cout, scratch.small, sq, ctx.threads);
        }
    }

    fn clipped_grads(
        &self,
        _x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        _params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let (gw, gb) = grads.split_at_mut(1);
        let (b, t) = (ctx.b, self.t_out());
        if self.train[0] {
            kernels::weighted_grad(
                &cache[0],
                g_out,
                c,
                b,
                t,
                self.d(),
                self.cout,
                scratch.partials,
                &mut gw[0],
                ctx.threads,
            );
        }
        if self.train[1] {
            kernels::bias_grad(g_out, c, b, t, self.cout, &mut gb[0]);
        }
    }

    fn psg_norms_stored(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        store: &mut [f32],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let (b, t) = (ctx.b, self.t_out());
        debug_assert!(self.train[0], "stored-psg route requires a trainable weight");
        // this hook has no cache access, so re-unfold the input into the
        // composite-layer scratch (sized >= b * t_out * d for conv)
        let n_unf = b * t * self.d();
        let patches = &mut scratch.attn[..n_unf];
        kernels::unfold(
            x.feat(),
            b,
            self.cin,
            self.h,
            self.w,
            self.k,
            self.stride,
            self.pad,
            patches,
            ctx.threads,
        );
        kernels::psg_instantiate(patches, g_out, b, t, self.d(), self.cout, store, ctx.threads);
        kernels::sq_norms_from_psg(store, b, self.d() * self.cout, sq, ctx.threads);
        if self.train[1] {
            kernels::bias_sq_norms(g_out, b, t, self.cout, scratch.small, sq, ctx.threads);
        }
    }

    fn psg_weighted_sum(
        &self,
        store: &[f32],
        g_out: &[f32],
        c: &[f32],
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let (gw, gb) = grads.split_at_mut(1);
        kernels::weighted_sum_psg(store, c, ctx.b, self.d(), self.cout, &mut gw[0], ctx.threads);
        if self.train[1] {
            kernels::bias_grad(g_out, Some(c), ctx.b, self.t_out(), self.cout, &mut gb[0]);
        }
    }
}
