//! Stateless 2-D pooling (average / max) over non-overlapping `win×win`
//! windows of an HWC activation. Contributes no norms and no gradients;
//! the tape only routes the data gradient through it. Average pooling's
//! backward is the exact transpose (spread `g / win²` uniformly); max
//! pooling's backward recomputes the argmax per (window, channel) from
//! the cached *input* activation the tape already holds — first
//! occurrence in scan order wins ties, so the route is deterministic
//! and no index cache is needed.

use super::super::kernels;
use super::super::model::PoolKind;
use super::{Ctx, DpLayer, LayerIn, Scratch};
use crate::arch::LayerDims;

/// Non-overlapping `win×win` pooling over `(c, h, w)` HWC input.
pub struct Pool2d {
    name: String,
    kind: PoolKind,
    c: usize,
    h: usize,
    w: usize,
    win: usize,
}

impl Pool2d {
    /// Build a pooling layer; `win` must tile `h` and `w` exactly
    /// (validated by the plan).
    pub fn new(name: String, kind: PoolKind, c: usize, h: usize, w: usize, win: usize) -> Self {
        Self {
            name,
            kind,
            c,
            h,
            w,
            win,
        }
    }
}

impl DpLayer for Pool2d {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.c * self.h * self.w
    }

    fn out_width(&self) -> usize {
        self.c * (self.h / self.win) * (self.w / self.win)
    }

    fn n_param_tensors(&self) -> usize {
        0
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        Vec::new()
    }

    fn dims(&self, _t: usize) -> Option<LayerDims> {
        None
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        _params: &[Vec<f32>],
        out: &mut [f32],
        _cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        match self.kind {
            PoolKind::Avg => kernels::avgpool2d(
                x.feat(),
                ctx.b,
                self.c,
                self.h,
                self.w,
                self.win,
                out,
                ctx.threads,
            ),
            PoolKind::Max => kernels::maxpool2d(
                x.feat(),
                ctx.b,
                self.c,
                self.h,
                self.w,
                self.win,
                out,
                ctx.threads,
            ),
        }
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        x: LayerIn<'_>,
        _out: &[f32],
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        ctx: Ctx,
    ) {
        match self.kind {
            PoolKind::Avg => kernels::avgpool2d_backward(
                g_out,
                ctx.b,
                self.c,
                self.h,
                self.w,
                self.win,
                g_in,
                ctx.threads,
            ),
            PoolKind::Max => kernels::maxpool2d_backward(
                x.feat(),
                g_out,
                ctx.b,
                self.c,
                self.h,
                self.w,
                self.win,
                g_in,
                ctx.threads,
            ),
        }
    }
}
