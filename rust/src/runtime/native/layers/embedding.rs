//! Token embedding layer: a `(vocab, dim)` lookup table consuming i32
//! token ids. Must be the first layer of its stack (nothing to
//! back-propagate into).
//!
//! The per-sample gradient has rows only at the sample's token ids, so
//! its squared norm ghosts with a **token-equality mask** in place of
//! the activation Gram (`ghost_preferred` is always true — per-sample
//! instantiation would be `vocab * dim` per sample). The clipped sum
//! is a cheap serial scatter-add, so the stored-psg route is never
//! needed either.

#![allow(clippy::too_many_arguments)]

use super::super::kernels;
use super::{Ctx, DpLayer, LayerIn, NormRoute, Scratch};
use crate::arch::{LayerDims, LayerKind};
use crate::util::rng::{GaussianSource, Xoshiro256};

/// `out[r, :] = table[tokens[r], :]`.
pub struct Embedding {
    name: String,
    vocab: usize,
    dim: usize,
}

impl Embedding {
    /// Build a `(vocab, dim)` embedding table.
    pub fn new(name: String, vocab: usize, dim: usize) -> Self {
        Self { name, vocab, dim }
    }
}

impl DpLayer for Embedding {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        0
    }

    fn out_width(&self) -> usize {
        self.dim
    }

    fn n_param_tensors(&self) -> usize {
        1
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![vec![self.vocab, self.dim]]
    }

    fn dims(&self, t: usize) -> Option<LayerDims> {
        Some(LayerDims {
            kind: LayerKind::Embedding,
            name: self.name.clone(),
            t: t as u64,
            d: self.vocab as u64,
            p: self.dim as u64,
        })
    }

    fn init(&self, rng: Xoshiro256, params: &mut [Vec<f32>], _is_head: bool) {
        let scale = (1.0 / self.dim as f32).sqrt();
        let mut gs = GaussianSource::from_rng(rng);
        gs.fill_f32(&mut params[0]);
        for v in params[0].iter_mut() {
            *v *= scale;
        }
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        _cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        kernels::embedding_forward(x.tokens(), &params[0], out, ctx.rows(), self.dim, ctx.threads);
    }

    fn accum_sq_norms(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        _route: NormRoute,
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        // The token-equality ghost norm is exact, so the route decision
        // is moot: every strategy takes this path.
        kernels::embedding_sq_norms(x.tokens(), g_out, ctx.b, ctx.t, self.dim, sq, ctx.threads);
    }

    fn clipped_grads(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        kernels::embedding_weighted_grad(x.tokens(), g_out, c, ctx.b, ctx.t, self.dim, &mut grads[0]);
    }

    /// Tied-head cross term (the table is shared with a transposed
    /// `TiedLinear` vocab head): `sq[i] += 2 <G_emb_i, G_head_i>`,
    /// contracted in O(T^2 d) without materializing either `(vocab, d)`
    /// gradient — the third Gram next to the token-equality mask and
    /// the head's activation/gradient Grams. `alias_g` is a stash copy
    /// on the two-pass norm walk and the head's still-live book-kept
    /// gradient on the fused walk (same bits either way — the shared
    /// group finalizes only after this hook runs).
    fn accum_tied_cross_sq_norms(
        &self,
        x: LayerIn<'_>,
        g_own: &[f32],
        alias_x: &[f32],
        alias_g: &[f32],
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        kernels::tied_cross_sq_norms(
            x.tokens(),
            g_own,
            alias_x,
            alias_g,
            ctx.b,
            ctx.t,
            self.dim,
            self.vocab,
            sq,
            ctx.threads,
        );
    }
}
