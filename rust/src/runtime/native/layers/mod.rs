//! Composable per-layer DP modules for the native backend.
//!
//! The Book-Keeping algorithm is fundamentally *per-layer*: during the
//! backward pass each trainable layer contributes a per-sample squared
//! gradient-norm term (via the ghost-norm trick or per-sample
//! instantiation, whichever `ghost_preferred` picks), and after the
//! clip factors are known the clipped weighted gradient sum is
//! assembled layer by layer from the book-kept caches. [`DpLayer`]
//! captures exactly that contract, and [`StackRun`] threads the
//! one-pass / two-pass BK schedules through an arbitrary layer stack —
//! the one-pass default is the *fused* walk ([`StackRun::fused_pass`]),
//! which finalizes each clipping group's clip factor and clipped sum at
//! the group boundary and frees the group's g-caches mid-walk —
//! so Embedding, LayerNorm, and causal self-[`Attention`] (including
//! transformer residual skips, see [`StackRun::residuals`]) run
//! natively next to Linear + ReLU without touching the scheduler.
//! Shared tensors (the GPT-2 `lm_head = wte^T` tie, [`TiedLinear`]) are
//! expressed through canonical-tensor slot indirection
//! ([`StackRun::slots`]) plus a norm-walk cross term
//! ([`StackRun::alias_of`]): aliasing layers accumulate their clipped
//! sums into the owner's gradient, and the owner adds
//! `2<G_own, G_alias>` so the clip factors see the true
//! `||G_own + G_alias||^2` sensitivity of the shared tensor.
//!
//! ## The `DpLayer` contract
//!
//! * **Forward** writes `(rows, out_width)` activations and fills the
//!   layer's arena-held `cache` buffers (declared by
//!   [`DpLayer::cache_lens`]) with whatever backward needs beyond the
//!   input activations — e.g. LayerNorm caches `xhat` and `inv_std`.
//! * **Norms** ([`DpLayer::accum_sq_norms`]) *accumulate* (`+=`) the
//!   squared Frobenius norm of the layer's per-sample parameter
//!   gradients into the caller's `sq` slice — one slot per sample of
//!   the layer's clipping group. No layer ever sees another group's
//!   accumulator.
//! * **Clipped sums** ([`DpLayer::clipped_grads`]) accumulate
//!   `sum_i c_i * dL_i/dtheta` into the caller's gradient tensors
//!   (`c = None` means the plain non-DP gradient).
//! * **Arena discipline**: layers never allocate. Per-step buffers come
//!   from the caller — caches via `cache_lens`, shared scratch via
//!   [`Scratch`] — and every kernel writes through `&mut` slices.
//!
//! Stateless layers (ReLU) implement only `forward`/`backward_data`;
//! the tape skips their norm and sum hooks entirely.

#![allow(clippy::too_many_arguments)]

pub mod attention;
pub mod conv2d;
pub mod embedding;
pub mod flatten;
pub mod layernorm;
pub mod linear;
pub mod lora;
pub mod pool;
pub mod pos_embedding;
pub mod relu;
pub mod tied_linear;

pub use attention::Attention;
pub use conv2d::Conv2d;
pub use embedding::Embedding;
pub use flatten::Flatten;
pub use layernorm::LayerNorm;
pub use linear::Linear;
pub use lora::LoraLinear;
pub use pool::Pool2d;
pub use pos_embedding::PosEmbedding;
pub use relu::Relu;
pub use tied_linear::TiedLinear;

use super::arena::Arena;
use super::kernels;
use super::model::{NativeSpec, PlanOp};
use crate::arch::LayerDims;
use crate::bail;
use crate::error::Result;
use crate::util::rng::Xoshiro256;

/// Per-layer norm route (the paper's mixed ghost/per-sample decision).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NormRoute {
    /// Ghost norm: Gram-based squared norms, no gradient materialized.
    Ghost,
    /// Per-sample instantiation (streamed or stored).
    Inst,
}

/// Per-step dimensions and threading shared by every layer call.
#[derive(Clone, Copy, Debug)]
pub struct Ctx {
    /// Samples per physical batch (the paper's B).
    pub b: usize,
    /// Tokens per sample (the paper's T).
    pub t: usize,
    /// Worker threads for the fan-out kernels.
    pub threads: usize,
}

impl Ctx {
    /// Activation rows per batch (`B * T`).
    pub fn rows(&self) -> usize {
        self.b * self.t
    }

    /// Effective batch-reduction worker count (scratch sizing).
    pub fn workers(&self) -> usize {
        self.threads.max(1).min(self.b.max(1))
    }
}

/// Input to a layer: feature activations for every layer except an
/// embedding front layer, which consumes token ids.
#[derive(Clone, Copy)]
pub enum LayerIn<'a> {
    /// `(rows, in_width)` feature rows, row-major.
    Feat(&'a [f32]),
    /// `(rows,)` i32 token ids.
    Tokens(&'a [i32]),
}

impl<'a> LayerIn<'a> {
    /// Feature view. Panics on token input — only the embedding layer
    /// accepts tokens, and it never calls this.
    pub fn feat(&self) -> &'a [f32] {
        match *self {
            LayerIn::Feat(x) => x,
            LayerIn::Tokens(_) => panic!("layer expected f32 features, got token ids"),
        }
    }

    /// Token view. Panics on feature input.
    pub fn tokens(&self) -> &'a [i32] {
        match *self {
            LayerIn::Tokens(x) => x,
            LayerIn::Feat(_) => panic!("layer expected token ids, got f32 features"),
        }
    }
}

/// Shared per-step scratch, carved out of the arena by the backend and
/// sized to the worst layer's need (see `NativeBackend` sizing). Layers
/// may use any prefix; slices can be longer than one layer needs.
pub struct Scratch<'a> {
    /// Activation Gram scratch, `>= B*T*T` when any linear layer ghosts
    /// at `T > 1` (empty otherwise).
    pub gram_a: &'a mut [f32],
    /// Output-gradient Gram scratch, same sizing as `gram_a`.
    pub gram_g: &'a mut [f32],
    /// Streaming per-sample-gradient scratch, `>= workers * max(d*p)`.
    pub stream: &'a mut [f32],
    /// Small per-worker scratch (bias / LayerNorm sums),
    /// `>= workers * max(p, 2*norm_width)`.
    pub small: &'a mut [f32],
    /// Batch-reduction partials for the weighted contraction,
    /// `>= workers * max(d*p)`.
    pub partials: &'a mut [f32],
    /// Composite-layer backward scratch: `>= B*T * 4*d_model` for the
    /// widest attention layer (the recomputed `[g_ao | g_qkv]` pair),
    /// `>= B*T * (rank + d)` for the widest LoRA layer (the recomputed
    /// `[gA | gA·A^T]` pair), and `>= B * t_out * cin*k*k` for the
    /// widest conv layer (the unfolded data gradient before `fold`,
    /// plus re-unfolded patches on the stored-psg route); empty when
    /// the stack has none of them.
    pub attn: &'a mut [f32],
}

/// One composable DP layer: forward with caching, per-sample norm
/// contributions, and clipped weighted gradient sums (see the module
/// docs for the full contract).
pub trait DpLayer: Send + Sync {
    /// Stable display name (`fc0`, `emb`, ...).
    fn name(&self) -> &str;

    /// Input feature width (0 when consuming token ids).
    fn in_width(&self) -> usize;

    /// Output feature width.
    fn out_width(&self) -> usize;

    /// Number of trainable tensors (0 for stateless layers).
    fn n_param_tensors(&self) -> usize;

    /// Shapes of the trainable tensors, in parameter order.
    fn param_shapes(&self) -> Vec<Vec<usize>>;

    /// Complexity-engine dims for the mixed ghost/per-sample dispatch;
    /// `None` for stateless layers.
    fn dims(&self, t: usize) -> Option<LayerDims>;

    /// Per-sample element count of a stored per-sample gradient;
    /// 0 = the stored-psg route is unsupported for this layer.
    fn psg_len(&self) -> usize {
        0
    }

    /// Arena buffer lengths the forward pass fills for backward reuse.
    fn cache_lens(&self, ctx: Ctx) -> Vec<usize> {
        let _ = ctx;
        Vec::new()
    }

    /// Initialize this layer's parameters from a forked rng stream.
    /// `is_head` marks the stack's final trainable layer (damped init).
    fn init(&self, rng: Xoshiro256, params: &mut [Vec<f32>], is_head: bool) {
        let _ = (rng, params, is_head);
    }

    /// Forward: consume `x`, write `(rows, out_width)` into `out`.
    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        cache: &mut [Vec<f32>],
        ctx: Ctx,
    );

    /// dL/d input from dL/d output. Never called for the first stack
    /// layer; layers that can only sit first (embedding) keep the
    /// default. Composite layers (attention) use `scratch` for their
    /// recomputed internal gradients.
    fn backward_data(
        &self,
        g_out: &[f32],
        x: LayerIn<'_>,
        out: &[f32],
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        ctx: Ctx,
    ) {
        let _ = (g_out, x, out, params, cache, scratch, g_in, ctx);
        unreachable!("{}: layer cannot back-propagate to its input", self.name());
    }

    /// Accumulate (`+=`) the per-sample squared norms of this layer's
    /// parameter gradients into `sq` (`(B,)`, the layer's clip group).
    /// `params` lets composite layers (attention) recompute internal
    /// output gradients from the caches.
    fn accum_sq_norms(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        route: NormRoute,
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let _ = (x, g_out, route, params, cache, scratch, sq, ctx);
        unreachable!("{}: stateless layer has no norm contributions", self.name());
    }

    /// Accumulate clipped weighted gradient sums into `grads` (one
    /// tensor per `param_shapes` entry); `c = None` means the plain
    /// (non-DP) summed gradient.
    fn clipped_grads(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let _ = (x, g_out, c, params, cache, scratch, grads, ctx);
        unreachable!("{}: stateless layer has no gradients", self.name());
    }

    /// Stored-psg norm route (layers with `psg_len() > 0` only):
    /// materialize per-sample grads into `store` (`B * psg_len`) for
    /// later reuse and accumulate their squared norms into `sq`.
    fn psg_norms_stored(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        store: &mut [f32],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let _ = (x, g_out, store, scratch, sq, ctx);
        unreachable!("{}: stored per-sample gradients unsupported", self.name());
    }

    /// Clipped weighted sum reusing the stored per-sample grads.
    fn psg_weighted_sum(
        &self,
        store: &[f32],
        g_out: &[f32],
        c: &[f32],
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let _ = (store, g_out, c, grads, ctx);
        unreachable!("{}: stored per-sample gradients unsupported", self.name());
    }

    /// Shared-parameter norm cross term, called on the **owner** of a
    /// canonical tensor when another layer aliases it (`StackRun::
    /// alias_of`): accumulate `2 <G_own_i, G_alias_i>` per sample into
    /// `sq`, on top of the two layers' individual squared norms —
    /// together they form `||G_own_i + G_alias_i||^2`, the true
    /// sensitivity of the shared tensor. `alias_x` / `alias_g` are the
    /// aliasing layer's input activations and output gradient (the
    /// two-pass norm walk stashes a copy of `alias_g` on the way down;
    /// the fused one-pass walk hands the alias's book-kept gradient
    /// directly, since it stays alive until the shared group
    /// finalizes). Only owners of aliased tensors implement this
    /// (Embedding, for the tied vocab head).
    fn accum_tied_cross_sq_norms(
        &self,
        x: LayerIn<'_>,
        g_own: &[f32],
        alias_x: &[f32],
        alias_g: &[f32],
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let _ = (x, g_own, alias_x, alias_g, sq, ctx);
        unreachable!("{}: layer does not own an aliased tensor", self.name());
    }

    /// Per-group finalize hook of the fused one-pass schedule
    /// ([`StackRun::fused_pass`]): called the moment this layer's
    /// clipping group's clip factors are known — *mid-walk*, right
    /// after the backward crosses the group boundary — to consume the
    /// book-kept output gradient `g_out` (and the stored per-sample
    /// grads, when this layer took the stored-psg route) into the
    /// clipped weighted sum. The tape releases `g_out`'s buffer
    /// immediately after this returns, so implementations must not
    /// retain it. The default dispatches exactly like the unfused
    /// second pass, which keeps the fused schedule bitwise identical;
    /// layers only override to change *when* their stashes die, never
    /// what is computed.
    fn finalize_group(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        psg_store: Option<&[f32]>,
        c: &[f32],
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        match psg_store {
            Some(store) => self.psg_weighted_sum(store, g_out, c, grads, ctx),
            None => self.clipped_grads(x, g_out, Some(c), params, cache, scratch, grads, ctx),
        }
    }
}

/// Build the executable layer stack from a spec's canonical plan.
///
/// Trainability: layers with per-tensor kernel dispatch (Linear,
/// Attention, LoRA) receive their slice of [`NativeSpec::plan_masks`]
/// so partially-frozen layers (bias-only) skip the frozen tensors'
/// kernels internally; uniformly-masked layers are gated at the tape
/// level through [`StackRun::trainable`] instead.
pub fn build_stack(spec: &NativeSpec) -> Result<Vec<Box<dyn DpLayer>>> {
    let masks = spec.plan_masks();
    let mut out: Vec<Box<dyn DpLayer>> = Vec::new();
    for (k, l) in spec.plan().into_iter().enumerate() {
        let mask = &masks[k];
        match l.op {
            PlanOp::Embedding { vocab, dim } => {
                if k != 0 {
                    bail!(
                        "embedding layer '{}' must be the first layer of model '{}'",
                        l.name,
                        spec.name
                    );
                }
                out.push(Box::new(Embedding::new(l.name, vocab, dim)));
            }
            PlanOp::Linear { d, p } => {
                out.push(Box::new(Linear::new(l.name, d, p).with_trainable([mask[0], mask[1]])))
            }
            PlanOp::LoraLinear { d, p, rank } => {
                if rank == 0 || rank > d.min(p) {
                    bail!(
                        "lora layer '{}' of model '{}': rank {} must be in 1..={}",
                        l.name,
                        spec.name,
                        rank,
                        d.min(p)
                    );
                }
                out.push(Box::new(
                    LoraLinear::new(l.name, d, p, rank)
                        .with_trainable([mask[0], mask[1], mask[2], mask[3]]),
                ));
            }
            PlanOp::TiedLinear { d, p } => {
                if k == 0 {
                    bail!(
                        "tied head '{}' of model '{}' cannot be the first layer",
                        l.name,
                        spec.name
                    );
                }
                out.push(Box::new(TiedLinear::new(l.name, d, p)));
            }
            PlanOp::Relu { width } => out.push(Box::new(Relu::new(l.name, width))),
            PlanOp::LayerNorm { width } => out.push(Box::new(LayerNorm::new(l.name, width))),
            PlanOp::Attention { d, heads } => {
                if heads == 0 || d % heads != 0 {
                    bail!(
                        "attention layer '{}' of model '{}': heads {} must divide width {}",
                        l.name,
                        spec.name,
                        heads,
                        d
                    );
                }
                out.push(Box::new(
                    Attention::new(l.name, d, heads)
                        .with_trainable([mask[0], mask[1], mask[2], mask[3]]),
                ));
            }
            PlanOp::Conv2d {
                cin,
                h,
                w,
                cout,
                k: kk,
                stride,
                pad,
            } => {
                if kk == 0 || stride == 0 || kk > h + 2 * pad || kk > w + 2 * pad {
                    bail!(
                        "conv layer '{}' of model '{}': kernel {}x{} stride {} does not \
                         fit the {}x{} (+{} pad) input",
                        l.name,
                        spec.name,
                        kk,
                        kk,
                        stride,
                        h,
                        w,
                        pad
                    );
                }
                out.push(Box::new(
                    Conv2d::new(l.name, cin, h, w, cout, kk, stride, pad)
                        .with_trainable([mask[0], mask[1]]),
                ));
            }
            PlanOp::Pool2d { kind, c, h, w, win } => {
                if win == 0 || h % win != 0 || w % win != 0 {
                    bail!(
                        "pool layer '{}' of model '{}': window {} must tile the {}x{} input",
                        l.name,
                        spec.name,
                        win,
                        h,
                        w
                    );
                }
                out.push(Box::new(Pool2d::new(l.name, kind, c, h, w, win)));
            }
            PlanOp::Flatten { n } => out.push(Box::new(Flatten::new(l.name, n))),
            PlanOp::PosEmbedding { seq, dim } => {
                if k == 0 {
                    bail!(
                        "positional embedding '{}' of model '{}' cannot be the first \
                         layer (it adds to feature activations)",
                        l.name,
                        spec.name
                    );
                }
                out.push(Box::new(PosEmbedding::new(l.name, seq, dim)));
            }
        }
    }
    if out.is_empty() {
        bail!("model '{}' has an empty layer stack", spec.name);
    }
    // residual skips must point at an earlier layer of matching width
    // (and never at a token input, which has no feature activation)
    for (k, l) in spec.plan().iter().enumerate() {
        if let Some(r) = l.residual {
            if r > k || (r == 0 && spec.vocab > 0) || out[r].in_width() != out[k].out_width() {
                bail!(
                    "layer '{}' of model '{}' has an invalid residual source {r}",
                    l.name,
                    spec.name
                );
            }
        }
    }
    Ok(out)
}

/// The tape: borrows a backend's stack + parameters and threads the
/// Book-Keeping schedules through it. All per-step buffers come from
/// the arena passed into each walk; the tape itself holds no state.
pub struct StackRun<'a> {
    /// The layer stack, front to head.
    pub layers: &'a [Box<dyn DpLayer>],
    /// Canonical trainable tensors (each stored exactly once, even when
    /// several layers view it).
    pub params: &'a [Vec<f32>],
    /// Canonical-tensor slot range per layer: layer `k` reads/writes
    /// `params[slots[k].0..slots[k].1]` (and the matching `grads`
    /// range). Owners get their own range; an aliasing layer (the tied
    /// vocab head) points back at the owner's tensor, so clipped sums
    /// from every aliasing layer accumulate into the one canonical
    /// gradient.
    pub slots: &'a [(usize, usize)],
    /// Shared-parameter links: `alias_of[k] = Some(j)` means layer `k`
    /// views tensors owned by the earlier layer `j`. The norm walk
    /// stashes `k`'s output gradient and has `j` add the ghost cross
    /// term `2 <G_j, G_k>` to the group's per-sample squared norms.
    pub alias_of: &'a [Option<usize>],
    /// Per-layer trainability gate: `trainable[k]` is true iff any of
    /// layer `k`'s canonical tensors trains under the active mask
    /// (aliasing layers carry their owner's state). A false entry makes
    /// every walk skip the layer's norm, clipped-sum, and book-keeping
    /// hooks entirely — `backward_data` still flows activations through
    /// — which is the frozen-layer skip invariant of DESIGN.md §9.
    /// Distinct from `n_param_tensors() > 0`: a parameterized layer can
    /// be frozen; a stateless layer is never trainable.
    pub trainable: &'a [bool],
    /// Norm route per layer (meaningful for trainable layers).
    pub routes: &'a [NormRoute],
    /// Clipping-group id per layer (meaningful for trainable layers).
    pub groups: &'a [usize],
    /// Residual skip per layer: `residuals[k] = Some(r)` adds the input
    /// activation of layer `r` to layer `k`'s output
    /// (`acts[k+1] = layer_k(acts[k]) + acts[r]`, the transformer
    /// pre-LN skip). The backward walks mirror it by routing the output
    /// gradient of layer `k` straight to level `r` as well.
    pub residuals: &'a [Option<usize>],
    /// Step dimensions.
    pub ctx: Ctx,
}

impl StackRun<'_> {
    fn params_of(&self, k: usize) -> &[Vec<f32>] {
        &self.params[self.slots[k].0..self.slots[k].1]
    }

    fn input_of<'b>(&self, k: usize, acts: &'b [Vec<f32>], input: LayerIn<'b>) -> LayerIn<'b> {
        if k == 0 {
            match input {
                LayerIn::Feat(_) => LayerIn::Feat(acts[0].as_slice()),
                tokens => tokens,
            }
        } else {
            LayerIn::Feat(acts[k].as_slice())
        }
    }

    /// Forward pass: returns `acts` (`acts[k]` = input of layer `k`,
    /// `acts[n]` = logits; `acts[0]` is empty for token input) and the
    /// per-layer forward caches. All buffers come from `arena`.
    pub fn forward(
        &self,
        arena: &mut Arena,
        input: LayerIn<'_>,
    ) -> (Vec<Vec<f32>>, Vec<Vec<Vec<f32>>>) {
        let rows = self.ctx.rows();
        let nl = self.layers.len();
        let mut caches: Vec<Vec<Vec<f32>>> = Vec::with_capacity(nl);
        for l in self.layers {
            let lens = l.cache_lens(self.ctx);
            caches.push(lens.into_iter().map(|n| arena.take(n)).collect());
        }
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl + 1);
        match input {
            LayerIn::Feat(x) => {
                let mut a0 = arena.take(x.len());
                a0.copy_from_slice(x);
                acts.push(a0);
            }
            // token input: a capacity-0 placeholder. `Arena::take(0)`
            // now returns exactly this non-pooled empty vec (it used to
            // steal the smallest pooled buffer — see the arena tests),
            // and the backend's give-back loop skips capacity-0 vecs.
            LayerIn::Tokens(_) => acts.push(Vec::new()),
        }
        for k in 0..nl {
            let mut out = arena.take(rows * self.layers[k].out_width());
            let xin = self.input_of(k, &acts, input);
            self.layers[k].forward(xin, self.params_of(k), &mut out, &mut caches[k], self.ctx);
            if let Some(r) = self.residuals[k] {
                let src = &acts[r];
                debug_assert_eq!(src.len(), out.len(), "residual width mismatch");
                for (o, &s) in out.iter_mut().zip(src.iter()) {
                    *o += s;
                }
            }
            acts.push(out);
        }
        (acts, caches)
    }

    /// Stash the skip half of a residual during a backward walk: layer
    /// `k`'s output gradient also flows straight to level `r`
    /// (`pending[r]`), to be merged once the walk computes the
    /// through-path gradient at that level.
    fn stash_residual(
        &self,
        arena: &mut Arena,
        pending: &mut [Option<Vec<f32>>],
        k: usize,
        g: &[f32],
    ) {
        if let Some(r) = self.residuals[k] {
            match pending[r].as_mut() {
                Some(p) => {
                    for (pv, &gv) in p.iter_mut().zip(g) {
                        *pv += gv;
                    }
                }
                None => {
                    let mut copy = arena.take(g.len());
                    copy.copy_from_slice(g);
                    pending[r] = Some(copy);
                }
            }
        }
    }

    /// Merge a pending skip gradient into the freshly computed
    /// through-path gradient at its level.
    fn merge_residual(
        &self,
        arena: &mut Arena,
        pending: &mut [Option<Vec<f32>>],
        level: usize,
        g: &mut [f32],
    ) {
        if let Some(p) = pending[level].take() {
            for (gv, &pv) in g.iter_mut().zip(p.iter()) {
                *gv += pv;
            }
            arena.give(p);
        }
    }

    /// Norm backward: one softmax backward walking the stack top-down,
    /// each trainable layer accumulating its per-sample squared norms
    /// into its clipping group's row of `sq` (`n_groups * B`, zeroed by
    /// the caller). Layers with a `psg` store materialize per-sample
    /// grads for reuse. With `keep_g` the book-kept output gradients of
    /// every trainable layer are returned (the BK one-pass cache);
    /// otherwise they are recycled as the walk descends.
    ///
    /// Shared tensors: when layer `k` aliases layer `j` (`alias_of`),
    /// the walk stashes a copy of `k`'s output gradient on the way down
    /// and, right after `j`'s own norm contribution, has `j` accumulate
    /// the ghost cross term `2 <G_j_i, G_k_i>` into the same group row —
    /// completing `||G_j_i + G_k_i||^2` for the canonical tensor.
    pub fn norm_pass(
        &self,
        arena: &mut Arena,
        acts: &[Vec<f32>],
        caches: &[Vec<Vec<f32>>],
        input: LayerIn<'_>,
        y: &[i32],
        scratch: &mut Scratch<'_>,
        psg: &mut [Option<Vec<f32>>],
        sq: &mut [f32],
        keep_g: bool,
    ) -> (f32, Vec<Option<Vec<f32>>>) {
        let ctx = self.ctx;
        let b = ctx.b;
        let rows = ctx.rows();
        let nl = self.layers.len();
        let c_out = self.layers[nl - 1].out_width();
        let mut kept: Vec<Option<Vec<f32>>> = (0..nl).map(|_| None).collect();
        let mut pending: Vec<Option<Vec<f32>>> = (0..nl).map(|_| None).collect();
        // stashed (alias layer index, its output gradient) per owner,
        // consumed when the walk reaches the owner
        let mut cross: Vec<Option<(usize, Vec<f32>)>> = (0..nl).map(|_| None).collect();
        let mut g = arena.take(rows * c_out);
        let loss = kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
        for k in (0..nl).rev() {
            let layer = &self.layers[k];
            let xin = self.input_of(k, acts, input);
            self.stash_residual(arena, &mut pending, k, &g);
            if let Some(owner) = self.alias_of[k] {
                debug_assert!(owner < k, "alias must point at an earlier layer");
                // a frozen shared tensor needs no cross term: neither
                // side contributes norms
                if self.trainable[owner] {
                    let mut copy = arena.take(g.len());
                    copy.copy_from_slice(&g);
                    cross[owner] = Some((k, copy));
                }
            }
            if self.trainable[k] {
                let gr = self.groups[k] * b..(self.groups[k] + 1) * b;
                match psg[k].as_mut() {
                    Some(store) => {
                        layer.psg_norms_stored(xin, &g, store, scratch, &mut sq[gr.clone()], ctx)
                    }
                    None => layer.accum_sq_norms(
                        xin,
                        &g,
                        self.routes[k],
                        self.params_of(k),
                        &caches[k],
                        scratch,
                        &mut sq[gr.clone()],
                        ctx,
                    ),
                }
                if let Some((ak, ag)) = cross[k].take() {
                    // the aliasing layer shares this layer's clip group
                    // (enforced at backend build), so the cross term
                    // lands in the same accumulator row
                    layer.accum_tied_cross_sq_norms(xin, &g, &acts[ak], &ag, &mut sq[gr], ctx);
                    arena.give(ag);
                }
            }
            if k > 0 {
                let mut g_prev = arena.take(rows * layer.in_width());
                layer.backward_data(
                    &g,
                    xin,
                    &acts[k + 1],
                    self.params_of(k),
                    &caches[k],
                    scratch,
                    &mut g_prev,
                    ctx,
                );
                self.merge_residual(arena, &mut pending, k, &mut g_prev);
                let old = std::mem::replace(&mut g, g_prev);
                if keep_g && self.trainable[k] {
                    kept[k] = Some(old);
                } else {
                    arena.give(old);
                }
            }
        }
        if keep_g && self.trainable[0] {
            kept[0] = Some(g);
        } else {
            arena.give(g);
        }
        for p in pending.into_iter().flatten() {
            arena.give(p);
        }
        for (_, ag) in cross.into_iter().flatten() {
            arena.give(ag);
        }
        (loss, kept)
    }

    /// The fused one-pass BK schedule: norms **and** clipped sums in a
    /// single backward walk, releasing each clipping group's book-kept
    /// g-caches at the group boundary instead of stashing all of them
    /// to the end of the pass.
    ///
    /// Clipping groups are contiguous over *owner* layers in stack
    /// order, so walking top-down the walk leaves group `G-1` first,
    /// then `G-2`, ... and a group's per-sample norms are complete the
    /// moment its lowest-index member has contributed
    /// (`finalize_at[k] = Some(g)` marks that member; aliasing layers
    /// sit higher in the stack than their owner, so the owner is always
    /// that member for a shared group). At the boundary the group's
    /// clip factors are computed via `clip` (filling that group's row
    /// of `cfac`) and every member's [`DpLayer::finalize_group`] runs
    /// in descending stack order — the same per-tensor accumulation
    /// order as [`StackRun::clipped_from_cache`], so the fused schedule
    /// is bitwise identical to the unfused one; only buffer lifetimes
    /// move.
    ///
    /// A group finalizes only *after* the boundary layer's
    /// `backward_data`, preserving the attention invariant that a
    /// layer's norm hook and its `backward_data` share one
    /// `Scratch::attn` recompute with no other attention call between
    /// them.
    ///
    /// Tied tensors: the aliasing layer's book-kept gradient doubles as
    /// the owner's cross-term input (no separate stash copy — one
    /// `B*T*vocab` buffer fewer than the two-pass norm walk), which is
    /// safe exactly because the alias shares the owner's group and so
    /// outlives the owner's norm hook.
    ///
    /// Returns `(summed loss, peak g-cache floats)`. The peak gauge
    /// counts the frontier gradient plus every live book-kept cache —
    /// the quantity `complexity::bk_gcache_floats` predicts; residual
    /// skip copies and psg stores are outside its definition.
    pub fn fused_pass(
        &self,
        arena: &mut Arena,
        acts: &[Vec<f32>],
        caches: &[Vec<Vec<f32>>],
        input: LayerIn<'_>,
        y: &[i32],
        scratch: &mut Scratch<'_>,
        psg: &mut [Option<Vec<f32>>],
        sq: &mut [f32],
        cfac: &mut [f32],
        finalize_at: &[Option<usize>],
        clip: &mut dyn FnMut(&[f32], &mut [f32]),
        grads: &mut [Vec<f32>],
    ) -> (f32, usize) {
        let ctx = self.ctx;
        let b = ctx.b;
        let rows = ctx.rows();
        let nl = self.layers.len();
        let c_out = self.layers[nl - 1].out_width();
        let mut kept: Vec<Option<Vec<f32>>> = (0..nl).map(|_| None).collect();
        let mut pending: Vec<Option<Vec<f32>>> = (0..nl).map(|_| None).collect();
        let mut g = arena.take(rows * c_out);
        // g-cache gauge: frontier + book-kept caches currently alive
        let mut live = g.len();
        let mut peak = live;
        let loss = kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
        for k in (0..nl).rev() {
            let layer = &self.layers[k];
            let xin = self.input_of(k, acts, input);
            self.stash_residual(arena, &mut pending, k, &g);
            let trainable = self.trainable[k];
            if trainable {
                let gr = self.groups[k] * b..(self.groups[k] + 1) * b;
                match psg[k].as_mut() {
                    Some(store) => {
                        layer.psg_norms_stored(xin, &g, store, scratch, &mut sq[gr.clone()], ctx)
                    }
                    None => layer.accum_sq_norms(
                        xin,
                        &g,
                        self.routes[k],
                        self.params_of(k),
                        &caches[k],
                        scratch,
                        &mut sq[gr.clone()],
                        ctx,
                    ),
                }
                if let Some(ak) = self.alias_of.iter().position(|a| *a == Some(k)) {
                    let ag = kept[ak]
                        .as_ref()
                        .expect("aliasing layer's book-kept gradient outlives its owner's norms");
                    layer.accum_tied_cross_sq_norms(xin, &g, &acts[ak], ag, &mut sq[gr], ctx);
                }
            }
            if k > 0 {
                let mut g_prev = arena.take(rows * layer.in_width());
                layer.backward_data(
                    &g,
                    xin,
                    &acts[k + 1],
                    self.params_of(k),
                    &caches[k],
                    scratch,
                    &mut g_prev,
                    ctx,
                );
                self.merge_residual(arena, &mut pending, k, &mut g_prev);
                let old = std::mem::replace(&mut g, g_prev);
                if trainable {
                    // the old frontier becomes this layer's book-kept
                    // cache; the new frontier joins it in the gauge
                    live += g.len();
                    kept[k] = Some(old);
                } else {
                    // stateless: the frontier merely changes width
                    live += g.len();
                    live -= old.len();
                    arena.give(old);
                }
                peak = peak.max(live);
            } else if trainable {
                // no backward below the front layer: the frontier
                // itself is the book-kept cache (gauge unchanged)
                kept[0] = Some(std::mem::take(&mut g));
            }
            if let Some(gi) = finalize_at[k] {
                clip(&sq[gi * b..(gi + 1) * b], &mut cfac[gi * b..(gi + 1) * b]);
                let c = &cfac[gi * b..(gi + 1) * b];
                for j in (k..nl).rev() {
                    if !self.trainable[j] || self.groups[j] != gi {
                        continue;
                    }
                    let gj = kept[j]
                        .take()
                        .expect("book-kept gradient of a finalizing group member");
                    let xj = self.input_of(j, acts, input);
                    let gk = &mut grads[self.slots[j].0..self.slots[j].1];
                    self.layers[j].finalize_group(
                        xj,
                        &gj,
                        psg[j].as_deref(),
                        c,
                        self.params_of(j),
                        &caches[j],
                        scratch,
                        gk,
                        ctx,
                    );
                    live -= gj.len();
                    arena.give(gj);
                }
            }
        }
        if g.capacity() > 0 {
            // only reachable when the front layer is stateless (no such
            // plan today); return the unconsumed frontier
            live -= g.len();
            arena.give(g);
        }
        for p in pending.into_iter().flatten() {
            arena.give(p);
        }
        debug_assert_eq!(live, 0, "g-cache gauge must drain to zero");
        debug_assert!(
            kept.iter().all(Option::is_none),
            "every book-kept cache must have been finalized"
        );
        (loss, peak)
    }

    /// BK one-pass clipped sums: no recompute, every trainable layer
    /// contracts its book-kept gradient (or stored psg) against its
    /// group's clip factors (`cfac` is `n_groups * B`).
    pub fn clipped_from_cache(
        &self,
        acts: &[Vec<f32>],
        caches: &[Vec<Vec<f32>>],
        input: LayerIn<'_>,
        kept: &[Option<Vec<f32>>],
        psg: &[Option<Vec<f32>>],
        cfac: &[f32],
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
    ) {
        let ctx = self.ctx;
        let b = ctx.b;
        for k in (0..self.layers.len()).rev() {
            let layer = &self.layers[k];
            if !self.trainable[k] {
                continue;
            }
            let g = kept[k].as_ref().expect("book-kept output gradient");
            let xin = self.input_of(k, acts, input);
            let c = &cfac[self.groups[k] * b..(self.groups[k] + 1) * b];
            // aliasing layers resolve to the owner's grad tensor, so the
            // shared tensor's clipped sum accumulates both contributions
            let gk = &mut grads[self.slots[k].0..self.slots[k].1];
            match psg[k].as_ref() {
                Some(store) => layer.psg_weighted_sum(store, g, c, gk, ctx),
                None => layer.clipped_grads(
                    xin,
                    g,
                    Some(c),
                    self.params_of(k),
                    &caches[k],
                    scratch,
                    gk,
                    ctx,
                ),
            }
        }
    }

    /// Recompute backward with clipped sums: a fresh softmax backward
    /// (the honest second backprop of the two-pass strategies, and the
    /// single backward of non-DP training when `cfac` is `None`).
    pub fn clipped_recompute(
        &self,
        arena: &mut Arena,
        acts: &[Vec<f32>],
        caches: &[Vec<Vec<f32>>],
        input: LayerIn<'_>,
        y: &[i32],
        cfac: Option<&[f32]>,
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
    ) -> f32 {
        let ctx = self.ctx;
        let b = ctx.b;
        let rows = ctx.rows();
        let nl = self.layers.len();
        let c_out = self.layers[nl - 1].out_width();
        let mut pending: Vec<Option<Vec<f32>>> = (0..nl).map(|_| None).collect();
        let mut g = arena.take(rows * c_out);
        let loss = kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
        for k in (0..nl).rev() {
            let layer = &self.layers[k];
            let xin = self.input_of(k, acts, input);
            self.stash_residual(arena, &mut pending, k, &g);
            if self.trainable[k] {
                let c = cfac.map(|cf| &cf[self.groups[k] * b..(self.groups[k] + 1) * b]);
                let gk = &mut grads[self.slots[k].0..self.slots[k].1];
                layer.clipped_grads(xin, &g, c, self.params_of(k), &caches[k], scratch, gk, ctx);
            }
            if k > 0 {
                let mut g_prev = arena.take(rows * layer.in_width());
                layer.backward_data(
                    &g,
                    xin,
                    &acts[k + 1],
                    self.params_of(k),
                    &caches[k],
                    scratch,
                    &mut g_prev,
                    ctx,
                );
                self.merge_residual(arena, &mut pending, k, &mut g_prev);
                arena.give(std::mem::replace(&mut g, g_prev));
            }
        }
        arena.give(g);
        for p in pending.into_iter().flatten() {
            arena.give(p);
        }
        loss
    }
}
