//! Learned positional-embedding table (GPT-2 `wpe`): a `(t, dim)`
//! table whose row `ti` is added to every sample's position-`ti`
//! activation row — `out[i, ti, :] = x[i, ti, :] + W[ti, :]`.
//!
//! Unlike the token embedding, the table's rows never collide across
//! positions within a sample: each position reads exactly its own row,
//! once. The per-sample gradient with respect to the table is therefore
//! just the sample's output gradient laid out over the `t` rows, so the
//! per-sample squared norm is the plain gradient Frobenius norm — no
//! token-equality Gram, no activation Gram, no instantiation. Both norm
//! routes collapse to the same O(B T d) reduction, and the clipped sum
//! is a serial position-wise scatter like the token embedding's.
//! `backward_data` is the identity (the addition passes gradients
//! straight through).

#![allow(clippy::too_many_arguments)]

use super::super::kernels;
use super::{Ctx, DpLayer, LayerIn, NormRoute, Scratch};
use crate::arch::{LayerDims, LayerKind};
use crate::util::rng::{GaussianSource, Xoshiro256};

/// `out[i, ti, :] = x[i, ti, :] + W[ti, :]` over `(b, t, dim)` rows.
pub struct PosEmbedding {
    name: String,
    t: usize,
    dim: usize,
}

impl PosEmbedding {
    /// Build a `(t, dim)` position table.
    pub fn new(name: String, t: usize, dim: usize) -> Self {
        Self { name, t, dim }
    }
}

impl DpLayer for PosEmbedding {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.dim
    }

    fn out_width(&self) -> usize {
        self.dim
    }

    fn n_param_tensors(&self) -> usize {
        1
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![vec![self.t, self.dim]]
    }

    fn dims(&self, t: usize) -> Option<LayerDims> {
        debug_assert_eq!(t, self.t, "wpe table rows are the sequence length");
        Some(LayerDims {
            kind: LayerKind::PosEmbedding,
            name: self.name.clone(),
            t: t as u64,
            d: self.dim as u64,
            p: self.dim as u64,
        })
    }

    fn init(&self, rng: Xoshiro256, params: &mut [Vec<f32>], _is_head: bool) {
        // small like GPT-2's wpe: positions start as a gentle bias on
        // top of the token embedding, not a competing signal
        let scale = 0.1 * (1.0 / self.dim as f32).sqrt();
        let mut gs = GaussianSource::from_rng(rng);
        gs.fill_f32(&mut params[0]);
        for v in params[0].iter_mut() {
            *v *= scale;
        }
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        _cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let x = x.feat();
        let (t, dim) = (self.t, self.dim);
        debug_assert_eq!(ctx.t, t);
        for i in 0..ctx.b {
            for ti in 0..t {
                let row = (i * t + ti) * dim;
                let w = &params[0][ti * dim..(ti + 1) * dim];
                for j in 0..dim {
                    out[row + j] = x[row + j] + w[j];
                }
            }
        }
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        _out: &[f32],
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        _ctx: Ctx,
    ) {
        // the addition is identity in x
        g_in.copy_from_slice(g_out);
    }

    fn accum_sq_norms(
        &self,
        _x: LayerIn<'_>,
        g_out: &[f32],
        _route: NormRoute,
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        // rows never collide: the per-sample table gradient IS the
        // sample's output gradient, so both routes are this one exact
        // Frobenius reduction
        kernels::sq_norms_from_psg(g_out, ctx.b, self.t * self.dim, sq, ctx.threads);
    }

    fn clipped_grads(
        &self,
        _x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        // grad_W[ti, :] += sum_i c_i g[i, ti, :] — a position-wise
        // scatter, serial like the token embedding's
        let (t, dim) = (self.t, self.dim);
        for i in 0..ctx.b {
            let ci = match c {
                Some(cs) => cs[i],
                None => 1.0,
            };
            if ci == 0.0 {
                continue;
            }
            for ti in 0..t {
                let g_row = &g_out[(i * t + ti) * dim..(i * t + ti + 1) * dim];
                let w_row = &mut grads[0][ti * dim..(ti + 1) * dim];
                for (wv, &gv) in w_row.iter_mut().zip(g_row) {
                    *wv += ci * gv;
                }
            }
        }
    }
}
