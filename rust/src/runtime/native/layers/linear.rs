//! Fully connected layer `(d, p)` with bias — the generalized-linear
//! workhorse of the Book-Keeping algorithm. Supports both norm routes
//! (ghost Grams, streamed instantiation) plus the stored-psg reuse path
//! (Opacus / BK-MixOpt instantiation layers). The fused schedule's
//! per-group finalize is the default [`DpLayer::finalize_group`]
//! dispatch: `psg_weighted_sum` when this layer stored its per-sample
//! grads during the norm walk, the `weighted_grad` contraction
//! otherwise — bit-for-bit the unfused second pass, just earlier.

#![allow(clippy::too_many_arguments)]

use super::super::kernels;
use super::{Ctx, DpLayer, LayerIn, NormRoute, Scratch};
use crate::arch::{LayerDims, LayerKind};
use crate::util::rng::{GaussianSource, Xoshiro256};

/// `out = x . W + b` over `(rows, d)` feature rows.
pub struct Linear {
    name: String,
    d: usize,
    p: usize,
    /// Per-tensor trainability `[weight, bias]`: a frozen tensor's
    /// norm/sum kernels are skipped entirely (bias-only fine-tuning
    /// freezes the weight but keeps the d*p forward/backward_data).
    train: [bool; 2],
}

impl Linear {
    /// Build a `(d, p)` linear layer, fully trainable.
    pub fn new(name: String, d: usize, p: usize) -> Self {
        Self {
            name,
            d,
            p,
            train: [true, true],
        }
    }

    /// Set the `[weight, bias]` trainability mask.
    pub fn with_trainable(mut self, train: [bool; 2]) -> Self {
        self.train = train;
        self
    }
}

impl DpLayer for Linear {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.d
    }

    fn out_width(&self) -> usize {
        self.p
    }

    fn n_param_tensors(&self) -> usize {
        2
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![vec![self.d, self.p], vec![self.p]]
    }

    fn dims(&self, t: usize) -> Option<LayerDims> {
        Some(LayerDims {
            kind: LayerKind::Linear,
            name: self.name.clone(),
            t: t as u64,
            d: self.d as u64,
            p: self.p as u64,
        })
    }

    fn psg_len(&self) -> usize {
        // a frozen weight never instantiates per-sample grads; the bias
        // norm/sum kernels read `g_out` directly and need no store
        if self.train[0] {
            self.d * self.p
        } else {
            0
        }
    }

    fn init(&self, rng: Xoshiro256, params: &mut [Vec<f32>], is_head: bool) {
        // He init for hidden (ReLU) layers; a damped head so initial
        // logits are near-uniform (loss ~ ln C, like the artifacts).
        let scale = if is_head {
            0.05 * (1.0 / self.d as f32).sqrt()
        } else {
            (2.0 / self.d as f32).sqrt()
        };
        let mut gs = GaussianSource::from_rng(rng);
        gs.fill_f32(&mut params[0]);
        for v in params[0].iter_mut() {
            *v *= scale;
        }
        for v in params[1].iter_mut() {
            *v = 0.0;
        }
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        _cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        kernels::linear_forward(
            x.feat(),
            &params[0],
            Some(&params[1]),
            out,
            ctx.rows(),
            self.d,
            self.p,
            ctx.threads,
        );
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        _out: &[f32],
        params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        ctx: Ctx,
    ) {
        kernels::backward_data(g_out, &params[0], g_in, ctx.rows(), self.d, self.p, ctx.threads);
    }

    fn accum_sq_norms(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        route: NormRoute,
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let (b, t) = (ctx.b, ctx.t);
        if self.train[0] {
            match route {
                NormRoute::Ghost => kernels::ghost_norm(
                    x.feat(),
                    g_out,
                    b,
                    t,
                    self.d,
                    self.p,
                    scratch.gram_a,
                    scratch.gram_g,
                    sq,
                    ctx.threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    x.feat(),
                    g_out,
                    b,
                    t,
                    self.d,
                    self.p,
                    scratch.stream,
                    sq,
                    ctx.threads,
                ),
            }
        }
        if self.train[1] {
            kernels::bias_sq_norms(g_out, b, t, self.p, scratch.small, sq, ctx.threads);
        }
    }

    fn clipped_grads(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let (gw, gb) = grads.split_at_mut(1);
        if self.train[0] {
            kernels::weighted_grad(
                x.feat(),
                g_out,
                c,
                ctx.b,
                ctx.t,
                self.d,
                self.p,
                scratch.partials,
                &mut gw[0],
                ctx.threads,
            );
        }
        if self.train[1] {
            kernels::bias_grad(g_out, c, ctx.b, ctx.t, self.p, &mut gb[0]);
        }
    }

    fn psg_norms_stored(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        store: &mut [f32],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let (b, t) = (ctx.b, ctx.t);
        debug_assert!(self.train[0], "stored-psg route requires a trainable weight");
        kernels::psg_instantiate(x.feat(), g_out, b, t, self.d, self.p, store, ctx.threads);
        kernels::sq_norms_from_psg(store, b, self.d * self.p, sq, ctx.threads);
        if self.train[1] {
            kernels::bias_sq_norms(g_out, b, t, self.p, scratch.small, sq, ctx.threads);
        }
    }

    fn psg_weighted_sum(
        &self,
        store: &[f32],
        g_out: &[f32],
        c: &[f32],
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let (gw, gb) = grads.split_at_mut(1);
        kernels::weighted_sum_psg(store, c, ctx.b, self.d, self.p, &mut gw[0], ctx.threads);
        if self.train[1] {
            kernels::bias_grad(g_out, Some(c), ctx.b, ctx.t, self.p, &mut gb[0]);
        }
    }
}
