//! Stateless ReLU layer: `out = max(0, x)` elementwise, fused into a
//! single pass. Contributes no norms and no gradients — the tape only
//! routes the data gradient through it (masked by the cached
//! *post*-activation output, exactly the legacy fused Linear+ReLU
//! semantics, bitwise). Having no parameters it belongs to no clipping
//! group: the fused walk never calls its finalize hook, and its only
//! memory effect on the g-cache gauge is the width-preserving frontier
//! swap.

use super::{Ctx, DpLayer, LayerIn, Scratch};
use crate::arch::LayerDims;

/// Elementwise `max(0, x)`.
pub struct Relu {
    name: String,
    width: usize,
}

impl Relu {
    /// Build a ReLU over `width` features.
    pub fn new(name: String, width: usize) -> Self {
        Self { name, width }
    }
}

impl DpLayer for Relu {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.width
    }

    fn out_width(&self) -> usize {
        self.width
    }

    fn n_param_tensors(&self) -> usize {
        0
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        Vec::new()
    }

    fn dims(&self, _t: usize) -> Option<LayerDims> {
        None
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        _params: &[Vec<f32>],
        out: &mut [f32],
        _cache: &mut [Vec<f32>],
        _ctx: Ctx,
    ) {
        // single fused pass (not copy + in-place relu): bitwise-equal
        // values, half the memory traffic on the hot path
        for (o, &v) in out.iter_mut().zip(x.feat()) {
            *o = if v < 0.0 { 0.0 } else { v };
        }
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        out: &[f32],
        _params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        _scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        _ctx: Ctx,
    ) {
        // mask by the cached *post*-activation in one pass (legacy
        // relu_backward semantics: zero wherever out <= 0)
        for ((gi, &go), &o) in g_in.iter_mut().zip(g_out).zip(out) {
            *gi = if o <= 0.0 { 0.0 } else { go };
        }
    }
}
