//! LoRA-adapted linear layer: a frozen `(d, p)` base with trainable
//! rank-`r` adapters — `out = x·W + b + (x·A)·B` with `A (d, r)`,
//! `B (r, p)`, `r ≪ min(d, p)`.
//!
//! Both adapters are themselves generalized linear, so the whole BK
//! machinery applies to the skinny factors directly:
//!
//! * `grad_A_i = x_i^T gA_i` with `gA = g·B^T` — a `(d, r)` linear with
//!   the layer's input and a recomputed rank-wide output gradient;
//! * `grad_B_i = h_i^T g_i` with `h = x·A` — an `(r, p)` linear whose
//!   input is the cached adapter activation.
//!
//! Ghost norms cost `O(B T^2)` Grams against `d*r` / `r*p`
//! instantiation, so at small rank the ghost route is almost always
//! cheap (`complexity::ghost_preferred` decides per dims as usual). The
//! frozen base contributes only its forward matmul and the
//! `backward_data` flow `g·W^T + (g·B^T)·A^T` — no norms, no sums, no
//! optimizer state.
//!
//! Forward caches: `h = x·A` (rows, r) for `grad_B`, plus a (rows, p)
//! temp for the adapter path's forward product. The recompute scratch
//! `[gA | gA·A^T]` lives in [`Scratch::attn`] (`rows * (r + d)`).

#![allow(clippy::too_many_arguments)]

use super::super::kernels;
use super::{Ctx, DpLayer, LayerIn, NormRoute, Scratch};
use crate::arch::{LayerDims, LayerKind};
use crate::util::rng::{GaussianSource, Xoshiro256};

/// `out = x·W + b + (x·A)·B` over `(rows, d)` feature rows.
pub struct LoraLinear {
    name: String,
    d: usize,
    p: usize,
    rank: usize,
    /// Per-tensor trainability `[W, b, A, B]`; the `lora:<rank>` preset
    /// is `[false, false, true, true]` (frozen base, live adapters).
    train: [bool; 4],
}

impl LoraLinear {
    /// Build a `(d, p)` LoRA linear with rank-`rank` adapters (frozen
    /// base by default).
    pub fn new(name: String, d: usize, p: usize, rank: usize) -> Self {
        debug_assert!(rank > 0 && rank <= d.min(p));
        Self {
            name,
            d,
            p,
            rank,
            train: [false, false, true, true],
        }
    }

    /// Set the `[W, b, A, B]` trainability mask.
    pub fn with_trainable(mut self, train: [bool; 4]) -> Self {
        self.train = train;
        self
    }

    /// Recompute the adapter output gradient `gA = g·B^T` into the
    /// front of `attn`; returns the freshly written `(rows, r)` view.
    fn recompute_ga<'s>(
        &self,
        g_out: &[f32],
        params: &[Vec<f32>],
        attn: &'s mut [f32],
        ctx: Ctx,
    ) -> &'s [f32] {
        let rows = ctx.rows();
        let (ga, _) = attn.split_at_mut(rows * self.rank);
        kernels::backward_data(g_out, &params[3], ga, rows, self.rank, self.p, ctx.threads);
        &*ga
    }
}

impl DpLayer for LoraLinear {
    fn name(&self) -> &str {
        &self.name
    }

    fn in_width(&self) -> usize {
        self.d
    }

    fn out_width(&self) -> usize {
        self.p
    }

    fn n_param_tensors(&self) -> usize {
        4
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![
            vec![self.d, self.p],
            vec![self.p],
            vec![self.d, self.rank],
            vec![self.rank, self.p],
        ]
    }

    fn dims(&self, t: usize) -> Option<LayerDims> {
        Some(LayerDims {
            kind: LayerKind::Lora {
                rank: self.rank as u64,
            },
            name: self.name.clone(),
            t: t as u64,
            d: self.d as u64,
            p: self.p as u64,
        })
    }

    fn cache_lens(&self, ctx: Ctx) -> Vec<usize> {
        // h = x·A (rows, r) + the adapter forward temp (rows, p)
        vec![ctx.rows() * self.rank, ctx.rows() * self.p]
    }

    fn init(&self, rng: Xoshiro256, params: &mut [Vec<f32>], is_head: bool) {
        // base W like a plain Linear (there is no pretrained tensor to
        // load; the frozen base is a fixed random feature map), bias 0
        let scale = if is_head {
            0.05 * (1.0 / self.d as f32).sqrt()
        } else {
            (2.0 / self.d as f32).sqrt()
        };
        let mut gs = GaussianSource::from_rng(rng);
        gs.fill_f32(&mut params[0]);
        for v in params[0].iter_mut() {
            *v *= scale;
        }
        for v in params[1].iter_mut() {
            *v = 0.0;
        }
        // A ~ N(0, 1/d) as in the LoRA paper. B is conventionally zero
        // (adapter starts as identity on a pretrained base); here there
        // is no pretrained base to preserve, and a zero B would zero
        // grad_A = x^T(g·B^T) at step 0 — so B gets a small random init
        // to keep both adapter paths live from the first step.
        let a_scale = (1.0 / self.d as f32).sqrt();
        gs.fill_f32(&mut params[2]);
        for v in params[2].iter_mut() {
            *v *= a_scale;
        }
        let b_scale = 0.1 * (1.0 / self.rank as f32).sqrt();
        gs.fill_f32(&mut params[3]);
        for v in params[3].iter_mut() {
            *v *= b_scale;
        }
    }

    fn forward(
        &self,
        x: LayerIn<'_>,
        params: &[Vec<f32>],
        out: &mut [f32],
        cache: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let rows = ctx.rows();
        let x = x.feat();
        let (h_c, tmp_c) = cache.split_at_mut(1);
        kernels::linear_forward(
            x,
            &params[0],
            Some(&params[1]),
            out,
            rows,
            self.d,
            self.p,
            ctx.threads,
        );
        kernels::linear_forward(x, &params[2], None, &mut h_c[0], rows, self.d, self.rank, ctx.threads);
        kernels::linear_forward(
            &h_c[0],
            &params[3],
            None,
            &mut tmp_c[0],
            rows,
            self.rank,
            self.p,
            ctx.threads,
        );
        for (o, &a) in out.iter_mut().zip(tmp_c[0].iter()) {
            *o += a;
        }
    }

    fn backward_data(
        &self,
        g_out: &[f32],
        _x: LayerIn<'_>,
        _out: &[f32],
        params: &[Vec<f32>],
        _cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        g_in: &mut [f32],
        ctx: Ctx,
    ) {
        // g_in = g·W^T + (g·B^T)·A^T. gA is recomputed here rather than
        // reused from the norm hook: it is a skinny O(rows·p·r) product,
        // and recomputing keeps this layer independent of whether the
        // hooks ran at all (a fully frozen LoRA layer is skippable).
        let rows = ctx.rows();
        let (ga, rest) = scratch.attn.split_at_mut(rows * self.rank);
        let (tmp, _) = rest.split_at_mut(rows * self.d);
        kernels::backward_data(g_out, &params[3], ga, rows, self.rank, self.p, ctx.threads);
        kernels::backward_data(g_out, &params[0], g_in, rows, self.d, self.p, ctx.threads);
        kernels::backward_data(ga, &params[2], tmp, rows, self.d, self.rank, ctx.threads);
        for (g, &a) in g_in.iter_mut().zip(tmp.iter()) {
            *g += a;
        }
    }

    fn accum_sq_norms(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        route: NormRoute,
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        sq: &mut [f32],
        ctx: Ctx,
    ) {
        let (b, t) = (ctx.b, ctx.t);
        let (d, p, r) = (self.d, self.p, self.rank);
        if self.train[0] {
            match route {
                NormRoute::Ghost => kernels::ghost_norm(
                    x.feat(),
                    g_out,
                    b,
                    t,
                    d,
                    p,
                    scratch.gram_a,
                    scratch.gram_g,
                    sq,
                    ctx.threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    x.feat(),
                    g_out,
                    b,
                    t,
                    d,
                    p,
                    scratch.stream,
                    sq,
                    ctx.threads,
                ),
            }
        }
        if self.train[1] {
            kernels::bias_sq_norms(g_out, b, t, p, scratch.small, sq, ctx.threads);
        }
        if self.train[2] {
            // adapter A is a (d, r) linear with output gradient gA
            let rows = ctx.rows();
            let (ga, _) = scratch.attn.split_at_mut(rows * r);
            kernels::backward_data(g_out, &params[3], ga, rows, r, p, ctx.threads);
            match route {
                NormRoute::Ghost => kernels::ghost_norm(
                    x.feat(),
                    ga,
                    b,
                    t,
                    d,
                    r,
                    scratch.gram_a,
                    scratch.gram_g,
                    sq,
                    ctx.threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    x.feat(),
                    ga,
                    b,
                    t,
                    d,
                    r,
                    scratch.stream,
                    sq,
                    ctx.threads,
                ),
            }
        }
        if self.train[3] {
            // adapter B is an (r, p) linear with cached input h = x·A
            match route {
                NormRoute::Ghost => kernels::ghost_norm(
                    &cache[0],
                    g_out,
                    b,
                    t,
                    r,
                    p,
                    scratch.gram_a,
                    scratch.gram_g,
                    sq,
                    ctx.threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    &cache[0],
                    g_out,
                    b,
                    t,
                    r,
                    p,
                    scratch.stream,
                    sq,
                    ctx.threads,
                ),
            }
        }
    }

    fn clipped_grads(
        &self,
        x: LayerIn<'_>,
        g_out: &[f32],
        c: Option<&[f32]>,
        params: &[Vec<f32>],
        cache: &[Vec<f32>],
        scratch: &mut Scratch<'_>,
        grads: &mut [Vec<f32>],
        ctx: Ctx,
    ) {
        let (b, t) = (ctx.b, ctx.t);
        let (d, p, r) = (self.d, self.p, self.rank);
        let [gw, gb, ga_grad, gb_ad] = grads else {
            unreachable!("{}: lora has exactly 4 param tensors", self.name);
        };
        if self.train[0] {
            kernels::weighted_grad(
                x.feat(),
                g_out,
                c,
                b,
                t,
                d,
                p,
                scratch.partials,
                gw,
                ctx.threads,
            );
        }
        if self.train[1] {
            kernels::bias_grad(g_out, c, b, t, p, gb);
        }
        if self.train[2] {
            let ga = self.recompute_ga(g_out, params, scratch.attn, ctx);
            kernels::weighted_grad(x.feat(), ga, c, b, t, d, r, scratch.partials, ga_grad, ctx.threads);
        }
        if self.train[3] {
            kernels::weighted_grad(
                &cache[0],
                g_out,
                c,
                b,
                t,
                r,
                p,
                scratch.partials,
                gb_ad,
                ctx.threads,
            );
        }
    }
}
