//! Native model specs: layer stacks executed entirely by the native
//! kernels — no AOT artifacts, no manifest.
//!
//! A spec is a shape recipe: input width `d_in` (the embedding dimension
//! for token models), hidden widths, class count, and the paper's `T`
//! (tokens per sample; 1 for plain MLPs). `vocab > 0` prepends an
//! `Embedding(vocab, d_in)` front layer consuming i32 token ids, and
//! `layernorm` inserts a LayerNorm after the embedding and after every
//! hidden linear layer.
//!
//! Every shape-derived view — [`NativeSpec::layer_widths`],
//! [`NativeSpec::n_params`], [`NativeSpec::arch_layers`],
//! [`NativeSpec::info`], and the executable layer stack built by
//! [`super::layers::build_stack`] — derives from the **one** canonical
//! iterator [`NativeSpec::plan`], so a new layer kind cannot drift
//! between the parameter census, the complexity dims, and the runtime.

use crate::arch::{LayerDims, LayerKind};
use crate::bail;
use crate::error::Result;
use crate::runtime::ModelInfo;
use std::collections::BTreeMap;

/// Parsed trainability preset (`NativeSpec::trainable`): which canonical
/// tensors take gradients, noise, and optimizer state. Frozen tensors
/// still forward (and `backward_data` still flows activation gradients
/// through their layers) but contribute no per-sample norms, no clipped
/// sums, no noise draws, and no opt state — the DP-PEFT contract
/// (DESIGN.md §9).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Trainable {
    /// Every tensor trains (the default; bitwise-identical to the
    /// pre-mask backend).
    All,
    /// Only 1-D tensors train: biases and LayerNorm affines (BiTFiT).
    BiasOnly,
    /// Every `Linear` in the plan becomes a [`PlanOp::LoraLinear`] with
    /// rank-`rank` adapters; only the adapters train, everything else
    /// (embeddings, attention, norms, the frozen bases) is frozen.
    Lora {
        /// Adapter rank (`r ≪ d`, so ghost norms are always cheap).
        rank: usize,
    },
    /// Exactly the named plan layers train (all their tensors); every
    /// other layer is frozen. Aliasing layers (the tied head) follow
    /// their owner and cannot be named independently.
    Mask(Vec<String>),
}

impl Trainable {
    /// Parse a preset string: `all` | `bias-only` | `lora:<rank>` |
    /// `mask:<layer,layer,...>`. The empty string means `all`.
    pub fn parse(s: &str) -> Result<Trainable> {
        match s {
            "" | "all" => Ok(Trainable::All),
            "bias-only" => Ok(Trainable::BiasOnly),
            _ => {
                if let Some(r) = s.strip_prefix("lora:") {
                    let rank: usize = r.parse().map_err(|_| {
                        crate::anyhow!("bad LoRA rank '{r}' in trainable preset '{s}'")
                    })?;
                    if rank == 0 {
                        bail!("LoRA rank must be > 0 in trainable preset '{s}'");
                    }
                    Ok(Trainable::Lora { rank })
                } else if let Some(list) = s.strip_prefix("mask:") {
                    let names: Vec<String> = list
                        .split(',')
                        .map(str::trim)
                        .filter(|n| !n.is_empty())
                        .map(String::from)
                        .collect();
                    if names.is_empty() {
                        bail!("trainable mask '{s}' names no layers");
                    }
                    Ok(Trainable::Mask(names))
                } else {
                    bail!(
                        "unknown trainable preset '{s}' \
                         (expected all | bias-only | lora:<rank> | mask:<layer,...>)"
                    );
                }
            }
        }
    }

    /// Canonical string form (round-trips through [`Trainable::parse`]).
    pub fn canonical(&self) -> String {
        match self {
            Trainable::All => "all".into(),
            Trainable::BiasOnly => "bias-only".into(),
            Trainable::Lora { rank } => format!("lora:{rank}"),
            Trainable::Mask(names) => format!("mask:{}", names.join(",")),
        }
    }
}

/// Explicit model family for [`NativeSpec`]: which plan `plan()` builds.
///
/// Historically the family was inferred from flag combinations
/// (`blocks > 0` ⇒ GPT, `vocab > 0` ⇒ token model, …). That implicit
/// rule still works — field-struct construction gets [`ModelKind::Auto`]
/// and resolves through [`NativeSpec::model_kind`] — but the plan-builder
/// constructors ([`NativeSpec::mlp`], [`NativeSpec::gpt`],
/// [`NativeSpec::conv`]) set the family explicitly, and conv spec fields
/// (input image shape, conv stages) live **only** on the conv arm.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Legacy flag resolution: `blocks > 0` ⇒ [`ModelKind::Gpt`], else
    /// [`ModelKind::Mlp`]. What `..NativeSpec::default()` construction
    /// gets, so existing field-struct callers keep working.
    Auto,
    /// Flat MLP / token-classifier plan (`vocab`/`layernorm`/`seq`
    /// flags shape the stack as before).
    Mlp,
    /// GPT-style pre-LN transformer plan (`blocks`, `attn_heads`, `ff`,
    /// `tied`, `wpe` flags apply).
    Gpt,
    /// Conv2d/pool/flatten vision plan. The image shape and the conv
    /// stage list live here and nowhere else; `hidden` still names
    /// post-flatten linear widths and `n_classes` the head width.
    Conv {
        /// Input channels.
        cin: usize,
        /// Input image height.
        h: usize,
        /// Input image width.
        w: usize,
        /// Conv stages in order (each: conv → ReLU → optional pool).
        stages: Vec<ConvStage>,
    },
}

/// One conv stage of a [`ModelKind::Conv`] plan: a `k×k` convolution
/// (stride/pad), a ReLU, and an optional non-overlapping pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvStage {
    /// Output channels.
    pub cout: usize,
    /// Square kernel extent.
    pub k: usize,
    /// Stride (both axes).
    pub stride: usize,
    /// Zero padding (both axes).
    pub pad: usize,
    /// Identity skip around the conv (`out += input`, the ResNet block
    /// skip); requires a shape-preserving conv (`cin == cout`, output
    /// spatial extent == input extent).
    pub residual: bool,
    /// Non-overlapping `win×win` pooling (stride = win) after the ReLU.
    pub pool: Option<(PoolKind, usize)>,
}

impl ConvStage {
    /// A plain `k×k` conv stage (no skip, no pool).
    pub fn new(cout: usize, k: usize, stride: usize, pad: usize) -> Self {
        ConvStage {
            cout,
            k,
            stride,
            pad,
            residual: false,
            pool: None,
        }
    }

    /// Add a non-overlapping `win×win` pool after the ReLU.
    pub fn pool(mut self, kind: PoolKind, win: usize) -> Self {
        self.pool = Some((kind, win));
        self
    }

    /// Add the identity skip around the conv.
    pub fn residual(mut self) -> Self {
        self.residual = true;
        self
    }
}

/// Pooling reduction over each window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    /// Window mean; backward spreads the gradient uniformly.
    Avg,
    /// Window max; backward routes the gradient to the argmax element.
    Max,
}

/// Output spatial extent of one conv axis: `(n + 2·pad − k)/stride + 1`.
pub fn conv_out(n: usize, k: usize, stride: usize, pad: usize) -> usize {
    (n + 2 * pad).saturating_sub(k) / stride + 1
}

/// One operation in a native layer stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanOp {
    /// Token embedding lookup: a `(vocab, dim)` table consuming i32 ids.
    Embedding {
        /// Vocabulary size (token ids are `0..vocab`).
        vocab: usize,
        /// Embedding dimension.
        dim: usize,
    },
    /// Fully connected `(d, p)` with bias.
    Linear {
        /// Input feature width.
        d: usize,
        /// Output feature width.
        p: usize,
    },
    /// Elementwise `max(0, x)`.
    Relu {
        /// Feature width (unchanged by the op).
        width: usize,
    },
    /// LayerNorm over the feature axis with affine `(gamma, beta)`.
    LayerNorm {
        /// Normalized feature width.
        width: usize,
    },
    /// Causal multi-head self-attention over width `d` (fused QKV
    /// projection + output projection; `heads` must divide `d`).
    Attention {
        /// Model width (input and output feature width).
        d: usize,
        /// Attention head count.
        heads: usize,
    },
    /// Vocab head tied to the embedding table: `out = x · W^T` with `W`
    /// the front embedding's `(p, d)` tensor (GPT-2 `lm_head = wte^T`),
    /// no bias. Declared by repeating the owner's tensor name in
    /// `param_names` — the backend resolves the alias to one canonical
    /// tensor slot.
    TiedLinear {
        /// Input feature width (the embedding dimension).
        d: usize,
        /// Output width = vocabulary size.
        p: usize,
    },
    /// Learned positional embedding (GPT-2 `wpe`): adds a `(seq, dim)`
    /// table row-wise to the sequence, `out[i, t, :] = x[i, t, :] +
    /// W[t, :]`. Rows never collide across positions, so its per-sample
    /// norm is the plain gradient Frobenius norm and backward to the
    /// layer below is the identity.
    PosEmbedding {
        /// Table rows (= the spec's sequence length).
        seq: usize,
        /// Embedding dimension.
        dim: usize,
    },
    /// LoRA-adapted linear (`trainable = "lora:<rank>"` rewrites every
    /// plain `Linear` into this): a frozen `(d, p)` base W, b plus
    /// trainable rank-`rank` adapters `A (d, rank)`, `B (rank, p)` —
    /// `out = x·W + b + (x·A)·B`.
    LoraLinear {
        /// Input feature width.
        d: usize,
        /// Output feature width.
        p: usize,
        /// Adapter rank.
        rank: usize,
    },
    /// 2-D convolution over an HWC activation layout (`h·w` spatial
    /// positions, channels innermost), square `k×k` kernel with bias.
    /// Executed as im2col: unfold the input into `(B, T, cin·k²)`
    /// patches with T = output spatial positions, then the same
    /// `(d, p)` matmul / ghost-norm / instantiation kernels every
    /// linear layer uses — the weight tensor is stored `(cin·k², cout)`.
    Conv2d {
        /// Input channels.
        cin: usize,
        /// Input spatial height.
        h: usize,
        /// Input spatial width.
        w: usize,
        /// Output channels.
        cout: usize,
        /// Square kernel extent.
        k: usize,
        /// Stride (both axes).
        stride: usize,
        /// Zero padding (both axes).
        pad: usize,
    },
    /// Non-overlapping `win×win` spatial pooling (stride = win) over an
    /// HWC activation of `c` channels; stateless.
    Pool2d {
        /// Window reduction (avg or max).
        kind: PoolKind,
        /// Channels (unchanged by the op).
        c: usize,
        /// Input spatial height (`h % win == 0`).
        h: usize,
        /// Input spatial width (`w % win == 0`).
        w: usize,
        /// Pool window extent = stride.
        win: usize,
    },
    /// CHW/HWC → flat-vector boundary between the conv trunk and the
    /// linear tail. Numerically the identity (activations are already
    /// flat rows); stateless.
    Flatten {
        /// Flattened feature width (`c·h·w` of the layer below).
        n: usize,
    },
}

impl PlanOp {
    /// Output spatial extent of the conv/pool ops (`None` otherwise).
    pub fn out_hw(&self) -> Option<(usize, usize)> {
        match *self {
            PlanOp::Conv2d {
                h, w, k, stride, pad, ..
            } => Some((conv_out(h, k, stride, pad), conv_out(w, k, stride, pad))),
            PlanOp::Pool2d { h, w, win, .. } => Some((h / win, w / win)),
            _ => None,
        }
    }
}

/// One planned layer: the op plus its display / parameter names.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlannedLayer {
    /// Display name (`fc0`, `emb`, `ln1`, ...).
    pub name: String,
    /// The operation.
    pub op: PlanOp,
    /// Names of this layer's trainable tensors, in parameter order.
    pub param_names: Vec<String>,
    /// Residual skip: `Some(r)` adds the *input* activation of plan
    /// layer `r` to this layer's output (the transformer pre-LN skip).
    pub residual: Option<usize>,
}

impl PlannedLayer {
    /// Output feature width of the op.
    pub fn out_width(&self) -> usize {
        match self.op {
            PlanOp::Embedding { dim, .. } => dim,
            PlanOp::Linear { p, .. } => p,
            PlanOp::Relu { width } | PlanOp::LayerNorm { width } => width,
            PlanOp::Attention { d, .. } => d,
            PlanOp::TiedLinear { p, .. } => p,
            PlanOp::PosEmbedding { dim, .. } => dim,
            PlanOp::LoraLinear { p, .. } => p,
            PlanOp::Conv2d { cout, .. } => {
                let (ho, wo) = self.op.out_hw().unwrap();
                cout * ho * wo
            }
            PlanOp::Pool2d { c, .. } => {
                let (ho, wo) = self.op.out_hw().unwrap();
                c * ho * wo
            }
            PlanOp::Flatten { n } => n,
        }
    }

    /// Shapes of the trainable tensors, matching `param_names` order.
    /// For an aliasing layer (`TiedLinear`) this is the **canonical**
    /// (owner's) shape, so name-keyed shape maps stay consistent.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        match self.op {
            PlanOp::Embedding { vocab, dim } => vec![vec![vocab, dim]],
            PlanOp::Linear { d, p } => vec![vec![d, p], vec![p]],
            PlanOp::Relu { .. } => Vec::new(),
            PlanOp::LayerNorm { width } => vec![vec![width], vec![width]],
            PlanOp::Attention { d, .. } => {
                vec![vec![d, 3 * d], vec![3 * d], vec![d, d], vec![d]]
            }
            PlanOp::TiedLinear { d, p } => vec![vec![p, d]],
            PlanOp::PosEmbedding { seq, dim } => vec![vec![seq, dim]],
            PlanOp::LoraLinear { d, p, rank } => {
                vec![vec![d, p], vec![p], vec![d, rank], vec![rank, p]]
            }
            // weight in the kernel's (d, p) = (cin·k², cout) layout
            PlanOp::Conv2d { cin, cout, k, .. } => {
                vec![vec![cin * k * k, cout], vec![cout]]
            }
            PlanOp::Pool2d { .. } | PlanOp::Flatten { .. } => Vec::new(),
        }
    }

    /// Complexity-engine dims (`None` for stateless ops), in the
    /// paper's (T, d, p) convention at sequence length `t`. Attention
    /// encodes d = model width and p = head count (see
    /// `complexity::attention_sublayers`).
    pub fn dims(&self, t: usize) -> Option<LayerDims> {
        let (kind, d, p) = match self.op {
            PlanOp::Embedding { vocab, dim } => (LayerKind::Embedding, vocab, dim),
            PlanOp::Linear { d, p } => (LayerKind::Linear, d, p),
            PlanOp::Relu { .. } => return None,
            // a conv carries its *own* T — the output spatial positions
            // of the im2col view — regardless of the spec's sequence axis
            PlanOp::Conv2d { cin, cout, k, .. } => {
                let (ho, wo) = self.op.out_hw().unwrap();
                return Some(LayerDims {
                    kind: LayerKind::Conv,
                    name: self.name.clone(),
                    t: (ho * wo) as u64,
                    d: (cin * k * k) as u64,
                    p: cout as u64,
                });
            }
            PlanOp::Pool2d { .. } | PlanOp::Flatten { .. } => return None,
            PlanOp::LayerNorm { width } => (LayerKind::Norm, width, width),
            PlanOp::Attention { d, heads } => (LayerKind::Attention, d, heads),
            PlanOp::TiedLinear { d, p } => (LayerKind::TiedLinear, d, p),
            // d = p = dim; the table rows are the t axis (weight census
            // is t*p — see `LayerDims::weight_params`)
            PlanOp::PosEmbedding { dim, .. } => (LayerKind::PosEmbedding, dim, dim),
            PlanOp::LoraLinear { d, p, rank } => (LayerKind::Lora { rank: rank as u64 }, d, p),
        };
        Some(LayerDims {
            kind,
            name: self.name.clone(),
            t: t as u64,
            d: d as u64,
            p: p as u64,
        })
    }
}

/// Shape recipe for a natively executable model.
#[derive(Clone, Debug)]
pub struct NativeSpec {
    /// Registry name.
    pub name: String,
    /// Samples per physical batch (the paper's B).
    pub batch: usize,
    /// Tokens per sample (the paper's T; 1 for flat inputs).
    pub seq: usize,
    /// Input feature width d (the embedding dimension when `vocab > 0`).
    pub d_in: usize,
    /// Hidden layer widths (ReLU between layers).
    pub hidden: Vec<usize>,
    /// Output classes (must equal `vocab` for token models: the native
    /// sequence pipeline is next-token prediction).
    pub n_classes: usize,
    /// "sgd" | "adam".
    pub optimizer: String,
    /// "abadi" | "automatic" | "flat".
    pub clip_fn: String,
    /// Vocabulary size; `> 0` prepends `Embedding(vocab, d_in)` and the
    /// model consumes i32 token ids instead of f32 features.
    pub vocab: usize,
    /// Insert LayerNorm after the embedding and each hidden linear.
    pub layernorm: bool,
    /// Transformer block count. `> 0` switches the plan to a GPT-style
    /// stack — Embedding, `blocks` pre-LN blocks (causal self-attention
    /// + MLP, both with residual adds), final LayerNorm, vocab head —
    /// and `hidden` / `layernorm` are ignored (`ff` is the block MLP
    /// width, `attn_heads` the head count; requires `vocab > 0`).
    pub blocks: usize,
    /// Attention heads per block (must divide `d_in`).
    pub attn_heads: usize,
    /// Feed-forward width of the block MLP.
    pub ff: usize,
    /// Tie the vocab head to the embedding table (`lm_head = wte^T`,
    /// the GPT-2 convention). Transformer plans only (`blocks > 0`):
    /// the head becomes a bias-free [`PlanOp::TiedLinear`] viewing the
    /// `(vocab, d_in)` embedding tensor, the shared tensor is counted
    /// once, and its per-sample norm includes the ghost cross term.
    pub tied: bool,
    /// Insert a learned positional-embedding layer (`wpe`, a
    /// `(seq, d_in)` table added row-wise) right after the token
    /// embedding. Token models only (`vocab > 0`).
    pub wpe: bool,
    /// Trainability preset: `all` (default) | `bias-only` |
    /// `lora:<rank>` | `mask:<layer,...>` — see [`Trainable::parse`].
    /// `lora:<rank>` structurally rewrites every plain `Linear` of the
    /// plan into a [`PlanOp::LoraLinear`]; the other presets only flag
    /// tensors frozen. Validated by [`NativeSpec::trainable_preset`].
    pub trainable: String,
    /// Explicit model family. [`ModelKind::Auto`] (the `Default`)
    /// resolves through the legacy flag rules, so field-struct
    /// construction keeps working; the plan-builder constructors set
    /// this explicitly, and the conv image shape / stage list live only
    /// on [`ModelKind::Conv`].
    pub model: ModelKind,
}

impl Default for NativeSpec {
    fn default() -> Self {
        Self {
            name: String::new(),
            batch: 1,
            seq: 1,
            d_in: 1,
            hidden: Vec::new(),
            n_classes: 2,
            optimizer: "sgd".into(),
            clip_fn: "automatic".into(),
            vocab: 0,
            layernorm: false,
            blocks: 0,
            attn_heads: 0,
            ff: 0,
            tied: false,
            wpe: false,
            trainable: "all".into(),
            model: ModelKind::Auto,
        }
    }
}

impl NativeSpec {
    /// Plan-builder constructor: a flat MLP (`ReLU` between hidden
    /// widths) with an explicit [`ModelKind::Mlp`]. Defaults for the
    /// remaining fields come from `Default` — set them with struct
    /// update syntax (`NativeSpec { optimizer: .., ..NativeSpec::mlp(..) }`).
    pub fn mlp(name: &str, batch: usize, d_in: usize, hidden: &[usize], n_classes: usize) -> Self {
        NativeSpec {
            name: name.into(),
            batch,
            d_in,
            hidden: hidden.to_vec(),
            n_classes,
            model: ModelKind::Mlp,
            ..NativeSpec::default()
        }
    }

    /// Plan-builder constructor: a GPT-style pre-LN transformer
    /// (next-token over `vocab`) with an explicit [`ModelKind::Gpt`].
    #[allow(clippy::too_many_arguments)]
    pub fn gpt(
        name: &str,
        batch: usize,
        seq: usize,
        d_model: usize,
        vocab: usize,
        blocks: usize,
        heads: usize,
        ff: usize,
    ) -> Self {
        NativeSpec {
            name: name.into(),
            batch,
            seq,
            d_in: d_model,
            n_classes: vocab,
            vocab,
            blocks,
            attn_heads: heads,
            ff,
            model: ModelKind::Gpt,
            ..NativeSpec::default()
        }
    }

    /// Plan-builder constructor: a conv/pool/flatten vision stack over
    /// `cin×h×w` images with an explicit [`ModelKind::Conv`]. `hidden`
    /// (post-flatten linear widths) and `n_classes` shape the linear
    /// tail exactly as in the MLP plan.
    pub fn conv(
        name: &str,
        batch: usize,
        cin: usize,
        h: usize,
        w: usize,
        stages: &[ConvStage],
        n_classes: usize,
    ) -> Self {
        NativeSpec {
            name: name.into(),
            batch,
            d_in: cin * h * w,
            n_classes,
            model: ModelKind::Conv {
                cin,
                h,
                w,
                stages: stages.to_vec(),
            },
            ..NativeSpec::default()
        }
    }

    /// The effective model family: the explicit [`NativeSpec::model`]
    /// when set, else the legacy flag resolution (`blocks > 0` ⇒ GPT,
    /// everything else the MLP/token plan).
    pub fn model_kind(&self) -> ModelKind {
        match &self.model {
            ModelKind::Auto => {
                if self.blocks > 0 {
                    ModelKind::Gpt
                } else {
                    ModelKind::Mlp
                }
            }
            k => k.clone(),
        }
    }

    /// Validate kind/flag consistency and (for conv models) the stage
    /// geometry. Backends call this at construction.
    pub fn validate_kind(&self) -> Result<()> {
        match self.model_kind() {
            ModelKind::Auto => unreachable!("model_kind resolves Auto"),
            ModelKind::Mlp => {
                if self.blocks > 0 {
                    bail!(
                        "model '{}': ModelKind::Mlp with blocks = {} (use NativeSpec::gpt)",
                        self.name,
                        self.blocks
                    );
                }
            }
            ModelKind::Gpt => {
                if self.blocks == 0 {
                    bail!("model '{}': ModelKind::Gpt needs blocks > 0", self.name);
                }
            }
            ModelKind::Conv { cin, h, w, stages } => {
                if self.blocks > 0 || self.vocab > 0 || self.seq != 1 {
                    bail!(
                        "model '{}': conv plans are flat-image (seq = 1, no vocab/blocks)",
                        self.name
                    );
                }
                if self.d_in != cin * h * w {
                    bail!(
                        "model '{}': d_in {} != cin*h*w = {}",
                        self.name,
                        self.d_in,
                        cin * h * w
                    );
                }
                if stages.is_empty() {
                    bail!("model '{}': conv plan has no stages", self.name);
                }
                let (mut c, mut hh, mut ww) = (cin, h, w);
                for (si, st) in stages.iter().enumerate() {
                    if st.cout == 0 || st.k == 0 || st.stride == 0 {
                        bail!("model '{}': conv stage {si} has a zero dim", self.name);
                    }
                    if st.k > hh + 2 * st.pad || st.k > ww + 2 * st.pad {
                        bail!(
                            "model '{}': conv stage {si} kernel {} exceeds padded input {}x{}",
                            self.name,
                            st.k,
                            hh + 2 * st.pad,
                            ww + 2 * st.pad
                        );
                    }
                    let (mut ho, mut wo) = (
                        conv_out(hh, st.k, st.stride, st.pad),
                        conv_out(ww, st.k, st.stride, st.pad),
                    );
                    if st.residual && (st.cout != c || ho != hh || wo != ww) {
                        bail!(
                            "model '{}': conv stage {si} residual needs a shape-preserving \
                             conv ({}x{}x{} in vs {}x{}x{} out)",
                            self.name,
                            c,
                            hh,
                            ww,
                            st.cout,
                            ho,
                            wo
                        );
                    }
                    if let Some((_, win)) = st.pool {
                        if win == 0 || ho % win != 0 || wo % win != 0 {
                            bail!(
                                "model '{}': conv stage {si} pool window {win} \
                                 does not tile {ho}x{wo}",
                                self.name
                            );
                        }
                        ho /= win;
                        wo /= win;
                    }
                    c = st.cout;
                    hh = ho;
                    ww = wo;
                }
            }
        }
        Ok(())
    }

    /// The canonical layer walk: every other shape view derives from
    /// this one iterator, so layer kinds cannot drift between views.
    /// The `lora:<rank>` trainability preset is *structural*: it
    /// rewrites every plain `Linear` into a [`PlanOp::LoraLinear`]
    /// carrying the frozen base tensors plus the trainable adapters.
    pub fn plan(&self) -> Vec<PlannedLayer> {
        let mut out = match self.model_kind() {
            ModelKind::Auto => unreachable!("model_kind resolves Auto"),
            ModelKind::Gpt => self.transformer_plan(),
            ModelKind::Conv { cin, h, w, stages } => self.conv_plan(cin, h, w, &stages),
            ModelKind::Mlp => self.mlp_plan(),
        };
        if let Ok(Trainable::Lora { rank }) = Trainable::parse(&self.trainable) {
            for l in out.iter_mut() {
                if let PlanOp::Linear { d, p } = l.op {
                    l.op = PlanOp::LoraLinear { d, p, rank };
                    l.param_names.push(format!("{}_lora_a", l.name));
                    l.param_names.push(format!("{}_lora_b", l.name));
                }
            }
        }
        out
    }

    /// The flat MLP / token-classifier plan (`blocks == 0`).
    fn mlp_plan(&self) -> Vec<PlannedLayer> {
        let mut out = Vec::new();
        let mut d = self.d_in;
        let mut fc = 0usize;
        let mut ln = 0usize;
        let push_ln = |out: &mut Vec<PlannedLayer>, ln: &mut usize, width: usize| {
            out.push(PlannedLayer {
                name: format!("ln{ln}"),
                op: PlanOp::LayerNorm { width },
                param_names: vec![format!("ln{ln}_g"), format!("ln{ln}_b")],
                residual: None,
            });
            *ln += 1;
        };
        if self.vocab > 0 {
            out.push(PlannedLayer {
                name: "emb".into(),
                op: PlanOp::Embedding {
                    vocab: self.vocab,
                    dim: self.d_in,
                },
                param_names: vec!["emb_w".into()],
                residual: None,
            });
            if self.wpe {
                self.push_wpe(&mut out);
            }
            if self.layernorm {
                push_ln(&mut out, &mut ln, d);
            }
        }
        for &h in &self.hidden {
            out.push(PlannedLayer {
                name: format!("fc{fc}"),
                op: PlanOp::Linear { d, p: h },
                param_names: vec![format!("w{fc}"), format!("b{fc}")],
                residual: None,
            });
            fc += 1;
            if self.layernorm {
                push_ln(&mut out, &mut ln, h);
            }
            out.push(PlannedLayer {
                name: format!("relu{}", fc - 1),
                op: PlanOp::Relu { width: h },
                param_names: Vec::new(),
                residual: None,
            });
            d = h;
        }
        out.push(PlannedLayer {
            name: format!("fc{fc}"),
            op: PlanOp::Linear {
                d,
                p: self.n_classes,
            },
            param_names: vec![format!("w{fc}"), format!("b{fc}")],
            residual: None,
        });
        out
    }

    /// The conv/pool/flatten vision plan ([`ModelKind::Conv`]):
    ///
    /// ```text
    /// [ Conv2d (+x if residual) -> ReLU -> Pool? ] * stages
    ///   -> Flatten -> [ Linear -> ReLU ] * hidden -> Linear(n_classes)
    /// ```
    ///
    /// Activations are HWC (spatial positions major, channels
    /// innermost), so the im2col gradient `(B, T, cout)` that flows on
    /// the tape is directly the ghost-norm / instantiation operand — no
    /// transpose anywhere. A residual stage marks `residual =
    /// Some(self)`: the tape adds the conv's *own input* back to its
    /// output, the ResNet identity skip.
    fn conv_plan(
        &self,
        cin: usize,
        h: usize,
        w: usize,
        stages: &[ConvStage],
    ) -> Vec<PlannedLayer> {
        let mut out = Vec::new();
        let (mut c, mut hh, mut ww) = (cin, h, w);
        for (si, st) in stages.iter().enumerate() {
            let conv_idx = out.len();
            let op = PlanOp::Conv2d {
                cin: c,
                h: hh,
                w: ww,
                cout: st.cout,
                k: st.k,
                stride: st.stride,
                pad: st.pad,
            };
            let (mut ho, mut wo) = op.out_hw().unwrap();
            out.push(PlannedLayer {
                name: format!("conv{si}"),
                op,
                param_names: vec![format!("conv{si}_w"), format!("conv{si}_b")],
                residual: st.residual.then_some(conv_idx),
            });
            out.push(PlannedLayer {
                name: format!("crelu{si}"),
                op: PlanOp::Relu {
                    width: st.cout * ho * wo,
                },
                param_names: Vec::new(),
                residual: None,
            });
            if let Some((kind, win)) = st.pool {
                out.push(PlannedLayer {
                    name: format!("pool{si}"),
                    op: PlanOp::Pool2d {
                        kind,
                        c: st.cout,
                        h: ho,
                        w: wo,
                        win,
                    },
                    param_names: Vec::new(),
                    residual: None,
                });
                ho /= win;
                wo /= win;
            }
            c = st.cout;
            hh = ho;
            ww = wo;
        }
        out.push(PlannedLayer {
            name: "flatten".into(),
            op: PlanOp::Flatten { n: c * hh * ww },
            param_names: Vec::new(),
            residual: None,
        });
        // the linear tail reuses the MLP naming (fc{i} / w{i} / b{i})
        let mut d = c * hh * ww;
        let mut fc = 0usize;
        for &hwid in &self.hidden {
            out.push(PlannedLayer {
                name: format!("fc{fc}"),
                op: PlanOp::Linear { d, p: hwid },
                param_names: vec![format!("w{fc}"), format!("b{fc}")],
                residual: None,
            });
            out.push(PlannedLayer {
                name: format!("relu{fc}"),
                op: PlanOp::Relu { width: hwid },
                param_names: Vec::new(),
                residual: None,
            });
            fc += 1;
            d = hwid;
        }
        out.push(PlannedLayer {
            name: format!("fc{fc}"),
            op: PlanOp::Linear {
                d,
                p: self.n_classes,
            },
            param_names: vec![format!("w{fc}"), format!("b{fc}")],
            residual: None,
        });
        out
    }

    /// GPT-style pre-LN transformer plan:
    ///
    /// ```text
    /// Embedding -> [ LN -> Attention (+x) -> LN -> Linear -> ReLU -> Linear (+x) ] * blocks
    ///           -> LN -> Linear(d, vocab)   (next-token head)
    /// ```
    ///
    /// Each `residual` marker names the plan position whose *input*
    /// activation is added to the layer's output — the block input for
    /// the attention skip, the attention output for the MLP skip.
    fn transformer_plan(&self) -> Vec<PlannedLayer> {
        let d = self.d_in;
        let mut out = Vec::new();
        out.push(PlannedLayer {
            name: "emb".into(),
            op: PlanOp::Embedding {
                vocab: self.vocab,
                dim: d,
            },
            param_names: vec!["emb_w".into()],
            residual: None,
        });
        if self.wpe {
            self.push_wpe(&mut out);
        }
        for bi in 0..self.blocks {
            let block_in = out.len();
            out.push(PlannedLayer {
                name: format!("b{bi}_ln1"),
                op: PlanOp::LayerNorm { width: d },
                param_names: vec![format!("b{bi}_ln1_g"), format!("b{bi}_ln1_b")],
                residual: None,
            });
            out.push(PlannedLayer {
                name: format!("b{bi}_attn"),
                op: PlanOp::Attention {
                    d,
                    heads: self.attn_heads,
                },
                param_names: vec![
                    format!("b{bi}_attn_wqkv"),
                    format!("b{bi}_attn_bqkv"),
                    format!("b{bi}_attn_wo"),
                    format!("b{bi}_attn_bo"),
                ],
                residual: Some(block_in),
            });
            let mlp_in = out.len();
            out.push(PlannedLayer {
                name: format!("b{bi}_ln2"),
                op: PlanOp::LayerNorm { width: d },
                param_names: vec![format!("b{bi}_ln2_g"), format!("b{bi}_ln2_b")],
                residual: None,
            });
            out.push(PlannedLayer {
                name: format!("b{bi}_fc1"),
                op: PlanOp::Linear { d, p: self.ff },
                param_names: vec![format!("b{bi}_w1"), format!("b{bi}_b1")],
                residual: None,
            });
            out.push(PlannedLayer {
                name: format!("b{bi}_relu"),
                op: PlanOp::Relu { width: self.ff },
                param_names: Vec::new(),
                residual: None,
            });
            out.push(PlannedLayer {
                name: format!("b{bi}_fc2"),
                op: PlanOp::Linear { d: self.ff, p: d },
                param_names: vec![format!("b{bi}_w2"), format!("b{bi}_b2")],
                residual: Some(mlp_in),
            });
        }
        out.push(PlannedLayer {
            name: "lnf".into(),
            op: PlanOp::LayerNorm { width: d },
            param_names: vec!["lnf_g".into(), "lnf_b".into()],
            residual: None,
        });
        if self.tied {
            // the head aliases the embedding tensor: same param name,
            // canonical (vocab, d) shape, no bias
            out.push(PlannedLayer {
                name: "head".into(),
                op: PlanOp::TiedLinear {
                    d,
                    p: self.n_classes,
                },
                param_names: vec!["emb_w".into()],
                residual: None,
            });
        } else {
            out.push(PlannedLayer {
                name: "head".into(),
                op: PlanOp::Linear {
                    d,
                    p: self.n_classes,
                },
                param_names: vec!["head_w".into(), "head_b".into()],
                residual: None,
            });
        }
        out
    }

    /// The `wpe` positional-embedding layer, right after the token
    /// embedding (GPT-2 order: `wte + wpe`, before any LayerNorm).
    fn push_wpe(&self, out: &mut Vec<PlannedLayer>) {
        out.push(PlannedLayer {
            name: "wpe".into(),
            op: PlanOp::PosEmbedding {
                seq: self.seq,
                dim: self.d_in,
            },
            param_names: vec!["wpe_w".into()],
            residual: None,
        });
    }

    /// Per-linear-layer (d, p) width pairs, input to logits (derived
    /// view over [`NativeSpec::plan`]; linear layers only).
    pub fn layer_widths(&self) -> Vec<(usize, usize)> {
        self.plan()
            .iter()
            .filter_map(|l| match l.op {
                PlanOp::Linear { d, p } => Some((d, p)),
                _ => None,
            })
            .collect()
    }

    /// Number of linear layers.
    pub fn n_layers(&self) -> usize {
        self.layer_widths().len()
    }

    /// Total trainable parameter count, over every layer kind. Keyed on
    /// **canonical** tensors: a name repeated by an aliasing layer (the
    /// tied vocab head) is counted once.
    pub fn n_params(&self) -> usize {
        let mut seen: Vec<String> = Vec::new();
        let mut total = 0usize;
        for l in self.plan() {
            for (name, shape) in l.param_names.iter().zip(l.param_shapes()) {
                if !seen.iter().any(|s| s == name) {
                    seen.push(name.clone());
                    total += shape.iter().product::<usize>();
                }
            }
        }
        total
    }

    /// Trainable-layer dims in the complexity engine's (T, d, p)
    /// convention, used for the mixed ghost/per-sample dispatch
    /// (`ghost_preferred`) and cost reporting.
    pub fn arch_layers(&self) -> Vec<LayerDims> {
        self.plan()
            .iter()
            .filter_map(|l| l.dims(self.seq))
            .collect()
    }

    /// Per-[`NativeSpec::arch_layers`]-entry trainability under this
    /// spec's `trainable` preset: a layer counts as trainable when *any*
    /// of its tensors does (a bias-only Linear still book-keeps its
    /// full-width output gradient for `bias_grad`). Feed this to
    /// [`crate::complexity::bk_gcache_floats_masked`] — the two vectors
    /// are index-parallel by construction.
    pub fn arch_layer_trainable(&self) -> Vec<bool> {
        self.plan()
            .iter()
            .zip(self.plan_masks())
            .filter(|(l, _)| l.dims(self.seq).is_some())
            .map(|(_, mask)| mask.iter().any(|&f| f))
            .collect()
    }

    /// Plan-derived entries for the fused g-cache walk
    /// ([`crate::complexity::bk_gcache_floats_layers`]): one entry per
    /// plan layer — stateless ops included — as whole-batch element
    /// counts at this spec's batch. The `(T, d, p)` view behind
    /// [`crate::complexity::bk_gcache_floats_masked`] cannot represent
    /// stacks whose activation width changes between parameterized
    /// layers (a conv's frontier gradient is `B·cin·h·w`, and pooling/
    /// flatten transitions are invisible to it), so conv predictions
    /// route through this instead. The frontier below layer `k` is the
    /// previous layer's output activation; the walk ignores layer 0's.
    /// The fused-schedule tests pin `StackRun`'s measured gauge ==
    /// this walk's prediction on the registry models.
    pub fn gcache_layers(&self) -> Vec<crate::complexity::GcacheLayer> {
        let plan = self.plan();
        let masks = self.plan_masks();
        let rows = (self.batch * self.seq) as f64;
        let emb = plan.iter().position(|l| matches!(l.op, PlanOp::Embedding { .. }));
        plan.iter()
            .zip(&masks)
            .enumerate()
            .map(|(k, (l, mask))| crate::complexity::GcacheLayer {
                cache: rows * l.out_width() as f64,
                frontier: if k == 0 { 0.0 } else { rows * plan[k - 1].out_width() as f64 },
                trainable: mask.iter().any(|&f| f),
                alias_of: match l.op {
                    PlanOp::TiedLinear { .. } => emb,
                    _ => None,
                },
            })
            .collect()
    }

    /// The complexity-side census of this spec: an [`crate::arch::Arch`]
    /// mirroring the plan layer by layer, with the same conventions
    /// `arch::language` uses for the real model zoo (notably the GPT-2
    /// tied head: a `TiedLinear` carries the head's compute but zero new
    /// parameters). `arch().total_params()` must equal
    /// [`NativeSpec::n_params`] for every registry model — untied heads
    /// are counted honestly on both sides, tied heads once —
    /// which `fastdp complexity` and the registry tests enforce.
    pub fn arch(&self) -> crate::arch::Arch {
        let t = self.seq as u64;
        let mut a = crate::arch::Arch::new(&self.name);
        for l in self.plan() {
            match l.op {
                PlanOp::Embedding { vocab, dim } => {
                    a.embedding(&l.name, t, vocab as u64, dim as u64);
                }
                PlanOp::Linear { d, p } => {
                    a.linear(&l.name, t, d as u64, p as u64, true);
                }
                PlanOp::Relu { .. } => {}
                PlanOp::LayerNorm { width } => {
                    a.norm(&l.name, t, width as u64);
                }
                PlanOp::Attention { d, heads } => {
                    a.attention(&l.name, t, d as u64, heads as u64);
                }
                PlanOp::TiedLinear { d, p } => {
                    a.tied_linear(&l.name, t, d as u64, p as u64);
                }
                PlanOp::PosEmbedding { seq, dim } => {
                    a.pos_embedding(&l.name, seq as u64, dim as u64);
                }
                PlanOp::LoraLinear { d, p, rank } => {
                    a.lora_linear(&l.name, t, d as u64, p as u64, rank as u64, true);
                }
                PlanOp::Conv2d { cin, cout, k, .. } => {
                    let (ho, wo) = l.op.out_hw().unwrap();
                    a.conv_dims(
                        &l.name,
                        (ho * wo) as u64,
                        cin as u64,
                        cout as u64,
                        k as u64,
                        true,
                    );
                }
                PlanOp::Pool2d { .. } | PlanOp::Flatten { .. } => {}
            }
        }
        a
    }

    /// Per-plan-layer, per-tensor trainability flags under the spec's
    /// `trainable` preset — parallel to [`NativeSpec::plan`] (one bool
    /// per `param_names` entry). Aliasing layers (the tied head) carry
    /// their owner's flags: a shared tensor has exactly one
    /// trainability state. An unparseable preset degrades to
    /// fully-trainable here; [`NativeSpec::trainable_preset`] is the
    /// validating entry point.
    pub fn plan_masks(&self) -> Vec<Vec<bool>> {
        let preset = Trainable::parse(&self.trainable).unwrap_or(Trainable::All);
        let plan = self.plan();
        let mut by_name: BTreeMap<String, bool> = BTreeMap::new();
        let mut out = Vec::with_capacity(plan.len());
        for l in &plan {
            let shapes = l.param_shapes();
            let mut mask = Vec::with_capacity(shapes.len());
            for (name, shape) in l.param_names.iter().zip(&shapes) {
                let flag = if let Some(&f) = by_name.get(name) {
                    // alias: the owner's flag, always
                    f
                } else {
                    let f = match &preset {
                        Trainable::All => true,
                        // biases + LayerNorm affines: every 1-D tensor
                        Trainable::BiasOnly => shape.len() == 1,
                        // only the adapter pairs of the rewritten linears
                        Trainable::Lora { .. } => {
                            matches!(l.op, PlanOp::LoraLinear { .. })
                                && (name.ends_with("_lora_a") || name.ends_with("_lora_b"))
                        }
                        Trainable::Mask(names) => names.iter().any(|n| n == &l.name),
                    };
                    by_name.insert(name.clone(), f);
                    f
                };
                mask.push(flag);
            }
            out.push(mask);
        }
        out
    }

    /// Trainability flag per **canonical** tensor, in `info()` /
    /// state-census order (the owner's flag; aliases share the slot).
    pub fn slot_trainable(&self) -> Vec<bool> {
        let plan = self.plan();
        let masks = self.plan_masks();
        let mut names: Vec<&String> = Vec::new();
        let mut out = Vec::new();
        for (l, m) in plan.iter().zip(&masks) {
            for (name, &flag) in l.param_names.iter().zip(m) {
                if !names.contains(&name) {
                    names.push(name);
                    out.push(flag);
                }
            }
        }
        out
    }

    /// Parameters the preset actually trains (canonical tensors only).
    pub fn n_trainable_params(&self) -> usize {
        let plan = self.plan();
        let masks = self.plan_masks();
        let mut seen: Vec<&String> = Vec::new();
        let mut total = 0usize;
        for (l, m) in plan.iter().zip(&masks) {
            for ((name, shape), &flag) in l.param_names.iter().zip(l.param_shapes()).zip(m) {
                if !seen.contains(&name) {
                    seen.push(name);
                    if flag {
                        total += shape.iter().product::<usize>();
                    }
                }
            }
        }
        total
    }

    /// Parse **and validate** the trainability preset against this
    /// spec's plan: mask names must be real parameterized layers (and
    /// owners, not aliases), `lora:` needs a linear to adapt, and the
    /// preset must leave at least one tensor trainable. Backends call
    /// this at construction; `fastdp` config validation calls it too.
    pub fn trainable_preset(&self) -> Result<Trainable> {
        let preset = Trainable::parse(&self.trainable)?;
        let plan = self.plan();
        match &preset {
            Trainable::Mask(names) => {
                for want in names {
                    let Some(l) = plan.iter().find(|l| &l.name == want) else {
                        let known: Vec<&str> = plan
                            .iter()
                            .filter(|l| !l.param_names.is_empty())
                            .map(|l| l.name.as_str())
                            .collect();
                        bail!(
                            "trainable mask names unknown layer '{want}' in model '{}' \
                             (parameterized layers: {})",
                            self.name,
                            known.join(", ")
                        );
                    };
                    if l.param_names.is_empty() {
                        bail!(
                            "trainable mask names stateless layer '{want}' in model '{}'",
                            self.name
                        );
                    }
                    // an aliasing layer repeats an earlier layer's tensor
                    // name; its trainability is the owner's
                    let aliased = l.param_names.iter().any(|n| {
                        plan.iter()
                            .take_while(|o| !std::ptr::eq(*o, l))
                            .any(|o| o.param_names.contains(n))
                    });
                    if aliased {
                        bail!(
                            "trainable mask names aliasing layer '{want}' in model '{}' — \
                             mask the layer owning '{}' instead",
                            self.name,
                            l.param_names[0]
                        );
                    }
                }
            }
            Trainable::Lora { .. } => {
                if !plan.iter().any(|l| matches!(l.op, PlanOp::LoraLinear { .. })) {
                    bail!(
                        "trainable preset '{}' on model '{}' finds no linear layer to adapt",
                        self.trainable,
                        self.name
                    );
                }
            }
            _ => {}
        }
        if !self.slot_trainable().iter().any(|&f| f) {
            bail!(
                "trainable preset '{}' freezes every tensor of model '{}'",
                self.trainable,
                self.name
            );
        }
        Ok(preset)
    }

    /// Backend-neutral description (params in stack order: w0, b0, ...).
    /// Canonical tensors only: an aliased name (tied head) appears once,
    /// at its owner's position — state, noise, and checkpoints all key
    /// off this census.
    pub fn info(&self) -> ModelInfo {
        let mut param_names: Vec<String> = Vec::new();
        let mut param_shapes = BTreeMap::new();
        for layer in self.plan() {
            for (name, shape) in layer.param_names.iter().zip(layer.param_shapes()) {
                if param_names.iter().any(|n| n == name) {
                    debug_assert_eq!(
                        param_shapes.get(name),
                        Some(&shape),
                        "alias '{name}' must view the owner's shape"
                    );
                    continue;
                }
                param_shapes.insert(name.clone(), shape);
                param_names.push(name.clone());
            }
        }
        let kind = match self.model_kind() {
            // GPT-style transformer: same next-token Markov-corpus
            // pipeline the pjrt gpt artifacts use
            ModelKind::Gpt => "gpt",
            // conv trunk + linear tail over flat image vectors
            ModelKind::Conv { .. } => "conv",
            _ if self.vocab > 0 => "seqtok",
            _ if self.seq > 1 => "seqmlp",
            _ => "mlp",
        };
        ModelInfo {
            name: self.name.clone(),
            kind: kind.to_string(),
            batch: self.batch,
            seq: self.seq,
            d_in: self.d_in,
            n_classes: self.n_classes,
            optimizer: self.optimizer.clone(),
            clip_fn: self.clip_fn.clone(),
            param_names,
            param_shapes,
            n_params: self.n_params(),
            trainable: self.slot_trainable(),
            // same degrade-to-All policy as `plan_masks`; validation
            // happens in `trainable_preset()` at backend construction
            trainable_preset: Trainable::parse(&self.trainable)
                .unwrap_or(Trainable::All)
                .canonical(),
        }
    }

    /// Built-in model registry (the native analogue of `artifacts/`),
    /// built entirely through the plan-builder constructors.
    pub fn registry() -> Vec<NativeSpec> {
        vec![
            // The seed MLP config: the bench acceptance target.
            NativeSpec::mlp("mlp_e2e", 32, 128, &[256, 256], 10),
            // Wider variant where per-sample instantiation gets expensive
            // (Opacus memory blows up; BK does not).
            NativeSpec::mlp("mlp_wide", 32, 512, &[1024, 1024], 10),
            // MLP with LayerNorm after each hidden linear: exercises the
            // norm-layer DP path (instantiated per-sample grads) on the
            // flat-vector pipeline.
            NativeSpec {
                layernorm: true,
                ..NativeSpec::mlp("mlp_ln", 32, 64, &[128, 128], 10)
            },
            // Sequential per-token classifier: T = 32 makes the mixed
            // dispatch non-trivial (2T^2 = 2048 straddles the layer pd's).
            NativeSpec {
                seq: 32,
                optimizer: "adam".into(),
                ..NativeSpec::mlp("seq_e2e", 16, 64, &[128, 128], 10)
            },
            // Larger sequence workload for benching the Gram kernels.
            NativeSpec {
                seq: 64,
                optimizer: "adam".into(),
                ..NativeSpec::mlp("seq_bench", 32, 128, &[256, 256], 16)
            },
            // Token sequence model: Embedding -> LayerNorm -> MLP head,
            // next-token prediction over a 64-token vocabulary. The
            // embedding exercises the token-equality ghost norm and the
            // LayerNorms the norm-layer route, all natively.
            NativeSpec {
                seq: 16,
                vocab: 64,
                layernorm: true,
                optimizer: "adam".into(),
                ..NativeSpec::mlp("seq_tok_e2e", 16, 32, &[64], 64)
            },
            // Bigger token workload for benching the embedding + LN path.
            NativeSpec {
                seq: 32,
                vocab: 128,
                layernorm: true,
                optimizer: "adam".into(),
                ..NativeSpec::mlp("seq_tok_bench", 16, 64, &[128, 128], 128)
            },
            // GPT-nano: a real causal-attention transformer (the paper's
            // actual experimental subject, scaled to the CPU testbed) —
            // Embedding -> 2 pre-LN blocks -> LN -> vocab head,
            // next-token over the Markov corpus, entirely native.
            NativeSpec {
                optimizer: "adam".into(),
                ..NativeSpec::gpt("gpt_nano_e2e", 8, 16, 32, 64, 2, 4, 64)
            },
            // Bigger transformer workload for benching the attention
            // kernels (T = 32 keeps the ghost/instantiation dispatch
            // non-trivial: 2T^2 = 2048 vs d^2 = 4096).
            NativeSpec {
                optimizer: "adam".into(),
                ..NativeSpec::gpt("gpt_nano_bench", 16, 32, 64, 128, 2, 4, 128)
            },
            // Weight-tied gpt_nano (lm_head = wte^T, the real GPT-2
            // convention): the head is a TiedLinear view of the
            // embedding, the shared tensor is clipped as one unit with
            // the ghost cross term, and the model has vocab*d fewer
            // parameters than its untied sibling.
            NativeSpec {
                tied: true,
                optimizer: "adam".into(),
                ..NativeSpec::gpt("gpt_nano_tied_e2e", 8, 16, 32, 64, 2, 4, 64)
            },
            // Tied bench workload: same dims as gpt_nano_bench, tied
            // head — benches the cross-term kernel next to the Grams.
            NativeSpec {
                tied: true,
                optimizer: "adam".into(),
                ..NativeSpec::gpt("gpt_nano_tied_bench", 16, 32, 64, 128, 2, 4, 128)
            },
            // gpt_nano with a learned positional-embedding table (GPT-2
            // wpe): exercises the PosEmbedding DpLayer whose rows never
            // collide across positions, so its ghost norm is the plain
            // gradient Frobenius norm.
            NativeSpec {
                wpe: true,
                optimizer: "adam".into(),
                ..NativeSpec::gpt("gpt_nano_wpe_e2e", 8, 16, 32, 64, 2, 4, 64)
            },
            // LoRA fine-tune of gpt_nano: every Linear rewritten to a
            // frozen base + rank-4 adapter pair, only adapters (and
            // biases via their own mask state: frozen here) train.
            NativeSpec {
                trainable: "lora:4".into(),
                optimizer: "adam".into(),
                ..NativeSpec::gpt("gpt_nano_lora_e2e", 8, 16, 32, 64, 2, 4, 64)
            },
            // Bigger LoRA workload for benching adapter ghost norms
            // (rank 8 against d = 64 keeps 2T^2 vs d*r dispatch honest).
            NativeSpec {
                trainable: "lora:8".into(),
                optimizer: "adam".into(),
                ..NativeSpec::gpt("gpt_nano_lora_bench", 16, 32, 64, 128, 2, 4, 128)
            },
            // MNIST-style conv stack over 1x14x14 images: conv -> pool ->
            // conv -> flatten -> linear head. Both convs sit in the
            // paper's 2T^2 > pd regime (Table 4): the mixed dispatch must
            // pick per-sample instantiation, where the im2col BK cost
            // stays linear in T while ghost norms would be O(B T^2).
            NativeSpec::conv(
                "conv_mnist_e2e",
                16,
                1,
                14,
                14,
                &[
                    ConvStage::new(8, 3, 1, 1).pool(PoolKind::Max, 2),
                    ConvStage::new(16, 3, 1, 1),
                ],
                10,
            ),
            // ResNet-style trunk over 3x16x16 images: a stem conv plus
            // two shape-preserving residual stages (identity skips ride
            // the same tape residual machinery as the transformer
            // blocks), avg-pooled down to a 128-wide linear head.
            NativeSpec {
                optimizer: "adam".into(),
                ..NativeSpec::conv(
                    "resnet_tiny_e2e",
                    8,
                    3,
                    16,
                    16,
                    &[
                        ConvStage::new(8, 3, 1, 1),
                        ConvStage::new(8, 3, 1, 1).residual().pool(PoolKind::Avg, 2),
                        ConvStage::new(8, 3, 1, 1).residual().pool(PoolKind::Avg, 2),
                    ],
                    10,
                )
            },
            // Bigger vision workload for benching the unfold/fold + conv
            // kernels (T = 1024 on the stem: the regime where ghost-only
            // implementations explode and BK instantiation stays flat).
            NativeSpec::conv(
                "conv_bench",
                16,
                3,
                32,
                32,
                &[
                    ConvStage::new(16, 3, 1, 1).pool(PoolKind::Max, 2),
                    ConvStage::new(16, 3, 1, 1).residual().pool(PoolKind::Max, 2),
                    ConvStage::new(32, 3, 1, 1),
                ],
                10,
            ),
        ]
    }

    /// Look a registry model up by name.
    pub fn by_name(name: &str) -> Option<NativeSpec> {
        Self::registry().into_iter().find(|s| s.name == name)
    }
}

/// Names of every registry model.
pub fn registry_names() -> Vec<String> {
    NativeSpec::registry().into_iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complexity::ghost_preferred;

    #[test]
    fn registry_specs_are_consistent() {
        for spec in NativeSpec::registry() {
            let info = spec.info();
            // every view agrees with the canonical plan; repeated names
            // (tied aliases) collapse to one canonical tensor
            let plan = spec.plan();
            let mut canonical: Vec<&String> = Vec::new();
            for l in &plan {
                for n in &l.param_names {
                    if !canonical.contains(&n) {
                        canonical.push(n);
                    }
                }
            }
            assert_eq!(info.param_names.len(), canonical.len(), "{}", spec.name);
            let total: usize = info
                .param_names
                .iter()
                .map(|n| info.param_shapes[n].iter().product::<usize>())
                .sum();
            assert_eq!(total, spec.n_params(), "{}", spec.name);
            assert_eq!(spec.arch_layers().len(), plan.iter().filter(|l| l.dims(1).is_some()).count());
            assert!(crate::runtime::native::kernels::ClipKind::parse(&spec.clip_fn).is_some());
            assert!(spec.optimizer == "sgd" || spec.optimizer == "adam");
            if spec.vocab > 0 {
                assert_eq!(spec.vocab, spec.n_classes, "{}: token models are next-token", spec.name);
                assert!(matches!(plan[0].op, PlanOp::Embedding { .. }));
            }
            // a repeated name is only legal on an aliasing (tied) layer
            for l in &plan {
                if !matches!(l.op, PlanOp::TiedLinear { .. }) {
                    continue;
                }
                for n in &l.param_names {
                    assert!(
                        plan.iter()
                            .take_while(|o| !std::ptr::eq(*o, l))
                            .any(|o| o.param_names.contains(n)),
                        "{}: tied layer '{}' must alias an earlier tensor",
                        spec.name,
                        l.name
                    );
                }
            }
            // param names are unique per canonical tensor
            let mut names = info.param_names.clone();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), info.param_names.len(), "{}", spec.name);
        }
    }

    #[test]
    fn registry_param_census_matches_arch() {
        // The bug this pins: `arch/language.rs` counts the GPT-2 tied
        // head once while the native registry used to build an untied
        // head and count it — so `fastdp complexity` and the native tape
        // disagreed on parameter totals. Both sides now key on canonical
        // tensors: the arch census must equal the spec census for every
        // registry model (untied heads counted honestly on both sides,
        // tied heads once).
        for spec in NativeSpec::registry() {
            let arch_total = spec.arch().total_params() as usize;
            assert_eq!(
                arch_total,
                spec.n_params(),
                "{}: arch census {} != spec n_params {}",
                spec.name,
                arch_total,
                spec.n_params()
            );
            assert_eq!(spec.info().n_params, spec.n_params(), "{}", spec.name);
        }
    }

    #[test]
    fn mlp_e2e_matches_seed_shape() {
        let s = NativeSpec::by_name("mlp_e2e").unwrap();
        assert_eq!(s.batch, 32);
        assert_eq!(s.d_in, 128);
        assert_eq!(s.n_classes, 10);
        assert_eq!(s.layer_widths(), vec![(128, 256), (256, 256), (256, 10)]);
        assert_eq!(s.n_params(), 128 * 256 + 256 + 256 * 256 + 256 + 256 * 10 + 10);
        // legacy parameter naming is preserved for MLP stacks
        assert_eq!(
            s.info().param_names,
            vec!["w0", "b0", "w1", "b1", "w2", "b2"]
        );
    }

    #[test]
    fn seq_e2e_mixes_routes() {
        // The point of the seq_e2e dims: at T = 32 the wide layers prefer
        // ghost norms and the narrow head prefers instantiation.
        let s = NativeSpec::by_name("seq_e2e").unwrap();
        let layers = s.arch_layers();
        assert!(ghost_preferred(&layers[0]), "64x128 layer should ghost");
        assert!(ghost_preferred(&layers[1]), "128x128 layer should ghost");
        assert!(!ghost_preferred(&layers[2]), "128x10 head should instantiate");
    }

    #[test]
    fn token_plan_has_embedding_and_norms() {
        let s = NativeSpec::by_name("seq_tok_e2e").unwrap();
        let plan = s.plan();
        assert!(matches!(plan[0].op, PlanOp::Embedding { vocab: 64, dim: 32 }));
        assert!(matches!(plan[1].op, PlanOp::LayerNorm { width: 32 }));
        assert!(matches!(plan[2].op, PlanOp::Linear { d: 32, p: 64 }));
        assert!(matches!(plan[3].op, PlanOp::LayerNorm { width: 64 }));
        assert!(matches!(plan[4].op, PlanOp::Relu { width: 64 }));
        assert!(matches!(plan[5].op, PlanOp::Linear { d: 64, p: 64 }));
        assert_eq!(plan.len(), 6);
        // params: emb 64*32 + ln0 2*32 + fc0 32*64+64 + ln1 2*64 + fc1 64*64+64
        assert_eq!(
            s.n_params(),
            64 * 32 + 2 * 32 + (32 * 64 + 64) + 2 * 64 + (64 * 64 + 64)
        );
        // embedding always prefers ghost; norm layers always instantiate
        let arch = s.arch_layers();
        assert!(ghost_preferred(&arch[0]), "embedding ghosts");
        assert!(!ghost_preferred(&arch[1]), "layernorm instantiates");
        let info = s.info();
        assert_eq!(info.kind, "seqtok");
        assert_eq!(
            info.param_names,
            vec!["emb_w", "ln0_g", "ln0_b", "w0", "b0", "ln1_g", "ln1_b", "w1", "b1"]
        );
    }

    #[test]
    fn derived_views_agree_with_plan() {
        // layer_widths / n_layers / arch_layers / info all re-derive from
        // plan(): spot-check consistency on an LN model.
        let s = NativeSpec::by_name("mlp_ln").unwrap();
        assert_eq!(s.layer_widths(), vec![(64, 128), (128, 128), (128, 10)]);
        assert_eq!(s.n_layers(), 3);
        // 3 linear + 2 layernorm trainable layers
        assert_eq!(s.arch_layers().len(), 5);
        assert_eq!(
            s.n_params(),
            (64 * 128 + 128) + 2 * 128 + (128 * 128 + 128) + 2 * 128 + (128 * 10 + 10)
        );
        assert_eq!(s.info().n_params, s.n_params());
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(NativeSpec::by_name("resnet9000").is_none());
        assert!(registry_names().contains(&"mlp_e2e".to_string()));
        assert!(registry_names().contains(&"seq_tok_e2e".to_string()));
        assert!(registry_names().contains(&"gpt_nano_e2e".to_string()));
        assert!(registry_names().contains(&"gpt_nano_bench".to_string()));
        assert!(registry_names().contains(&"gpt_nano_tied_e2e".to_string()));
        assert!(registry_names().contains(&"gpt_nano_tied_bench".to_string()));
    }

    #[test]
    fn transformer_plan_shape_and_residuals() {
        let s = NativeSpec::by_name("gpt_nano_e2e").unwrap();
        let plan = s.plan();
        // emb + 2 * (ln, attn, ln, fc, relu, fc) + lnf + head
        assert_eq!(plan.len(), 1 + 2 * 6 + 2);
        assert!(matches!(plan[0].op, PlanOp::Embedding { vocab: 64, dim: 32 }));
        assert!(matches!(plan[1].op, PlanOp::LayerNorm { width: 32 }));
        assert!(matches!(plan[2].op, PlanOp::Attention { d: 32, heads: 4 }));
        assert!(matches!(plan[3].op, PlanOp::LayerNorm { width: 32 }));
        assert!(matches!(plan[4].op, PlanOp::Linear { d: 32, p: 64 }));
        assert!(matches!(plan[5].op, PlanOp::Relu { width: 64 }));
        assert!(matches!(plan[6].op, PlanOp::Linear { d: 64, p: 32 }));
        // residual markers: attention adds the block input, the MLP tail
        // adds the attention output; everything else is skip-free
        assert_eq!(plan[2].residual, Some(1), "attn skip from the block input");
        assert_eq!(plan[6].residual, Some(3), "mlp skip from the attn output");
        assert_eq!(plan[8].residual, Some(7), "block 1 attn skip");
        assert_eq!(plan[12].residual, Some(9), "block 1 mlp skip");
        assert!(plan
            .iter()
            .enumerate()
            .all(|(k, l)| l.residual.is_none() || [2, 6, 8, 12].contains(&k)));
        // head maps to the vocab; final LN precedes it
        assert!(matches!(plan[13].op, PlanOp::LayerNorm { width: 32 }));
        assert!(matches!(plan[14].op, PlanOp::Linear { d: 32, p: 64 }));
        assert_eq!(s.info().kind, "gpt");
        // params: emb 64*32 + per block (2*32 ln + attn (32*96+96+32*32+32)
        // + 2*32 ln + fc1 32*64+64 + fc2 64*32+32) + lnf 2*32 + head 32*64+64
        let attn = 32 * 96 + 96 + 32 * 32 + 32;
        let block = 2 * 32 + attn + 2 * 32 + (32 * 64 + 64) + (64 * 32 + 32);
        assert_eq!(s.n_params(), 64 * 32 + 2 * block + 2 * 32 + (32 * 64 + 64));
    }

    #[test]
    fn tied_plan_aliases_the_embedding() {
        let tied = NativeSpec::by_name("gpt_nano_tied_e2e").unwrap();
        let untied = NativeSpec::by_name("gpt_nano_e2e").unwrap();
        let plan = tied.plan();
        // same stack shape; only the head op differs
        assert_eq!(plan.len(), untied.plan().len());
        let head = plan.last().unwrap();
        assert!(matches!(head.op, PlanOp::TiedLinear { d: 32, p: 64 }));
        assert_eq!(head.param_names, vec!["emb_w".to_string()]);
        // canonical shape (vocab, d) — the owner's orientation
        assert_eq!(head.param_shapes(), vec![vec![64, 32]]);
        // tied model is exactly head_w + head_b lighter
        assert_eq!(untied.n_params() - tied.n_params(), 32 * 64 + 64);
        // info lists emb_w once, and no head_w/head_b
        let info = tied.info();
        assert_eq!(info.param_names.iter().filter(|n| *n == "emb_w").count(), 1);
        assert!(!info.param_names.iter().any(|n| n == "head_w" || n == "head_b"));
        assert_eq!(info.n_params, tied.n_params());
        // the head is a TiedLinear in the complexity dims with the
        // in/out convention (d = model width, p = vocab)
        let arch = tied.arch_layers();
        let head_dims = arch.last().unwrap();
        assert_eq!(head_dims.kind, LayerKind::TiedLinear);
        assert_eq!((head_dims.d, head_dims.p), (32, 64));
    }

    #[test]
    fn attention_dims_and_routes() {
        let s = NativeSpec::by_name("gpt_nano_e2e").unwrap();
        let arch = s.arch_layers();
        // emb + 2 * (ln, attn, ln, fc1, fc2) + lnf + head trainables
        assert_eq!(arch.len(), 1 + 2 * 5 + 2);
        let attn = arch.iter().find(|l| l.kind == LayerKind::Attention).unwrap();
        assert_eq!((attn.t, attn.d, attn.p), (16, 32, 4));
        // at T = 16, 2T^2 = 512 < d^2 = 1024: attention ghosts
        assert!(ghost_preferred(attn));
        // gpt_nano_bench: 2T^2 = 2048 vs d^2 = 4096 still ghosts, but
        // barely — the dispatch threshold is live on the bench model
        let b = NativeSpec::by_name("gpt_nano_bench").unwrap();
        let attn_b = b
            .arch_layers()
            .into_iter()
            .find(|l| l.kind == LayerKind::Attention)
            .unwrap();
        assert!(ghost_preferred(&attn_b));
    }

    #[test]
    fn wpe_plan_inserts_position_table_after_embedding() {
        let s = NativeSpec::by_name("gpt_nano_wpe_e2e").unwrap();
        let base = NativeSpec::by_name("gpt_nano_e2e").unwrap();
        let plan = s.plan();
        assert_eq!(plan.len(), base.plan().len() + 1);
        assert!(matches!(plan[0].op, PlanOp::Embedding { vocab: 64, dim: 32 }));
        assert!(matches!(plan[1].op, PlanOp::PosEmbedding { seq: 16, dim: 32 }));
        assert_eq!(plan[1].name, "wpe");
        assert_eq!(plan[1].param_names, vec!["wpe_w".to_string()]);
        assert_eq!(plan[1].param_shapes(), vec![vec![16, 32]]);
        // residual markers shift by one against the wpe-less plan
        assert_eq!(plan[3].residual, Some(2), "attn skip from the block input");
        // census: exactly seq * d more parameters than the base model
        assert_eq!(s.n_params(), base.n_params() + 16 * 32);
        assert_eq!(s.arch().total_params() as usize, s.n_params());
        // rows never collide -> plain-gradient ghost norm is always the
        // cheap route for the position table
        let arch = s.arch_layers();
        let wpe = arch.iter().find(|l| l.kind == LayerKind::PosEmbedding).unwrap();
        assert_eq!((wpe.t, wpe.d, wpe.p), (16, 32, 32));
    }

    #[test]
    fn lora_plan_rewrites_every_linear() {
        let s = NativeSpec::by_name("gpt_nano_lora_e2e").unwrap();
        let base = NativeSpec::by_name("gpt_nano_e2e").unwrap();
        let plan = s.plan();
        assert_eq!(plan.len(), base.plan().len());
        let loras: Vec<_> = plan
            .iter()
            .filter(|l| matches!(l.op, PlanOp::LoraLinear { .. }))
            .collect();
        // 2 blocks * (fc1, fc2) + head
        assert_eq!(loras.len(), 5);
        assert!(!plan.iter().any(|l| matches!(l.op, PlanOp::Linear { .. })));
        // each rewritten layer carries base w, b + adapters a, b
        let head = plan.last().unwrap();
        assert!(matches!(head.op, PlanOp::LoraLinear { d: 32, p: 64, rank: 4 }));
        assert_eq!(
            head.param_names,
            vec!["head_w", "head_b", "head_lora_a", "head_lora_b"]
        );
        assert_eq!(
            head.param_shapes(),
            vec![vec![32, 64], vec![64], vec![32, 4], vec![4, 64]]
        );
        // census: base params + rank * (d + p) per rewritten linear
        let adapters = 4 * (32 + 64) + 4 * (64 + 32) + 4 * (32 + 64) + 4 * (64 + 32) + 4 * (32 + 64);
        assert_eq!(s.n_params(), base.n_params() + adapters);
        assert_eq!(s.arch().total_params() as usize, s.n_params());
        // only the adapter pairs are trainable
        let masks = s.plan_masks();
        for (l, m) in plan.iter().zip(&masks) {
            for (name, &flag) in l.param_names.iter().zip(m) {
                let is_adapter = name.ends_with("_lora_a") || name.ends_with("_lora_b");
                assert_eq!(flag, is_adapter, "{name}");
            }
        }
        assert_eq!(s.n_trainable_params(), adapters);
    }

    #[test]
    fn trainable_presets_parse_and_mask() {
        assert!(matches!(Trainable::parse("all"), Ok(Trainable::All)));
        assert!(matches!(Trainable::parse(""), Ok(Trainable::All)));
        assert!(matches!(Trainable::parse("bias-only"), Ok(Trainable::BiasOnly)));
        assert!(matches!(Trainable::parse("lora:4"), Ok(Trainable::Lora { rank: 4 })));
        assert!(Trainable::parse("lora:0").is_err());
        assert!(Trainable::parse("lora:x").is_err());
        assert!(Trainable::parse("frozen-ish").is_err());
        let Ok(Trainable::Mask(names)) = Trainable::parse("mask:emb, fc0") else {
            panic!("mask parse");
        };
        assert_eq!(names, vec!["emb".to_string(), "fc0".to_string()]);
        assert!(Trainable::parse("mask:").is_err());
        assert_eq!(Trainable::parse("lora:4").unwrap().canonical(), "lora:4");

        // bias-only: every 1-D tensor (biases + LN affines) trains
        let mut s = NativeSpec::by_name("gpt_nano_e2e").unwrap();
        s.trainable = "bias-only".into();
        let plan = s.plan();
        for (l, m) in plan.iter().zip(s.plan_masks()) {
            for (shape, flag) in l.param_shapes().iter().zip(m) {
                assert_eq!(flag, shape.len() == 1);
            }
        }
        let info = s.info();
        let n_bias: usize = info
            .param_names
            .iter()
            .zip(&info.trainable)
            .filter(|(_, &f)| f)
            .map(|(n, _)| info.param_shapes[n].iter().product::<usize>())
            .sum();
        assert_eq!(s.n_trainable_params(), n_bias);
        assert!(n_bias > 0 && n_bias < s.n_params());
        assert!(s.trainable_preset().is_ok());
    }

    #[test]
    fn mask_preset_validation_names_the_problem() {
        let mut s = NativeSpec::by_name("gpt_nano_e2e").unwrap();
        s.trainable = "mask:head".into();
        let masks = s.plan_masks();
        let plan = s.plan();
        for (l, m) in plan.iter().zip(&masks) {
            let want = l.name == "head";
            assert!(m.iter().all(|&f| f == want), "{}", l.name);
        }
        assert!(s.trainable_preset().is_ok());
        // unknown layer
        s.trainable = "mask:nope".into();
        let err = s.trainable_preset().unwrap_err().to_string();
        assert!(err.contains("unknown layer 'nope'"), "{err}");
        assert!(err.contains("head"), "lists parameterized layers: {err}");
        // stateless layer
        s.trainable = "mask:b0_relu".into();
        let err = s.trainable_preset().unwrap_err().to_string();
        assert!(err.contains("stateless layer"), "{err}");
        // aliasing layer: the tied head does not own its tensor
        let mut tied = NativeSpec::by_name("gpt_nano_tied_e2e").unwrap();
        tied.trainable = "mask:head".into();
        let err = tied.trainable_preset().unwrap_err().to_string();
        assert!(err.contains("aliasing layer 'head'"), "{err}");
        assert!(err.contains("emb_w"), "{err}");
        // masking the owner instead is fine, and the alias inherits
        tied.trainable = "mask:emb".into();
        assert!(tied.trainable_preset().is_ok());
        let masks = tied.plan_masks();
        assert_eq!(masks.last().unwrap(), &vec![true], "alias inherits owner flag");
        // lora on a model with no linear to adapt
        let mut emb_only = NativeSpec {
            name: "embless".into(),
            ..NativeSpec::by_name("mlp_e2e").unwrap()
        };
        emb_only.trainable = "lora:2".into();
        // mlp has linears, so this one is fine; freeze-everything is not
        assert!(emb_only.trainable_preset().is_ok());
    }

    #[test]
    fn model_kind_resolves_legacy_flags() {
        // field-struct construction (ModelKind::Auto) resolves exactly
        // as the old implicit rules did
        let legacy_gpt = NativeSpec {
            name: "legacy".into(),
            vocab: 64,
            n_classes: 64,
            blocks: 2,
            attn_heads: 4,
            ff: 64,
            d_in: 32,
            seq: 16,
            ..NativeSpec::default()
        };
        assert_eq!(legacy_gpt.model, ModelKind::Auto);
        assert_eq!(legacy_gpt.model_kind(), ModelKind::Gpt);
        let explicit = NativeSpec::by_name("gpt_nano_e2e").unwrap();
        assert_eq!(explicit.model, ModelKind::Gpt);
        // same name-for-name plan either way
        let mut twin = legacy_gpt.clone();
        twin.name = "gpt_nano_e2e".into();
        twin.batch = 8;
        twin.optimizer = "adam".into();
        assert_eq!(twin.plan(), explicit.plan());
        assert!(legacy_gpt.validate_kind().is_ok());
        // inconsistent explicit kinds are rejected
        let mut bad = explicit.clone();
        bad.model = ModelKind::Mlp;
        assert!(bad.validate_kind().unwrap_err().to_string().contains("blocks"));
        let mut bad = NativeSpec::by_name("mlp_e2e").unwrap();
        bad.model = ModelKind::Gpt;
        assert!(bad.validate_kind().is_err());
    }

    #[test]
    fn conv_plan_shape_and_residuals() {
        let s = NativeSpec::by_name("conv_mnist_e2e").unwrap();
        assert_eq!(s.info().kind, "conv");
        assert_eq!(s.d_in, 14 * 14);
        let plan = s.plan();
        // conv0, crelu0, pool0, conv1, crelu1, flatten, fc0
        assert_eq!(plan.len(), 7);
        assert!(matches!(
            plan[0].op,
            PlanOp::Conv2d { cin: 1, h: 14, w: 14, cout: 8, k: 3, stride: 1, pad: 1 }
        ));
        assert_eq!(plan[0].out_width(), 8 * 14 * 14);
        assert!(matches!(plan[1].op, PlanOp::Relu { width } if width == 8 * 14 * 14));
        assert!(matches!(
            plan[2].op,
            PlanOp::Pool2d { kind: PoolKind::Max, c: 8, h: 14, w: 14, win: 2 }
        ));
        assert_eq!(plan[2].out_width(), 8 * 7 * 7);
        assert!(matches!(
            plan[3].op,
            PlanOp::Conv2d { cin: 8, h: 7, w: 7, cout: 16, .. }
        ));
        assert!(matches!(plan[5].op, PlanOp::Flatten { n } if n == 16 * 7 * 7));
        assert!(matches!(plan[6].op, PlanOp::Linear { d, p: 10 } if d == 16 * 7 * 7));
        assert_eq!(plan[6].param_names, vec!["w0".to_string(), "b0".to_string()]);
        assert!(plan.iter().all(|l| l.residual.is_none()));
        // census: conv weights are (cin*k^2, cout) + bias
        assert_eq!(
            s.n_params(),
            (1 * 9 * 8 + 8) + (8 * 9 * 16 + 16) + (16 * 49 * 10 + 10)
        );
        assert_eq!(s.arch().total_params() as usize, s.n_params());
        // conv dims carry their own T (output spatial positions)
        let arch = s.arch_layers();
        assert_eq!(arch.len(), 3);
        assert_eq!((arch[0].t, arch[0].d, arch[0].p), (196, 9, 8));
        assert_eq!((arch[1].t, arch[1].d, arch[1].p), (49, 72, 16));
        assert_eq!(arch[0].kind, LayerKind::Conv);
        // both convs sit in the 2T^2 > pd regime: instantiation wins
        assert!(!ghost_preferred(&arch[0]));
        assert!(!ghost_preferred(&arch[1]));
    }

    #[test]
    fn resnet_residuals_mark_self_skips() {
        let s = NativeSpec::by_name("resnet_tiny_e2e").unwrap();
        let plan = s.plan();
        // stem conv, relu, [conv res, relu, pool] x2, flatten, head
        let convs: Vec<usize> = plan
            .iter()
            .enumerate()
            .filter(|(_, l)| matches!(l.op, PlanOp::Conv2d { .. }))
            .map(|(k, _)| k)
            .collect();
        assert_eq!(convs.len(), 3);
        assert_eq!(plan[convs[0]].residual, None, "stem has no skip");
        // residual stages skip to their own input (identity add)
        assert_eq!(plan[convs[1]].residual, Some(convs[1]));
        assert_eq!(plan[convs[2]].residual, Some(convs[2]));
        // skip shape check: residual convs are shape-preserving
        for &k in &convs[1..] {
            let in_w = if k == 0 { s.d_in } else { plan[k - 1].out_width() };
            assert_eq!(plan[k].out_width(), in_w, "residual width must match");
        }
        assert_eq!(plan.last().unwrap().out_width(), 10);
        assert!(s.validate_kind().is_ok());
    }

    #[test]
    fn conv_geometry_validation_names_the_problem() {
        // pool window must tile the conv output
        let bad = NativeSpec::conv(
            "bad_pool",
            4,
            1,
            7,
            7,
            &[ConvStage::new(4, 3, 1, 1).pool(PoolKind::Max, 2)],
            10,
        );
        let err = bad.validate_kind().unwrap_err().to_string();
        assert!(err.contains("pool window 2"), "{err}");
        // residual around a non-shape-preserving conv
        let bad = NativeSpec::conv(
            "bad_res",
            4,
            1,
            8,
            8,
            &[ConvStage::new(4, 3, 1, 1).residual()],
            10,
        );
        let err = bad.validate_kind().unwrap_err().to_string();
        assert!(err.contains("shape-preserving"), "{err}");
        // d_in drift against the image shape
        let mut bad = NativeSpec::by_name("conv_mnist_e2e").unwrap();
        bad.d_in = 100;
        assert!(bad.validate_kind().unwrap_err().to_string().contains("d_in"));
        // kernel larger than the padded input
        let bad = NativeSpec::conv("bad_k", 4, 1, 2, 2, &[ConvStage::new(4, 5, 1, 0)], 10);
        assert!(bad.validate_kind().unwrap_err().to_string().contains("kernel"));
        // the registry conv models all pass
        for name in ["conv_mnist_e2e", "resnet_tiny_e2e", "conv_bench"] {
            assert!(NativeSpec::by_name(name).unwrap().validate_kind().is_ok(), "{name}");
        }
    }

    #[test]
    fn conv_models_take_masks_and_bias_only() {
        let mut s = NativeSpec::by_name("conv_mnist_e2e").unwrap();
        s.trainable = "bias-only".into();
        for (l, m) in s.plan().iter().zip(s.plan_masks()) {
            for (shape, flag) in l.param_shapes().iter().zip(m) {
                assert_eq!(flag, shape.len() == 1, "{}", l.name);
            }
        }
        assert!(s.trainable_preset().is_ok());
        s.trainable = "mask:conv1".into();
        assert!(s.trainable_preset().is_ok());
        let plan = s.plan();
        for (l, m) in plan.iter().zip(s.plan_masks()) {
            let want = l.name == "conv1";
            assert!(m.iter().all(|&f| f == want), "{}", l.name);
        }
        // lora adapts the head linear only; convs stay frozen
        s.trainable = "lora:2".into();
        assert!(s.trainable_preset().is_ok());
        let plan = s.plan();
        assert!(plan.iter().any(|l| matches!(l.op, PlanOp::LoraLinear { .. })));
        assert!(plan.iter().any(|l| matches!(l.op, PlanOp::Conv2d { .. })));
    }

    #[test]
    fn gcache_layers_match_dims_walk_on_uniform_stacks() {
        // Plan-derived entries and the (T, d, p) dims walk are the same
        // simulation wherever the dims view is expressive enough: every
        // non-conv registry model must predict identically through both
        // (conv stacks are exactly where the dims view breaks down).
        use crate::complexity::{bk_gcache_floats_layers, bk_gcache_floats_masked, ClippingStyle};
        for spec in NativeSpec::registry() {
            if spec.model_kind() == ModelKind::Conv {
                continue;
            }
            let entries = spec.gcache_layers();
            assert_eq!(entries.len(), spec.plan().len(), "{}", spec.name);
            for style in [
                ClippingStyle::AllLayer,
                ClippingStyle::LayerWise,
                ClippingStyle::GroupWise(2),
            ] {
                assert_eq!(
                    bk_gcache_floats_layers(style, &entries),
                    bk_gcache_floats_masked(
                        style,
                        spec.batch as f64,
                        &spec.arch_layers(),
                        &spec.arch_layer_trainable(),
                    ),
                    "{} {:?}",
                    spec.name,
                    style
                );
            }
        }
    }

    #[test]
    fn gcache_layers_carry_conv_activation_widths() {
        let s = NativeSpec::by_name("conv_mnist_e2e").unwrap();
        let e = s.gcache_layers();
        let b = s.batch as f64;
        // conv0, crelu0, pool0, conv1, crelu1, flatten, fc0
        assert_eq!(e.len(), 7);
        assert_eq!(e[0].cache, b * (8 * 14 * 14) as f64);
        assert_eq!(e[0].frontier, 0.0, "front layer has no frontier below");
        assert!(e[0].trainable);
        // frontier below the pool is conv0's FULL output activation —
        // the width the (T, d, p) view cannot express
        assert_eq!(e[2].frontier, b * (8 * 14 * 14) as f64);
        assert!(!e[2].trainable, "pooling is stateless");
        // frontier below conv1 is the pooled activation, not T·cin·k²
        assert_eq!(e[3].frontier, b * (8 * 7 * 7) as f64);
        assert_eq!(e[3].cache, b * (16 * 7 * 7) as f64);
        assert_eq!(e[6].cache, b * 10.0, "head loss gradient");
        assert!(e.iter().all(|l| l.alias_of.is_none()));
    }

    #[test]
    fn all_trainable_masks_are_all_true() {
        // the default preset must leave every census view untouched
        for spec in NativeSpec::registry() {
            if spec.trainable != "all" {
                continue;
            }
            assert!(spec.slot_trainable().iter().all(|&f| f), "{}", spec.name);
            assert_eq!(spec.n_trainable_params(), spec.n_params(), "{}", spec.name);
            assert_eq!(spec.info().trainable.len(), spec.info().param_names.len());
        }
    }
}
