//! Native model specs: generalized-linear stacks (MLPs over flat or
//! sequential inputs) executed entirely by the native kernels — no AOT
//! artifacts, no manifest.
//!
//! A spec is a shape recipe: input width `d_in`, hidden widths, class
//! count, and the paper's `T` (tokens per sample; 1 for plain MLPs).
//! Sequential specs (`seq > 1`) classify every token, so per-sample
//! gradients sum over `T` and the ghost-norm Gram path is exercised
//! end-to-end; the mixed ghost/per-sample decision is evaluated per
//! layer from the complexity engine on these dims.

use crate::arch::{LayerDims, LayerKind};
use crate::runtime::ModelInfo;
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct NativeSpec {
    pub name: String,
    /// Samples per physical batch (the paper's B).
    pub batch: usize,
    /// Tokens per sample (the paper's T; 1 for flat inputs).
    pub seq: usize,
    /// Input feature width d.
    pub d_in: usize,
    /// Hidden layer widths (ReLU between layers).
    pub hidden: Vec<usize>,
    pub n_classes: usize,
    /// "sgd" | "adam".
    pub optimizer: String,
    /// "abadi" | "automatic" | "flat".
    pub clip_fn: String,
}

impl NativeSpec {
    /// Per-layer (d, p) width pairs, input to logits.
    pub fn layer_widths(&self) -> Vec<(usize, usize)> {
        let mut dims = Vec::with_capacity(self.hidden.len() + 1);
        let mut d = self.d_in;
        for &h in &self.hidden {
            dims.push((d, h));
            d = h;
        }
        dims.push((d, self.n_classes));
        dims
    }

    pub fn n_layers(&self) -> usize {
        self.hidden.len() + 1
    }

    pub fn n_params(&self) -> usize {
        self.layer_widths().iter().map(|&(d, p)| d * p + p).sum()
    }

    /// Layer dims in the complexity engine's (T, d, p) convention, used
    /// for the mixed ghost/per-sample dispatch (`ghost_preferred`).
    pub fn arch_layers(&self) -> Vec<LayerDims> {
        self.layer_widths()
            .iter()
            .enumerate()
            .map(|(l, &(d, p))| LayerDims {
                kind: LayerKind::Linear,
                name: format!("fc{l}"),
                t: self.seq as u64,
                d: d as u64,
                p: p as u64,
            })
            .collect()
    }

    /// Backend-neutral description (param order: w0, b0, w1, b1, ...).
    pub fn info(&self) -> ModelInfo {
        let mut param_names = Vec::new();
        let mut param_shapes = BTreeMap::new();
        for (l, (d, p)) in self.layer_widths().into_iter().enumerate() {
            let wn = format!("w{l}");
            let bn = format!("b{l}");
            param_shapes.insert(wn.clone(), vec![d, p]);
            param_shapes.insert(bn.clone(), vec![p]);
            param_names.push(wn);
            param_names.push(bn);
        }
        ModelInfo {
            name: self.name.clone(),
            kind: if self.seq > 1 { "seqmlp" } else { "mlp" }.to_string(),
            batch: self.batch,
            seq: self.seq,
            d_in: self.d_in,
            n_classes: self.n_classes,
            optimizer: self.optimizer.clone(),
            clip_fn: self.clip_fn.clone(),
            param_names,
            param_shapes,
            n_params: self.n_params(),
        }
    }

    /// Built-in model registry (the native analogue of `artifacts/`).
    pub fn registry() -> Vec<NativeSpec> {
        vec![
            // The seed MLP config: the bench acceptance target.
            NativeSpec {
                name: "mlp_e2e".into(),
                batch: 32,
                seq: 1,
                d_in: 128,
                hidden: vec![256, 256],
                n_classes: 10,
                optimizer: "sgd".into(),
                clip_fn: "automatic".into(),
            },
            // Wider variant where per-sample instantiation gets expensive
            // (Opacus memory blows up; BK does not).
            NativeSpec {
                name: "mlp_wide".into(),
                batch: 32,
                seq: 1,
                d_in: 512,
                hidden: vec![1024, 1024],
                n_classes: 10,
                optimizer: "sgd".into(),
                clip_fn: "automatic".into(),
            },
            // Sequential per-token classifier: T = 32 makes the mixed
            // dispatch non-trivial (2T^2 = 2048 straddles the layer pd's).
            NativeSpec {
                name: "seq_e2e".into(),
                batch: 16,
                seq: 32,
                d_in: 64,
                hidden: vec![128, 128],
                n_classes: 10,
                optimizer: "adam".into(),
                clip_fn: "automatic".into(),
            },
            // Larger sequence workload for benching the Gram kernels.
            NativeSpec {
                name: "seq_bench".into(),
                batch: 32,
                seq: 64,
                d_in: 128,
                hidden: vec![256, 256],
                n_classes: 16,
                optimizer: "adam".into(),
                clip_fn: "automatic".into(),
            },
        ]
    }

    pub fn by_name(name: &str) -> Option<NativeSpec> {
        Self::registry().into_iter().find(|s| s.name == name)
    }
}

pub fn registry_names() -> Vec<String> {
    NativeSpec::registry().into_iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complexity::ghost_preferred;

    #[test]
    fn registry_specs_are_consistent() {
        for spec in NativeSpec::registry() {
            let info = spec.info();
            assert_eq!(info.param_names.len(), 2 * spec.n_layers());
            let total: usize = info
                .param_names
                .iter()
                .map(|n| info.param_shapes[n].iter().product::<usize>())
                .sum();
            assert_eq!(total, spec.n_params(), "{}", spec.name);
            assert!(crate::runtime::native::kernels::ClipKind::parse(&spec.clip_fn).is_some());
            assert!(spec.optimizer == "sgd" || spec.optimizer == "adam");
        }
    }

    #[test]
    fn mlp_e2e_matches_seed_shape() {
        let s = NativeSpec::by_name("mlp_e2e").unwrap();
        assert_eq!(s.batch, 32);
        assert_eq!(s.d_in, 128);
        assert_eq!(s.n_classes, 10);
        assert_eq!(s.layer_widths(), vec![(128, 256), (256, 256), (256, 10)]);
        assert_eq!(s.n_params(), 128 * 256 + 256 + 256 * 256 + 256 + 256 * 10 + 10);
    }

    #[test]
    fn seq_e2e_mixes_routes() {
        // The point of the seq_e2e dims: at T = 32 the wide layers prefer
        // ghost norms and the narrow head prefers instantiation.
        let s = NativeSpec::by_name("seq_e2e").unwrap();
        let layers = s.arch_layers();
        assert!(ghost_preferred(&layers[0]), "64x128 layer should ghost");
        assert!(ghost_preferred(&layers[1]), "128x128 layer should ghost");
        assert!(!ghost_preferred(&layers[2]), "128x10 head should instantiate");
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(NativeSpec::by_name("resnet9000").is_none());
        assert!(registry_names().contains(&"mlp_e2e".to_string()));
    }
}
