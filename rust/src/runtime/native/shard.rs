//! Data-parallel sharded driver for the native backend.
//!
//! [`ShardedRun`] owns `N` full [`NativeBackend`] replicas (params +
//! optimizer state + arena each) and splits every logical batch — an
//! ordered list of K micro-batches — across them at *micro-batch*
//! granularity using the same balanced contiguous split as the kernel
//! fan-out (`par::split_sizes`). Each shard runs whole physical
//! micro-batches through the unchanged fused `StackRun` schedule on its
//! own replica, so every per-micro-batch clipped sum is bitwise
//! identical to what the 1-shard tape computes for that micro-batch.
//!
//! **Reduction-order contract.** f32 addition is non-associative, so
//! shards never pre-merge their local micro-batches: each shard ships
//! every micro-batch result `(k, grads, metrics)` individually over a
//! channel, and rank 0 folds them strictly in ascending global
//! micro-batch order k = 0..K-1 with the same flat left fold
//! ([`merge_micro_batch`]) the sequential accumulation path uses.
//! Out-of-order arrivals park in a pending map until their turn. The
//! result: an N-shard logical step is bitwise identical to the 1-shard
//! step at equal global batch, for any N, including ragged K % N != 0
//! splits and idle shards when K < N.
//!
//! **Rank 0 stays authoritative.** The coordinator owns the noise
//! stream and the RDP accountant; this driver never draws noise or
//! touches the accountant. Reads (`info`, `eval_loss`, `state`,
//! `clipped_grads`, `alloc_stats`) are served by replica 0; writes
//! (`init`, `load_state`, `apply_update`) broadcast to every replica,
//! and because the optimizer update is deterministic element-wise
//! arithmetic, the replicas remain bitwise identical forever.
//!
//! **Determinism scope.** Bitwise parity holds per fixed kernel
//! `threads` and ISA, exactly like the 1-shard tape: every replica is
//! built with the *same* `threads` the 1-shard run would use. N shards
//! x `threads` kernel workers can oversubscribe the machine; that costs
//! wall time, never bits.

use super::model::NativeSpec;
use super::{par, NativeBackend};
use crate::complexity::{ClippingStyle, Dispatch, Strategy};
use crate::error::{Error, Result};
use crate::runtime::{
    finalize_step_out, merge_micro_batch, AllocStats, Backend, BatchX, ModelInfo, StepHyper,
    StepOut,
};
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::mpsc;

/// `N` bitwise-identical [`NativeBackend`] replicas plus the rank-0
/// fixed-order reduction. Implements [`Backend`], so the coordinator,
/// bench, and tests drive it exactly like a single-worker backend.
pub struct ShardedRun {
    /// Replica 0 is rank 0: it serves reads and anchors parity checks.
    shards: Vec<NativeBackend>,
}

impl ShardedRun {
    pub fn new(
        spec: NativeSpec,
        strategy: Strategy,
        style: ClippingStyle,
        threads: usize,
        dispatch: &Dispatch,
        n_shards: usize,
    ) -> Result<Self> {
        if n_shards == 0 {
            bail!("shards must be >= 1");
        }
        let mut shards = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            shards.push(
                NativeBackend::builder(spec.clone(), strategy)
                    .style(style)
                    .threads(threads)
                    .dispatch(dispatch.clone())
                    .build()?,
            );
        }
        Ok(Self { shards })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Rank-0 replica (parity tests compare its state to a 1-shard run).
    pub fn rank0(&self) -> &NativeBackend {
        &self.shards[0]
    }

    pub fn rank0_mut(&mut self) -> &mut NativeBackend {
        &mut self.shards[0]
    }

    /// Contiguous global micro-batch range per shard: the balanced
    /// split (first `K % N` shards take one extra micro-batch).
    fn shard_ranges(&self, k_total: usize) -> Vec<Range<usize>> {
        let mut ranges = Vec::with_capacity(self.shards.len());
        let mut start = 0usize;
        for n in par::split_sizes(k_total, self.shards.len()) {
            ranges.push(start..start + n);
            start += n;
        }
        ranges
    }
}

impl Backend for ShardedRun {
    fn info(&self) -> &ModelInfo {
        self.shards[0].info()
    }

    fn strategy(&self) -> &str {
        self.shards[0].strategy()
    }

    fn init(&mut self, seed: u64) -> Result<()> {
        // Same seed on every replica: the init streams are a pure
        // function of (seed, layer), so all replicas start bitwise
        // identical.
        for shard in self.shards.iter_mut() {
            shard.init(seed)?;
        }
        Ok(())
    }

    fn eval_loss(&mut self, x: &BatchX, y: &[i32]) -> Result<f32> {
        self.shards[0].eval_loss(x, y)
    }

    fn step(
        &mut self,
        x: &BatchX,
        y: &[i32],
        noise: &[Vec<f32>],
        h: &StepHyper,
    ) -> Result<StepOut> {
        // One physical batch == one micro-batch: rank 0 computes the
        // clipped sums (other shards idle) and the update broadcasts.
        // guarded_step pins fused step == clipped_grads + apply_update
        // bitwise, so this matches the 1-shard fused path.
        let (grads, out) = {
            let (grads, mut out) = self.shards[0].clipped_grads(x, y, h.clip)?;
            finalize_step_out(&mut out, 1);
            (grads, out)
        };
        self.apply_update(&grads, noise, h)?;
        Ok(out)
    }

    fn clipped_grads(
        &mut self,
        x: &BatchX,
        y: &[i32],
        clip: f32,
    ) -> Result<(Vec<Vec<f32>>, StepOut)> {
        // Read-only w.r.t. params: rank 0 serves it; replicas stay
        // in sync because nothing is applied here.
        self.shards[0].clipped_grads(x, y, clip)
    }

    fn sharded_grads(
        &mut self,
        batches: &[(BatchX, Vec<i32>)],
        clip: f32,
    ) -> Result<(Vec<Vec<f32>>, StepOut)> {
        if batches.is_empty() {
            bail!("sharded_grads needs at least one micro-batch");
        }
        let k_total = batches.len();
        if self.shards.len() == 1 || k_total == 1 {
            // Degenerate fan-out: run the sequential contract directly
            // on rank 0 (bitwise the same fold, no thread spawn).
            let mut acc_grads: Vec<Vec<f32>> = Vec::new();
            let mut out = StepOut::default();
            for (x, y) in batches {
                let (grads, micro) = self.shards[0].clipped_grads(x, y, clip)?;
                merge_micro_batch(&mut acc_grads, &mut out, grads, micro);
            }
            finalize_step_out(&mut out, k_total);
            return Ok((acc_grads, out));
        }

        let ranges = self.shard_ranges(k_total);
        let (tx, rx) = mpsc::channel::<(usize, Result<(Vec<Vec<f32>>, StepOut)>)>();
        let merged = std::thread::scope(|s| {
            for (shard, range) in self.shards.iter_mut().zip(ranges) {
                if range.is_empty() {
                    continue; // K < N leaves trailing shards idle
                }
                let tx = tx.clone();
                let slice = &batches[range.clone()];
                let k0 = range.start;
                s.spawn(move || {
                    for (i, (x, y)) in slice.iter().enumerate() {
                        let res = shard.clipped_grads(x, y, clip);
                        let failed = res.is_err();
                        if tx.send((k0 + i, res)).is_err() || failed {
                            return; // receiver gone or shard errored
                        }
                    }
                });
            }
            drop(tx);

            // Rank-0 reduction: fold strictly in ascending global
            // micro-batch order. Results arriving early for a later k
            // park in `pending` until every earlier k has been folded —
            // this is what makes the N-shard sum bitwise equal to the
            // sequential flat left fold.
            let mut acc_grads: Vec<Vec<f32>> = Vec::new();
            let mut out = StepOut::default();
            let mut next_k = 0usize;
            let mut pending: BTreeMap<usize, (Vec<Vec<f32>>, StepOut)> = BTreeMap::new();
            let mut first_err: Option<Error> = None;
            for (k, res) in rx {
                match res {
                    Ok(pair) => {
                        pending.insert(k, pair);
                    }
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e.wrap(format!("shard micro-batch {k}")));
                        }
                    }
                }
                while let Some((grads, micro)) = pending.remove(&next_k) {
                    merge_micro_batch(&mut acc_grads, &mut out, grads, micro);
                    next_k += 1;
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
            if next_k != k_total {
                return Err(anyhow!(
                    "sharded reduction incomplete: merged {next_k} of {k_total} micro-batches"
                ));
            }
            finalize_step_out(&mut out, k_total);
            Ok((acc_grads, out))
        })?;
        Ok(merged)
    }

    fn apply_update(
        &mut self,
        grads: &[Vec<f32>],
        noise: &[Vec<f32>],
        h: &StepHyper,
    ) -> Result<()> {
        // Broadcast the identical (grads, noise, hyper) update to every
        // replica; the element-wise optimizer keeps them bitwise equal.
        // Replicas update concurrently — each owns its state.
        let mut results: Vec<Result<()>> = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = self
                .shards
                .iter_mut()
                .map(|shard| s.spawn(move || shard.apply_update(grads, noise, h)))
                .collect();
            results = handles
                .into_iter()
                .map(|hdl| match hdl.join() {
                    Ok(r) => r,
                    Err(_) => Err(anyhow!("shard update thread panicked")),
                })
                .collect();
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    fn state(&self) -> Result<Vec<Vec<f32>>> {
        self.shards[0].state()
    }

    fn load_state(&mut self, tensors: Vec<Vec<f32>>) -> Result<()> {
        for shard in self.shards.iter_mut().skip(1) {
            shard.load_state(tensors.clone())?;
        }
        self.shards[0].load_state(tensors)
    }

    fn alloc_stats(&self) -> AllocStats {
        // Rank 0's arena telemetry: per-shard peaks equal the 1-shard
        // peaks (the physical micro-batch is unchanged), and rank 0
        // always owns micro-batch 0, so its g-cache peak is the pinned
        // one. Fresh allocs are summed so the zero-steady-state
        // invariant covers every replica.
        let mut stats = self.shards[0].alloc_stats();
        for shard in self.shards.iter().skip(1) {
            let s = shard.alloc_stats();
            stats.fresh_allocs_last_step += s.fresh_allocs_last_step;
            stats.arena_bytes += s.arena_bytes;
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn mk(n_shards: usize) -> ShardedRun {
        let spec = NativeSpec::by_name("mlp_e2e").unwrap();
        ShardedRun::new(
            spec,
            Strategy::Bk,
            ClippingStyle::AllLayer,
            2,
            &Dispatch::Formula,
            n_shards,
        )
        .unwrap()
    }

    fn batch_for(info: &ModelInfo, rng: &mut Xoshiro256) -> (BatchX, Vec<i32>) {
        let n = info.batch * info.seq * info.d_in;
        let x: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let y: Vec<i32> = (0..info.batch)
            .map(|_| (rng.next_u64() % info.n_classes as u64) as i32)
            .collect();
        (BatchX::F32(x), y)
    }

    #[test]
    fn rejects_zero_shards() {
        let spec = NativeSpec::by_name("mlp_e2e").unwrap();
        assert!(ShardedRun::new(
            spec,
            Strategy::Bk,
            ClippingStyle::AllLayer,
            1,
            &Dispatch::Formula,
            0
        )
        .is_err());
    }

    #[test]
    fn shard_ranges_balanced_and_contiguous() {
        let run = mk(3);
        let r = run.shard_ranges(7);
        assert_eq!(r, vec![0..3, 3..5, 5..7]);
        let r = run.shard_ranges(2); // K < N: last shard idle
        assert_eq!(r, vec![0..1, 1..2, 2..2]);
    }

    #[test]
    fn replicas_stay_bitwise_identical_after_updates() {
        let mut run = mk(3);
        run.init(7).unwrap();
        let mut rng = Xoshiro256::new(11);
        let info = run.info().clone();
        let batches: Vec<_> = (0..5).map(|_| batch_for(&info, &mut rng)).collect();
        let h = StepHyper {
            lr: 0.1,
            clip: 1.0,
            sigma_r: 0.0,
            logical_batch: (info.batch * batches.len()) as f32,
            step: 1.0,
        };
        let (grads, _) = run.sharded_grads(&batches, h.clip).unwrap();
        run.apply_update(&grads, &[], &h).unwrap();
        let s0 = run.shards[0].state().unwrap();
        for (i, shard) in run.shards.iter().enumerate().skip(1) {
            assert_eq!(s0, shard.state().unwrap(), "replica {i} diverged");
        }
    }

    #[test]
    fn masked_sharded_fold_ships_no_frozen_grads() {
        // Frozen slots carry zero-length grads end to end: shards ship
        // nothing for them, the rank-0 fold keeps them empty, and the
        // broadcast update leaves every replica bitwise identical to
        // the 1-shard masked run.
        let mk_masked = |n_shards: usize| {
            let mut spec = NativeSpec::by_name("mlp_e2e").unwrap();
            spec.trainable = "bias-only".into();
            ShardedRun::new(
                spec,
                Strategy::Bk,
                ClippingStyle::AllLayer,
                2,
                &Dispatch::Formula,
                n_shards,
            )
            .unwrap()
        };
        let mut run = mk_masked(3);
        run.init(13).unwrap();
        let mut solo = mk_masked(1);
        solo.init(13).unwrap();
        let mut rng = Xoshiro256::new(17);
        let info = run.info().clone();
        let batches: Vec<_> = (0..4).map(|_| batch_for(&info, &mut rng)).collect();
        let (g_n, o_n) = run.sharded_grads(&batches, 1.0).unwrap();
        let (g_1, o_1) = solo.sharded_grads(&batches, 1.0).unwrap();
        assert_eq!(g_n, g_1, "masked grads diverged");
        assert_eq!(o_n.loss.to_bits(), o_1.loss.to_bits());
        for (len, tr) in g_n.iter().map(Vec::len).zip(&info.trainable) {
            assert_eq!(len == 0, !tr, "frozen slots must reduce as zero-length");
        }
        assert!(g_n.iter().any(|g| g.is_empty()), "bias-only must freeze weights");
        let h = StepHyper {
            lr: 0.1,
            clip: 1.0,
            sigma_r: 0.0,
            logical_batch: (info.batch * batches.len()) as f32,
            step: 1.0,
        };
        run.apply_update(&g_n, &[], &h).unwrap();
        solo.apply_update(&g_1, &[], &h).unwrap();
        let s0 = solo.shards[0].state().unwrap();
        for (i, shard) in run.shards.iter().enumerate() {
            assert_eq!(s0, shard.state().unwrap(), "masked replica {i} diverged");
        }
    }

    #[test]
    fn sharded_matches_sequential_fold_bitwise() {
        // K=5 micro-batches: ragged over N=2 (3+2) and N=3 (2+2+1),
        // idle shards at N=7. The full N x K matrix lives in
        // tests/shard_parity.rs.
        for n in [2usize, 3, 7] {
            let mut run = mk(n);
            run.init(3).unwrap();
            let mut solo = mk(1);
            solo.init(3).unwrap();
            let mut rng = Xoshiro256::new(5);
            let info = run.info().clone();
            let batches: Vec<_> = (0..5).map(|_| batch_for(&info, &mut rng)).collect();
            let (g_n, o_n) = run.sharded_grads(&batches, 1.0).unwrap();
            let (g_1, o_1) = solo.sharded_grads(&batches, 1.0).unwrap();
            assert_eq!(g_n, g_1, "grads diverged at N={n}");
            assert_eq!(o_n.loss.to_bits(), o_1.loss.to_bits(), "loss at N={n}");
            assert_eq!(o_n.group_clip, o_1.group_clip, "group clips at N={n}");
        }
    }
}
