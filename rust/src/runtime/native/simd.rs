//! Wide-lane f32 primitives for the hot kernels.
//!
//! Every inner loop in `kernels.rs` bottoms out in one of three shapes:
//! a dot product (`dot`), a scaled accumulate (`axpy`), or a 4-way fused
//! scaled accumulate (`axpy4`, the register-tiled variant that amortizes
//! the load/store of the accumulator row over four reduction steps).
//! Each primitive has
//!
//! * a **portable** body written around `[f32; LANES]` chunk
//!   accumulators — fixed-width arrays the autovectorizer reliably turns
//!   into SIMD on any target, with a scalar tail for the remainder — and
//! * a **specialized** body behind runtime feature detection
//!   (`core::arch` AVX2+FMA on x86_64) selected once per process.
//!
//! Determinism contract: for a fixed instruction set, lane width
//! (`LANES`), and thread count, every primitive is a pure function of
//! its inputs — results are bitwise reproducible run-to-run. Lane
//! reassociation means results may differ in final bits *across* ISAs
//! (FMA contracts the multiply-add) or if `LANES` changes; all
//! cross-run golden tests therefore fix the configuration, and
//! cross-path invariants (fused vs unfused, style equivalences) hold
//! bitwise because both sides run the identical primitives. Setting
//! `FASTDP_FORCE_PORTABLE=1` pins the portable body everywhere, which
//! CI uses to keep the fallback green.
//!
//! The lane reduction order is shared by every body: the `LANES`-wide
//! accumulator collapses pairwise (`reduce_lanes`), never left-to-right,
//! so the portable and specialized paths agree in structure and the
//! portable path keeps the same rounding tree whether or not the
//! autovectorizer fires.

/// Accumulator width of the portable micro-kernel, in f32 lanes. Eight
/// lanes = one AVX2 register; wide enough that the autovectorizer emits
/// full-width SIMD, narrow enough not to spill on 128-bit targets.
pub const LANES: usize = 8;

/// Instruction set selected for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// `[f32; LANES]` chunk accumulators, autovectorized.
    Portable,
    /// AVX2 + FMA `core::arch` bodies (x86_64, runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2Fma,
}

static ACTIVE: std::sync::OnceLock<Isa> = std::sync::OnceLock::new();

/// The ISA every primitive dispatches to, detected once per process.
/// `FASTDP_FORCE_PORTABLE` (any value but `0`) pins `Portable`.
pub fn active_isa() -> Isa {
    *ACTIVE.get_or_init(detect)
}

/// Human-readable ISA name for bench/calibration reports.
pub fn isa_name() -> &'static str {
    match active_isa() {
        Isa::Portable => "portable",
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => "avx2+fma",
    }
}

fn detect() -> Isa {
    if matches!(std::env::var("FASTDP_FORCE_PORTABLE"), Ok(v) if v != "0") {
        return Isa::Portable;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Isa::Avx2Fma;
        }
    }
    Isa::Portable
}

/// Collapse a `LANES`-wide accumulator pairwise. The fixed tree (4+4,
/// then 2+2, then 1+1) is shared by the portable and AVX2 bodies so
/// both produce the same reduction order for equal lane contents.
#[inline(always)]
fn reduce_lanes(acc: [f32; LANES]) -> f32 {
    let a = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
    let b = [a[0] + a[2], a[1] + a[3]];
    b[0] + b[1]
}

/// `sum_i x[i] * y[i]` over `min(x.len(), y.len())` elements.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { dot_avx2(x, y) },
        Isa::Portable => dot_portable(x, y),
    }
}

/// `out[i] += a * x[i]` over `min(x.len(), out.len())` elements.
#[inline]
pub fn axpy(a: f32, x: &[f32], out: &mut [f32]) {
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { axpy_avx2(a, x, out) },
        Isa::Portable => axpy_portable(a, x, out),
    }
}

/// `out[i] += c[0]*x0[i] + c[1]*x1[i] + c[2]*x2[i] + c[3]*x3[i]`.
///
/// The four products are summed into `out[i]` as one expression per
/// element (left to right), so the result is independent of whether the
/// body is scalar or vector for a fixed ISA.
#[inline]
pub fn axpy4(c: [f32; 4], x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], out: &mut [f32]) {
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { axpy4_avx2(c, x0, x1, x2, x3, out) },
        Isa::Portable => axpy4_portable(c, x0, x1, x2, x3, out),
    }
}

fn dot_portable(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let (x, y) = (&x[..n], &y[..n]);
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact(LANES);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        for l in 0..LANES {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut s = reduce_lanes(acc);
    for (xv, yv) in xc.remainder().iter().zip(yc.remainder()) {
        s += xv * yv;
    }
    s
}

fn axpy_portable(a: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len().min(out.len());
    for (o, xv) in out[..n].iter_mut().zip(&x[..n]) {
        *o += a * xv;
    }
}

fn axpy4_portable(c: [f32; 4], x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], out: &mut [f32]) {
    let n = out
        .len()
        .min(x0.len())
        .min(x1.len())
        .min(x2.len())
        .min(x3.len());
    for i in 0..n {
        out[i] += c[0] * x0[i] + c[1] * x1[i] + c[2] * x2[i] + c[3] * x3[i];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = x.len().min(y.len());
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + LANES <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        acc = _mm256_fmadd_ps(xv, yv, acc);
        i += LANES;
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut s = reduce_lanes(lanes);
    while i < n {
        s += x[i] * y[i];
        i += 1;
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(a: f32, x: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len().min(out.len());
    let av = _mm256_set1_ps(a);
    let mut i = 0usize;
    while i + LANES <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let ov = _mm256_loadu_ps(out.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(av, xv, ov));
        i += LANES;
    }
    while i < n {
        out[i] += a * x[i];
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy4_avx2(c: [f32; 4], x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = out
        .len()
        .min(x0.len())
        .min(x1.len())
        .min(x2.len())
        .min(x3.len());
    let c0 = _mm256_set1_ps(c[0]);
    let c1 = _mm256_set1_ps(c[1]);
    let c2 = _mm256_set1_ps(c[2]);
    let c3 = _mm256_set1_ps(c[3]);
    let mut i = 0usize;
    while i + LANES <= n {
        let mut ov = _mm256_loadu_ps(out.as_ptr().add(i));
        ov = _mm256_fmadd_ps(c0, _mm256_loadu_ps(x0.as_ptr().add(i)), ov);
        ov = _mm256_fmadd_ps(c1, _mm256_loadu_ps(x1.as_ptr().add(i)), ov);
        ov = _mm256_fmadd_ps(c2, _mm256_loadu_ps(x2.as_ptr().add(i)), ov);
        ov = _mm256_fmadd_ps(c3, _mm256_loadu_ps(x3.as_ptr().add(i)), ov);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), ov);
        i += LANES;
    }
    while i < n {
        out[i] += c[0] * x0[i] + c[1] * x1[i] + c[2] * x2[i] + c[3] * x3[i];
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seeded(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).max(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
            })
            .collect()
    }

    fn dot_ref(x: &[f32], y: &[f32]) -> f64 {
        x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
    }

    #[test]
    fn dot_matches_f64_reference_at_odd_lengths() {
        // lengths straddle the lane width and include primes, so the
        // chunked body and the scalar tail both get exercised
        for n in [0usize, 1, 3, 7, 8, 9, 13, 16, 31, 57, 128, 257] {
            let x = seeded(n, 1 + n as u64);
            let y = seeded(n, 1000 + n as u64);
            let want = dot_ref(&x, &y);
            for got in [dot(&x, &y), dot_portable(&x, &y)] {
                assert!(
                    (got as f64 - want).abs() <= 1e-5 * want.abs().max(1.0),
                    "n={n}: got {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn dot_is_bitwise_deterministic() {
        let x = seeded(103, 7);
        let y = seeded(103, 11);
        assert_eq!(dot(&x, &y).to_bits(), dot(&x, &y).to_bits());
        assert_eq!(
            dot_portable(&x, &y).to_bits(),
            dot_portable(&x, &y).to_bits()
        );
    }

    #[test]
    fn axpy_matches_scalar() {
        for n in [1usize, 5, 8, 21, 64, 101] {
            let x = seeded(n, 3 + n as u64);
            let mut out = seeded(n, 5 + n as u64);
            let mut want = out.clone();
            for (o, xv) in want.iter_mut().zip(&x) {
                *o += 0.37 * xv;
            }
            axpy(0.37, &x, &mut out);
            for (got, want) in out.iter().zip(&want) {
                assert!((got - want).abs() <= 1e-6 * want.abs().max(1.0));
            }
        }
    }

    #[test]
    fn axpy4_matches_composed_axpys() {
        for n in [1usize, 7, 8, 19, 40, 97] {
            let c = [0.5f32, -1.25, 0.0, 2.0];
            let xs: Vec<Vec<f32>> = (0..4).map(|k| seeded(n, 20 + k + n as u64)).collect();
            let mut out = seeded(n, 40 + n as u64);
            let mut want = out.clone();
            for i in 0..n {
                let w: f64 = (0..4).map(|k| c[k] as f64 * xs[k][i] as f64).sum();
                want[i] = (want[i] as f64 + w) as f32;
            }
            axpy4(c, &xs[0], &xs[1], &xs[2], &xs[3], &mut out);
            for (got, want) in out.iter().zip(&want) {
                assert!(
                    (got - want).abs() <= 1e-5 * want.abs().max(1.0),
                    "n={n}: got {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn isa_name_is_stable_within_process() {
        assert_eq!(isa_name(), isa_name());
        assert_eq!(active_isa(), active_isa());
    }
}
