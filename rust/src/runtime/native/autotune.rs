//! Startup microbenchmark calibrating the measured ghost-vs-
//! instantiation dispatch (`complexity::dispatch`) to this machine.
//!
//! Calibration times the two real norm kernels — `kernels::ghost_norm`
//! and `kernels::psg_norms_streaming` — on one mid-size calibration
//! layer with *equal* FLOP counts on both routes (`T(p+d) = pd`, so
//! the two module costs coincide), then divides the best-of-reps wall time
//! by the analytic FLOP count to get seconds-per-FLOP coefficients.
//! The profile is cached to a JSON file so later runs skip the bench;
//! `resolve_dispatch` is the single entry point the trainer and CLI
//! use, implementing the mode/cache/fallback policy:
//!
//! * mode `formula` → no benching, the paper's rule;
//! * mode `measured` + readable valid cache → use it;
//! * mode `measured` + no cache → calibrate now, write the cache;
//! * mode `measured` + corrupt/stale/unreadable cache → **warn and
//!   fall back to the formula** (never an error: a bad cache file must
//!   not stop training).

use super::kernels;
use super::par;
use super::simd;
use crate::arch::{LayerDims, LayerKind};
use crate::complexity::dispatch::{Dispatch, DispatchProfile};
use crate::complexity::{module_time, Module};
use crate::error::Result;
use crate::json;
use crate::json::Value;
use crate::util::rng::Xoshiro256;
use std::path::Path;
use std::time::Instant;

/// Calibration layer, chosen so both routes cost the same 2.1 MFLOP:
/// `2*B*T^2*(p+d) == 2*B*T*p*d` exactly when `T*(p+d) == p*d`, and
/// `32 * (64+64) == 64 * 64`. Equal FLOPs make the coefficient ratio a
/// direct measured speed ratio of the two kernels.
const CAL_B: usize = 8;
const CAL_T: usize = 32;
const CAL_D: usize = 64;
const CAL_P: usize = 64;
/// Timed repetitions (plus one untimed warm-up); best-of is used.
const CAL_REPS: usize = 5;

/// Run the calibration microbenchmark at the given thread count
/// (0 = `par::default_threads()`).
pub fn calibrate(threads: usize) -> DispatchProfile {
    let threads = if threads == 0 {
        par::default_threads()
    } else {
        threads
    };
    let (b, t, d, p) = (CAL_B, CAL_T, CAL_D, CAL_P);
    let mut rng = Xoshiro256::new(0xCA11B8);
    let a: Vec<f32> = (0..b * t * d).map(|_| rng.next_f32() - 0.5).collect();
    let g: Vec<f32> = (0..b * t * p).map(|_| rng.next_f32() - 0.5).collect();
    let mut sq = vec![0.0f32; b];

    let mut gram_a = vec![0.0f32; b * t * t];
    let mut gram_g = vec![0.0f32; b * t * t];
    let mut ghost_best = f64::INFINITY;
    for rep in 0..=CAL_REPS {
        sq.fill(0.0);
        let t0 = Instant::now();
        kernels::ghost_norm(
            &a,
            &g,
            b,
            t,
            d,
            p,
            &mut gram_a,
            &mut gram_g,
            &mut sq,
            threads,
        );
        let dt = t0.elapsed().as_secs_f64();
        if rep > 0 {
            ghost_best = ghost_best.min(dt);
        }
    }
    // the outputs keep the timed calls observable (and sane)
    assert!(sq.iter().all(|v| v.is_finite()));

    let workers = threads.max(1).min(b.max(1));
    let mut scratch = vec![0.0f32; workers * d * p];
    let mut inst_best = f64::INFINITY;
    for rep in 0..=CAL_REPS {
        sq.fill(0.0);
        let t0 = Instant::now();
        kernels::psg_norms_streaming(&a, &g, b, t, d, p, &mut scratch, &mut sq, threads);
        let dt = t0.elapsed().as_secs_f64();
        if rep > 0 {
            inst_best = inst_best.min(dt);
        }
    }
    assert!(sq.iter().all(|v| v.is_finite()));

    let l = LayerDims {
        kind: LayerKind::Linear,
        name: "calibration".to_string(),
        t: t as u64,
        d: d as u64,
        p: p as u64,
    };
    let ghost_flops = module_time(Module::GhostNorm, b as f64, &l);
    let inst_flops = module_time(Module::PsgInstantiation, b as f64, &l);
    // clock floor: a kernel faster than the timer granularity still
    // gets a positive coefficient
    let floor = 1e-9;
    DispatchProfile {
        ghost_secs_per_flop: ghost_best.max(floor) / ghost_flops,
        inst_secs_per_flop: inst_best.max(floor) / inst_flops,
        threads,
        isa: simd::isa_name().to_string(),
    }
}

/// Write a profile to its cache file (pretty JSON).
pub fn save_profile(path: &Path, profile: &DispatchProfile) -> std::result::Result<(), String> {
    let mut text = profile.to_json().to_string_pretty();
    text.push('\n');
    std::fs::write(path, text).map_err(|e| format!("write {}: {e}", path.display()))
}

/// Load and validate a cached profile.
pub fn load_profile(path: &Path) -> std::result::Result<DispatchProfile, String> {
    let v: Value = json::from_file(path)?;
    DispatchProfile::from_json(&v)
}

/// Resolve the dispatch for a run. `mode` is `"formula"` or
/// `"measured"`; `threads` is the run's thread count (0 = default) and
/// only matters when a fresh calibration runs. See the module docs for
/// the cache/fallback policy. Unknown modes are the only error.
pub fn resolve_dispatch(mode: &str, profile_path: &Path, threads: usize) -> Result<Dispatch> {
    match mode {
        "formula" => Ok(Dispatch::Formula),
        "measured" => {
            if profile_path.exists() {
                match load_profile(profile_path) {
                    Ok(p) => Ok(Dispatch::Measured(p)),
                    Err(e) => {
                        eprintln!(
                            "warning: dispatch profile {}: {e}; falling back to the formula rule \
                             (delete the file or rerun `fastdp calibrate-dispatch` to re-measure)",
                            profile_path.display()
                        );
                        Ok(Dispatch::Formula)
                    }
                }
            } else {
                let profile = calibrate(threads);
                if let Err(e) = save_profile(profile_path, &profile) {
                    eprintln!("warning: could not cache the dispatch profile: {e}");
                }
                Ok(Dispatch::Measured(profile))
            }
        }
        other => crate::bail!("unknown dispatch mode '{other}' (expected 'formula' or 'measured')"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrate_produces_positive_coefficients() {
        let p = calibrate(1);
        assert!(p.ghost_secs_per_flop > 0.0 && p.ghost_secs_per_flop.is_finite());
        assert!(p.inst_secs_per_flop > 0.0 && p.inst_secs_per_flop.is_finite());
        assert_eq!(p.threads, 1);
        assert_eq!(p.isa, simd::isa_name());
    }

    #[test]
    fn resolve_rejects_unknown_modes() {
        let path = std::env::temp_dir().join("fastdp_test_no_such_profile.json");
        assert!(resolve_dispatch("formula", &path, 1).is_ok());
        assert!(resolve_dispatch("sometimes", &path, 1).is_err());
    }
}
