//! Native backend: the Book-Keeping DP step end-to-end in Rust.
//!
//! Executes generalized-linear models (see `model`) with the fused
//! kernels in `kernels`, dispatching per layer between the ghost-norm
//! and per-sample-instantiation routes exactly as the complexity
//! engine's `ghost_preferred` decides. One `NativeBackend` is
//! constructed per (model, strategy) pair — mirroring the one
//! artifact-per-strategy layout of the PJRT path — and implements the
//! [`Backend`](crate::runtime::Backend) trait the coordinator drives.
//!
//! Strategy execution plans (paper Table 2):
//!
//! | strategy          | backprops | norms              | clipped sum        |
//! |-------------------|-----------|--------------------|--------------------|
//! | `nondp`           | 1         | —                  | plain sum          |
//! | `opacus`          | 1         | stored psg         | from stored psg    |
//! | `fastgradclip`    | 2         | streamed psg       | weighted contraction |
//! | `ghostclip`       | 2         | ghost (Gram)       | weighted contraction |
//! | `mixghostclip`    | 2         | per-layer min      | weighted contraction |
//! | `bk`              | 1         | ghost, g cached    | weighted contraction |
//! | `bk_mixghostclip` | 1         | per-layer min      | weighted contraction |
//! | `bk_mixopt`       | 1         | per-layer min      | psg reused on inst layers |
//!
//! All per-step buffers come from the [`arena::Arena`]; after the first
//! (warm-up) step the pool is saturated and steady-state heap
//! allocation is zero — asserted by tests and reported by the bench.

pub mod arena;
pub mod kernels;
pub mod model;
pub mod par;

use self::arena::Arena;
use self::kernels::ClipKind;
use self::model::NativeSpec;
use crate::complexity::{ghost_preferred, Strategy};
use crate::error::Result;
use crate::runtime::{AllocStats, Backend, BatchX, ModelInfo, StepHyper, StepOut};
use crate::util::rng::{GaussianSource, Xoshiro256};
use crate::{anyhow, bail};

/// Per-layer norm route (the mixed ghost/per-sample decision).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum NormRoute {
    Ghost,
    Inst,
}

pub struct NativeBackend {
    spec: NativeSpec,
    info: ModelInfo,
    strategy: Strategy,
    clip_kind: ClipKind,
    /// Norm route per layer (unused for nondp).
    routes: Vec<NormRoute>,
    /// Layers whose per-sample grads are materialized and reused.
    store_psg: Vec<bool>,
    threads: usize,
    /// Trainable tensors in order w0, b0, w1, b1, ...
    params: Vec<Vec<f32>>,
    opt_m: Vec<Vec<f32>>,
    opt_v: Vec<Vec<f32>>,
    arena: Arena,
    last_fresh: usize,
    initialized: bool,
}

impl NativeBackend {
    pub fn new(spec: NativeSpec, strategy: Strategy, threads: usize) -> Result<Self> {
        let clip_kind = ClipKind::parse(&spec.clip_fn)
            .ok_or_else(|| anyhow!("unknown clip_fn '{}' in model '{}'", spec.clip_fn, spec.name))?;
        if spec.optimizer != "sgd" && spec.optimizer != "adam" {
            bail!("unknown optimizer '{}' in model '{}'", spec.optimizer, spec.name);
        }
        if spec.batch == 0 || spec.seq == 0 || spec.d_in == 0 || spec.n_classes == 0 {
            bail!("model '{}' has a zero dimension", spec.name);
        }
        let layers = spec.arch_layers();
        let routes: Vec<NormRoute> = layers
            .iter()
            .map(|l| match strategy {
                Strategy::Opacus | Strategy::FastGradClip => NormRoute::Inst,
                Strategy::GhostClip | Strategy::Bk | Strategy::NonDp => NormRoute::Ghost,
                Strategy::MixGhostClip | Strategy::BkMixGhostClip | Strategy::BkMixOpt => {
                    if ghost_preferred(l) {
                        NormRoute::Ghost
                    } else {
                        NormRoute::Inst
                    }
                }
            })
            .collect();
        let store_psg: Vec<bool> = routes
            .iter()
            .map(|r| match strategy {
                Strategy::Opacus => true,
                Strategy::BkMixOpt => *r == NormRoute::Inst,
                _ => false,
            })
            .collect();
        let threads = if threads == 0 { par::default_threads() } else { threads };
        let info = spec.info();
        let zeros = || -> Vec<Vec<f32>> {
            info.param_names
                .iter()
                .map(|n| vec![0.0; info.param_shapes[n].iter().product()])
                .collect()
        };
        let params = zeros();
        let (opt_m, opt_v) = if info.is_adam() {
            (zeros(), zeros())
        } else {
            (Vec::new(), Vec::new())
        };
        Ok(Self {
            spec,
            info,
            strategy,
            clip_kind,
            routes,
            store_psg,
            threads,
            params,
            opt_m,
            opt_v,
            arena: Arena::new(),
            last_fresh: 0,
            initialized: false,
        })
    }

    pub fn strategy_enum(&self) -> Strategy {
        self.strategy
    }

    fn two_pass(&self) -> bool {
        matches!(
            self.strategy,
            Strategy::FastGradClip | Strategy::GhostClip | Strategy::MixGhostClip
        )
    }

    fn rows(&self) -> usize {
        self.spec.batch * self.spec.seq
    }

    fn max_dp(&self) -> usize {
        self.spec.layer_widths().iter().map(|&(d, p)| d * p).max().unwrap_or(1)
    }

    fn max_p(&self) -> usize {
        self.spec.layer_widths().iter().map(|&(_, p)| p).max().unwrap_or(1)
    }

    fn features_of<'a>(&self, x: &'a BatchX) -> Result<&'a [f32]> {
        match x {
            BatchX::F32(v) => Ok(v.as_slice()),
            BatchX::I32(_) => {
                bail!("native backend takes f32 features (token inputs need the pjrt backend)")
            }
        }
    }

    fn check_batch(&self, x: &[f32], y: &[i32]) -> Result<()> {
        let rows = self.rows();
        if x.len() != rows * self.spec.d_in {
            bail!(
                "x has {} elements, expected {} (B*T*d = {}*{}*{})",
                x.len(),
                rows * self.spec.d_in,
                self.spec.batch,
                self.spec.seq,
                self.spec.d_in
            );
        }
        if y.len() != rows {
            bail!("y has {} labels, expected {}", y.len(), rows);
        }
        if !self.initialized {
            bail!("backend not initialized (call init first)");
        }
        Ok(())
    }

    /// Forward pass into arena-held activations; `acts[l]` is the input
    /// of layer `l`, `acts[n_layers]` the logits.
    fn forward(&mut self, x: &[f32]) -> Vec<Vec<f32>> {
        let rows = self.rows();
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl + 1);
        let mut a0 = self.arena.take(rows * dims[0].0);
        a0.copy_from_slice(x);
        acts.push(a0);
        for &(_, p) in &dims {
            acts.push(self.arena.take(rows * p));
        }
        for (l, &(d, p)) in dims.iter().enumerate() {
            let (head, tail) = acts.split_at_mut(l + 1);
            kernels::linear_forward(
                &head[l],
                &self.params[2 * l],
                Some(&self.params[2 * l + 1]),
                &mut tail[0],
                rows,
                d,
                p,
                self.threads,
            );
            if l + 1 < nl {
                kernels::relu_forward(&mut tail[0]);
            }
        }
        acts
    }

    /// Compute per-tensor gradient sums into `grads` (2 per layer,
    /// zero-initialized by the caller): the plain gradient for nondp,
    /// the clipped-per-sample sum for every DP strategy.
    fn compute_grads(
        &mut self,
        x: &[f32],
        y: &[i32],
        clip: f32,
        grads: &mut [Vec<f32>],
    ) -> Result<StepOut> {
        self.check_batch(x, y)?;
        let b = self.spec.batch;
        let t = self.spec.seq;
        let rows = self.rows();
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let c_out = dims[nl - 1].1;
        debug_assert_eq!(grads.len(), 2 * nl);
        let threads = self.threads;
        let workers = threads.max(1).min(b.max(1));

        let mut acts = self.forward(x);

        let out = if self.strategy == Strategy::NonDp {
            // -- single backward, plain summed gradients ---------------
            let mut g = self.arena.take(rows * c_out);
            let loss = kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
            let mut partials = self.arena.take(workers * self.max_dp());
            for l in (0..nl).rev() {
                let (d, p) = dims[l];
                kernels::weighted_grad(
                    &acts[l], &g, None, b, t, d, p, &mut partials, &mut grads[2 * l], threads,
                );
                kernels::bias_grad(&g, None, b, t, p, &mut grads[2 * l + 1]);
                if l > 0 {
                    let mut g_prev = self.arena.take(rows * d);
                    kernels::backward_data(&g, &self.params[2 * l], &mut g_prev, rows, d, p, threads);
                    kernels::relu_backward(&mut g_prev, &acts[l]);
                    self.arena.give(std::mem::replace(&mut g, g_prev));
                }
            }
            self.arena.give(g);
            self.arena.give(partials);
            StepOut {
                loss: loss / rows as f32,
                mean_clip: 1.0,
            }
        } else if self.two_pass() {
            self.grads_two_pass(&acts, y, clip, grads)?
        } else {
            self.grads_one_pass(&acts, y, clip, grads)?
        };

        while let Some(a) = acts.pop() {
            self.arena.give(a);
        }
        Ok(out)
    }

    /// GhostClip / FastGradClip / MixGhostClip: norm pass + a second
    /// backward that re-derives the output gradients for the clipped
    /// contraction (the honest 2-backprop cost of Table 2).
    fn grads_two_pass(
        &mut self,
        acts: &[Vec<f32>],
        y: &[i32],
        clip: f32,
        grads: &mut [Vec<f32>],
    ) -> Result<StepOut> {
        let b = self.spec.batch;
        let t = self.spec.seq;
        let rows = self.rows();
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let c_out = dims[nl - 1].1;
        let threads = self.threads;
        let workers = threads.max(1).min(b.max(1));

        let need_gram = t > 1 && self.routes.iter().any(|r| *r == NormRoute::Ghost);
        let need_stream = self.routes.iter().any(|r| *r == NormRoute::Inst);
        let mut gram_a = if need_gram { self.arena.take(b * t * t) } else { Vec::new() };
        let mut gram_g = if need_gram { self.arena.take(b * t * t) } else { Vec::new() };
        let mut stream = if need_stream {
            self.arena.take(workers * self.max_dp())
        } else {
            Vec::new()
        };
        let mut bias_scratch = self.arena.take(workers * self.max_p());
        let mut sq = self.arena.take(b);

        // ---- pass 1: norms ------------------------------------------
        let mut g = self.arena.take(rows * c_out);
        let loss = kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
        for l in (0..nl).rev() {
            let (d, p) = dims[l];
            match self.routes[l] {
                NormRoute::Ghost => kernels::ghost_norm(
                    &acts[l], &g, b, t, d, p, &mut gram_a, &mut gram_g, &mut sq, threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    &acts[l], &g, b, t, d, p, &mut stream, &mut sq, threads,
                ),
            }
            kernels::bias_sq_norms(&g, b, t, p, &mut bias_scratch, &mut sq, threads);
            if l > 0 {
                let mut g_prev = self.arena.take(rows * d);
                kernels::backward_data(&g, &self.params[2 * l], &mut g_prev, rows, d, p, threads);
                kernels::relu_backward(&mut g_prev, &acts[l]);
                self.arena.give(std::mem::replace(&mut g, g_prev));
            }
        }
        self.arena.give(g);

        let mut cfac = self.arena.take(b);
        kernels::clip_factors(&sq, clip, self.clip_kind, &mut cfac);
        let mean_clip = cfac.iter().sum::<f32>() / b as f32;

        // ---- pass 2: re-backpropagate + clipped contraction ----------
        let mut partials = self.arena.take(workers * self.max_dp());
        let mut g = self.arena.take(rows * c_out);
        kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
        for l in (0..nl).rev() {
            let (d, p) = dims[l];
            kernels::weighted_grad(
                &acts[l],
                &g,
                Some(&cfac),
                b,
                t,
                d,
                p,
                &mut partials,
                &mut grads[2 * l],
                threads,
            );
            kernels::bias_grad(&g, Some(&cfac), b, t, p, &mut grads[2 * l + 1]);
            if l > 0 {
                let mut g_prev = self.arena.take(rows * d);
                kernels::backward_data(&g, &self.params[2 * l], &mut g_prev, rows, d, p, threads);
                kernels::relu_backward(&mut g_prev, &acts[l]);
                self.arena.give(std::mem::replace(&mut g, g_prev));
            }
        }
        self.arena.give(g);
        self.arena.give(partials);
        self.arena.give(cfac);
        self.arena.give(sq);
        self.arena.give(bias_scratch);
        if need_stream {
            self.arena.give(stream);
        }
        if need_gram {
            self.arena.give(gram_g);
            self.arena.give(gram_a);
        }
        Ok(StepOut {
            loss: loss / rows as f32,
            mean_clip,
        })
    }

    /// Opacus / BK / BK-MixGhostClip / BK-MixOpt: one backward with the
    /// output gradients book-kept per layer; norms inline; the clipped
    /// sum reuses the caches (and, for Opacus / MixOpt-inst layers, the
    /// materialized per-sample grads) — no second backprop.
    fn grads_one_pass(
        &mut self,
        acts: &[Vec<f32>],
        y: &[i32],
        clip: f32,
        grads: &mut [Vec<f32>],
    ) -> Result<StepOut> {
        let b = self.spec.batch;
        let t = self.spec.seq;
        let rows = self.rows();
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let c_out = dims[nl - 1].1;
        let threads = self.threads;
        let workers = threads.max(1).min(b.max(1));

        let need_gram = t > 1 && self.routes.iter().any(|r| *r == NormRoute::Ghost);
        let need_stream = self
            .routes
            .iter()
            .zip(&self.store_psg)
            .any(|(r, s)| *r == NormRoute::Inst && !s);
        let mut gram_a = if need_gram { self.arena.take(b * t * t) } else { Vec::new() };
        let mut gram_g = if need_gram { self.arena.take(b * t * t) } else { Vec::new() };
        let mut stream = if need_stream {
            self.arena.take(workers * self.max_dp())
        } else {
            Vec::new()
        };
        let mut bias_scratch = self.arena.take(workers * self.max_p());
        let mut sq = self.arena.take(b);
        let mut psg: Vec<Option<Vec<f32>>> = Vec::with_capacity(nl);
        for l in 0..nl {
            let (d, p) = dims[l];
            if self.store_psg[l] {
                psg.push(Some(self.arena.take(b * d * p)));
            } else {
                psg.push(None);
            }
        }

        // ---- single backward: cache g, norms inline ------------------
        let mut gcache: Vec<Vec<f32>> = dims.iter().map(|&(_, p)| self.arena.take(rows * p)).collect();
        let loss = {
            let top = &mut gcache[nl - 1];
            kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(top))
        };
        for l in (0..nl).rev() {
            let (d, p) = dims[l];
            match (self.routes[l], psg[l].as_mut()) {
                (NormRoute::Inst, Some(store)) => {
                    kernels::psg_instantiate(&acts[l], &gcache[l], b, t, d, p, store, threads);
                    kernels::sq_norms_from_psg(store, b, d * p, &mut sq, threads);
                }
                (NormRoute::Inst, None) => kernels::psg_norms_streaming(
                    &acts[l], &gcache[l], b, t, d, p, &mut stream, &mut sq, threads,
                ),
                (NormRoute::Ghost, _) => kernels::ghost_norm(
                    &acts[l], &gcache[l], b, t, d, p, &mut gram_a, &mut gram_g, &mut sq, threads,
                ),
            }
            kernels::bias_sq_norms(&gcache[l], b, t, p, &mut bias_scratch, &mut sq, threads);
            if l > 0 {
                let (lo, hi) = gcache.split_at_mut(l);
                kernels::backward_data(&hi[0], &self.params[2 * l], &mut lo[l - 1], rows, d, p, threads);
                kernels::relu_backward(&mut lo[l - 1], &acts[l]);
            }
        }

        let mut cfac = self.arena.take(b);
        kernels::clip_factors(&sq, clip, self.clip_kind, &mut cfac);
        let mean_clip = cfac.iter().sum::<f32>() / b as f32;

        // ---- book-kept clipped sums (no recompute) -------------------
        let mut partials = self.arena.take(workers * self.max_dp());
        for l in (0..nl).rev() {
            let (d, p) = dims[l];
            match &psg[l] {
                Some(store) => {
                    kernels::weighted_sum_psg(store, &cfac, b, d, p, &mut grads[2 * l], threads)
                }
                None => kernels::weighted_grad(
                    &acts[l],
                    &gcache[l],
                    Some(&cfac),
                    b,
                    t,
                    d,
                    p,
                    &mut partials,
                    &mut grads[2 * l],
                    threads,
                ),
            }
            kernels::bias_grad(&gcache[l], Some(&cfac), b, t, p, &mut grads[2 * l + 1]);
        }

        self.arena.give(partials);
        self.arena.give(cfac);
        self.arena.give_all(gcache);
        for slot in psg.into_iter().flatten() {
            self.arena.give(slot);
        }
        self.arena.give(sq);
        self.arena.give(bias_scratch);
        if need_stream {
            self.arena.give(stream);
        }
        if need_gram {
            self.arena.give(gram_g);
            self.arena.give(gram_a);
        }
        Ok(StepOut {
            loss: loss / rows as f32,
            mean_clip,
        })
    }

    fn update_params(&mut self, grads: &[Vec<f32>], noise: &[Vec<f32>], h: &StepHyper) -> Result<()> {
        let n = self.params.len();
        if grads.len() != n {
            bail!("update got {} grad tensors, expected {n}", grads.len());
        }
        if !noise.is_empty() && noise.len() != n {
            bail!("update got {} noise tensors, expected 0 or {n}", noise.len());
        }
        if noise.is_empty() && h.sigma_r != 0.0 {
            // Refuse to silently run an unnoised "DP" step: the caller
            // would charge epsilon for noise that was never injected.
            bail!("sigma_r = {} but no noise tensors were supplied", h.sigma_r);
        }
        let adam = self.info.is_adam();
        for k in 0..n {
            if grads[k].len() != self.params[k].len() {
                bail!(
                    "grad tensor {k} has {} elements, expected {}",
                    grads[k].len(),
                    self.params[k].len()
                );
            }
            let z = if noise.is_empty() { None } else { Some(noise[k].as_slice()) };
            if adam {
                kernels::adam_update(
                    &mut self.params[k],
                    &mut self.opt_m[k],
                    &mut self.opt_v[k],
                    &grads[k],
                    z,
                    h.lr,
                    h.sigma_r,
                    h.logical_batch,
                    h.step,
                );
            } else {
                kernels::sgd_update(&mut self.params[k], &grads[k], z, h.lr, h.sigma_r, h.logical_batch);
            }
        }
        Ok(())
    }

    fn take_grad_bufs(&mut self) -> Vec<Vec<f32>> {
        let sizes: Vec<usize> = self.params.iter().map(Vec::len).collect();
        sizes.into_iter().map(|n| self.arena.take(n)).collect()
    }
}

impl Backend for NativeBackend {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn strategy(&self) -> &str {
        self.strategy.name()
    }

    fn init(&mut self, seed: u64) -> Result<()> {
        let root = Xoshiro256::new(seed ^ 0x1A17_F00D);
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        for (l, &(d, p)) in dims.iter().enumerate() {
            // He init for hidden (ReLU) layers; a damped head so initial
            // logits are near-uniform (loss ~ ln C, like the artifacts).
            let scale = if l + 1 < nl {
                (2.0 / d as f32).sqrt()
            } else {
                0.05 * (1.0 / d as f32).sqrt()
            };
            let mut gs = GaussianSource::from_rng(root.fork(l as u64 + 1));
            let w = &mut self.params[2 * l];
            gs.fill_f32(w);
            for v in w.iter_mut() {
                *v *= scale;
            }
            for v in self.params[2 * l + 1].iter_mut() {
                *v = 0.0;
            }
        }
        for t in self.opt_m.iter_mut().chain(self.opt_v.iter_mut()) {
            for v in t.iter_mut() {
                *v = 0.0;
            }
        }
        self.initialized = true;
        Ok(())
    }

    fn eval_loss(&mut self, x: &BatchX, y: &[i32]) -> Result<f32> {
        let x = self.features_of(x)?;
        self.check_batch(x, y)?;
        let rows = self.rows();
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let mut acts = self.forward(x);
        let loss = kernels::softmax_xent(&acts[nl], y, rows, dims[nl - 1].1, None);
        while let Some(a) = acts.pop() {
            self.arena.give(a);
        }
        Ok(loss / rows as f32)
    }

    fn step(&mut self, x: &BatchX, y: &[i32], noise: &[Vec<f32>], h: &StepHyper) -> Result<StepOut> {
        let x = self.features_of(x)?;
        self.arena.begin_step();
        let mut grads = self.take_grad_bufs();
        let out = self.compute_grads(x, y, h.clip, &mut grads);
        let upd = match &out {
            Ok(_) => self.update_params(&grads, noise, h),
            Err(_) => Ok(()),
        };
        self.arena.give_all(grads);
        let out = out?;
        upd?;
        self.last_fresh = self.arena.fresh_allocs();
        debug_assert_eq!(self.arena.outstanding(), 0, "arena leak in step");
        Ok(out)
    }

    fn clipped_grads(&mut self, x: &BatchX, y: &[i32], clip: f32) -> Result<(Vec<Vec<f32>>, StepOut)> {
        let x = self.features_of(x)?;
        self.arena.begin_step();
        // The gradient sums are handed to the caller (host-side
        // accumulation), so they are plain Vecs rather than arena
        // buffers — cloning out of the arena would cost the same
        // allocation plus an extra copy.
        let mut grads: Vec<Vec<f32>> = self.params.iter().map(|p| vec![0.0; p.len()]).collect();
        let out = self.compute_grads(x, y, clip, &mut grads)?;
        self.last_fresh = self.arena.fresh_allocs();
        Ok((grads, out))
    }

    fn apply_update(&mut self, grads: &[Vec<f32>], noise: &[Vec<f32>], h: &StepHyper) -> Result<()> {
        self.update_params(grads, noise, h)
    }

    fn state(&self) -> Result<Vec<Vec<f32>>> {
        let mut out: Vec<Vec<f32>> = self.params.clone();
        out.extend(self.opt_m.iter().cloned());
        out.extend(self.opt_v.iter().cloned());
        Ok(out)
    }

    fn load_state(&mut self, tensors: Vec<Vec<f32>>) -> Result<()> {
        let n = self.params.len();
        let want_full = if self.info.is_adam() { 3 * n } else { n };
        if tensors.len() != n && tensors.len() != want_full {
            bail!(
                "load_state got {} tensors, expected {n} (params) or {want_full} (full state)",
                tensors.len()
            );
        }
        for (k, t) in tensors.iter().enumerate() {
            let slot = k % n;
            let want = self.params[slot].len();
            if t.len() != want {
                bail!("state tensor {k} has {} elements, expected {want}", t.len());
            }
        }
        let full = tensors.len() == want_full && self.info.is_adam();
        let mut it = tensors.into_iter();
        for slot in self.params.iter_mut() {
            *slot = it.next().unwrap();
        }
        if full {
            for slot in self.opt_m.iter_mut() {
                *slot = it.next().unwrap();
            }
            for slot in self.opt_v.iter_mut() {
                *slot = it.next().unwrap();
            }
        }
        self.initialized = true;
        Ok(())
    }

    fn alloc_stats(&self) -> AllocStats {
        AllocStats {
            fresh_allocs_last_step: self.last_fresh,
            arena_bytes: self.arena.total_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn tiny_spec() -> NativeSpec {
        NativeSpec {
            name: "tiny".into(),
            batch: 4,
            seq: 1,
            d_in: 8,
            hidden: vec![12],
            n_classes: 3,
            optimizer: "sgd".into(),
            clip_fn: "automatic".into(),
        }
    }

    fn batch_for(spec: &NativeSpec, seed: u64) -> (BatchX, Vec<i32>) {
        let rows = spec.batch * spec.seq;
        let mut rng = Xoshiro256::new(seed);
        let x: Vec<f32> = (0..rows * spec.d_in).map(|_| rng.next_f32() - 0.5).collect();
        let y: Vec<i32> = (0..rows)
            .map(|_| rng.next_below(spec.n_classes as u64) as i32)
            .collect();
        (BatchX::F32(x), y)
    }

    fn hyper() -> StepHyper {
        StepHyper {
            lr: 0.1,
            clip: 1.0,
            sigma_r: 0.0,
            logical_batch: 4.0,
            step: 1.0,
        }
    }

    #[test]
    fn step_is_deterministic() {
        let (x, y) = batch_for(&tiny_spec(), 7);
        let run = || -> Vec<Vec<f32>> {
            let mut bk = NativeBackend::new(tiny_spec(), Strategy::Bk, 2).unwrap();
            bk.init(3).unwrap();
            bk.step(&x, &y, &[], &hyper()).unwrap();
            bk.state().unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed + batch must give bitwise-equal state");
    }

    #[test]
    fn arena_reaches_steady_state() {
        for strat in [
            Strategy::NonDp,
            Strategy::Opacus,
            Strategy::FastGradClip,
            Strategy::GhostClip,
            Strategy::Bk,
            Strategy::BkMixOpt,
        ] {
            let (x, y) = batch_for(&tiny_spec(), 9);
            let mut be = NativeBackend::new(tiny_spec(), strat, 2).unwrap();
            be.init(1).unwrap();
            be.step(&x, &y, &[], &hyper()).unwrap();
            assert!(be.alloc_stats().fresh_allocs_last_step > 0, "cold step allocates");
            for _ in 0..3 {
                be.step(&x, &y, &[], &hyper()).unwrap();
                assert_eq!(
                    be.alloc_stats().fresh_allocs_last_step,
                    0,
                    "{strat:?}: steady-state step must not allocate"
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let spec = tiny_spec();
        let (x, y) = batch_for(&spec, 11);
        let mut be = NativeBackend::new(spec, Strategy::Bk, 2).unwrap();
        be.init(5).unwrap();
        let l0 = be.eval_loss(&x, &y).unwrap();
        let mut h = hyper();
        h.lr = 0.5;
        for _ in 0..20 {
            be.step(&x, &y, &[], &h).unwrap();
        }
        let l1 = be.eval_loss(&x, &y).unwrap();
        assert!(l1 < l0, "loss should fall on a fixed batch: {l0} -> {l1}");
    }

    #[test]
    fn rejects_bad_shapes_and_tokens() {
        let mut be = NativeBackend::new(tiny_spec(), Strategy::Bk, 1).unwrap();
        be.init(0).unwrap();
        let bad_x = BatchX::F32(vec![0.0; 5]);
        assert!(be.step(&bad_x, &[0; 4], &[], &hyper()).is_err());
        let (x, _) = batch_for(&tiny_spec(), 1);
        assert!(be.step(&x, &[0; 3], &[], &hyper()).is_err());
        let tok = BatchX::I32(vec![0; 32]);
        assert!(be.eval_loss(&tok, &[0; 4]).is_err());
    }

    #[test]
    fn state_roundtrip_restores_params() {
        let (x, y) = batch_for(&tiny_spec(), 2);
        let mut a = NativeBackend::new(tiny_spec(), Strategy::Bk, 1).unwrap();
        a.init(8).unwrap();
        a.step(&x, &y, &[], &hyper()).unwrap();
        let snap = a.state().unwrap();
        let la = a.eval_loss(&x, &y).unwrap();
        let mut b = NativeBackend::new(tiny_spec(), Strategy::Bk, 1).unwrap();
        b.load_state(snap).unwrap();
        let lb = b.eval_loss(&x, &y).unwrap();
        assert_eq!(la, lb);
        let mut c = NativeBackend::new(tiny_spec(), Strategy::Bk, 1).unwrap();
        assert!(c.load_state(vec![vec![0.0; 1]]).is_err());
    }
}
