//! Native backend: the Book-Keeping DP step end-to-end in Rust.
//!
//! Executes arbitrary stacks of [`layers::DpLayer`] modules (Linear,
//! ReLU, Embedding, LayerNorm — see `model`) with the fused kernels in
//! `kernels`, dispatching per layer between the ghost-norm and
//! per-sample-instantiation routes as a [`Dispatch`] decides — the
//! complexity engine's `2T^2 < pd` formula by default, or a measured
//! per-machine cost model (`complexity::dispatch` + `autotune`)
//! calibrated at startup. One `NativeBackend` is constructed per
//! (model, strategy, clipping style) triple and implements the
//! [`Backend`](crate::runtime::Backend) trait the coordinator drives.
//!
//! Strategy execution plans (paper Table 2):
//!
//! | strategy          | backprops | norms              | clipped sum        |
//! |-------------------|-----------|--------------------|--------------------|
//! | `nondp`           | 1         | —                  | plain sum          |
//! | `opacus`          | 1         | stored psg         | from stored psg    |
//! | `fastgradclip`    | 2         | streamed psg       | weighted contraction |
//! | `ghostclip`       | 2         | ghost (Gram)       | weighted contraction |
//! | `mixghostclip`    | 2         | per-layer min      | weighted contraction |
//! | `bk`              | 1         | ghost, g cached    | weighted contraction |
//! | `bk_mixghostclip` | 1         | per-layer min      | weighted contraction |
//! | `bk_mixopt`       | 1         | per-layer min      | psg reused on inst layers |
//!
//! Orthogonally, the [`ClippingStyle`] axis controls clipping
//! granularity: `all-layer` (one norm over every layer — the paper's
//! flat clipping, bitwise-identical to the pre-style code), `layer-wise`
//! (one clip factor per trainable layer), and `group-wise:<k>`
//! (contiguous layer groups). Each of the `G` groups clips to
//! `R / sqrt(G)`, keeping total sensitivity `R`, so sigma and the
//! accountant are untouched.
//!
//! All per-step buffers come from the [`arena::Arena`]; after the first
//! (warm-up) step the pool is saturated and steady-state heap
//! allocation is zero — asserted by tests and reported by the bench.

pub mod arena;
pub mod autotune;
pub mod kernels;
pub mod layers;
pub mod model;
pub mod par;
pub mod shard;
pub mod simd;

#[cfg(test)]
pub(crate) mod reference;

use self::arena::Arena;
use self::kernels::ClipKind;
use self::layers::{Ctx, DpLayer, LayerIn, NormRoute, Scratch, StackRun};
use self::model::NativeSpec;
use crate::arch::LayerKind;
use crate::complexity::{ClippingStyle, Dispatch, Strategy};
use crate::error::Result;
use crate::runtime::{AllocStats, Backend, BatchX, ModelInfo, StepHyper, StepOut};
use crate::util::rng::Xoshiro256;
use crate::{anyhow, bail};

/// A natively executable (model, strategy, clipping style) triple.
pub struct NativeBackend {
    spec: NativeSpec,
    info: ModelInfo,
    strategy: Strategy,
    clip_kind: ClipKind,
    style: ClippingStyle,
    /// The executable layer stack (from the spec's canonical plan).
    stack: Vec<Box<dyn DpLayer>>,
    /// Canonical-tensor slot range per stack layer: layer `k` views
    /// `params[slots[k].0..slots[k].1]`. Owners mint fresh consecutive
    /// slots; an aliasing layer (tied head) points at the owner's.
    slots: Vec<(usize, usize)>,
    /// Shared-parameter links: `alias_of[k] = Some(j)` means layer `k`
    /// views tensors owned by earlier layer `j` (the tied vocab head
    /// viewing the embedding table).
    alias_of: Vec<Option<usize>>,
    /// Norm route per stack layer (meaningful for trainable layers).
    routes: Vec<NormRoute>,
    /// Stack layers whose per-sample grads are materialized and reused.
    store_psg: Vec<bool>,
    /// Clipping-group id per stack layer (meaningful for trainable).
    groups: Vec<usize>,
    /// Residual skip per stack layer (`Some(r)` adds layer `r`'s input
    /// activation to layer `k`'s output; transformer blocks).
    residuals: Vec<Option<usize>>,
    /// Per canonical tensor: trains under the spec's trainability
    /// preset. Frozen tensors keep full parameter storage (forward and
    /// `backward_data` read them) but get zero-length grad, noise, and
    /// moment buffers — DESIGN.md §9.
    slot_trainable: Vec<bool>,
    /// Per stack layer: true iff any of its canonical tensors trains
    /// (aliases inherit the owner's flags). `false` means the tape
    /// skips the layer's norm/sum hooks entirely.
    layer_trainable: Vec<bool>,
    /// Fused-schedule group boundaries: `finalize_at[k] = Some(g)`
    /// marks stack layer `k` as the lowest-index member of clipping
    /// group `g` — the walk finalizes `g` (clip factors + clipped sums
    /// + g-cache release) right after processing `k`.
    finalize_at: Vec<Option<usize>>,
    /// Diagnostic switch: run the legacy unfused one-pass schedule
    /// (norm walk stashes every g-cache, then a separate clipped-sum
    /// sweep). The fused and unfused schedules are bitwise identical;
    /// tests flip this to prove it and to compare peak memory.
    unfused_schedule: bool,
    /// Peak g-cache floats of the last fused walk (0 when the last
    /// step ran two-pass, nondp, or the unfused diagnostic schedule).
    last_peak_gcache: usize,
    /// Number of clipping groups.
    n_groups: usize,
    threads: usize,
    /// Trainable tensors in stack order (w0, b0, ... / emb_w, ln0_g, ...).
    params: Vec<Vec<f32>>,
    opt_m: Vec<Vec<f32>>,
    opt_v: Vec<Vec<f32>>,
    arena: Arena,
    last_fresh: usize,
    initialized: bool,
    // scratch sizing (computed once from the stack)
    max_dp: usize,
    max_small: usize,
    /// Composite-layer backward scratch: `B*T * 4*d` of the widest
    /// attention layer, `B*T * (rank+d)` of the widest LoRA layer, or
    /// `B * t_out * cin*k*k` of the widest conv layer (the unfolded
    /// data gradient); 0 when the stack has none of them.
    max_attn: usize,
    /// Ghost-norm Gram scratch floats: `B * max(t_layer^2)` over the
    /// ghost-routed layers whose own token count exceeds 1 (`t_layer`
    /// is the spec seq for linear/attention layers and the output
    /// spatial count for conv layers); 0 when no layer needs Grams.
    max_gram: usize,
    need_stream_two: bool,
    need_stream_one: bool,
}

/// Construction options for a [`NativeBackend`] — the single entry
/// point (`NativeBackend::builder(spec, strategy)`) replacing the old
/// `new` / `with_style` / `with_style_dispatch` constructor ladder.
/// Defaults: all-layer clipping, formulaic `2T^2 < pd` dispatch, and
/// auto-detected threads (`0`).
#[must_use = "call .build() to construct the backend"]
pub struct NativeBackendBuilder {
    spec: NativeSpec,
    strategy: Strategy,
    style: ClippingStyle,
    dispatch: Dispatch,
    threads: usize,
}

impl NativeBackendBuilder {
    /// Clipping granularity (all-layer / layer-wise / group-wise:k).
    pub fn style(mut self, style: ClippingStyle) -> Self {
        self.style = style;
        self
    }

    /// Ghost-vs-instantiation norm-route dispatch for the mixed
    /// strategies — the paper's formula or a measured per-machine cost
    /// model (see `complexity::dispatch` and `autotune`). Non-mixed
    /// strategies force their route and ignore this.
    pub fn dispatch(mut self, dispatch: Dispatch) -> Self {
        self.dispatch = dispatch;
        self
    }

    /// Worker threads (`0` = auto-detect).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Validate the spec and construct the backend.
    pub fn build(self) -> Result<NativeBackend> {
        NativeBackend::build_impl(self.spec, self.strategy, self.style, self.threads, &self.dispatch)
    }
}

impl NativeBackend {
    /// Start building a (model, strategy) backend; finish with
    /// [`NativeBackendBuilder::build`]. See the builder for the
    /// defaults its setters override.
    pub fn builder(spec: NativeSpec, strategy: Strategy) -> NativeBackendBuilder {
        NativeBackendBuilder {
            spec,
            strategy,
            style: ClippingStyle::AllLayer,
            dispatch: Dispatch::Formula,
            threads: 0,
        }
    }

    /// Build with the default `all-layer` clipping style.
    #[deprecated(note = "use NativeBackend::builder(spec, strategy).threads(..).build()")]
    pub fn new(spec: NativeSpec, strategy: Strategy, threads: usize) -> Result<Self> {
        Self::build_impl(spec, strategy, ClippingStyle::AllLayer, threads, &Dispatch::Formula)
    }

    /// Build with an explicit clipping style.
    #[deprecated(note = "use NativeBackend::builder(spec, strategy).style(..).build()")]
    pub fn with_style(
        spec: NativeSpec,
        strategy: Strategy,
        style: ClippingStyle,
        threads: usize,
    ) -> Result<Self> {
        Self::build_impl(spec, strategy, style, threads, &Dispatch::Formula)
    }

    /// Build with an explicit clipping style and norm-route dispatch.
    #[deprecated(note = "use NativeBackend::builder(spec, strategy).dispatch(..).build()")]
    pub fn with_style_dispatch(
        spec: NativeSpec,
        strategy: Strategy,
        style: ClippingStyle,
        threads: usize,
        dispatch: &Dispatch,
    ) -> Result<Self> {
        Self::build_impl(spec, strategy, style, threads, dispatch)
    }

    fn build_impl(
        spec: NativeSpec,
        strategy: Strategy,
        style: ClippingStyle,
        threads: usize,
        dispatch: &Dispatch,
    ) -> Result<Self> {
        let clip_kind = ClipKind::parse(&spec.clip_fn).ok_or_else(|| {
            anyhow!(
                "unknown clip_fn '{}' in model '{}' (expected one of: abadi, automatic, flat)",
                spec.clip_fn,
                spec.name
            )
        })?;
        if spec.optimizer != "sgd" && spec.optimizer != "adam" {
            bail!(
                "unknown optimizer '{}' in model '{}' (expected 'sgd' or 'adam')",
                spec.optimizer,
                spec.name
            );
        }
        if spec.batch == 0 || spec.seq == 0 || spec.d_in == 0 || spec.n_classes == 0 {
            bail!("model '{}' has a zero dimension", spec.name);
        }
        if spec.vocab > 0 && spec.vocab != spec.n_classes {
            bail!(
                "token model '{}' must be next-token (vocab = {}, n_classes = {})",
                spec.name,
                spec.vocab,
                spec.n_classes
            );
        }
        if spec.blocks > 0 {
            if spec.vocab == 0 {
                bail!(
                    "transformer model '{}' requires vocab > 0 (token input)",
                    spec.name
                );
            }
            if spec.attn_heads == 0 || spec.d_in % spec.attn_heads != 0 {
                bail!(
                    "model '{}': attn_heads {} must divide d_in {}",
                    spec.name,
                    spec.attn_heads,
                    spec.d_in
                );
            }
            if spec.ff == 0 {
                bail!("transformer model '{}' needs ff > 0", spec.name);
            }
        } else if spec.tied {
            bail!(
                "model '{}': tied = true requires a transformer plan (blocks > 0) — \
                 only the GPT-style vocab head can alias the embedding table",
                spec.name
            );
        }
        if spec.wpe && spec.vocab == 0 {
            bail!(
                "model '{}': wpe = true requires token input (vocab > 0) — the position \
                 table rides on the token embedding",
                spec.name
            );
        }
        // kind-specific plan validation (conv geometry, flag/kind
        // consistency) before any layer construction
        spec.validate_kind()?;
        // parse + validate the trainability preset up front (unknown
        // mask names, lora on a lora-less plan, all-frozen specs)
        spec.trainable_preset()?;
        let stack = layers::build_stack(&spec)?;
        let residuals: Vec<Option<usize>> = spec.plan().iter().map(|l| l.residual).collect();
        let t = spec.seq;
        let routes: Vec<NormRoute> = stack
            .iter()
            .map(|l| match l.dims(t) {
                None => NormRoute::Ghost, // stateless: never consulted
                Some(d) => match d.kind {
                    // embeddings ghost via the token-equality mask
                    // (instantiation would be vocab*dim per sample);
                    // norm layers instantiate their O(p) grads directly.
                    LayerKind::Embedding => NormRoute::Ghost,
                    LayerKind::Norm => NormRoute::Inst,
                    _ => match strategy {
                        Strategy::Opacus | Strategy::FastGradClip => NormRoute::Inst,
                        Strategy::GhostClip | Strategy::Bk | Strategy::NonDp => NormRoute::Ghost,
                        Strategy::MixGhostClip | Strategy::BkMixGhostClip | Strategy::BkMixOpt => {
                            if dispatch.ghost_preferred(&d) {
                                NormRoute::Ghost
                            } else {
                                NormRoute::Inst
                            }
                        }
                    },
                },
            })
            .collect();
        let store_psg: Vec<bool> = stack
            .iter()
            .zip(&routes)
            .map(|(l, r)| {
                l.psg_len() > 0
                    && match strategy {
                        Strategy::Opacus => true,
                        Strategy::BkMixOpt => *r == NormRoute::Inst,
                        _ => false,
                    }
            })
            .collect();

        // ---- canonical parameter-slot indirection ---------------------
        // Tensors are identified by plan name; a repeated name aliases
        // the earlier (owning) tensor, so two layers view one canonical
        // slot — the tied vocab head viewing the embedding table. Each
        // layer's view must be one contiguous canonical range.
        let plan = spec.plan();
        let mut canon_names: Vec<String> = Vec::new();
        let mut canon_shapes: Vec<Vec<usize>> = Vec::new();
        let mut owner_layer: Vec<usize> = Vec::new();
        let mut slots: Vec<(usize, usize)> = Vec::with_capacity(stack.len());
        let mut alias_of: Vec<Option<usize>> = vec![None; stack.len()];
        for (k, l) in plan.iter().enumerate() {
            let shapes = l.param_shapes();
            if l.param_names.is_empty() {
                let n = canon_names.len();
                slots.push((n, n));
                continue;
            }
            let ids: Vec<Option<usize>> = l
                .param_names
                .iter()
                .map(|n| canon_names.iter().position(|c| c == n))
                .collect();
            if ids.iter().all(Option::is_none) {
                // owner: mint fresh consecutive canonical slots
                let start = canon_names.len();
                for (name, shape) in l.param_names.iter().zip(&shapes) {
                    canon_names.push(name.clone());
                    canon_shapes.push(shape.clone());
                    owner_layer.push(k);
                }
                slots.push((start, canon_names.len()));
            } else if ids.iter().all(Option::is_some) {
                // alias: every tensor must resolve to an existing slot,
                // contiguously, all owned by one earlier layer, with the
                // canonical shapes
                let ids: Vec<usize> = ids.into_iter().flatten().collect();
                let start = ids[0];
                if !ids.iter().enumerate().all(|(i, &id)| id == start + i) {
                    bail!(
                        "layer '{}' of model '{}' aliases a non-contiguous tensor range",
                        l.name,
                        spec.name
                    );
                }
                let own = owner_layer[start];
                if !ids.iter().all(|&id| owner_layer[id] == own) {
                    bail!(
                        "layer '{}' of model '{}' aliases tensors of several layers",
                        l.name,
                        spec.name
                    );
                }
                for (&id, shape) in ids.iter().zip(&shapes) {
                    if &canon_shapes[id] != shape {
                        bail!(
                            "layer '{}' of model '{}' aliases '{}' with shape {:?}, owner has {:?}",
                            l.name,
                            spec.name,
                            canon_names[id],
                            shape,
                            canon_shapes[id]
                        );
                    }
                }
                if alias_of.iter().any(|a| *a == Some(own)) {
                    bail!(
                        "model '{}': tensor of layer {own} is aliased more than once \
                         (the norm walk stashes one cross-term gradient per owner)",
                        spec.name
                    );
                }
                alias_of[k] = Some(own);
                slots.push((start, start + ids.len()));
            } else {
                bail!(
                    "layer '{}' of model '{}' mixes owned and aliased tensors",
                    l.name,
                    spec.name
                );
            }
        }

        // ---- trainability ---------------------------------------------
        // per canonical tensor from the spec's preset (aliases see the
        // owner's slots, so they inherit its flags), and per stack layer
        // (true iff any of its tensors trains). Frozen layers never
        // enter the norm/sum walks, clipping groups, or optimizer state.
        let slot_trainable = spec.slot_trainable();
        debug_assert_eq!(slot_trainable.len(), canon_names.len());
        let layer_trainable: Vec<bool> = slots
            .iter()
            .map(|&(s, e)| slot_trainable[s..e].iter().any(|&tr| tr))
            .collect();

        // clipping groups over *trainable owner* layers, in stack order;
        // aliasing layers inherit the owner's group — tied tensors must
        // land in one group or the per-group R/sqrt(G) sensitivity
        // argument breaks (splitting ||G_emb + G_head|| across groups
        // would double-charge the shared tensor). Frozen layers mint no
        // group: they contribute no norms, so counting them would dilute
        // R/sqrt(G) with groups that never see a gradient.
        let n_param_layers = stack
            .iter()
            .enumerate()
            .filter(|(k, _)| layer_trainable[*k] && alias_of[*k].is_none())
            .count();
        let n_groups = style.n_groups(n_param_layers);
        let mut groups = vec![0usize; stack.len()];
        let mut pl = 0usize;
        for k in 0..stack.len() {
            if layer_trainable[k] && alias_of[k].is_none() {
                groups[k] = style.group_of(pl, n_param_layers);
                pl += 1;
            }
        }
        for k in 0..stack.len() {
            if let Some(j) = alias_of[k] {
                groups[k] = groups[j];
            }
        }

        // fused-schedule group boundaries: a group's norms are complete
        // once its lowest-index trainable member has contributed (owner
        // groups are contiguous in stack order; an alias sits above its
        // owner, so the owner is always the boundary of a shared group)
        let mut finalize_at: Vec<Option<usize>> = vec![None; stack.len()];
        for gi in 0..n_groups {
            let min_k = (0..stack.len())
                .find(|&k| layer_trainable[k] && groups[k] == gi)
                .expect("every clipping group has a trainable member");
            finalize_at[min_k] = Some(gi);
        }

        // shared scratch sizing, masked by per-tensor trainability:
        // frozen weights never run norm/sum kernels, so they claim no
        // Gram / stream / partials scratch — the AllocStats arena-peak
        // drop for bias-only and LoRA runs comes from here. Recompute
        // scratch (`attn`) stays unconditional: `backward_data` uses it
        // even on fully frozen attention / LoRA layers.
        let masks = spec.plan_masks();
        debug_assert_eq!(masks.len(), stack.len());
        let mut max_dp = 1usize;
        let mut max_small = 1usize;
        let mut max_attn = 0usize;
        let mut max_gram = 0usize;
        let mut need_stream_two = false;
        let mut need_stream_one = false;
        for (k, l) in stack.iter().enumerate() {
            let mask = &masks[k];
            if let Some(d) = l.dims(t) {
                match d.kind {
                    LayerKind::Norm => max_small = max_small.max(2 * d.p as usize),
                    LayerKind::Embedding => {}
                    // the wpe norm is a plain Frobenius reduction and
                    // its clipped sum a serial scatter: no shared scratch
                    LayerKind::PosEmbedding => {}
                    LayerKind::Attention => {
                        // p encodes the head count; the widest projection
                        // is the fused QKV (d, 3d), and the recompute
                        // scratch holds [g_ao | g_qkv] = rows * 4d
                        let dm = d.d as usize;
                        max_small = max_small.max(3 * dm);
                        max_attn = max_attn.max(spec.batch * spec.seq * 4 * dm);
                        if mask[0] {
                            max_dp = max_dp.max(dm * 3 * dm);
                        }
                        if mask[2] {
                            max_dp = max_dp.max(dm * dm);
                        }
                        if mask[0] || mask[2] {
                            if routes[k] == NormRoute::Ghost && t > 1 {
                                max_gram = max_gram.max(spec.batch * t * t);
                            }
                            if routes[k] == NormRoute::Inst {
                                need_stream_two = true;
                                need_stream_one = true;
                            }
                        }
                    }
                    LayerKind::Lora { rank } => {
                        // recompute scratch holds [gA | gA·A^T] = rows*(r+d)
                        let (dd, pp, r) = (d.d as usize, d.p as usize, rank as usize);
                        max_small = max_small.max(pp);
                        max_attn = max_attn.max(spec.batch * spec.seq * (r + dd));
                        if mask[0] {
                            max_dp = max_dp.max(dd * pp);
                        }
                        if mask[2] {
                            max_dp = max_dp.max(dd * r);
                        }
                        if mask[3] {
                            max_dp = max_dp.max(r * pp);
                        }
                        if mask[0] || mask[2] || mask[3] {
                            if routes[k] == NormRoute::Ghost && t > 1 {
                                max_gram = max_gram.max(spec.batch * t * t);
                            }
                            if routes[k] == NormRoute::Inst {
                                need_stream_two = true;
                                need_stream_one = true;
                            }
                        }
                    }
                    LayerKind::Conv => {
                        // the conv layer runs the linear kernels at its
                        // own token count t_out (output spatial
                        // positions), not the spec seq: gram/stream
                        // sizing must use the per-layer dims. The fold
                        // scratch (`attn`) is unconditional — frozen
                        // convs still route the data gradient.
                        let (tt, dd, pp) = (d.t as usize, d.d as usize, d.p as usize);
                        max_small = max_small.max(pp);
                        max_attn = max_attn.max(spec.batch * tt * dd);
                        if mask[0] {
                            max_dp = max_dp.max(dd * pp);
                            if routes[k] == NormRoute::Ghost && tt > 1 {
                                max_gram = max_gram.max(spec.batch * tt * tt);
                            }
                            if routes[k] == NormRoute::Inst {
                                need_stream_two = true;
                                if !store_psg[k] {
                                    need_stream_one = true;
                                }
                            }
                        }
                    }
                    _ => {
                        max_small = max_small.max(d.p as usize);
                        if mask[0] {
                            max_dp = max_dp.max((d.d * d.p) as usize);
                            if routes[k] == NormRoute::Ghost && t > 1 {
                                max_gram = max_gram.max(spec.batch * t * t);
                            }
                            if routes[k] == NormRoute::Inst {
                                need_stream_two = true;
                                if !store_psg[k] {
                                    need_stream_one = true;
                                }
                            }
                        }
                    }
                }
            }
        }

        let threads = if threads == 0 { par::default_threads() } else { threads };
        let info = spec.info();
        debug_assert_eq!(info.trainable, slot_trainable);
        // params are full-size for every slot (the forward reads frozen
        // tensors); Adam moments exist only for trainable slots
        let params: Vec<Vec<f32>> = info
            .param_names
            .iter()
            .map(|n| vec![0.0; info.param_shapes[n].iter().product()])
            .collect();
        let (opt_m, opt_v) = if info.is_adam() {
            let moments = || -> Vec<Vec<f32>> {
                info.param_names
                    .iter()
                    .zip(&slot_trainable)
                    .map(|(n, &tr)| {
                        if tr {
                            vec![0.0; info.param_shapes[n].iter().product()]
                        } else {
                            Vec::new()
                        }
                    })
                    .collect()
            };
            (moments(), moments())
        } else {
            (Vec::new(), Vec::new())
        };
        debug_assert_eq!(params.len(), canon_names.len());
        Ok(Self {
            spec,
            info,
            strategy,
            clip_kind,
            style,
            stack,
            slots,
            alias_of,
            routes,
            store_psg,
            groups,
            residuals,
            slot_trainable,
            layer_trainable,
            finalize_at,
            unfused_schedule: false,
            last_peak_gcache: 0,
            n_groups,
            threads,
            params,
            opt_m,
            opt_v,
            arena: Arena::new(),
            last_fresh: 0,
            initialized: false,
            max_dp,
            max_small,
            max_attn,
            max_gram,
            need_stream_two,
            need_stream_one,
        })
    }

    /// The execution strategy.
    pub fn strategy_enum(&self) -> Strategy {
        self.strategy
    }

    /// The clipping style.
    pub fn clipping_style(&self) -> ClippingStyle {
        self.style
    }

    /// Number of clipping groups (1 for all-layer).
    pub fn n_clip_groups(&self) -> usize {
        self.n_groups
    }

    /// Diagnostic/test surface: `true` reverts the one-pass DP
    /// strategies to the legacy unfused schedule (norm walk stashes
    /// every g-cache to the end, then a separate clipped-sum sweep).
    /// Bitwise identical to the fused default — only buffer lifetimes
    /// differ — which the fused-schedule tests assert.
    pub fn set_unfused_schedule(&mut self, unfused: bool) {
        self.unfused_schedule = unfused;
    }

    /// Peak g-cache floats (frontier gradient + live book-kept output
    /// gradients, tied-alias cache included) of the last fused walk;
    /// 0 when the last step ran two-pass, nondp, or unfused. Matches
    /// `complexity::bk_gcache_floats` for the same (model, style).
    pub fn peak_gcache_floats(&self) -> usize {
        self.last_peak_gcache
    }

    fn two_pass(&self) -> bool {
        matches!(
            self.strategy,
            Strategy::FastGradClip | Strategy::GhostClip | Strategy::MixGhostClip
        )
    }

    fn rows(&self) -> usize {
        self.spec.batch * self.spec.seq
    }

    fn ctx(&self) -> Ctx {
        Ctx {
            b: self.spec.batch,
            t: self.spec.seq,
            threads: self.threads,
        }
    }

    fn check_batch(&self, x: &BatchX, y: &[i32]) -> Result<()> {
        let rows = self.rows();
        match (x, self.spec.vocab) {
            (BatchX::F32(v), 0) => {
                if v.len() != rows * self.spec.d_in {
                    bail!(
                        "x has {} elements, expected {} (B*T*d = {}*{}*{})",
                        v.len(),
                        rows * self.spec.d_in,
                        self.spec.batch,
                        self.spec.seq,
                        self.spec.d_in
                    );
                }
            }
            (BatchX::I32(toks), vocab) if vocab > 0 => {
                if toks.len() != rows {
                    bail!(
                        "x has {} token ids, expected {} (B*T = {}*{})",
                        toks.len(),
                        rows,
                        self.spec.batch,
                        self.spec.seq
                    );
                }
                if let Some(&bad) = toks.iter().find(|&&tk| tk < 0 || tk as usize >= vocab) {
                    bail!(
                        "token id {bad} out of range for vocab {vocab} in model '{}'",
                        self.spec.name
                    );
                }
            }
            (BatchX::I32(_), 0) => bail!(
                "model '{}' takes f32 features, got token ids (token inputs need a vocab > 0 \
                 embedding model or the pjrt backend)",
                self.spec.name
            ),
            (BatchX::F32(_), _) => bail!(
                "token model '{}' takes i32 token ids, got f32 features",
                self.spec.name
            ),
        }
        if y.len() != rows {
            bail!("y has {} labels, expected {}", y.len(), rows);
        }
        if !self.initialized {
            bail!("backend not initialized (call init first)");
        }
        Ok(())
    }

    fn layer_input<'a>(&self, x: &'a BatchX) -> LayerIn<'a> {
        match x {
            BatchX::F32(v) => LayerIn::Feat(v.as_slice()),
            BatchX::I32(v) => LayerIn::Tokens(v.as_slice()),
        }
    }

    /// Per-group clipping radius: with `G` groups each group clips to
    /// `R / sqrt(G)` so total sensitivity stays `R`. The single source
    /// of the split — the fused and unfused schedules both derive
    /// their factors from this, which the bitwise-equivalence tests
    /// depend on.
    fn group_radius(&self, clip: f32) -> f32 {
        if self.n_groups == 1 {
            clip
        } else {
            clip / (self.n_groups as f32).sqrt()
        }
    }

    /// Per-group clip factors from the grouped squared norms.
    fn grouped_clip_factors(&self, sq: &[f32], clip: f32, cfac: &mut [f32]) {
        let b = self.spec.batch;
        let rg = self.group_radius(clip);
        for gi in 0..self.n_groups {
            kernels::clip_factors(
                &sq[gi * b..(gi + 1) * b],
                rg,
                self.clip_kind,
                &mut cfac[gi * b..(gi + 1) * b],
            );
        }
    }

    /// Compute per-tensor gradient sums into `grads` (one per trainable
    /// tensor, zero-initialized by the caller): the plain gradient for
    /// nondp, the clipped-per-sample sum for every DP strategy.
    fn compute_grads(
        &mut self,
        x: &BatchX,
        y: &[i32],
        clip: f32,
        grads: &mut [Vec<f32>],
    ) -> Result<StepOut> {
        self.check_batch(x, y)?;
        let b = self.spec.batch;
        let rows = self.rows();
        let nl = self.stack.len();
        let workers = self.ctx().workers();
        debug_assert_eq!(grads.len(), self.params.len());
        let input = self.layer_input(x);
        // field-disjoint borrows: the tape reads the stack/params while
        // the arena hands out step buffers
        let run = StackRun {
            layers: &self.stack,
            params: &self.params,
            slots: &self.slots,
            alias_of: &self.alias_of,
            routes: &self.routes,
            groups: &self.groups,
            residuals: &self.residuals,
            trainable: &self.layer_trainable,
            ctx: self.ctx(),
        };

        let (mut acts, mut caches) = run.forward(&mut self.arena, input);
        // attention recompute scratch, shared by every backward walk
        let mut attn_buf = if self.max_attn > 0 {
            self.arena.take(self.max_attn)
        } else {
            Vec::new()
        };

        let mut peak_gcache = 0usize;
        let (loss, mean_clip, group_clip) = if self.strategy == Strategy::NonDp {
            // -- single backward, plain summed gradients ---------------
            let mut small = self.arena.take(workers * self.max_small);
            let mut partials = self.arena.take(workers * self.max_dp);
            let mut none_a: Vec<f32> = Vec::new();
            let mut none_g: Vec<f32> = Vec::new();
            let mut none_s: Vec<f32> = Vec::new();
            let loss = {
                let mut scratch = Scratch {
                    gram_a: &mut none_a[..],
                    gram_g: &mut none_g[..],
                    stream: &mut none_s[..],
                    small: &mut small[..],
                    partials: &mut partials[..],
                    attn: &mut attn_buf[..],
                };
                run.clipped_recompute(
                    &mut self.arena,
                    &acts,
                    &caches,
                    input,
                    y,
                    None,
                    &mut scratch,
                    grads,
                )
            };
            self.arena.give(partials);
            self.arena.give(small);
            (loss, 1.0, vec![1.0])
        } else {
            let two = self.two_pass();
            let need_stream = if two { self.need_stream_two } else { self.need_stream_one };
            // gram scratch is sized per-layer (`max_gram` covers the
            // largest b * t_layer^2 over ghost layers — conv layers run
            // at their own t_out, not the spec seq)
            let mut gram_a =
                if self.max_gram > 0 { self.arena.take(self.max_gram) } else { Vec::new() };
            let mut gram_g =
                if self.max_gram > 0 { self.arena.take(self.max_gram) } else { Vec::new() };
            let mut stream = if need_stream {
                self.arena.take(workers * self.max_dp)
            } else {
                Vec::new()
            };
            let mut small = self.arena.take(workers * self.max_small);
            let mut partials = self.arena.take(workers * self.max_dp);
            let mut sq = self.arena.take(self.n_groups * b);
            let mut psg: Vec<Option<Vec<f32>>> = Vec::with_capacity(nl);
            for k in 0..nl {
                if !two && self.store_psg[k] {
                    let n = b * self.stack[k].psg_len();
                    psg.push(Some(self.arena.take(n)));
                } else {
                    psg.push(None);
                }
            }

            let mut cfac = self.arena.take(self.n_groups * b);
            let loss = if !two && !self.unfused_schedule {
                // ---- fused one-pass: norms + per-group finalize ------
                // each clipping group's clip factors and clipped sums
                // are issued at its boundary inside the single backward
                // walk, releasing the group's g-caches early (bitwise
                // identical to the unfused schedule below)
                let rg = self.group_radius(clip);
                let ck = self.clip_kind;
                let mut scratch = Scratch {
                    gram_a: &mut gram_a[..],
                    gram_g: &mut gram_g[..],
                    stream: &mut stream[..],
                    small: &mut small[..],
                    partials: &mut partials[..],
                    attn: &mut attn_buf[..],
                };
                let (loss, peak) = run.fused_pass(
                    &mut self.arena,
                    &acts,
                    &caches,
                    input,
                    y,
                    &mut scratch,
                    &mut psg,
                    &mut sq,
                    &mut cfac,
                    &self.finalize_at,
                    &mut |sqr, cfr| kernels::clip_factors(sqr, rg, ck, cfr),
                    grads,
                );
                peak_gcache = peak;
                loss
            } else {
                // ---- pass 1: norms (book-keeping g for one-pass) -----
                let (loss, kept) = {
                    let mut scratch = Scratch {
                        gram_a: &mut gram_a[..],
                        gram_g: &mut gram_g[..],
                        stream: &mut stream[..],
                        small: &mut small[..],
                        partials: &mut partials[..],
                        attn: &mut attn_buf[..],
                    };
                    run.norm_pass(
                        &mut self.arena,
                        &acts,
                        &caches,
                        input,
                        y,
                        &mut scratch,
                        &mut psg,
                        &mut sq,
                        !two,
                    )
                };

                self.grouped_clip_factors(&sq, clip, &mut cfac);

                // ---- pass 2: clipped sums (cached or recomputed) -----
                {
                    let mut scratch = Scratch {
                        gram_a: &mut gram_a[..],
                        gram_g: &mut gram_g[..],
                        stream: &mut stream[..],
                        small: &mut small[..],
                        partials: &mut partials[..],
                        attn: &mut attn_buf[..],
                    };
                    if two {
                        run.clipped_recompute(
                            &mut self.arena,
                            &acts,
                            &caches,
                            input,
                            y,
                            Some(&cfac),
                            &mut scratch,
                            grads,
                        );
                    } else {
                        run.clipped_from_cache(
                            &acts, &caches, input, &kept, &psg, &cfac, &mut scratch, grads,
                        );
                    }
                }

                for buf in kept.into_iter().flatten() {
                    self.arena.give(buf);
                }
                loss
            };

            let mean_clip = cfac.iter().sum::<f32>() / (self.n_groups * b) as f32;
            let group_clip: Vec<f32> = (0..self.n_groups)
                .map(|gi| cfac[gi * b..(gi + 1) * b].iter().sum::<f32>() / b as f32)
                .collect();

            for buf in psg.into_iter().flatten() {
                self.arena.give(buf);
            }
            self.arena.give(cfac);
            self.arena.give(sq);
            self.arena.give(partials);
            self.arena.give(small);
            if need_stream {
                self.arena.give(stream);
            }
            if self.max_gram > 0 {
                self.arena.give(gram_g);
                self.arena.give(gram_a);
            }
            (loss, mean_clip, group_clip)
        };

        self.last_peak_gcache = peak_gcache;
        if self.max_attn > 0 {
            self.arena.give(attn_buf);
        }
        for c in caches.drain(..) {
            self.arena.give_all(c);
        }
        while let Some(a) = acts.pop() {
            // the token-input placeholder act is not an arena buffer
            if a.capacity() > 0 {
                self.arena.give(a);
            }
        }
        Ok(StepOut {
            loss: loss / rows as f32,
            mean_clip,
            group_clip,
        })
    }

    fn update_params(&mut self, grads: &[Vec<f32>], noise: &[Vec<f32>], h: &StepHyper) -> Result<()> {
        let n = self.params.len();
        if grads.len() != n {
            bail!("update got {} grad tensors, expected {n}", grads.len());
        }
        if !noise.is_empty() && noise.len() != n {
            bail!("update got {} noise tensors, expected 0 or {n}", noise.len());
        }
        if noise.is_empty() && h.sigma_r != 0.0 {
            // Refuse to silently run an unnoised "DP" step: the caller
            // would charge epsilon for noise that was never injected.
            bail!("sigma_r = {} but no noise tensors were supplied", h.sigma_r);
        }
        let adam = self.info.is_adam();
        for k in 0..n {
            // frozen slots expect zero-length grad/noise tensors and
            // never touch the params or moments
            let want = if self.slot_trainable[k] { self.params[k].len() } else { 0 };
            if grads[k].len() != want {
                bail!(
                    "grad tensor {k} has {} elements, expected {want}",
                    grads[k].len(),
                );
            }
            if !noise.is_empty() && noise[k].len() != want {
                bail!(
                    "noise tensor {k} has {} elements, expected {want}",
                    noise[k].len(),
                );
            }
            if !self.slot_trainable[k] {
                continue;
            }
            let z = if noise.is_empty() { None } else { Some(noise[k].as_slice()) };
            if adam {
                kernels::adam_update(
                    &mut self.params[k],
                    &mut self.opt_m[k],
                    &mut self.opt_v[k],
                    &grads[k],
                    z,
                    h.lr,
                    h.sigma_r,
                    h.logical_batch,
                    h.step,
                );
            } else {
                kernels::sgd_update(&mut self.params[k], &grads[k], z, h.lr, h.sigma_r, h.logical_batch);
            }
        }
        Ok(())
    }

    fn take_grad_bufs(&mut self) -> Vec<Vec<f32>> {
        // frozen slots get the arena's zero-length placeholder — the
        // walks never write them (the tape skips frozen layers)
        let sizes: Vec<usize> = self
            .params
            .iter()
            .zip(&self.slot_trainable)
            .map(|(p, &tr)| if tr { p.len() } else { 0 })
            .collect();
        sizes.into_iter().map(|n| self.arena.take(n)).collect()
    }

    /// Clipping-group id of every canonical tensor, in state order
    /// (the differential test harness maps oracle gradients to groups
    /// with this). Frozen tensors belong to no group; their entries are
    /// a meaningless 0 and callers must mask by `info().trainable`.
    pub fn tensor_groups(&self) -> Vec<usize> {
        // canonical tensors only: an aliasing layer shares its owner's
        // slots (and, by construction, its clipping group)
        let mut out = vec![0usize; self.params.len()];
        for (k, l) in self.stack.iter().enumerate() {
            if l.n_param_tensors() == 0 || self.alias_of[k].is_some() {
                continue;
            }
            let (s, e) = self.slots[k];
            for slot in out.iter_mut().take(e).skip(s) {
                *slot = self.groups[k];
            }
        }
        out
    }

    /// Per-sample squared gradient norms, one `(B,)` row per clipping
    /// group (group-major, `n_groups * B` total) — the quantities the
    /// clip factors derive from, computed by a single norm pass exactly
    /// as the configured (strategy, style) would. Diagnostic / test
    /// surface; rejects `nondp` (which never computes norms).
    ///
    /// NOTE: the scratch/arena choreography below mirrors
    /// `compute_grads` — when the scratch set changes (as `attn` did),
    /// both sites must be updated in lockstep.
    pub fn per_sample_sq_norms(&mut self, x: &BatchX, y: &[i32]) -> Result<Vec<f32>> {
        if self.strategy == Strategy::NonDp {
            bail!("nondp computes no per-sample norms");
        }
        self.check_batch(x, y)?;
        self.arena.begin_step();
        let b = self.spec.batch;
        let nl = self.stack.len();
        let workers = self.ctx().workers();
        let input = self.layer_input(x);
        let run = StackRun {
            layers: &self.stack,
            params: &self.params,
            slots: &self.slots,
            alias_of: &self.alias_of,
            routes: &self.routes,
            groups: &self.groups,
            residuals: &self.residuals,
            trainable: &self.layer_trainable,
            ctx: self.ctx(),
        };
        let (mut acts, mut caches) = run.forward(&mut self.arena, input);
        let mut attn_buf = if self.max_attn > 0 {
            self.arena.take(self.max_attn)
        } else {
            Vec::new()
        };
        let mut gram_a = if self.max_gram > 0 { self.arena.take(self.max_gram) } else { Vec::new() };
        let mut gram_g = if self.max_gram > 0 { self.arena.take(self.max_gram) } else { Vec::new() };
        let need_stream = self.need_stream_two;
        let mut stream = if need_stream {
            self.arena.take(workers * self.max_dp)
        } else {
            Vec::new()
        };
        let mut small = self.arena.take(workers * self.max_small);
        let mut partials = self.arena.take(workers * self.max_dp);
        let mut sq = self.arena.take(self.n_groups * b);
        // no stored-psg reuse on this path: every layer takes its
        // accum_sq_norms route (stored and streamed norms agree bitwise)
        let mut psg: Vec<Option<Vec<f32>>> = (0..nl).map(|_| None).collect();
        {
            let mut scratch = Scratch {
                gram_a: &mut gram_a[..],
                gram_g: &mut gram_g[..],
                stream: &mut stream[..],
                small: &mut small[..],
                partials: &mut partials[..],
                attn: &mut attn_buf[..],
            };
            let (_loss, kept) = run.norm_pass(
                &mut self.arena,
                &acts,
                &caches,
                input,
                y,
                &mut scratch,
                &mut psg,
                &mut sq,
                false,
            );
            debug_assert!(kept.iter().all(Option::is_none));
        }
        let out = sq.clone();
        self.arena.give(sq);
        self.arena.give(partials);
        self.arena.give(small);
        if need_stream {
            self.arena.give(stream);
        }
        if self.max_gram > 0 {
            self.arena.give(gram_g);
            self.arena.give(gram_a);
        }
        if self.max_attn > 0 {
            self.arena.give(attn_buf);
        }
        for c in caches.drain(..) {
            self.arena.give_all(c);
        }
        while let Some(a) = acts.pop() {
            if a.capacity() > 0 {
                self.arena.give(a);
            }
        }
        debug_assert_eq!(self.arena.outstanding(), 0, "arena leak in norm pass");
        Ok(out)
    }
}

impl Backend for NativeBackend {
    fn info(&self) -> &ModelInfo {
        &self.info
    }

    fn strategy(&self) -> &str {
        self.strategy.name()
    }

    fn init(&mut self, seed: u64) -> Result<()> {
        let root = Xoshiro256::new(seed ^ 0x1A17_F00D);
        let head_k = self
            .stack
            .iter()
            .rposition(|l| l.n_param_tensors() > 0)
            .expect("stack has a trainable layer");
        let mut pl = 0u64;
        for (k, layer) in self.stack.iter().enumerate() {
            let np = layer.n_param_tensors();
            if np == 0 {
                continue;
            }
            // one forked stream per trainable layer, in stack order
            // (identical to the legacy per-linear-layer forks for MLPs;
            // aliasing layers draw a fork too but their init is a no-op
            // — the owner initializes the shared tensor)
            let rng = root.fork(pl + 1);
            pl += 1;
            let (s, e) = self.slots[k];
            debug_assert_eq!(e - s, np);
            layer.init(rng, &mut self.params[s..e], k == head_k);
        }
        for t in self.opt_m.iter_mut().chain(self.opt_v.iter_mut()) {
            for v in t.iter_mut() {
                *v = 0.0;
            }
        }
        self.initialized = true;
        Ok(())
    }

    fn eval_loss(&mut self, x: &BatchX, y: &[i32]) -> Result<f32> {
        self.check_batch(x, y)?;
        let rows = self.rows();
        let nl = self.stack.len();
        let c_out = self.stack[nl - 1].out_width();
        let input = self.layer_input(x);
        let run = StackRun {
            layers: &self.stack,
            params: &self.params,
            slots: &self.slots,
            alias_of: &self.alias_of,
            routes: &self.routes,
            groups: &self.groups,
            residuals: &self.residuals,
            trainable: &self.layer_trainable,
            ctx: self.ctx(),
        };
        let (mut acts, mut caches) = run.forward(&mut self.arena, input);
        let loss = kernels::softmax_xent(&acts[nl], y, rows, c_out, None);
        for c in caches.drain(..) {
            self.arena.give_all(c);
        }
        while let Some(a) = acts.pop() {
            if a.capacity() > 0 {
                self.arena.give(a);
            }
        }
        Ok(loss / rows as f32)
    }

    fn step(&mut self, x: &BatchX, y: &[i32], noise: &[Vec<f32>], h: &StepHyper) -> Result<StepOut> {
        self.arena.begin_step();
        let mut grads = self.take_grad_bufs();
        let out = self.compute_grads(x, y, h.clip, &mut grads);
        let upd = match &out {
            Ok(_) => self.update_params(&grads, noise, h),
            Err(_) => Ok(()),
        };
        self.arena.give_all(grads);
        let out = out?;
        upd?;
        self.last_fresh = self.arena.fresh_allocs();
        debug_assert_eq!(self.arena.outstanding(), 0, "arena leak in step");
        Ok(out)
    }

    fn clipped_grads(&mut self, x: &BatchX, y: &[i32], clip: f32) -> Result<(Vec<Vec<f32>>, StepOut)> {
        self.arena.begin_step();
        // The gradient sums are handed to the caller (host-side
        // accumulation), so they are plain Vecs rather than arena
        // buffers — cloning out of the arena would cost the same
        // allocation plus an extra copy. Frozen slots stay zero-length.
        let mut grads: Vec<Vec<f32>> = self
            .params
            .iter()
            .zip(&self.slot_trainable)
            .map(|(p, &tr)| vec![0.0; if tr { p.len() } else { 0 }])
            .collect();
        let out = self.compute_grads(x, y, clip, &mut grads)?;
        self.last_fresh = self.arena.fresh_allocs();
        Ok((grads, out))
    }

    fn apply_update(&mut self, grads: &[Vec<f32>], noise: &[Vec<f32>], h: &StepHyper) -> Result<()> {
        self.update_params(grads, noise, h)
    }

    fn state(&self) -> Result<Vec<Vec<f32>>> {
        let mut out: Vec<Vec<f32>> = self.params.clone();
        out.extend(self.opt_m.iter().cloned());
        out.extend(self.opt_v.iter().cloned());
        Ok(out)
    }

    fn load_state(&mut self, tensors: Vec<Vec<f32>>) -> Result<()> {
        let n = self.params.len();
        let want_full = if self.info.is_adam() { 3 * n } else { n };
        if tensors.len() != n && tensors.len() != want_full {
            bail!(
                "load_state got {} tensors, expected {n} (params) or {want_full} (full state)",
                tensors.len()
            );
        }
        for (k, t) in tensors.iter().enumerate() {
            let slot = k % n;
            // params are full-size for every slot; Adam moments are
            // zero-length for frozen slots
            let want = if k < n {
                self.params[slot].len()
            } else {
                self.opt_m[slot].len()
            };
            if t.len() != want {
                bail!("state tensor {k} has {} elements, expected {want}", t.len());
            }
        }
        let full = tensors.len() == want_full && self.info.is_adam();
        let mut it = tensors.into_iter();
        for slot in self.params.iter_mut() {
            *slot = it.next().unwrap();
        }
        if full {
            for slot in self.opt_m.iter_mut() {
                *slot = it.next().unwrap();
            }
            for slot in self.opt_v.iter_mut() {
                *slot = it.next().unwrap();
            }
        }
        self.initialized = true;
        Ok(())
    }

    fn alloc_stats(&self) -> AllocStats {
        AllocStats {
            fresh_allocs_last_step: self.last_fresh,
            arena_bytes: self.arena.total_bytes(),
            arena_peak_floats: self.arena.peak_outstanding_elems(),
            peak_gcache_floats: self.last_peak_gcache,
            opt_state_floats: self.opt_m.iter().map(Vec::len).sum::<usize>()
                + self.opt_v.iter().map(Vec::len).sum::<usize>(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn tiny_spec() -> NativeSpec {
        NativeSpec {
            name: "tiny".into(),
            batch: 4,
            seq: 1,
            d_in: 8,
            hidden: vec![12],
            n_classes: 3,
            optimizer: "sgd".into(),
            clip_fn: "automatic".into(),
            ..NativeSpec::default()
        }
    }

    fn tiny_tok_spec() -> NativeSpec {
        NativeSpec {
            name: "tiny_tok".into(),
            batch: 4,
            seq: 5,
            d_in: 6,
            hidden: vec![9],
            n_classes: 11,
            optimizer: "sgd".into(),
            clip_fn: "automatic".into(),
            vocab: 11,
            layernorm: true,
            ..NativeSpec::default()
        }
    }

    fn tiny_gpt_spec() -> NativeSpec {
        NativeSpec {
            name: "tiny_gpt".into(),
            batch: 3,
            seq: 5,
            d_in: 8,
            hidden: Vec::new(),
            n_classes: 11,
            optimizer: "sgd".into(),
            clip_fn: "automatic".into(),
            vocab: 11,
            blocks: 1,
            attn_heads: 2,
            ff: 12,
            ..NativeSpec::default()
        }
    }

    fn tiny_tied_gpt_spec() -> NativeSpec {
        NativeSpec {
            tied: true,
            ..tiny_gpt_spec()
        }
    }

    fn batch_for(spec: &NativeSpec, seed: u64) -> (BatchX, Vec<i32>) {
        let rows = spec.batch * spec.seq;
        let mut rng = Xoshiro256::new(seed);
        let x = if spec.vocab > 0 {
            BatchX::I32((0..rows).map(|_| rng.next_below(spec.vocab as u64) as i32).collect())
        } else {
            BatchX::F32((0..rows * spec.d_in).map(|_| rng.next_f32() - 0.5).collect())
        };
        let y: Vec<i32> = (0..rows)
            .map(|_| rng.next_below(spec.n_classes as u64) as i32)
            .collect();
        (x, y)
    }

    fn hyper() -> StepHyper {
        StepHyper {
            lr: 0.1,
            clip: 1.0,
            sigma_r: 0.0,
            logical_batch: 4.0,
            step: 1.0,
        }
    }

    #[test]
    fn step_is_deterministic() {
        let (x, y) = batch_for(&tiny_spec(), 7);
        let run = || -> Vec<Vec<f32>> {
            let mut bk = NativeBackend::builder(tiny_spec(), Strategy::Bk).threads(2).build().unwrap();
            bk.init(3).unwrap();
            bk.step(&x, &y, &[], &hyper()).unwrap();
            bk.state().unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed + batch must give bitwise-equal state");
    }

    #[test]
    fn arena_reaches_steady_state() {
        for spec in [tiny_spec(), tiny_tok_spec(), tiny_gpt_spec(), tiny_tied_gpt_spec()] {
            for strat in [
                Strategy::NonDp,
                Strategy::Opacus,
                Strategy::FastGradClip,
                Strategy::GhostClip,
                Strategy::Bk,
                Strategy::BkMixOpt,
            ] {
                for style in [
                    ClippingStyle::AllLayer,
                    ClippingStyle::LayerWise,
                    ClippingStyle::GroupWise(2),
                ] {
                    let (x, y) = batch_for(&spec, 9);
                    let mut be =
                        NativeBackend::builder(spec.clone(), strat).style(style).threads(2).build().unwrap();
                    be.init(1).unwrap();
                    be.step(&x, &y, &[], &hyper()).unwrap();
                    assert!(be.alloc_stats().fresh_allocs_last_step > 0, "cold step allocates");
                    for _ in 0..3 {
                        be.step(&x, &y, &[], &hyper()).unwrap();
                        assert_eq!(
                            be.alloc_stats().fresh_allocs_last_step,
                            0,
                            "{}/{strat:?}/{style:?}: steady-state step must not allocate",
                            spec.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let spec = tiny_spec();
        let (x, y) = batch_for(&spec, 11);
        let mut be = NativeBackend::builder(spec, Strategy::Bk).threads(2).build().unwrap();
        be.init(5).unwrap();
        let l0 = be.eval_loss(&x, &y).unwrap();
        let mut h = hyper();
        h.lr = 0.5;
        for _ in 0..20 {
            be.step(&x, &y, &[], &h).unwrap();
        }
        let l1 = be.eval_loss(&x, &y).unwrap();
        assert!(l1 < l0, "loss should fall on a fixed batch: {l0} -> {l1}");
    }

    #[test]
    fn token_model_trains_all_styles() {
        let spec = tiny_tok_spec();
        for style in [
            ClippingStyle::AllLayer,
            ClippingStyle::LayerWise,
            ClippingStyle::GroupWise(2),
        ] {
            let (x, y) = batch_for(&spec, 13);
            let mut be = NativeBackend::builder(spec.clone(), Strategy::Bk).style(style).threads(2).build().unwrap();
            be.init(5).unwrap();
            let l0 = be.eval_loss(&x, &y).unwrap();
            let mut h = hyper();
            h.lr = 0.5;
            let mut out = StepOut::default();
            for _ in 0..25 {
                out = be.step(&x, &y, &[], &h).unwrap();
            }
            let l1 = be.eval_loss(&x, &y).unwrap();
            assert!(l1 < l0, "{style:?}: loss should fall: {l0} -> {l1}");
            assert_eq!(out.group_clip.len(), be.n_clip_groups());
            assert!(out.group_clip.iter().all(|c| c.is_finite() && *c > 0.0));
        }
    }

    #[test]
    fn gpt_stack_trains_and_reports_norms() {
        // The transformer path end-to-end: loss falls on a fixed batch,
        // and per-sample norms are positive/finite per clipping group.
        let spec = tiny_gpt_spec();
        let (x, y) = batch_for(&spec, 17);
        let mut be =
            NativeBackend::builder(spec.clone(), Strategy::Bk).style(ClippingStyle::LayerWise).threads(2).build()
                .unwrap();
        be.init(5).unwrap();
        let sq = be.per_sample_sq_norms(&x, &y).unwrap();
        assert_eq!(sq.len(), be.n_clip_groups() * spec.batch);
        assert!(sq.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert_eq!(be.tensor_groups().len(), be.info().param_names.len());
        let l0 = be.eval_loss(&x, &y).unwrap();
        assert!((l0 - (spec.n_classes as f32).ln()).abs() < 1.0, "init loss {l0}");
        let mut h = hyper();
        h.lr = 0.2;
        for _ in 0..40 {
            be.step(&x, &y, &[], &h).unwrap();
        }
        let l1 = be.eval_loss(&x, &y).unwrap();
        assert!(l1 < l0, "gpt loss should fall on a fixed batch: {l0} -> {l1}");
    }

    #[test]
    fn transformer_spec_validation() {
        let mut s = tiny_gpt_spec();
        s.attn_heads = 3; // does not divide d_in = 8
        let err = NativeBackend::builder(s, Strategy::Bk).threads(1).build().unwrap_err().to_string();
        assert!(err.contains("attn_heads"), "{err}");
        let mut s = tiny_gpt_spec();
        s.vocab = 0;
        s.n_classes = 11;
        let err = NativeBackend::builder(s, Strategy::Bk).threads(1).build().unwrap_err().to_string();
        assert!(err.contains("vocab"), "{err}");
        let mut s = tiny_gpt_spec();
        s.ff = 0;
        assert!(NativeBackend::builder(s, Strategy::Bk).threads(1).build().is_err());
        // tying is a transformer-head property: no blocks, no tie
        let mut s = tiny_tok_spec();
        s.tied = true;
        let err = NativeBackend::builder(s, Strategy::Bk).threads(1).build().unwrap_err().to_string();
        assert!(err.contains("tied"), "{err}");
    }

    #[test]
    fn every_registry_model_builds_with_consistent_census() {
        for spec in NativeSpec::registry() {
            let be = NativeBackend::builder(spec.clone(), Strategy::Bk).threads(1).build().unwrap();
            assert_eq!(be.info().n_params, spec.n_params(), "{}", spec.name);
            assert_eq!(
                be.tensor_groups().len(),
                be.info().param_names.len(),
                "{}",
                spec.name
            );
        }
    }

    #[test]
    fn tied_gpt_shares_one_canonical_tensor() {
        let spec = tiny_tied_gpt_spec();
        let be = NativeBackend::builder(spec.clone(), Strategy::Bk)
            .style(ClippingStyle::LayerWise)
            .threads(2)
            .build()
            .unwrap();
        let untied = NativeBackend::builder(tiny_gpt_spec(), Strategy::Bk)
            .style(ClippingStyle::LayerWise)
            .threads(2)
            .build()
            .unwrap();
        // one tensor fewer than untied (head_w + head_b collapse into
        // emb_w), and the state census follows the canonical tensors
        assert_eq!(
            be.info().param_names.len() + 2,
            untied.info().param_names.len()
        );
        assert_eq!(be.info().n_params, spec.n_params());
        assert_eq!(be.tensor_groups().len(), be.info().param_names.len());
        // layer-wise groups count *owner* layers only: the tied head
        // inherits the embedding's group instead of minting its own
        assert_eq!(be.n_clip_groups() + 1, untied.n_clip_groups());
        // the shared tensor's group id equals the embedding's (group 0)
        assert_eq!(be.tensor_groups()[0], 0);
    }

    #[test]
    fn tied_gpt_trains_and_norms_include_cross_term() {
        let spec = tiny_tied_gpt_spec();
        let (x, y) = batch_for(&spec, 23);
        let mut be = NativeBackend::builder(spec.clone(), Strategy::Bk).threads(2).build().unwrap();
        be.init(5).unwrap();
        let sq = be.per_sample_sq_norms(&x, &y).unwrap();
        assert_eq!(sq.len(), spec.batch);
        assert!(sq.iter().all(|v| v.is_finite() && *v >= 0.0));
        // the cross term is live: zeroing it (an untied backend run on
        // the same tied parameter values would omit it) must change the
        // norms — here we just check training works end-to-end
        let l0 = be.eval_loss(&x, &y).unwrap();
        let mut h = hyper();
        h.lr = 0.2;
        for _ in 0..40 {
            be.step(&x, &y, &[], &h).unwrap();
        }
        let l1 = be.eval_loss(&x, &y).unwrap();
        assert!(l1 < l0, "tied gpt loss should fall on a fixed batch: {l0} -> {l1}");
    }

    #[test]
    fn tied_norms_differ_from_an_untied_twin() {
        // Run the tied backend against an untied twin that *loads the
        // tied parameters* (head_w := emb_w^T, head_b := 0). Both
        // compute the identical forward, but the tied norms carry the
        // `2<G_emb, G_head>` cross term (and no head-bias term), so the
        // per-sample norms must differ — proving the shared-tensor
        // sensitivity is not just the sum of the two layers' norms.
        // (The exact decomposition identity is pinned by the FD golden
        // in tests/tied_golden.rs and the differential harness oracle.)
        let tied_spec = tiny_tied_gpt_spec();
        let (x, y) = batch_for(&tied_spec, 29);
        let mut tb = NativeBackend::builder(tied_spec.clone(), Strategy::Bk).threads(2).build().unwrap();
        tb.init(7).unwrap();
        let tied_params = tb.state().unwrap();

        // untied twin with head_w = emb_w^T, head_b = 0
        let untied_spec = tiny_gpt_spec();
        let mut ub = NativeBackend::builder(untied_spec.clone(), Strategy::Bk).threads(2).build().unwrap();
        let names = untied_spec.info().param_names;
        let emb_w = tied_params[0].clone();
        let (vocab, d) = (untied_spec.vocab, untied_spec.d_in);
        let mut head_w = vec![0.0f32; d * vocab];
        for v in 0..vocab {
            for j in 0..d {
                head_w[j * vocab + v] = emb_w[v * d + j];
            }
        }
        let mut untied_params = Vec::new();
        let mut it = tied_params.iter();
        for name in &names {
            match name.as_str() {
                "head_w" => untied_params.push(head_w.clone()),
                "head_b" => untied_params.push(vec![0.0f32; vocab]),
                _ => untied_params.push(it.next().unwrap().clone()),
            }
        }
        ub.load_state(untied_params).unwrap();

        let sq_tied = tb.per_sample_sq_norms(&x, &y).unwrap();
        let sq_untied = ub.per_sample_sq_norms(&x, &y).unwrap();
        // same forward function => same losses
        let lt = tb.eval_loss(&x, &y).unwrap();
        let lu = ub.eval_loss(&x, &y).unwrap();
        assert!((lt - lu).abs() < 1e-5, "tied and tied-by-hand forwards differ: {lt} vs {lu}");
        // the tied norm differs from the untied sum by exactly the
        // cross term; it must be non-trivial for at least one sample
        let mut any_cross = false;
        for i in 0..tied_spec.batch {
            let diff = sq_tied[i] - sq_untied[i];
            assert!(diff.is_finite());
            if diff.abs() > 1e-4 * sq_tied[i].abs().max(1e-3) {
                any_cross = true;
            }
        }
        assert!(any_cross, "cross term never fired: {sq_tied:?} vs {sq_untied:?}");
    }

    #[test]
    fn rejects_bad_shapes_and_tokens() {
        let mut be = NativeBackend::builder(tiny_spec(), Strategy::Bk).threads(1).build().unwrap();
        be.init(0).unwrap();
        let bad_x = BatchX::F32(vec![0.0; 5]);
        assert!(be.step(&bad_x, &[0; 4], &[], &hyper()).is_err());
        let (x, _) = batch_for(&tiny_spec(), 1);
        assert!(be.step(&x, &[0; 3], &[], &hyper()).is_err());
        let tok = BatchX::I32(vec![0; 32]);
        assert!(be.eval_loss(&tok, &[0; 4]).is_err());

        // token models reject features and out-of-range ids
        let mut tb = NativeBackend::builder(tiny_tok_spec(), Strategy::Bk).threads(1).build().unwrap();
        tb.init(0).unwrap();
        let feats = BatchX::F32(vec![0.0; 4 * 5 * 6]);
        assert!(tb.eval_loss(&feats, &[0; 20]).is_err());
        let big = BatchX::I32(vec![99; 20]);
        let err = tb.eval_loss(&big, &[0; 20]).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn new_splits_clip_and_optimizer_errors() {
        let mut s = tiny_spec();
        s.clip_fn = "quantum".into();
        let err = NativeBackend::builder(s, Strategy::Bk).threads(1).build().unwrap_err().to_string();
        assert!(err.contains("unknown clip_fn 'quantum'"), "{err}");
        assert!(err.contains("abadi"), "lists the valid clip_fns: {err}");
        assert!(!err.contains("optimizer"), "clip error must not mention optimizers: {err}");

        let mut s = tiny_spec();
        s.optimizer = "lion".into();
        let err = NativeBackend::builder(s, Strategy::Bk).threads(1).build().unwrap_err().to_string();
        assert!(err.contains("unknown optimizer 'lion'"), "{err}");
        assert!(err.contains("sgd"), "lists the valid optimizers: {err}");
        assert!(!err.contains("clip_fn"), "optimizer error must not mention clip_fn: {err}");
    }

    #[test]
    fn state_roundtrip_restores_params() {
        let (x, y) = batch_for(&tiny_spec(), 2);
        let mut a = NativeBackend::builder(tiny_spec(), Strategy::Bk).threads(1).build().unwrap();
        a.init(8).unwrap();
        a.step(&x, &y, &[], &hyper()).unwrap();
        let snap = a.state().unwrap();
        let la = a.eval_loss(&x, &y).unwrap();
        let mut b = NativeBackend::builder(tiny_spec(), Strategy::Bk).threads(1).build().unwrap();
        b.load_state(snap).unwrap();
        let lb = b.eval_loss(&x, &y).unwrap();
        assert_eq!(la, lb);
        let mut c = NativeBackend::builder(tiny_spec(), Strategy::Bk).threads(1).build().unwrap();
        assert!(c.load_state(vec![vec![0.0; 1]]).is_err());
    }

    #[test]
    fn bias_only_freezes_weights_and_trains() {
        let mut spec = tiny_tok_spec();
        spec.optimizer = "adam".into();
        spec.trainable = "bias-only".into();
        let (x, y) = batch_for(&spec, 31);
        let mut be = NativeBackend::builder(spec.clone(), Strategy::Bk).threads(2).build().unwrap();
        be.init(5).unwrap();
        let info = be.info().clone();
        // 1-D tensors train, 2-D tensors freeze
        for (i, n) in info.param_names.iter().enumerate() {
            assert_eq!(info.trainable[i], info.param_shapes[n].len() == 1, "{n}");
        }
        let before = be.state().unwrap();
        let l0 = be.eval_loss(&x, &y).unwrap();
        let mut h = hyper();
        h.lr = 0.5;
        for _ in 0..25 {
            be.step(&x, &y, &[], &h).unwrap();
        }
        let l1 = be.eval_loss(&x, &y).unwrap();
        assert!(l1 < l0, "bias-only loss should fall on a fixed batch: {l0} -> {l1}");
        let after = be.state().unwrap();
        let mut any_moved = false;
        for (i, n) in info.param_names.iter().enumerate() {
            if info.trainable[i] {
                any_moved |= before[i] != after[i];
            } else {
                assert_eq!(before[i], after[i], "frozen tensor '{n}' moved");
            }
        }
        assert!(any_moved, "no trainable tensor moved in 25 steps");
    }

    #[test]
    fn lora_adapters_train_while_base_stays_frozen() {
        let mut spec = tiny_gpt_spec();
        spec.trainable = "lora:2".into();
        let (x, y) = batch_for(&spec, 37);
        let mut be = NativeBackend::builder(spec.clone(), Strategy::Bk).threads(2).build().unwrap();
        be.init(5).unwrap();
        let info = be.info().clone();
        for (i, n) in info.param_names.iter().enumerate() {
            assert_eq!(
                info.trainable[i],
                n.ends_with("_lora_a") || n.ends_with("_lora_b"),
                "{n}"
            );
        }
        let before = be.state().unwrap();
        let l0 = be.eval_loss(&x, &y).unwrap();
        let mut h = hyper();
        h.lr = 0.5;
        for _ in 0..40 {
            be.step(&x, &y, &[], &h).unwrap();
        }
        let l1 = be.eval_loss(&x, &y).unwrap();
        assert!(l1 < l0, "lora loss should fall on a fixed batch: {l0} -> {l1}");
        let after = be.state().unwrap();
        for (i, n) in info.param_names.iter().enumerate() {
            if info.trainable[i] {
                assert_ne!(before[i], after[i], "adapter '{n}' never moved");
            } else {
                assert_eq!(before[i], after[i], "frozen tensor '{n}' moved");
            }
        }
    }

    #[test]
    fn wpe_model_trains_all_strategies() {
        let mut spec = tiny_gpt_spec();
        spec.wpe = true;
        for strat in [Strategy::Opacus, Strategy::GhostClip, Strategy::Bk, Strategy::BkMixOpt] {
            let (x, y) = batch_for(&spec, 41);
            let mut be = NativeBackend::builder(spec.clone(), strat).threads(2).build().unwrap();
            be.init(5).unwrap();
            let l0 = be.eval_loss(&x, &y).unwrap();
            let mut h = hyper();
            h.lr = 0.3;
            for _ in 0..30 {
                be.step(&x, &y, &[], &h).unwrap();
            }
            let l1 = be.eval_loss(&x, &y).unwrap();
            assert!(l1 < l0, "{strat:?}: wpe loss should fall: {l0} -> {l1}");
        }
        // wpe without token input is a spec error
        let mut s = tiny_spec();
        s.wpe = true;
        let err = NativeBackend::builder(s, Strategy::Bk).threads(1).build().unwrap_err().to_string();
        assert!(err.contains("wpe"), "{err}");
    }

    #[test]
    fn masked_runs_reach_arena_steady_state() {
        for (mut spec, preset) in [
            (tiny_tok_spec(), "bias-only"),
            (tiny_gpt_spec(), "lora:2"),
            (tiny_tied_gpt_spec(), "bias-only"),
        ] {
            spec.trainable = preset.into();
            for strat in [Strategy::Opacus, Strategy::GhostClip, Strategy::Bk, Strategy::BkMixOpt] {
                for style in [ClippingStyle::AllLayer, ClippingStyle::LayerWise] {
                    let (x, y) = batch_for(&spec, 9);
                    let mut be =
                        NativeBackend::builder(spec.clone(), strat).style(style).threads(2).build().unwrap();
                    be.init(1).unwrap();
                    be.step(&x, &y, &[], &hyper()).unwrap();
                    for _ in 0..3 {
                        be.step(&x, &y, &[], &hyper()).unwrap();
                        assert_eq!(
                            be.alloc_stats().fresh_allocs_last_step,
                            0,
                            "{}/{preset}/{strat:?}/{style:?}: steady-state step must not allocate",
                            spec.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn frozen_stacks_shrink_scratch_and_opt_state() {
        // the frozen-layer skip must show up in measured allocation:
        // bias-only drops the Gram/partials scratch (arena peak) and the
        // frozen slots' Adam moments (opt_state_floats)
        let mut full = tiny_gpt_spec();
        full.optimizer = "adam".into();
        let mut bias = full.clone();
        bias.trainable = "bias-only".into();
        let run = |spec: &NativeSpec| -> AllocStats {
            let (x, y) = batch_for(spec, 43);
            let mut be = NativeBackend::builder(spec.clone(), Strategy::Bk).threads(2).build().unwrap();
            be.init(1).unwrap();
            be.step(&x, &y, &[], &hyper()).unwrap();
            be.alloc_stats()
        };
        let f = run(&full);
        let b = run(&bias);
        assert!(
            b.arena_peak_floats < f.arena_peak_floats,
            "bias-only arena peak {} must drop below full {}",
            b.arena_peak_floats,
            f.arena_peak_floats
        );
        assert!(
            b.opt_state_floats < f.opt_state_floats,
            "bias-only opt state {} must drop below full {}",
            b.opt_state_floats,
            f.opt_state_floats
        );
        // bias-only layers still book-keep their full-width output
        // gradient (the bias sum reads it), so under flat clipping the
        // g-cache peak matches full fine-tuning — it must not grow
        assert!(
            b.peak_gcache_floats <= f.peak_gcache_floats,
            "bias-only g-cache peak {} must not exceed full {}",
            b.peak_gcache_floats,
            f.peak_gcache_floats
        );
        // lora freezes attention/norm/embedding outright — those layers
        // keep no caches at all, so the peak strictly drops
        let mut lora = full.clone();
        lora.trainable = "lora:2".into();
        let l = run(&lora);
        assert!(
            l.peak_gcache_floats < f.peak_gcache_floats,
            "lora g-cache peak {} must drop below full {}",
            l.peak_gcache_floats,
            f.peak_gcache_floats
        );
        assert!(
            l.opt_state_floats < f.opt_state_floats,
            "lora opt state {} must drop below full {}",
            l.opt_state_floats,
            f.opt_state_floats
        );
    }

    #[test]
    fn mask_all_layers_is_fully_trainable_bitwise() {
        // freezing nothing (a mask listing every parameterized layer)
        // must be bitwise identical to the default fully trainable run
        let spec = tiny_gpt_spec();
        let all_names: Vec<String> = spec
            .plan()
            .iter()
            .filter(|l| !l.param_names.is_empty())
            .map(|l| l.name.clone())
            .collect();
        let mut masked = spec.clone();
        masked.trainable = format!("mask:{}", all_names.join(","));
        let (x, y) = batch_for(&spec, 47);
        let run = |s: &NativeSpec| -> Vec<Vec<f32>> {
            let mut be = NativeBackend::builder(s.clone(), Strategy::Bk).threads(2).build().unwrap();
            be.init(4).unwrap();
            let mut out = StepOut::default();
            for _ in 0..3 {
                out = be.step(&x, &y, &[], &hyper()).unwrap();
            }
            assert!(out.mean_clip.is_finite());
            be.state().unwrap()
        };
        assert_eq!(run(&spec), run(&masked), "explicit all-layer mask must match default");
    }

    #[test]
    fn group_wise_one_group_is_all_layer_bitwise() {
        // group-wise:1 must be exactly flat clipping (R_1 = R) — with
        // tying too: the shared tensor's combined norm feeds one factor.
        for spec in [tiny_spec(), tiny_tok_spec(), tiny_gpt_spec(), tiny_tied_gpt_spec()] {
            let (x, y) = batch_for(&spec, 21);
            let run = |style: ClippingStyle| -> Vec<Vec<f32>> {
                let mut be = NativeBackend::builder(spec.clone(), Strategy::Bk).style(style).threads(2).build().unwrap();
                be.init(4).unwrap();
                be.step(&x, &y, &[], &hyper()).unwrap();
                be.state().unwrap()
            };
            assert_eq!(
                run(ClippingStyle::AllLayer),
                run(ClippingStyle::GroupWise(1)),
                "{}: group-wise:1 must match all-layer bitwise",
                spec.name
            );
        }
    }

    #[test]
    fn layer_wise_is_group_wise_n_bitwise() {
        let spec = tiny_tok_spec();
        let n_param_layers = spec.plan().iter().filter(|l| !l.param_names.is_empty()).count();
        let (x, y) = batch_for(&spec, 22);
        let run = |style: ClippingStyle| -> Vec<Vec<f32>> {
            let mut be = NativeBackend::builder(spec.clone(), Strategy::Bk).style(style).threads(2).build().unwrap();
            be.init(4).unwrap();
            be.step(&x, &y, &[], &hyper()).unwrap();
            be.state().unwrap()
        };
        assert_eq!(
            run(ClippingStyle::LayerWise),
            run(ClippingStyle::GroupWise(n_param_layers)),
            "layer-wise must equal group-wise:{n_param_layers} bitwise"
        );
    }
}
