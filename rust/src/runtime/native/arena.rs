//! Step-scoped buffer arena for the native backend.
//!
//! Every per-step tensor (activations, output-gradient caches, Gram
//! matrices, per-sample norms, reduction partials, gradient accumulators)
//! is checked out of the arena at the start of a step and returned at the
//! end. Shapes are static for a given (model, strategy) pair, so after
//! the first step the pool holds exactly the buffer set a step needs and
//! steady-state heap allocation is **zero** — the paper's "<1% memory
//! overhead" claim becomes an assertable invariant instead of a hope.
//! [`Arena::fresh_allocs`] reports how many pool misses the current step
//! incurred; the bench harness and tests assert it is 0 once warm.
//!
//! The arena also keeps a per-step **high-water mark** of checked-out
//! floats ([`Arena::peak_outstanding_elems`]): two schedules that check
//! out the same buffer *set* but with different lifetimes (the fused
//! vs. unfused group-wise clipped-sum walk) differ exactly in this
//! number, so the memory saving of early g-cache release is measured,
//! not just predicted by the complexity engine.

/// A recycling pool of `Vec<f32>` buffers.
#[derive(Debug, Default)]
pub struct Arena {
    free: Vec<Vec<f32>>,
    /// Buffers created because no pooled one fit (current step).
    fresh: usize,
    /// Total f32 capacity ever allocated through this arena.
    total_elems: usize,
    /// Buffers currently checked out (sanity/leak accounting).
    outstanding: usize,
    /// Floats currently checked out (sum of requested lengths).
    out_elems: usize,
    /// High-water mark of `out_elems` since `begin_step`.
    peak_elems: usize,
}

impl Arena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark the start of a step: resets the per-step miss counter and
    /// the checked-out-floats high-water mark.
    pub fn begin_step(&mut self) {
        self.fresh = 0;
        self.peak_elems = self.out_elems;
    }

    /// Check a zeroed buffer of exactly `len` elements out of the pool.
    ///
    /// Best-fit over pooled capacities; a miss allocates fresh (counted).
    ///
    /// `take(0)` returns a non-pooled empty vec and touches no
    /// accounting: best-fit would otherwise hand out the *smallest
    /// pooled buffer* for a zero-length request, cascading every later
    /// take in the step onto mismatched capacities (the pooled-buffer
    /// steal trap the token-input placeholder in `layers::StackRun::
    /// forward` used to have to dodge by hand). [`Arena::give`]
    /// symmetrically ignores capacity-0 buffers.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        self.outstanding += 1;
        self.out_elems += len;
        self.peak_elems = self.peak_elems.max(self.out_elems);
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len {
                let better = match best {
                    Some(j) => b.capacity() < self.free[j].capacity(),
                    None => true,
                };
                if better {
                    best = Some(i);
                }
            }
        }
        match best {
            Some(i) => {
                let mut b = self.free.swap_remove(i);
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => {
                self.fresh += 1;
                self.total_elems += len;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer to the pool. Capacity-0 buffers (placeholders
    /// and `take(0)` results) are dropped, not pooled — they were never
    /// counted as outstanding.
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        self.outstanding = self.outstanding.saturating_sub(1);
        self.out_elems = self.out_elems.saturating_sub(buf.len());
        self.free.push(buf);
    }

    /// Return several buffers at once.
    pub fn give_all(&mut self, bufs: Vec<Vec<f32>>) {
        for b in bufs {
            self.give(b);
        }
    }

    /// Pool misses (fresh heap allocations) since `begin_step`.
    pub fn fresh_allocs(&self) -> usize {
        self.fresh
    }

    /// Total bytes ever allocated through the arena.
    pub fn total_bytes(&self) -> usize {
        self.total_elems * std::mem::size_of::<f32>()
    }

    /// Buffers currently checked out.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Floats currently checked out (sum of requested lengths).
    pub fn outstanding_elems(&self) -> usize {
        self.out_elems
    }

    /// High-water mark of checked-out floats since `begin_step` — the
    /// measured peak working set of the step's buffer schedule.
    pub fn peak_outstanding_elems(&self) -> usize {
        self.peak_elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_step_has_zero_fresh_allocs() {
        let mut a = Arena::new();
        // step 1: cold pool
        a.begin_step();
        let x = a.take(128);
        let y = a.take(64);
        let z = a.take(128);
        assert_eq!(a.fresh_allocs(), 3);
        assert_eq!(a.outstanding(), 3);
        a.give(x);
        a.give(y);
        a.give(z);
        assert_eq!(a.outstanding(), 0);
        // step 2: identical request sequence is fully served by the pool
        a.begin_step();
        let x = a.take(128);
        let y = a.take(64);
        let z = a.take(128);
        assert_eq!(a.fresh_allocs(), 0, "steady state must not allocate");
        a.give_all(vec![x, y, z]);
    }

    #[test]
    fn buffers_come_back_zeroed_and_sized() {
        let mut a = Arena::new();
        let mut x = a.take(16);
        for v in x.iter_mut() {
            *v = 7.0;
        }
        a.give(x);
        let y = a.take(8);
        assert_eq!(y.len(), 8);
        assert!(y.iter().all(|&v| v == 0.0), "reused buffer must be zeroed");
        a.give(y);
    }

    #[test]
    fn take_zero_is_a_non_pooled_noop() {
        // The pooled-buffer steal trap: take(0) must NOT best-fit the
        // smallest pooled buffer (that would cascade later takes onto
        // mismatched capacities), and give()-ing the empty result must
        // not corrupt the accounting.
        let mut a = Arena::new();
        let small = a.take(8);
        a.give(small);
        a.begin_step();
        let z = a.take(0);
        assert_eq!(z.capacity(), 0, "take(0) must not steal a pooled buffer");
        assert_eq!(a.fresh_allocs(), 0);
        assert_eq!(a.outstanding(), 0, "take(0) is not outstanding");
        a.give(z);
        assert_eq!(a.outstanding(), 0, "give(empty) must not underflow accounting");
        // the pooled 8-cap buffer is still there for a real request
        let again = a.take(4);
        assert_eq!(a.fresh_allocs(), 0, "pool must still serve the real take");
        a.give(again);
    }

    #[test]
    fn high_water_mark_tracks_lifetimes_not_just_sizes() {
        // Two schedules over the same buffer set: holding both buffers
        // at once peaks at 96; releasing the first before taking the
        // second peaks at 64 — exactly the fused-vs-unfused g-cache
        // distinction the backend reports per step.
        let mut a = Arena::new();
        a.begin_step();
        let x = a.take(64);
        let y = a.take(32);
        assert_eq!(a.outstanding_elems(), 96);
        a.give(x);
        a.give(y);
        assert_eq!(a.peak_outstanding_elems(), 96);
        assert_eq!(a.outstanding_elems(), 0);

        a.begin_step();
        let x = a.take(64);
        a.give(x);
        let y = a.take(32);
        a.give(y);
        assert_eq!(a.peak_outstanding_elems(), 64, "early release lowers the peak");
        // take(0) placeholders stay invisible to the gauge
        let z = a.take(0);
        a.give(z);
        assert_eq!(a.peak_outstanding_elems(), 64);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut a = Arena::new();
        let big = a.take(1024);
        let small = a.take(32);
        a.give(big);
        a.give(small);
        a.begin_step();
        let b = a.take(16);
        // must have reused the 32-capacity buffer, not the 1024 one
        assert!(b.capacity() < 1024);
        assert_eq!(a.fresh_allocs(), 0);
        a.give(b);
    }
}
