//! Test-only reference: the pre-refactor monolithic Linear+ReLU
//! Book-Keeping step, kept verbatim so the composable [`super::layers`]
//! tape can be pinned **bitwise** against it (`clipping_style =
//! all-layer` must reproduce the monolithic path exactly — same kernel
//! calls, same float-op order). Compiled under `cfg(test)` only.

use super::arena::Arena;
use super::kernels::{self, ClipKind};
use super::layers::NormRoute;
use super::model::NativeSpec;
use crate::complexity::{ghost_preferred, Strategy};
use crate::runtime::StepHyper;
use crate::util::rng::{GaussianSource, Xoshiro256};

/// The legacy monolithic backend (MLP stacks only: `vocab == 0`,
/// `layernorm == false`).
pub(crate) struct ReferenceBackend {
    spec: NativeSpec,
    strategy: Strategy,
    clip_kind: ClipKind,
    routes: Vec<NormRoute>,
    store_psg: Vec<bool>,
    threads: usize,
    params: Vec<Vec<f32>>,
    opt_m: Vec<Vec<f32>>,
    opt_v: Vec<Vec<f32>>,
    arena: Arena,
}

impl ReferenceBackend {
    pub fn new(spec: NativeSpec, strategy: Strategy, threads: usize) -> Self {
        assert_eq!(spec.vocab, 0, "reference path is Linear+ReLU only");
        assert!(!spec.layernorm, "reference path is Linear+ReLU only");
        let clip_kind = ClipKind::parse(&spec.clip_fn).unwrap();
        let layers = spec.arch_layers();
        let routes: Vec<NormRoute> = layers
            .iter()
            .map(|l| match strategy {
                Strategy::Opacus | Strategy::FastGradClip => NormRoute::Inst,
                Strategy::GhostClip | Strategy::Bk | Strategy::NonDp => NormRoute::Ghost,
                Strategy::MixGhostClip | Strategy::BkMixGhostClip | Strategy::BkMixOpt => {
                    if ghost_preferred(l) {
                        NormRoute::Ghost
                    } else {
                        NormRoute::Inst
                    }
                }
            })
            .collect();
        let store_psg: Vec<bool> = routes
            .iter()
            .map(|r| match strategy {
                Strategy::Opacus => true,
                Strategy::BkMixOpt => *r == NormRoute::Inst,
                _ => false,
            })
            .collect();
        let info = spec.info();
        let zeros = || -> Vec<Vec<f32>> {
            info.param_names
                .iter()
                .map(|n| vec![0.0; info.param_shapes[n].iter().product()])
                .collect()
        };
        let params = zeros();
        let (opt_m, opt_v) = if info.is_adam() { (zeros(), zeros()) } else { (Vec::new(), Vec::new()) };
        Self {
            spec,
            strategy,
            clip_kind,
            routes,
            store_psg,
            threads,
            params,
            opt_m,
            opt_v,
            arena: Arena::new(),
        }
    }

    pub fn init(&mut self, seed: u64) {
        let root = Xoshiro256::new(seed ^ 0x1A17_F00D);
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        for (l, &(d, _)) in dims.iter().enumerate() {
            let scale = if l + 1 < nl {
                (2.0 / d as f32).sqrt()
            } else {
                0.05 * (1.0 / d as f32).sqrt()
            };
            let mut gs = GaussianSource::from_rng(root.fork(l as u64 + 1));
            let w = &mut self.params[2 * l];
            gs.fill_f32(w);
            for v in w.iter_mut() {
                *v *= scale;
            }
            for v in self.params[2 * l + 1].iter_mut() {
                *v = 0.0;
            }
        }
    }

    pub fn state(&self) -> Vec<Vec<f32>> {
        let mut out = self.params.clone();
        out.extend(self.opt_m.iter().cloned());
        out.extend(self.opt_v.iter().cloned());
        out
    }

    fn rows(&self) -> usize {
        self.spec.batch * self.spec.seq
    }

    fn max_dp(&self) -> usize {
        self.spec.layer_widths().iter().map(|&(d, p)| d * p).max().unwrap_or(1)
    }

    fn max_p(&self) -> usize {
        self.spec.layer_widths().iter().map(|&(_, p)| p).max().unwrap_or(1)
    }

    fn two_pass(&self) -> bool {
        matches!(
            self.strategy,
            Strategy::FastGradClip | Strategy::GhostClip | Strategy::MixGhostClip
        )
    }

    fn forward(&mut self, x: &[f32]) -> Vec<Vec<f32>> {
        let rows = self.rows();
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl + 1);
        let mut a0 = self.arena.take(rows * dims[0].0);
        a0.copy_from_slice(x);
        acts.push(a0);
        for &(_, p) in &dims {
            acts.push(self.arena.take(rows * p));
        }
        for (l, &(d, p)) in dims.iter().enumerate() {
            let (head, tail) = acts.split_at_mut(l + 1);
            kernels::linear_forward(
                &head[l],
                &self.params[2 * l],
                Some(&self.params[2 * l + 1]),
                &mut tail[0],
                rows,
                d,
                p,
                self.threads,
            );
            if l + 1 < nl {
                kernels::relu_forward(&mut tail[0]);
            }
        }
        acts
    }

    /// One full legacy step (compute clipped grads + optimizer update);
    /// returns (mean loss, mean clip factor).
    pub fn step(&mut self, x: &[f32], y: &[i32], noise: &[Vec<f32>], h: &StepHyper) -> (f32, f32) {
        self.arena.begin_step();
        let sizes: Vec<usize> = self.params.iter().map(Vec::len).collect();
        let mut grads: Vec<Vec<f32>> = sizes.into_iter().map(|n| self.arena.take(n)).collect();
        let rows = self.rows();
        let b = self.spec.batch;
        let t = self.spec.seq;
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let c_out = dims[nl - 1].1;
        let threads = self.threads;
        let workers = threads.max(1).min(b.max(1));

        let mut acts = self.forward(x);

        let (loss, mean_clip) = if self.strategy == Strategy::NonDp {
            let mut g = self.arena.take(rows * c_out);
            let loss = kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
            let mut partials = self.arena.take(workers * self.max_dp());
            for l in (0..nl).rev() {
                let (d, p) = dims[l];
                kernels::weighted_grad(
                    &acts[l], &g, None, b, t, d, p, &mut partials, &mut grads[2 * l], threads,
                );
                kernels::bias_grad(&g, None, b, t, p, &mut grads[2 * l + 1]);
                if l > 0 {
                    let mut g_prev = self.arena.take(rows * d);
                    kernels::backward_data(&g, &self.params[2 * l], &mut g_prev, rows, d, p, threads);
                    kernels::relu_backward(&mut g_prev, &acts[l]);
                    self.arena.give(std::mem::replace(&mut g, g_prev));
                }
            }
            self.arena.give(g);
            self.arena.give(partials);
            (loss, 1.0)
        } else if self.two_pass() {
            self.grads_two_pass(&acts, y, h.clip, &mut grads)
        } else {
            self.grads_one_pass(&acts, y, h.clip, &mut grads)
        };

        while let Some(a) = acts.pop() {
            self.arena.give(a);
        }

        // optimizer update (identical kernels)
        let adam = self.spec.optimizer == "adam";
        for k in 0..self.params.len() {
            let z = if noise.is_empty() { None } else { Some(noise[k].as_slice()) };
            if adam {
                kernels::adam_update(
                    &mut self.params[k],
                    &mut self.opt_m[k],
                    &mut self.opt_v[k],
                    &grads[k],
                    z,
                    h.lr,
                    h.sigma_r,
                    h.logical_batch,
                    h.step,
                );
            } else {
                kernels::sgd_update(&mut self.params[k], &grads[k], z, h.lr, h.sigma_r, h.logical_batch);
            }
        }
        self.arena.give_all(grads);
        (loss / rows as f32, mean_clip)
    }

    fn grads_two_pass(
        &mut self,
        acts: &[Vec<f32>],
        y: &[i32],
        clip: f32,
        grads: &mut [Vec<f32>],
    ) -> (f32, f32) {
        let b = self.spec.batch;
        let t = self.spec.seq;
        let rows = self.rows();
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let c_out = dims[nl - 1].1;
        let threads = self.threads;
        let workers = threads.max(1).min(b.max(1));

        let need_gram = t > 1 && self.routes.iter().any(|r| *r == NormRoute::Ghost);
        let need_stream = self.routes.iter().any(|r| *r == NormRoute::Inst);
        let mut gram_a = if need_gram { self.arena.take(b * t * t) } else { Vec::new() };
        let mut gram_g = if need_gram { self.arena.take(b * t * t) } else { Vec::new() };
        let mut stream = if need_stream {
            self.arena.take(workers * self.max_dp())
        } else {
            Vec::new()
        };
        let mut bias_scratch = self.arena.take(workers * self.max_p());
        let mut sq = self.arena.take(b);

        let mut g = self.arena.take(rows * c_out);
        let loss = kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
        for l in (0..nl).rev() {
            let (d, p) = dims[l];
            match self.routes[l] {
                NormRoute::Ghost => kernels::ghost_norm(
                    &acts[l], &g, b, t, d, p, &mut gram_a, &mut gram_g, &mut sq, threads,
                ),
                NormRoute::Inst => kernels::psg_norms_streaming(
                    &acts[l], &g, b, t, d, p, &mut stream, &mut sq, threads,
                ),
            }
            kernels::bias_sq_norms(&g, b, t, p, &mut bias_scratch, &mut sq, threads);
            if l > 0 {
                let mut g_prev = self.arena.take(rows * d);
                kernels::backward_data(&g, &self.params[2 * l], &mut g_prev, rows, d, p, threads);
                kernels::relu_backward(&mut g_prev, &acts[l]);
                self.arena.give(std::mem::replace(&mut g, g_prev));
            }
        }
        self.arena.give(g);

        let mut cfac = self.arena.take(b);
        kernels::clip_factors(&sq, clip, self.clip_kind, &mut cfac);
        let mean_clip = cfac.iter().sum::<f32>() / b as f32;

        let mut partials = self.arena.take(workers * self.max_dp());
        let mut g = self.arena.take(rows * c_out);
        kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(&mut g));
        for l in (0..nl).rev() {
            let (d, p) = dims[l];
            kernels::weighted_grad(
                &acts[l],
                &g,
                Some(&cfac),
                b,
                t,
                d,
                p,
                &mut partials,
                &mut grads[2 * l],
                threads,
            );
            kernels::bias_grad(&g, Some(&cfac), b, t, p, &mut grads[2 * l + 1]);
            if l > 0 {
                let mut g_prev = self.arena.take(rows * d);
                kernels::backward_data(&g, &self.params[2 * l], &mut g_prev, rows, d, p, threads);
                kernels::relu_backward(&mut g_prev, &acts[l]);
                self.arena.give(std::mem::replace(&mut g, g_prev));
            }
        }
        self.arena.give(g);
        self.arena.give(partials);
        self.arena.give(cfac);
        self.arena.give(sq);
        self.arena.give(bias_scratch);
        if need_stream {
            self.arena.give(stream);
        }
        if need_gram {
            self.arena.give(gram_g);
            self.arena.give(gram_a);
        }
        (loss, mean_clip)
    }

    fn grads_one_pass(
        &mut self,
        acts: &[Vec<f32>],
        y: &[i32],
        clip: f32,
        grads: &mut [Vec<f32>],
    ) -> (f32, f32) {
        let b = self.spec.batch;
        let t = self.spec.seq;
        let rows = self.rows();
        let dims = self.spec.layer_widths();
        let nl = dims.len();
        let c_out = dims[nl - 1].1;
        let threads = self.threads;
        let workers = threads.max(1).min(b.max(1));

        let need_gram = t > 1 && self.routes.iter().any(|r| *r == NormRoute::Ghost);
        let need_stream = self
            .routes
            .iter()
            .zip(&self.store_psg)
            .any(|(r, s)| *r == NormRoute::Inst && !s);
        let mut gram_a = if need_gram { self.arena.take(b * t * t) } else { Vec::new() };
        let mut gram_g = if need_gram { self.arena.take(b * t * t) } else { Vec::new() };
        let mut stream = if need_stream {
            self.arena.take(workers * self.max_dp())
        } else {
            Vec::new()
        };
        let mut bias_scratch = self.arena.take(workers * self.max_p());
        let mut sq = self.arena.take(b);
        let mut psg: Vec<Option<Vec<f32>>> = Vec::with_capacity(nl);
        for l in 0..nl {
            let (d, p) = dims[l];
            if self.store_psg[l] {
                psg.push(Some(self.arena.take(b * d * p)));
            } else {
                psg.push(None);
            }
        }

        let mut gcache: Vec<Vec<f32>> = dims.iter().map(|&(_, p)| self.arena.take(rows * p)).collect();
        let loss = {
            let top = &mut gcache[nl - 1];
            kernels::softmax_xent(&acts[nl], y, rows, c_out, Some(top))
        };
        for l in (0..nl).rev() {
            let (d, p) = dims[l];
            match (self.routes[l], psg[l].as_mut()) {
                (NormRoute::Inst, Some(store)) => {
                    kernels::psg_instantiate(&acts[l], &gcache[l], b, t, d, p, store, threads);
                    kernels::sq_norms_from_psg(store, b, d * p, &mut sq, threads);
                }
                (NormRoute::Inst, None) => kernels::psg_norms_streaming(
                    &acts[l], &gcache[l], b, t, d, p, &mut stream, &mut sq, threads,
                ),
                (NormRoute::Ghost, _) => kernels::ghost_norm(
                    &acts[l], &gcache[l], b, t, d, p, &mut gram_a, &mut gram_g, &mut sq, threads,
                ),
            }
            kernels::bias_sq_norms(&gcache[l], b, t, p, &mut bias_scratch, &mut sq, threads);
            if l > 0 {
                let (lo, hi) = gcache.split_at_mut(l);
                kernels::backward_data(&hi[0], &self.params[2 * l], &mut lo[l - 1], rows, d, p, threads);
                kernels::relu_backward(&mut lo[l - 1], &acts[l]);
            }
        }

        let mut cfac = self.arena.take(b);
        kernels::clip_factors(&sq, clip, self.clip_kind, &mut cfac);
        let mean_clip = cfac.iter().sum::<f32>() / b as f32;

        let mut partials = self.arena.take(workers * self.max_dp());
        for l in (0..nl).rev() {
            let (d, p) = dims[l];
            match &psg[l] {
                Some(store) => {
                    kernels::weighted_sum_psg(store, &cfac, b, d, p, &mut grads[2 * l], threads)
                }
                None => kernels::weighted_grad(
                    &acts[l],
                    &gcache[l],
                    Some(&cfac),
                    b,
                    t,
                    d,
                    p,
                    &mut partials,
                    &mut grads[2 * l],
                    threads,
                ),
            }
            kernels::bias_grad(&gcache[l], Some(&cfac), b, t, p, &mut grads[2 * l + 1]);
        }

        self.arena.give(partials);
        self.arena.give(cfac);
        self.arena.give_all(gcache);
        for slot in psg.into_iter().flatten() {
            self.arena.give(slot);
        }
        self.arena.give(sq);
        self.arena.give(bias_scratch);
        if need_stream {
            self.arena.give(stream);
        }
        if need_gram {
            self.arena.give(gram_g);
            self.arena.give(gram_a);
        }
        (loss, mean_clip)
    }
}

// ---- golden tests: tape(all-layer) == monolith, bitwise ----------------

#[cfg(test)]
mod golden {
    use super::super::NativeBackend;
    use super::*;
    use crate::runtime::{Backend, BatchX};

    fn batch_for(spec: &NativeSpec, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let rows = spec.batch * spec.seq;
        let mut rng = Xoshiro256::new(seed);
        let x: Vec<f32> = (0..rows * spec.d_in).map(|_| rng.next_f32() - 0.5).collect();
        let y: Vec<i32> = (0..rows)
            .map(|_| rng.next_below(spec.n_classes as u64) as i32)
            .collect();
        (x, y)
    }

    fn noise_for(spec: &NativeSpec, seed: u64) -> Vec<Vec<f32>> {
        let info = spec.info();
        let mut ns = crate::coordinator::noise::NoiseSource::new(seed);
        ns.tensors(&info)
    }

    /// The acceptance gate of the refactor: a seeded step through the
    /// composable DpLayer tape under `all-layer` clipping is
    /// bitwise-identical to the pre-refactor monolithic path — same
    /// init, same loss bits, same mean clip bits, same updated state —
    /// for every strategy, on both golden models.
    #[test]
    fn tape_matches_monolith_bitwise() {
        for model in ["mlp_e2e", "seq_e2e"] {
            let spec = NativeSpec::by_name(model).unwrap();
            let (x, y) = batch_for(&spec, 41);
            let noise = noise_for(&spec, 99);
            let h = StepHyper {
                lr: 0.05,
                clip: 1.0,
                sigma_r: 0.5,
                logical_batch: spec.batch as f32,
                step: 1.0,
            };
            for strat in [
                Strategy::NonDp,
                Strategy::Opacus,
                Strategy::FastGradClip,
                Strategy::GhostClip,
                Strategy::MixGhostClip,
                Strategy::Bk,
                Strategy::BkMixGhostClip,
                Strategy::BkMixOpt,
            ] {
                let threads = 3;
                let nondp = strat == Strategy::NonDp;
                let noise_s: &[Vec<f32>] = if nondp { &[] } else { &noise };
                let hs = StepHyper {
                    sigma_r: if nondp { 0.0 } else { h.sigma_r },
                    ..h
                };

                let mut new = NativeBackend::builder(spec.clone(), strat).threads(threads).build().unwrap();
                new.init(17).unwrap();
                let mut old = ReferenceBackend::new(spec.clone(), strat, threads);
                old.init(17);
                assert_eq!(new.state().unwrap(), old.state(), "{model}/{strat:?}: init differs");

                let out = new.step(&BatchX::F32(x.clone()), &y, noise_s, &hs).unwrap();
                let (old_loss, old_clip) = old.step(&x, &y, noise_s, &hs);
                assert_eq!(out.loss.to_bits(), old_loss.to_bits(), "{model}/{strat:?}: loss bits");
                assert_eq!(
                    out.mean_clip.to_bits(),
                    old_clip.to_bits(),
                    "{model}/{strat:?}: mean_clip bits"
                );
                assert_eq!(
                    new.state().unwrap(),
                    old.state(),
                    "{model}/{strat:?}: post-step state must be bitwise identical"
                );
            }
        }
    }
}
