//! Thread fan-out for the native kernels.
//!
//! The environment has no rayon, so parallelism is built on
//! `std::thread::scope`: each parallel region splits its *output* buffer
//! into disjoint `&mut` chunks (rows of a matrix, samples of a batch) and
//! hands one chunk per worker. Inputs are shared as `&[f32]`. This keeps
//! every kernel data-race-free by construction — no worker ever writes
//! memory another can see — and makes results deterministic for a fixed
//! thread count (reductions merge per-worker partials in worker order).
//!
//! Spawn cost is a few microseconds per region; the kernels only fan out
//! when the work comfortably amortizes it (see `MIN_ROWS_PER_THREAD`).

/// Below this many rows per worker a parallel region runs serially.
const MIN_ROWS_PER_THREAD: usize = 8;

/// Default worker count: one per available core, capped to keep spawn
/// overhead sane on very wide machines.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Split `out` into per-worker chunks of whole rows (`row_w` elements per
/// row) and run `f(first_row, chunk)` on each chunk, in parallel when
/// `rows` is large enough. `f` sees disjoint `&mut` windows of `out`.
pub fn par_rows<F>(out: &mut [f32], rows: usize, row_w: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_w);
    let t = threads.max(1).min(rows.max(1));
    if t == 1 || rows < 2 * MIN_ROWS_PER_THREAD {
        f(0, out);
        return;
    }
    let rows_per = (rows + t - 1) / t;
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows_per * row_w).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * rows_per, chunk));
        }
    });
}

/// Fan a batch reduction out over workers: `out_chunks` is split by
/// `chunk_out` rows (of width `out_w`), `scratch` provides one disjoint
/// `scratch_w`-sized accumulator per worker. `f(first_item, out_chunk,
/// scratch_chunk)` runs once per worker. Used by kernels whose output is
/// per-sample (norms) or that reduce over the batch into per-worker
/// partial buffers.
pub fn par_batch<F>(
    out: &mut [f32],
    items: usize,
    out_w: usize,
    scratch: &mut [f32],
    scratch_w: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, usize, &mut [f32], &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), items * out_w);
    let t = threads.max(1).min(items.max(1));
    if t == 1 || items < 2 {
        let sw = scratch_w.min(scratch.len());
        f(0, items, out, &mut scratch[..sw]);
        return;
    }
    debug_assert!(scratch.len() >= t * scratch_w);
    let items_per = (items + t - 1) / t;
    std::thread::scope(|s| {
        let mut rest = scratch;
        for (ci, chunk) in out.chunks_mut(items_per * out_w).enumerate() {
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(scratch_w);
            rest = tail;
            let f = &f;
            let n_items = chunk.len() / out_w.max(1);
            s.spawn(move || f(ci * items_per, n_items, chunk, mine));
        }
    });
}

/// Reduce over `items` with one disjoint `scratch_w`-sized accumulator
/// per worker: `f(first_item, n_items, accumulator)` runs once per
/// worker. The caller merges the per-worker accumulators afterwards (in
/// worker order, keeping the reduction deterministic).
pub fn par_reduce<F>(items: usize, scratch: &mut [f32], scratch_w: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let t = threads.max(1).min(items.max(1));
    if t == 1 {
        let sw = scratch_w.min(scratch.len());
        f(0, items, &mut scratch[..sw]);
        return;
    }
    debug_assert!(scratch.len() >= t * scratch_w);
    let per = (items + t - 1) / t;
    std::thread::scope(|s| {
        let mut rest = scratch;
        let mut i0 = 0;
        while i0 < items {
            let n = per.min(items - i0);
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(scratch_w);
            rest = tail;
            let f = &f;
            s.spawn(move || f(i0, n, mine));
            i0 += n;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_rows_covers_all_rows() {
        let rows = 103;
        let w = 7;
        let mut out = vec![0f32; rows * w];
        par_rows(&mut out, rows, w, 4, |r0, chunk| {
            for (ri, row) in chunk.chunks_mut(w).enumerate() {
                for x in row.iter_mut() {
                    *x = (r0 + ri) as f32;
                }
            }
        });
        for r in 0..rows {
            for j in 0..w {
                assert_eq!(out[r * w + j], r as f32, "row {r}");
            }
        }
    }

    #[test]
    fn par_rows_serial_small() {
        let mut out = vec![0f32; 3 * 2];
        par_rows(&mut out, 3, 2, 8, |r0, chunk| {
            assert_eq!(r0, 0);
            assert_eq!(chunk.len(), 6);
            chunk[0] = 1.0;
        });
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn par_batch_reduces_with_scratch() {
        // Sum i..i+1 per item into out, and count items per worker in
        // scratch slot 0 — verifies disjoint scratch distribution.
        let items = 37;
        let threads = 5;
        let mut out = vec![0f32; items];
        let mut scratch = vec![0f32; threads];
        par_batch(&mut out, items, 1, &mut scratch, 1, threads, |i0, n, o, s| {
            for (k, slot) in o.iter_mut().enumerate() {
                *slot = (i0 + k) as f32;
            }
            s[0] = n as f32;
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
        let counted: f32 = scratch.iter().sum();
        assert_eq!(counted, items as f32);
    }

    #[test]
    fn par_reduce_partials_sum_to_total() {
        // Sum of 0..items via per-worker partials.
        let items = 101usize;
        let threads = 4;
        let mut scratch = vec![0f32; threads];
        par_reduce(items, &mut scratch, 1, threads, |i0, n, acc| {
            for i in i0..i0 + n {
                acc[0] += i as f32;
            }
        });
        let total: f32 = scratch.iter().sum();
        assert_eq!(total, (items * (items - 1) / 2) as f32);
    }
}
