//! Thread fan-out for the native kernels.
//!
//! The environment has no rayon, so parallelism is built on
//! `std::thread::scope`: each parallel region splits its *output* buffer
//! into disjoint `&mut` chunks (rows of a matrix, samples of a batch) and
//! hands one chunk per worker. Inputs are shared as `&[f32]`. This keeps
//! every kernel data-race-free by construction — no worker ever writes
//! memory another can see — and makes results deterministic for a fixed
//! thread count (reductions merge per-worker partials in worker order;
//! the split itself is a pure function of `(items, threads)`).
//!
//! Splits are **balanced**: `items` divides into ranges whose sizes
//! differ by at most one (the first `items % workers` workers take one
//! extra). The old ceil-split handed the last worker anywhere from half
//! a share to a double share on ragged counts — the slowest worker sets
//! the region's wall time, so the ragged tail was pure loss.
//!
//! Spawn cost is a few microseconds per region. Row regions
//! (`par_rows`) are work-size-aware: the worker count is capped so every
//! worker owns at least `MIN_ROWS_PER_THREAD` rows, degenerating to a
//! serial call for small outputs. Batch regions (`par_batch` /
//! `par_reduce`) keep one worker per item up to `threads` — their items
//! (per-sample norms, gradient reductions) are heavyweight enough to
//! amortize a spawn each.

/// Minimum rows a `par_rows` worker must own; fewer rows than
/// `2 * MIN_ROWS_PER_THREAD` runs serially.
const MIN_ROWS_PER_THREAD: usize = 8;

/// Default worker count: one per available core. There is no hard cap —
/// `--threads N` (config `threads`) is the way to bound fan-out on wide
/// machines, and the work-size-aware splits below already keep small
/// regions from spawning more workers than their rows can feed.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Sizes of the balanced partition of `items` into `workers` consecutive
/// ranges: `base = items / workers` each, the first `items % workers`
/// ranges getting one extra. Public because the sharded driver reuses
/// the same balanced split for its micro-batch ranges.
pub fn split_sizes(items: usize, workers: usize) -> impl Iterator<Item = usize> {
    let base = items / workers;
    let extra = items % workers;
    (0..workers).map(move |w| base + usize::from(w < extra))
}

/// Work-size-aware worker count for row regions: never more than
/// `threads`, never more than one worker per `MIN_ROWS_PER_THREAD` rows.
fn row_workers(rows: usize, threads: usize) -> usize {
    threads
        .max(1)
        .min(rows.max(1))
        .min((rows / MIN_ROWS_PER_THREAD).max(1))
}

/// Split `out` into per-worker chunks of whole rows (`row_w` elements per
/// row) and run `f(first_row, chunk)` on each chunk, in parallel when
/// `rows` is large enough. `f` sees disjoint `&mut` windows of `out`.
pub fn par_rows<F>(out: &mut [f32], rows: usize, row_w: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_w);
    let t = row_workers(rows, threads);
    if t == 1 {
        f(0, out);
        return;
    }
    std::thread::scope(|s| {
        let mut rest = out;
        let mut r0 = 0usize;
        for n in split_sizes(rows, t) {
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(n * row_w);
            rest = tail;
            let f = &f;
            let first = r0;
            s.spawn(move || f(first, mine));
            r0 += n;
        }
    });
}

/// Fan a batch reduction out over workers: `out` is split by items (of
/// width `out_w`), `scratch` provides one disjoint `scratch_w`-sized
/// accumulator per worker. `f(first_item, n_items, out_chunk,
/// scratch_chunk)` runs once per worker. Used by kernels whose output is
/// per-sample (norms) or that reduce over the batch into per-worker
/// partial buffers.
pub fn par_batch<F>(
    out: &mut [f32],
    items: usize,
    out_w: usize,
    scratch: &mut [f32],
    scratch_w: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, usize, &mut [f32], &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), items * out_w);
    let t = threads.max(1).min(items.max(1));
    if t == 1 || items < 2 {
        let sw = scratch_w.min(scratch.len());
        f(0, items, out, &mut scratch[..sw]);
        return;
    }
    debug_assert!(scratch.len() >= t * scratch_w);
    std::thread::scope(|s| {
        let mut out_rest = out;
        let mut rest = scratch;
        let mut i0 = 0usize;
        for n in split_sizes(items, t) {
            let (chunk, out_tail) = std::mem::take(&mut out_rest).split_at_mut(n * out_w);
            out_rest = out_tail;
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(scratch_w);
            rest = tail;
            let f = &f;
            let first = i0;
            s.spawn(move || f(first, n, chunk, mine));
            i0 += n;
        }
    });
}

/// Reduce over `items` with one disjoint `scratch_w`-sized accumulator
/// per worker: `f(first_item, n_items, accumulator)` runs once per
/// worker. The caller merges the per-worker accumulators afterwards (in
/// worker order, keeping the reduction deterministic).
pub fn par_reduce<F>(items: usize, scratch: &mut [f32], scratch_w: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let t = threads.max(1).min(items.max(1));
    if t == 1 {
        let sw = scratch_w.min(scratch.len());
        f(0, items, &mut scratch[..sw]);
        return;
    }
    debug_assert!(scratch.len() >= t * scratch_w);
    std::thread::scope(|s| {
        let mut rest = scratch;
        let mut i0 = 0usize;
        for n in split_sizes(items, t) {
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(scratch_w);
            rest = tail;
            let f = &f;
            let first = i0;
            s.spawn(move || f(first, n, mine));
            i0 += n;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn split_sizes_are_balanced() {
        // The ragged case the old ceil-split got wrong: 17 rows over 4
        // workers was [5, 5, 5, 2]; balanced is [5, 4, 4, 4].
        assert_eq!(split_sizes(17, 4).collect::<Vec<_>>(), vec![5, 4, 4, 4]);
        assert_eq!(split_sizes(21, 4).collect::<Vec<_>>(), vec![6, 5, 5, 5]);
        assert_eq!(split_sizes(8, 4).collect::<Vec<_>>(), vec![2, 2, 2, 2]);
        assert_eq!(split_sizes(5, 5).collect::<Vec<_>>(), vec![1, 1, 1, 1, 1]);
        for (items, workers) in [(17usize, 4usize), (103, 7), (64, 16), (9, 2)] {
            let sizes: Vec<usize> = split_sizes(items, workers).collect();
            assert_eq!(sizes.iter().sum::<usize>(), items);
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "{items}/{workers}: {sizes:?}");
        }
    }

    #[test]
    fn par_rows_chunks_are_balanced() {
        // 35 rows over 4 threads: work-size cap allows all 4 workers
        // (35 / MIN_ROWS_PER_THREAD = 4) and the split is [9, 9, 9, 8].
        let rows = 35;
        let w = 3;
        let mut out = vec![0f32; rows * w];
        let seen = Mutex::new(Vec::new());
        par_rows(&mut out, rows, w, 4, |r0, chunk| {
            seen.lock().unwrap().push((r0, chunk.len() / w));
        });
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![(0, 9), (9, 9), (18, 9), (27, 8)]);
    }

    #[test]
    fn par_rows_covers_all_rows() {
        let rows = 103;
        let w = 7;
        let mut out = vec![0f32; rows * w];
        par_rows(&mut out, rows, w, 4, |r0, chunk| {
            for (ri, row) in chunk.chunks_mut(w).enumerate() {
                for x in row.iter_mut() {
                    *x = (r0 + ri) as f32;
                }
            }
        });
        for r in 0..rows {
            for j in 0..w {
                assert_eq!(out[r * w + j], r as f32, "row {r}");
            }
        }
    }

    #[test]
    fn par_rows_serial_small() {
        let mut out = vec![0f32; 3 * 2];
        par_rows(&mut out, 3, 2, 8, |r0, chunk| {
            assert_eq!(r0, 0);
            assert_eq!(chunk.len(), 6);
            chunk[0] = 1.0;
        });
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn par_rows_worker_count_is_work_size_aware() {
        // 16 rows with 8 threads offered: only 2 workers spawn, each
        // owning MIN_ROWS_PER_THREAD rows.
        assert_eq!(row_workers(16, 8), 2);
        assert_eq!(row_workers(7, 8), 1);
        assert_eq!(row_workers(1024, 8), 8);
        assert_eq!(row_workers(0, 4), 1);
    }

    #[test]
    fn par_batch_reduces_with_scratch() {
        // Sum i..i+1 per item into out, and count items per worker in
        // scratch slot 0 — verifies disjoint scratch distribution.
        let items = 37;
        let threads = 5;
        let mut out = vec![0f32; items];
        let mut scratch = vec![0f32; threads];
        par_batch(&mut out, items, 1, &mut scratch, 1, threads, |i0, n, o, s| {
            for (k, slot) in o.iter_mut().enumerate() {
                *slot = (i0 + k) as f32;
            }
            s[0] = n as f32;
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
        let counted: f32 = scratch.iter().sum();
        assert_eq!(counted, items as f32);
        // balanced: 37 over 5 → [8, 8, 7, 7, 7]
        let mut sizes: Vec<f32> = scratch.clone();
        sizes.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(sizes, vec![8.0, 8.0, 7.0, 7.0, 7.0]);
    }

    #[test]
    fn par_reduce_partials_sum_to_total() {
        // Sum of 0..items via per-worker partials.
        let items = 101usize;
        let threads = 4;
        let mut scratch = vec![0f32; threads];
        par_reduce(items, &mut scratch, 1, threads, |i0, n, acc| {
            for i in i0..i0 + n {
                acc[0] += i as f32;
            }
        });
        let total: f32 = scratch.iter().sum();
        assert_eq!(total, (items * (items - 1) / 2) as f32);
    }

    #[test]
    fn default_threads_is_uncapped_core_count() {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        assert_eq!(default_threads(), cores);
    }
}
