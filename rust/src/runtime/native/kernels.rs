//! Native Book-Keeping kernels: the DP hot path as plain Rust.
//!
//! These mirror the reference semantics of `python/compile/kernels/ref.py`
//! (the jnp oracles) with the paper's (B, T, d, p) shape conventions:
//!
//! * `a` — layer-input activations, `(B, T, d)` flattened row-major
//! * `g` — output gradients of the **summed** loss, `(B, T, p)`
//! * `c` — per-sample clip factors, `(B,)`
//!
//! Performance model (see DESIGN.md):
//! * every inner loop bottoms out in the wide-lane primitives of
//!   `simd` (`dot` / `axpy` / `axpy4`) — `[f32; LANES]` chunk
//!   accumulators with runtime-detected `core::arch` specializations;
//! * matmuls are register-tiled (the `MR`-row tile of `backward_data`,
//!   the 4-way `axpy4` reduction unroll of the forward) and cache-
//!   blocked over the reduction dimension (`KC`), fanning out over
//!   rows / the batch via `par`;
//! * reductions over the batch accumulate into per-worker partial
//!   buffers merged in worker order;
//! * no kernel allocates: all scratch is passed in by the caller (the
//!   backend checks it out of the step arena).
//!
//! Determinism contract: for a fixed thread count, instruction set
//! (`simd::active_isa`), lane width, and tile config, every kernel is a
//! pure function of its inputs — step results are bitwise reproducible
//! run-to-run. Changing any of those knobs may change final bits (lane
//! reassociation, FMA contraction, different reduction split), which is
//! why golden/bitwise tests pin the configuration rather than compare
//! across configurations.
//!
//! The clipped-weighted-sum kernel is shared by every DP strategy, so
//! two strategies given bitwise-identical clip factors produce
//! bitwise-identical clipped gradients (asserted in
//! `tests/native_kernels.rs`).

#![allow(clippy::too_many_arguments)]

use super::par;
use super::simd;
use super::simd::dot;

/// Reduction-dimension cache block (the `KC` of an MR×NR×KC tiling):
/// keeps a block of weight rows hot in L1/L2 while streaming the row
/// chunk. 256 rows × a typical `p` fits comfortably in L2.
const KC: usize = 256;

/// Register-tile height: rows processed together so a streamed weight
/// row is reused `MR` times from registers/L1 instead of once.
const MR: usize = 4;

/// Forward: `out (rows, p) = a (rows, d) · w (d, p) [+ bias]`.
///
/// `rows = B*T`. Register-tiled i-k-j loop, threaded over rows: the
/// reduction dimension is cache-blocked by `KC` and unrolled 4-wide
/// through `simd::axpy4`, so the `out` row is loaded/stored once per
/// four weight rows instead of once per weight row. Groups whose four
/// coefficients are all zero are skipped (ReLU sparsity).
pub fn linear_forward(
    a: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
    rows: usize,
    d: usize,
    p: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), rows * d);
    debug_assert_eq!(w.len(), d * p);
    debug_assert_eq!(out.len(), rows * p);
    par::par_rows(out, rows, p, threads, |r0, chunk| {
        for out_row in chunk.chunks_mut(p) {
            match bias {
                Some(b) => out_row.copy_from_slice(b),
                None => out_row.fill(0.0),
            }
        }
        let n_rows = chunk.len() / p;
        for j0 in (0..d).step_by(KC) {
            let j1 = (j0 + KC).min(d);
            for ri in 0..n_rows {
                let a_row = &a[(r0 + ri) * d..(r0 + ri) * d + d];
                let out_row = &mut chunk[ri * p..ri * p + p];
                let mut j = j0;
                while j + 4 <= j1 {
                    let c = [a_row[j], a_row[j + 1], a_row[j + 2], a_row[j + 3]];
                    if c != [0.0; 4] {
                        simd::axpy4(
                            c,
                            &w[j * p..j * p + p],
                            &w[(j + 1) * p..(j + 1) * p + p],
                            &w[(j + 2) * p..(j + 2) * p + p],
                            &w[(j + 3) * p..(j + 3) * p + p],
                            out_row,
                        );
                    }
                    j += 4;
                }
                while j < j1 {
                    let av = a_row[j];
                    if av != 0.0 {
                        simd::axpy(av, &w[j * p..j * p + p], out_row);
                    }
                    j += 1;
                }
            }
        }
    });
}

/// Backward (data): `da (rows, d) = g (rows, p) · w^T`, i.e.
/// `da[r, j] = g[r, :] · w[j, :]` — contiguous dot products.
///
/// Register-tiled `MR` rows at a time: each streamed weight row feeds
/// `MR` dots while it is hot, cutting the weight-matrix traffic by
/// `MR`x. Every element is still one `simd::dot` of the same operands,
/// so the result is independent of the tiling.
pub fn backward_data(
    g: &[f32],
    w: &[f32],
    da: &mut [f32],
    rows: usize,
    d: usize,
    p: usize,
    threads: usize,
) {
    debug_assert_eq!(g.len(), rows * p);
    debug_assert_eq!(w.len(), d * p);
    debug_assert_eq!(da.len(), rows * d);
    par::par_rows(da, rows, d, threads, |r0, chunk| {
        let mut blocks = chunk.chunks_exact_mut(MR * d);
        let mut r = r0;
        for block in &mut blocks {
            let (da0, rest) = block.split_at_mut(d);
            let (da1, rest) = rest.split_at_mut(d);
            let (da2, da3) = rest.split_at_mut(d);
            let g0 = &g[r * p..r * p + p];
            let g1 = &g[(r + 1) * p..(r + 1) * p + p];
            let g2 = &g[(r + 2) * p..(r + 2) * p + p];
            let g3 = &g[(r + 3) * p..(r + 3) * p + p];
            for j in 0..d {
                let w_row = &w[j * p..j * p + p];
                da0[j] = dot(g0, w_row);
                da1[j] = dot(g1, w_row);
                da2[j] = dot(g2, w_row);
                da3[j] = dot(g3, w_row);
            }
            r += MR;
        }
        for da_row in blocks.into_remainder().chunks_mut(d) {
            let g_row = &g[r * p..r * p + p];
            for (j, slot) in da_row.iter_mut().enumerate() {
                *slot = dot(g_row, &w[j * p..j * p + p]);
            }
            r += 1;
        }
    });
}

/// ReLU forward, in place.
pub fn relu_forward(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: zero `da` wherever the *post-activation* is zero.
pub fn relu_backward(da: &mut [f32], act: &[f32]) {
    debug_assert_eq!(da.len(), act.len());
    for (d, &a) in da.iter_mut().zip(act) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Row-wise softmax cross-entropy with integer labels.
///
/// Returns the loss **summed** over rows (the per-sample-clipping
/// convention: L = sum_i L_i). When `g` is given, writes the gradient of
/// the summed loss: `g = softmax(logits) - onehot(y)`.
pub fn softmax_xent(
    logits: &[f32],
    y: &[i32],
    rows: usize,
    c: usize,
    mut g: Option<&mut [f32]>,
) -> f32 {
    debug_assert_eq!(logits.len(), rows * c);
    debug_assert_eq!(y.len(), rows);
    let mut loss = 0.0f64;
    for r in 0..rows {
        let row = &logits[r * c..r * c + c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &v in row {
            z += (v - m).exp();
        }
        let yi = y[r] as usize;
        debug_assert!(yi < c, "label {yi} out of range {c}");
        loss += (z.ln() - (row[yi] - m)) as f64;
        if let Some(gbuf) = g.as_deref_mut() {
            let grow = &mut gbuf[r * c..r * c + c];
            for (gq, &v) in grow.iter_mut().zip(row) {
                *gq = (v - m).exp() / z;
            }
            grow[yi] -= 1.0;
        }
    }
    loss as f32
}

/// Ghost norm (paper Eq. 2, module 3 of Table 3): accumulates the
/// per-sample squared Frobenius norm of `dL_i/dW` into `sq[i]` **without
/// forming the gradient**, from the activation and output-gradient Gram
/// matrices: `||dL_i/dW||^2 = sum_{t,s} (a_t·a_s)(g_t·g_s)`.
///
/// Time `O(B T^2 (p+d))`, scratch `2 B T^2` (`gram_a`, `gram_g`). For
/// `t == 1` the Grams are scalars and the norm factorizes to
/// `||a_i||^2 ||g_i||^2` in `O(B (p+d))` with no scratch touched.
pub fn ghost_norm(
    a: &[f32],
    g: &[f32],
    b: usize,
    t: usize,
    d: usize,
    p: usize,
    gram_a: &mut [f32],
    gram_g: &mut [f32],
    sq: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(a.len(), b * t * d);
    debug_assert_eq!(g.len(), b * t * p);
    debug_assert_eq!(sq.len(), b);
    if t == 1 {
        for i in 0..b {
            let a2 = dot(&a[i * d..(i + 1) * d], &a[i * d..(i + 1) * d]);
            let g2 = dot(&g[i * p..(i + 1) * p], &g[i * p..(i + 1) * p]);
            sq[i] += a2 * g2;
        }
        return;
    }
    debug_assert!(gram_a.len() >= b * t * t);
    debug_assert!(gram_g.len() >= b * t * t);
    gram_of(a, b, t, d, gram_a, threads);
    gram_of(g, b, t, p, gram_g, threads);
    par::par_rows(sq, b, 1, threads, |i0, chunk| {
        for (k, slot) in chunk.iter_mut().enumerate() {
            let i = i0 + k;
            *slot += dot(
                &gram_a[i * t * t..(i + 1) * t * t],
                &gram_g[i * t * t..(i + 1) * t * t],
            );
        }
    });
}

/// Per-sample Gram matrices: `gram[i, t1, t2] = x_i[t1, :] · x_i[t2, :]`
/// for `x (b, t, w)`. Symmetric — computes the upper triangle and
/// mirrors. Threaded over the batch.
fn gram_of(x: &[f32], b: usize, t: usize, w: usize, gram: &mut [f32], threads: usize) {
    par::par_rows(gram, b, t * t, threads, |i0, chunk| {
        for (k, gm) in chunk.chunks_mut(t * t).enumerate() {
            let xi = &x[(i0 + k) * t * w..(i0 + k + 1) * t * w];
            for t1 in 0..t {
                let r1 = &xi[t1 * w..(t1 + 1) * w];
                for t2 in t1..t {
                    let v = dot(r1, &xi[t2 * w..(t2 + 1) * w]);
                    gm[t1 * t + t2] = v;
                    gm[t2 * t + t1] = v;
                }
            }
        }
    });
}

/// Per-sample gradient instantiation (module 4): `psg[i] = a_i^T g_i`,
/// stored `(b, d, p)`. Time `O(B T p d)`, space `B p d` — the route the
/// mixed decision picks when `2T^2 >= pd`.
pub fn psg_instantiate(
    a: &[f32],
    g: &[f32],
    b: usize,
    t: usize,
    d: usize,
    p: usize,
    psg: &mut [f32],
    threads: usize,
) {
    let dp = d * p;
    debug_assert_eq!(psg.len(), b * dp);
    par::par_rows(psg, b, dp, threads, |i0, chunk| {
        for (k, pg) in chunk.chunks_mut(dp).enumerate() {
            pg.fill(0.0);
            let i = i0 + k;
            for tt in 0..t {
                let row = i * t + tt;
                let a_row = &a[row * d..row * d + d];
                let g_row = &g[row * p..row * p + p];
                for (j, &av) in a_row.iter().enumerate() {
                    if av != 0.0 {
                        simd::axpy(av, g_row, &mut pg[j * p..j * p + p]);
                    }
                }
            }
        }
    });
}

/// Accumulate `sq[i] += ||psg_i||^2` from stored per-sample gradients.
pub fn sq_norms_from_psg(psg: &[f32], b: usize, n_per: usize, sq: &mut [f32], threads: usize) {
    debug_assert_eq!(psg.len(), b * n_per);
    debug_assert_eq!(sq.len(), b);
    par::par_rows(sq, b, 1, threads, |i0, chunk| {
        for (k, slot) in chunk.iter_mut().enumerate() {
            let s = &psg[(i0 + k) * n_per..(i0 + k + 1) * n_per];
            *slot += dot(s, s);
        }
    });
}

/// Instantiation-route norms **without** storing all per-sample grads:
/// each worker materializes one `d*p` gradient at a time in its scratch
/// slice and accumulates its squared norm. `scratch >= workers * d * p`.
pub fn psg_norms_streaming(
    a: &[f32],
    g: &[f32],
    b: usize,
    t: usize,
    d: usize,
    p: usize,
    scratch: &mut [f32],
    sq: &mut [f32],
    threads: usize,
) {
    let dp = d * p;
    debug_assert_eq!(sq.len(), b);
    par::par_batch(sq, b, 1, scratch, dp, threads, |i0, n, sqc, scr| {
        for k in 0..n {
            let i = i0 + k;
            scr.fill(0.0);
            for tt in 0..t {
                let row = i * t + tt;
                let a_row = &a[row * d..row * d + d];
                let g_row = &g[row * p..row * p + p];
                for (j, &av) in a_row.iter().enumerate() {
                    if av != 0.0 {
                        simd::axpy(av, g_row, &mut scr[j * p..j * p + p]);
                    }
                }
            }
            sqc[k] += dot(scr, scr);
        }
    });
}

/// Book-keeping weighted sum (module 5 fused with the parameter-gradient
/// contraction): `out (d, p) += sum_i c_i a_i^T g_i`, with `c_i = 1` when
/// `c` is `None` (the non-DP parameter gradient).
///
/// Fans out over the batch into per-worker `d*p` partials (`partials >=
/// workers * d * p`), merged in worker order. This single kernel computes
/// the clipped gradient for **every** strategy, so identical clip factors
/// yield bitwise-identical gradients across strategies.
pub fn weighted_grad(
    a: &[f32],
    g: &[f32],
    c: Option<&[f32]>,
    b: usize,
    t: usize,
    d: usize,
    p: usize,
    partials: &mut [f32],
    out: &mut [f32],
    threads: usize,
) {
    let dp = d * p;
    debug_assert_eq!(out.len(), dp);
    let accum = |acc: &mut [f32], i0: usize, n: usize| {
        for i in i0..i0 + n {
            let ci = match c {
                Some(cs) => cs[i],
                None => 1.0,
            };
            if ci == 0.0 {
                continue;
            }
            for tt in 0..t {
                let row = i * t + tt;
                let a_row = &a[row * d..row * d + d];
                let g_row = &g[row * p..row * p + p];
                for (j, &av) in a_row.iter().enumerate() {
                    let s = ci * av;
                    if s != 0.0 {
                        simd::axpy(s, g_row, &mut acc[j * p..j * p + p]);
                    }
                }
            }
        }
    };
    let workers = threads.max(1).min(b.max(1));
    if workers <= 1 || b < 2 {
        accum(out, 0, b);
        return;
    }
    debug_assert!(partials.len() >= workers * dp);
    let used = workers * dp;
    partials[..used].fill(0.0);
    par::par_reduce(b, &mut partials[..used], dp, workers, |i0, n, acc| accum(acc, i0, n));
    for wk in 0..workers {
        let src = &partials[wk * dp..(wk + 1) * dp];
        for (o, &s) in out.iter_mut().zip(src) {
            *o += s;
        }
    }
}

/// Weighted sum from **stored** per-sample gradients (BK-MixOpt reuses
/// the instantiation done for the norms): `out += sum_i c_i psg_i`.
///
/// The batch reduction is unrolled 4 samples wide (`simd::axpy4`), so
/// each output chunk is loaded/stored once per four samples; groups
/// whose four clip factors are all zero are skipped (flat clipping).
pub fn weighted_sum_psg(
    psg: &[f32],
    c: &[f32],
    b: usize,
    d: usize,
    p: usize,
    out: &mut [f32],
    threads: usize,
) {
    let dp = d * p;
    debug_assert_eq!(psg.len(), b * dp);
    debug_assert_eq!(out.len(), dp);
    par::par_rows(out, d, p, threads, |j0, chunk| {
        let base = |i: usize| i * dp + j0 * p;
        let mut i = 0usize;
        while i + 4 <= b {
            let cc = [c[i], c[i + 1], c[i + 2], c[i + 3]];
            if cc != [0.0; 4] {
                simd::axpy4(
                    cc,
                    &psg[base(i)..base(i) + chunk.len()],
                    &psg[base(i + 1)..base(i + 1) + chunk.len()],
                    &psg[base(i + 2)..base(i + 2) + chunk.len()],
                    &psg[base(i + 3)..base(i + 3) + chunk.len()],
                    chunk,
                );
            }
            i += 4;
        }
        while i < b {
            if c[i] != 0.0 {
                simd::axpy(c[i], &psg[base(i)..base(i) + chunk.len()], chunk);
            }
            i += 1;
        }
    });
}

/// Per-sample bias-gradient squared norms: `sq[i] += ||sum_t g_i[t,:]||^2`
/// (ghost and instantiation coincide for bias). `scratch >= workers * p`.
pub fn bias_sq_norms(
    g: &[f32],
    b: usize,
    t: usize,
    p: usize,
    scratch: &mut [f32],
    sq: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(sq.len(), b);
    par::par_batch(sq, b, 1, scratch, p, threads, |i0, n, sqc, scr| {
        for k in 0..n {
            let i = i0 + k;
            scr.fill(0.0);
            for tt in 0..t {
                let g_row = &g[(i * t + tt) * p..(i * t + tt) * p + p];
                simd::axpy(1.0, g_row, scr);
            }
            sqc[k] += dot(scr, scr);
        }
    });
}

/// Clipped bias-gradient sum: `out[q] += sum_i c_i sum_t g_i[t, q]`
/// (`c_i = 1` when `c` is `None`). Serial — `p` is tiny next to `d*p`.
pub fn bias_grad(g: &[f32], c: Option<&[f32]>, b: usize, t: usize, p: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), p);
    for i in 0..b {
        let ci = match c {
            Some(cs) => cs[i],
            None => 1.0,
        };
        if ci == 0.0 {
            continue;
        }
        for tt in 0..t {
            let g_row = &g[(i * t + tt) * p..(i * t + tt) * p + p];
            simd::axpy(ci, g_row, out);
        }
    }
}

/// LayerNorm variance epsilon (matches the PyTorch default).
pub const LN_EPS: f32 = 1e-5;

/// LayerNorm forward over the feature axis: for each of the `rows`
/// length-`d` rows, `out = gamma * (x - mu) / sqrt(var + eps) + beta`.
///
/// Caches `xhat` (the normalized input, `(rows, d)`) and `inv_std`
/// (`(rows,)`) for the backward pass. Serial: O(rows * d) is negligible
/// next to the matmuls on either side.
pub fn layernorm_forward(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    out: &mut [f32],
    xhat: &mut [f32],
    inv_std: &mut [f32],
    rows: usize,
    d: usize,
) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(gamma.len(), d);
    debug_assert_eq!(beta.len(), d);
    debug_assert_eq!(out.len(), rows * d);
    debug_assert_eq!(xhat.len(), rows * d);
    debug_assert_eq!(inv_std.len(), rows);
    for r in 0..rows {
        let xr = &x[r * d..r * d + d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let is = 1.0 / (var + LN_EPS).sqrt();
        inv_std[r] = is;
        for j in 0..d {
            let xh = (xr[j] - mu) * is;
            xhat[r * d + j] = xh;
            out[r * d + j] = gamma[j] * xh + beta[j];
        }
    }
}

/// LayerNorm backward (data): from `g` = dL/d out, with the cached
/// `xhat` and `inv_std`, writes `da` = dL/d x:
/// `da = inv_std * (g*gamma - mean(g*gamma) - xhat * mean(g*gamma*xhat))`.
pub fn layernorm_backward_data(
    g: &[f32],
    gamma: &[f32],
    xhat: &[f32],
    inv_std: &[f32],
    da: &mut [f32],
    rows: usize,
    d: usize,
) {
    debug_assert_eq!(g.len(), rows * d);
    debug_assert_eq!(da.len(), rows * d);
    for r in 0..rows {
        let gr = &g[r * d..r * d + d];
        let xh = &xhat[r * d..r * d + d];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for j in 0..d {
            let gx = gr[j] * gamma[j];
            m1 += gx;
            m2 += gx * xh[j];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let is = inv_std[r];
        for j in 0..d {
            let gx = gr[j] * gamma[j];
            da[r * d + j] = is * (gx - m1 - xh[j] * m2);
        }
    }
}

/// Per-sample squared norms of the LayerNorm (gamma, beta) gradients:
/// `sq[i] += ||sum_t g_i[t,:]*xhat_i[t,:]||^2 + ||sum_t g_i[t,:]||^2`.
/// Instantiation and ghost coincide for norm layers (params are `O(p)`);
/// every DP strategy takes this route. `scratch >= workers * 2p`.
pub fn ln_sq_norms(
    g: &[f32],
    xhat: &[f32],
    b: usize,
    t: usize,
    p: usize,
    scratch: &mut [f32],
    sq: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(g.len(), b * t * p);
    debug_assert_eq!(xhat.len(), b * t * p);
    debug_assert_eq!(sq.len(), b);
    par::par_batch(sq, b, 1, scratch, 2 * p, threads, |i0, n, sqc, scr| {
        for k in 0..n {
            let i = i0 + k;
            scr.fill(0.0);
            let (sg, sb) = scr.split_at_mut(p);
            for tt in 0..t {
                let row = (i * t + tt) * p;
                let g_row = &g[row..row + p];
                let x_row = &xhat[row..row + p];
                for j in 0..p {
                    sg[j] += g_row[j] * x_row[j];
                    sb[j] += g_row[j];
                }
            }
            sqc[k] += dot(sg, sg) + dot(sb, sb);
        }
    });
}

/// Clipped weighted LayerNorm gradient sums (`c_i = 1` when `c` is
/// `None`): `ggamma[j] += sum_i c_i sum_t g_i[t,j]*xhat_i[t,j]` and
/// `gbeta[j] += sum_i c_i sum_t g_i[t,j]`. Serial — `p` is tiny.
pub fn ln_weighted_grads(
    g: &[f32],
    xhat: &[f32],
    c: Option<&[f32]>,
    b: usize,
    t: usize,
    p: usize,
    ggamma: &mut [f32],
    gbeta: &mut [f32],
) {
    debug_assert_eq!(ggamma.len(), p);
    debug_assert_eq!(gbeta.len(), p);
    for i in 0..b {
        let ci = match c {
            Some(cs) => cs[i],
            None => 1.0,
        };
        if ci == 0.0 {
            continue;
        }
        for tt in 0..t {
            let row = (i * t + tt) * p;
            let g_row = &g[row..row + p];
            let x_row = &xhat[row..row + p];
            for j in 0..p {
                ggamma[j] += ci * g_row[j] * x_row[j];
                gbeta[j] += ci * g_row[j];
            }
        }
    }
}

/// Embedding forward: `out[r, :] = table[tokens[r], :]` for `rows` i32
/// token ids and a `(vocab, p)` table. Token bounds are validated by the
/// backend before the step starts.
pub fn embedding_forward(
    tokens: &[i32],
    table: &[f32],
    out: &mut [f32],
    rows: usize,
    p: usize,
    threads: usize,
) {
    debug_assert_eq!(tokens.len(), rows);
    debug_assert_eq!(out.len(), rows * p);
    par::par_rows(out, rows, p, threads, |r0, chunk| {
        for (ri, out_row) in chunk.chunks_mut(p).enumerate() {
            let tok = tokens[r0 + ri] as usize;
            out_row.copy_from_slice(&table[tok * p..tok * p + p]);
        }
    });
}

/// Embedding ghost norm: the per-sample embedding-gradient squared norm
/// without forming the `(vocab, p)` gradient. Rows of `dL_i/dW` collide
/// exactly where token ids repeat, so
/// `||dL_i/dW||^2 = sum_{t,s} 1[tok_t == tok_s] (g_t . g_s)` — the
/// token-equality mask playing the activation Gram's role
/// (`ghost_preferred` is always true for embeddings). Time `O(B T^2 p)`,
/// no scratch.
pub fn embedding_sq_norms(
    tokens: &[i32],
    g: &[f32],
    b: usize,
    t: usize,
    p: usize,
    sq: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(tokens.len(), b * t);
    debug_assert_eq!(g.len(), b * t * p);
    debug_assert_eq!(sq.len(), b);
    par::par_rows(sq, b, 1, threads, |i0, chunk| {
        for (k, slot) in chunk.iter_mut().enumerate() {
            let i = i0 + k;
            let mut acc = 0.0f32;
            for t1 in 0..t {
                let g1 = &g[(i * t + t1) * p..(i * t + t1) * p + p];
                for t2 in t1..t {
                    if tokens[i * t + t1] == tokens[i * t + t2] {
                        let v = dot(g1, &g[(i * t + t2) * p..(i * t + t2) * p + p]);
                        acc += if t1 == t2 { v } else { 2.0 * v };
                    }
                }
            }
            *slot += acc;
        }
    });
}

/// Clipped weighted embedding-gradient sum: scatter-add
/// `out[tokens[i,t], :] += c_i * g[i,t,:]` (`c_i = 1` when `c` is
/// `None`). Serial — the scatter is `O(B T p)` and rows collide.
pub fn embedding_weighted_grad(
    tokens: &[i32],
    g: &[f32],
    c: Option<&[f32]>,
    b: usize,
    t: usize,
    p: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(tokens.len(), b * t);
    debug_assert_eq!(g.len(), b * t * p);
    for i in 0..b {
        let ci = match c {
            Some(cs) => cs[i],
            None => 1.0,
        };
        if ci == 0.0 {
            continue;
        }
        for tt in 0..t {
            let tok = tokens[i * t + tt] as usize;
            let g_row = &g[(i * t + tt) * p..(i * t + tt) * p + p];
            simd::axpy(ci, g_row, &mut out[tok * p..tok * p + p]);
        }
    }
}

/// Ghost cross term for a tied embedding + transposed vocab head
/// (`lm_head = wte^T`, the GPT-2 tie). A sample's gradient with respect
/// to the shared `(vocab, d)` tensor is `G_i = G_emb_i + G_head_i`, so
/// its squared norm needs `2 <G_emb_i, G_head_i>` on top of the two
/// layers' own ghost norms. Expanding both gradients,
///
/// ```text
/// <G_emb_i, G_head_i>
///   = sum_{t1,t2} g_head_i[t2, tok_i[t1]] * (g_emb_i[t1,:] . x_head_i[t2,:])
/// ```
///
/// — a third Gram-structured contraction next to the embedding's
/// token-equality mask and the head's activation/gradient Grams, in
/// `O(B T^2 d)` time with **no** `(vocab, d)` gradient materialized and
/// no scratch. `sq[i] += 2 * cross_i`. Pinned to the FD-verified numpy
/// golden in `tests/tied_golden.rs` (`python/tools/gen_tied_golden.py`).
pub fn tied_cross_sq_norms(
    tokens: &[i32],
    g_emb: &[f32],
    x_head: &[f32],
    g_head: &[f32],
    b: usize,
    t: usize,
    d: usize,
    vocab: usize,
    sq: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(tokens.len(), b * t);
    debug_assert_eq!(g_emb.len(), b * t * d);
    debug_assert_eq!(x_head.len(), b * t * d);
    debug_assert_eq!(g_head.len(), b * t * vocab);
    debug_assert_eq!(sq.len(), b);
    par::par_rows(sq, b, 1, threads, |i0, chunk| {
        for (k, slot) in chunk.iter_mut().enumerate() {
            let i = i0 + k;
            let mut acc = 0.0f32;
            for t1 in 0..t {
                let tok = tokens[i * t + t1] as usize;
                debug_assert!(tok < vocab);
                let ge = &g_emb[(i * t + t1) * d..(i * t + t1) * d + d];
                for t2 in 0..t {
                    let gh = g_head[(i * t + t2) * vocab + tok];
                    if gh != 0.0 {
                        acc += gh * dot(ge, &x_head[(i * t + t2) * d..(i * t + t2) * d + d]);
                    }
                }
            }
            *slot += 2.0 * acc;
        }
    });
}

/// Causal multi-head attention forward from the fused QKV activations.
///
/// `qkv` is `(rows, 3d)` laid out `[q | k | v]` per row; `heads` splits
/// the model width `d` into `hd = d / heads` head slices. For each
/// sample and head, `scores[t1, t2] = (q_t1 · k_t2) / sqrt(hd)` over
/// the causal prefix `t2 <= t1`, `probs` is the row softmax with the
/// strict upper triangle zeroed (`(b, heads, t, t)`, cached for the
/// backward pass), and `ao[t1] = sum_{t2<=t1} probs[t1,t2] v_t2` with
/// the heads concatenated back to width `d`.
///
/// Time `O(B T^2 d)` per pass (scores + apply); the probs cache is the
/// only extra state, `B*H*T^2` — the non-DP activation cost, shared by
/// every strategy. Threaded over the batch; no scratch.
pub fn attention_forward(
    qkv: &[f32],
    probs: &mut [f32],
    ao: &mut [f32],
    b: usize,
    t: usize,
    d: usize,
    heads: usize,
    threads: usize,
) {
    let hd = d / heads;
    debug_assert_eq!(hd * heads, d, "heads must divide d");
    debug_assert_eq!(qkv.len(), b * t * 3 * d);
    debug_assert_eq!(probs.len(), b * heads * t * t);
    debug_assert_eq!(ao.len(), b * t * d);
    let scale = 1.0 / (hd as f32).sqrt();
    let w3 = 3 * d;
    // pass 1: causal softmax probabilities
    par::par_rows(probs, b, heads * t * t, threads, |i0, chunk| {
        for (k, pb) in chunk.chunks_mut(heads * t * t).enumerate() {
            let i = i0 + k;
            for h in 0..heads {
                let ph = &mut pb[h * t * t..(h + 1) * t * t];
                for t1 in 0..t {
                    let q = &qkv[(i * t + t1) * w3 + h * hd..][..hd];
                    let row = &mut ph[t1 * t..t1 * t + t];
                    let mut m = f32::NEG_INFINITY;
                    for (t2, slot) in row.iter_mut().enumerate().take(t1 + 1) {
                        let kk = &qkv[(i * t + t2) * w3 + d + h * hd..][..hd];
                        let s = scale * dot(q, kk);
                        *slot = s;
                        if s > m {
                            m = s;
                        }
                    }
                    let mut z = 0.0f32;
                    for slot in row.iter_mut().take(t1 + 1) {
                        let e = (*slot - m).exp();
                        *slot = e;
                        z += e;
                    }
                    let inv = 1.0 / z;
                    for (t2, slot) in row.iter_mut().enumerate() {
                        if t2 <= t1 {
                            *slot *= inv;
                        } else {
                            *slot = 0.0; // causal mask
                        }
                    }
                }
            }
        }
    });
    // pass 2: ao = probs @ v, heads re-concatenated
    par::par_rows(ao, b, t * d, threads, |i0, chunk| {
        for (k, av) in chunk.chunks_mut(t * d).enumerate() {
            let i = i0 + k;
            av.fill(0.0);
            for h in 0..heads {
                let ph = &probs[(i * heads + h) * t * t..][..t * t];
                for t1 in 0..t {
                    let out = &mut av[t1 * d + h * hd..t1 * d + h * hd + hd];
                    for t2 in 0..=t1 {
                        let p = ph[t1 * t + t2];
                        if p != 0.0 {
                            let v = &qkv[(i * t + t2) * w3 + 2 * d + h * hd..][..hd];
                            simd::axpy(p, v, out);
                        }
                    }
                }
            }
        }
    });
}

/// Backward of the causal attention core: from `g_ao = dL/d ao` and the
/// cached `qkv` + `probs`, writes `g_qkv = dL/d qkv` — the gradient
/// flowing into the fused QKV projection. The softmax backward is
/// *recomputed* from the cached probabilities (per row:
/// `g_score = p * (g_prob - sum_s p_s g_prob_s) / sqrt(hd)`), so
/// nothing per-sample is stored beyond the forward caches; the
/// `g_prob = g_ao · v` dots are evaluated twice (once for the row sum,
/// once for the scores) to keep the kernel scratch-free. Time
/// `O(B T^2 d)`; threaded over the batch.
pub fn attention_backward(
    qkv: &[f32],
    probs: &[f32],
    g_ao: &[f32],
    g_qkv: &mut [f32],
    b: usize,
    t: usize,
    d: usize,
    heads: usize,
    threads: usize,
) {
    let hd = d / heads;
    debug_assert_eq!(hd * heads, d, "heads must divide d");
    debug_assert_eq!(qkv.len(), b * t * 3 * d);
    debug_assert_eq!(probs.len(), b * heads * t * t);
    debug_assert_eq!(g_ao.len(), b * t * d);
    debug_assert_eq!(g_qkv.len(), b * t * 3 * d);
    let scale = 1.0 / (hd as f32).sqrt();
    let w3 = 3 * d;
    par::par_rows(g_qkv, b, t * w3, threads, |i0, chunk| {
        for (k, gq) in chunk.chunks_mut(t * w3).enumerate() {
            let i = i0 + k;
            gq.fill(0.0);
            for h in 0..heads {
                let ph = &probs[(i * heads + h) * t * t..][..t * t];
                for t1 in 0..t {
                    let ga = &g_ao[(i * t + t1) * d + h * hd..][..hd];
                    let mut dotsum = 0.0f32;
                    for t2 in 0..=t1 {
                        let p = ph[t1 * t + t2];
                        if p != 0.0 {
                            let v = &qkv[(i * t + t2) * w3 + 2 * d + h * hd..][..hd];
                            dotsum += p * dot(ga, v);
                        }
                    }
                    for t2 in 0..=t1 {
                        let p = ph[t1 * t + t2];
                        if p == 0.0 {
                            continue;
                        }
                        let v = &qkv[(i * t + t2) * w3 + 2 * d + h * hd..][..hd];
                        let gs = p * (dot(ga, v) - dotsum) * scale;
                        // dL/d v_t2 += p * g_ao_t1
                        simd::axpy(p, ga, &mut gq[t2 * w3 + 2 * d + h * hd..][..hd]);
                        // dL/d q_t1 += gs * k_t2
                        let kk = &qkv[(i * t + t2) * w3 + d + h * hd..][..hd];
                        simd::axpy(gs, kk, &mut gq[t1 * w3 + h * hd..][..hd]);
                        // dL/d k_t2 += gs * q_t1
                        let q = &qkv[(i * t + t1) * w3 + h * hd..][..hd];
                        simd::axpy(gs, q, &mut gq[t2 * w3 + d + h * hd..][..hd]);
                    }
                }
            }
        }
    });
}

/// im2col unfold: gather every `k×k` receptive field of an HWC image
/// batch into patch rows, so a conv becomes the plain `(d, p)` matmul /
/// ghost-norm / instantiation kernels every linear layer uses.
///
/// `x` is `(b, h·w, cin)` — spatial positions major, channels innermost
/// — and `patches` is `(b, t, k·k·cin)` with `t` = output spatial
/// positions and patch element order `(ky, kx, ci)`, matching the conv
/// weight's `(cin·k², cout)` layout. Out-of-bounds taps (zero padding)
/// write zeros. Threaded over patch rows.
#[allow(clippy::too_many_arguments)]
pub fn unfold(
    x: &[f32],
    b: usize,
    cin: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    patches: &mut [f32],
    threads: usize,
) {
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let t = ho * wo;
    let dk = cin * k * k;
    debug_assert_eq!(x.len(), b * h * w * cin);
    debug_assert_eq!(patches.len(), b * t * dk);
    par::par_rows(patches, b * t, dk, threads, |r0, chunk| {
        for (ri, row) in chunk.chunks_mut(dk).enumerate() {
            let r = r0 + ri;
            let (i, pos) = (r / t, r % t);
            let (oy, ox) = (pos / wo, pos % wo);
            let xs = &x[i * h * w * cin..(i + 1) * h * w * cin];
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad as isize;
                let dst = &mut row[ky * k * cin..(ky + 1) * k * cin];
                if iy < 0 || iy >= h as isize {
                    dst.fill(0.0);
                    continue;
                }
                let base = iy as usize * w;
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad as isize;
                    let cell = &mut dst[kx * cin..(kx + 1) * cin];
                    if ix < 0 || ix >= w as isize {
                        cell.fill(0.0);
                    } else {
                        cell.copy_from_slice(&xs[(base + ix as usize) * cin..][..cin]);
                    }
                }
            }
        }
    });
}

/// col2im fold — the exact transpose of [`unfold`]: scatter-adds patch
/// rows back onto the `(b, h·w, cin)` image grid (overlapping receptive
/// fields accumulate), producing dL/dx from the unfolded gradient
/// `patches = g · Wᵀ`. Zeroes `dx` first. Threaded over samples — every
/// scatter target stays inside its own sample's row.
#[allow(clippy::too_many_arguments)]
pub fn fold(
    patches: &[f32],
    b: usize,
    cin: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    dx: &mut [f32],
    threads: usize,
) {
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let t = ho * wo;
    let dk = cin * k * k;
    debug_assert_eq!(patches.len(), b * t * dk);
    debug_assert_eq!(dx.len(), b * h * w * cin);
    par::par_rows(dx, b, h * w * cin, threads, |i0, chunk| {
        for (ii, dxs) in chunk.chunks_mut(h * w * cin).enumerate() {
            let i = i0 + ii;
            dxs.fill(0.0);
            for pos in 0..t {
                let row = &patches[(i * t + pos) * dk..][..dk];
                let (oy, ox) = (pos / wo, pos % wo);
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = &row[(ky * k + kx) * cin..][..cin];
                        let dst = &mut dxs[(iy as usize * w + ix as usize) * cin..][..cin];
                        for (dv, &sv) in dst.iter_mut().zip(src) {
                            *dv += sv;
                        }
                    }
                }
            }
        }
    });
}

/// Non-overlapping `win×win` average pooling over an HWC activation:
/// `out (b, ho·wo, c)` = window means of `x (b, h·w, c)` with
/// `ho = h/win`, `wo = w/win` (exact tiling — the plan validates
/// divisibility). Threaded over samples.
pub fn avgpool2d(
    x: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    win: usize,
    out: &mut [f32],
    threads: usize,
) {
    let (ho, wo) = (h / win, w / win);
    debug_assert_eq!(x.len(), b * h * w * c);
    debug_assert_eq!(out.len(), b * ho * wo * c);
    let inv = 1.0 / (win * win) as f32;
    par::par_rows(out, b, ho * wo * c, threads, |i0, chunk| {
        for (ii, os) in chunk.chunks_mut(ho * wo * c).enumerate() {
            let xs = &x[(i0 + ii) * h * w * c..][..h * w * c];
            os.fill(0.0);
            for oy in 0..ho {
                for ox in 0..wo {
                    let cell = &mut os[(oy * wo + ox) * c..][..c];
                    for dy in 0..win {
                        for dx_ in 0..win {
                            let src = &xs[((oy * win + dy) * w + ox * win + dx_) * c..][..c];
                            for (ov, &sv) in cell.iter_mut().zip(src) {
                                *ov += sv;
                            }
                        }
                    }
                    for ov in cell.iter_mut() {
                        *ov *= inv;
                    }
                }
            }
        }
    });
}

/// Average-pool backward: spread each output gradient uniformly
/// (`g / win²`) over its window. The exact transpose of [`avgpool2d`].
pub fn avgpool2d_backward(
    g: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    win: usize,
    dx: &mut [f32],
    threads: usize,
) {
    let (ho, wo) = (h / win, w / win);
    debug_assert_eq!(g.len(), b * ho * wo * c);
    debug_assert_eq!(dx.len(), b * h * w * c);
    let inv = 1.0 / (win * win) as f32;
    par::par_rows(dx, b, h * w * c, threads, |i0, chunk| {
        for (ii, dxs) in chunk.chunks_mut(h * w * c).enumerate() {
            let gs = &g[(i0 + ii) * ho * wo * c..][..ho * wo * c];
            for y in 0..h {
                for x_ in 0..w {
                    let src = &gs[((y / win) * wo + x_ / win) * c..][..c];
                    let dst = &mut dxs[(y * w + x_) * c..][..c];
                    for (dv, &sv) in dst.iter_mut().zip(src) {
                        *dv = sv * inv;
                    }
                }
            }
        }
    });
}

/// Non-overlapping `win×win` max pooling over an HWC activation.
/// Backward recomputes the argmax from the cached input, so no index
/// cache is needed (ties go to the first element in scan order — the
/// same rule [`maxpool2d_backward`] applies, keeping the pair exact).
pub fn maxpool2d(
    x: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    win: usize,
    out: &mut [f32],
    threads: usize,
) {
    let (ho, wo) = (h / win, w / win);
    debug_assert_eq!(x.len(), b * h * w * c);
    debug_assert_eq!(out.len(), b * ho * wo * c);
    par::par_rows(out, b, ho * wo * c, threads, |i0, chunk| {
        for (ii, os) in chunk.chunks_mut(ho * wo * c).enumerate() {
            let xs = &x[(i0 + ii) * h * w * c..][..h * w * c];
            for oy in 0..ho {
                for ox in 0..wo {
                    let cell = &mut os[(oy * wo + ox) * c..][..c];
                    cell.copy_from_slice(&xs[(oy * win * w + ox * win) * c..][..c]);
                    for dy in 0..win {
                        for dx_ in 0..win {
                            if dy == 0 && dx_ == 0 {
                                continue;
                            }
                            let src = &xs[((oy * win + dy) * w + ox * win + dx_) * c..][..c];
                            for (ov, &sv) in cell.iter_mut().zip(src) {
                                if sv > *ov {
                                    *ov = sv;
                                }
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Max-pool backward: route each output gradient to the first window
/// element (scan order) attaining the max, recomputed from the cached
/// input `x`. Everything else gets zero.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_backward(
    x: &[f32],
    g: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    win: usize,
    dx: &mut [f32],
    threads: usize,
) {
    let (ho, wo) = (h / win, w / win);
    debug_assert_eq!(x.len(), b * h * w * c);
    debug_assert_eq!(g.len(), b * ho * wo * c);
    debug_assert_eq!(dx.len(), b * h * w * c);
    par::par_rows(dx, b, h * w * c, threads, |i0, chunk| {
        for (ii, dxs) in chunk.chunks_mut(h * w * c).enumerate() {
            let i = i0 + ii;
            let xs = &x[i * h * w * c..][..h * w * c];
            let gs = &g[i * ho * wo * c..][..ho * wo * c];
            dxs.fill(0.0);
            for oy in 0..ho {
                for ox in 0..wo {
                    for ci in 0..c {
                        let (mut best, mut by, mut bx) =
                            (xs[(oy * win * w + ox * win) * c + ci], 0usize, 0usize);
                        for dy in 0..win {
                            for dx_ in 0..win {
                                let v = xs[((oy * win + dy) * w + ox * win + dx_) * c + ci];
                                if v > best {
                                    best = v;
                                    by = dy;
                                    bx = dx_;
                                }
                            }
                        }
                        dxs[((oy * win + by) * w + ox * win + bx) * c + ci] +=
                            gs[(oy * wo + ox) * c + ci];
                    }
                }
            }
        }
    });
}

/// Clipping flavors (matching `ref.py` exactly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClipKind {
    /// Abadi et al. (2016): `C_i = min(R / ||g_i||, 1)`.
    Abadi,
    /// Bu et al. (2022b) automatic: `C_i = R / (||g_i|| + 0.01)`.
    Automatic,
    /// Bu et al. (2021b) flat: `C_i = 1[||g_i|| <= R]`.
    Flat,
}

impl ClipKind {
    pub fn parse(s: &str) -> Option<ClipKind> {
        match s {
            "abadi" => Some(ClipKind::Abadi),
            "automatic" => Some(ClipKind::Automatic),
            "flat" => Some(ClipKind::Flat),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClipKind::Abadi => "abadi",
            ClipKind::Automatic => "automatic",
            ClipKind::Flat => "flat",
        }
    }
}

/// Per-sample clip factors from squared norms.
pub fn clip_factors(sq: &[f32], r: f32, kind: ClipKind, c: &mut [f32]) {
    debug_assert_eq!(sq.len(), c.len());
    for (ci, &s) in c.iter_mut().zip(sq) {
        let norm = s.max(0.0).sqrt();
        *ci = match kind {
            ClipKind::Abadi => (r / norm.max(1e-12)).min(1.0),
            ClipKind::Automatic => r / (norm + 0.01),
            ClipKind::Flat => {
                if norm <= r {
                    1.0
                } else {
                    0.0
                }
            }
        };
    }
}

/// Private SGD step on one tensor (paper Eq. 1):
/// `w -= lr * (G + sigma_r * z) / batch`.
pub fn sgd_update(w: &mut [f32], gsum: &[f32], noise: Option<&[f32]>, lr: f32, sigma_r: f32, batch: f32) {
    debug_assert_eq!(w.len(), gsum.len());
    match noise {
        Some(z) => {
            for ((wv, &gv), &zv) in w.iter_mut().zip(gsum).zip(z) {
                *wv -= lr * (gv + sigma_r * zv) / batch;
            }
        }
        None => {
            for (wv, &gv) in w.iter_mut().zip(gsum) {
                *wv -= lr * gv / batch;
            }
        }
    }
}

/// Private Adam step on one tensor (matching `dp_adam_update_ref`).
pub fn adam_update(
    w: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    gsum: &[f32],
    noise: Option<&[f32]>,
    lr: f32,
    sigma_r: f32,
    batch: f32,
    step: f32,
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let c1 = 1.0 - B1.powf(step);
    let c2 = 1.0 - B2.powf(step);
    for i in 0..w.len() {
        let z = noise.map(|n| n[i]).unwrap_or(0.0);
        let ghat = (gsum[i] + sigma_r * z) / batch;
        m[i] = B1 * m[i] + (1.0 - B1) * ghat;
        v[i] = B2 * v[i] + (1.0 - B2) * ghat * ghat;
        let mhat = m[i] / c1;
        let vhat = v[i] / c2;
        w[i] -= lr * mhat / (vhat.sqrt() + EPS);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn randv(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() - 0.5).collect()
    }

    fn naive_matmul(a: &[f32], w: &[f32], rows: usize, d: usize, p: usize) -> Vec<f32> {
        let mut out = vec![0f32; rows * p];
        for r in 0..rows {
            for j in 0..d {
                for q in 0..p {
                    out[r * p + q] += a[r * d + j] * w[j * p + q];
                }
            }
        }
        out
    }

    #[test]
    fn forward_matches_naive() {
        let mut rng = Xoshiro256::new(1);
        for &(rows, d, p) in &[(1usize, 1usize, 1usize), (7, 5, 3), (33, 17, 9), (64, 128, 32)] {
            let a = randv(&mut rng, rows * d);
            let w = randv(&mut rng, d * p);
            let bias = randv(&mut rng, p);
            let mut out = vec![0f32; rows * p];
            linear_forward(&a, &w, Some(&bias), &mut out, rows, d, p, 4);
            let mut want = naive_matmul(&a, &w, rows, d, p);
            for r in 0..rows {
                for q in 0..p {
                    want[r * p + q] += bias[q];
                }
            }
            for (x, y) in out.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn backward_data_matches_naive() {
        let mut rng = Xoshiro256::new(2);
        let (rows, d, p) = (19usize, 11usize, 13usize);
        let g = randv(&mut rng, rows * p);
        let w = randv(&mut rng, d * p);
        let mut da = vec![0f32; rows * d];
        backward_data(&g, &w, &mut da, rows, d, p, 4);
        for r in 0..rows {
            for j in 0..d {
                let mut want = 0f32;
                for q in 0..p {
                    want += g[r * p + q] * w[j * p + q];
                }
                assert!((da[r * d + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let rows = 4;
        let c = 10;
        let logits = vec![0f32; rows * c];
        let y = vec![3i32; rows];
        let mut g = vec![0f32; rows * c];
        let loss = softmax_xent(&logits, &y, rows, c, Some(&mut g));
        assert!((loss / rows as f32 - (c as f32).ln()).abs() < 1e-5);
        // gradient rows sum to zero; true class is prob - 1
        for r in 0..rows {
            let s: f32 = g[r * c..(r + 1) * c].iter().sum();
            assert!(s.abs() < 1e-5);
            assert!((g[r * c + 3] - (0.1 - 1.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_roundtrip() {
        let mut x = vec![-1.0f32, 0.0, 2.0];
        relu_forward(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut da = vec![5.0f32, 5.0, 5.0];
        relu_backward(&mut da, &x);
        assert_eq!(da, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn ghost_norm_t1_factorizes() {
        let mut rng = Xoshiro256::new(3);
        let (b, d, p) = (6usize, 9usize, 4usize);
        let a = randv(&mut rng, b * d);
        let g = randv(&mut rng, b * p);
        let mut sq = vec![0f32; b];
        ghost_norm(&a, &g, b, 1, d, p, &mut [], &mut [], &mut sq, 2);
        for i in 0..b {
            let a2: f32 = a[i * d..(i + 1) * d].iter().map(|x| x * x).sum();
            let g2: f32 = g[i * p..(i + 1) * p].iter().map(|x| x * x).sum();
            assert!((sq[i] - a2 * g2).abs() / (a2 * g2).max(1e-6) < 1e-5);
        }
    }

    /// Direct (no-im2col) conv reference: HWC in, HWC out, weight
    /// `(cin·k², cout)` in the `(ky, kx, ci)` patch order.
    #[allow(clippy::too_many_arguments)]
    fn naive_conv(
        x: &[f32],
        w_t: &[f32],
        bias: &[f32],
        b: usize,
        cin: usize,
        h: usize,
        w: usize,
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Vec<f32> {
        let ho = (h + 2 * pad - k) / stride + 1;
        let wo = (w + 2 * pad - k) / stride + 1;
        let mut out = vec![0f32; b * ho * wo * cout];
        for i in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    for q in 0..cout {
                        let mut acc = bias[q];
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = (oy * stride + ky) as isize - pad as isize;
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                for ci in 0..cin {
                                    let xv = x
                                        [((i * h + iy as usize) * w + ix as usize) * cin + ci];
                                    let wv = w_t[((ky * k + kx) * cin + ci) * cout + q];
                                    acc += xv * wv;
                                }
                            }
                        }
                        out[((i * ho + oy) * wo + ox) * cout + q] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn unfold_matmul_matches_direct_conv() {
        let mut rng = Xoshiro256::new(11);
        for &(b, cin, h, w, cout, k, stride, pad) in &[
            (2usize, 1usize, 5usize, 5usize, 3usize, 3usize, 1usize, 1usize),
            (3, 4, 6, 7, 2, 3, 2, 0),
            (1, 2, 4, 4, 5, 1, 1, 0),
            (2, 3, 5, 5, 4, 5, 1, 2),
        ] {
            let ho = (h + 2 * pad - k) / stride + 1;
            let wo = (w + 2 * pad - k) / stride + 1;
            let (t, dk) = (ho * wo, cin * k * k);
            let x = randv(&mut rng, b * h * w * cin);
            let wt = randv(&mut rng, dk * cout);
            let bias = randv(&mut rng, cout);
            let mut patches = vec![0f32; b * t * dk];
            unfold(&x, b, cin, h, w, k, stride, pad, &mut patches, 3);
            let mut out = vec![0f32; b * t * cout];
            linear_forward(&patches, &wt, Some(&bias), &mut out, b * t, dk, cout, 3);
            let want = naive_conv(&x, &wt, &bias, b, cin, h, w, cout, k, stride, pad);
            for (o, wv) in out.iter().zip(&want) {
                assert!((o - wv).abs() < 1e-4, "{o} vs {wv}");
            }
        }
    }

    #[test]
    fn fold_is_the_exact_transpose_of_unfold() {
        // adjointness <unfold(x), y> == <x, fold(y)> makes fold the
        // correct dL/dx scatter for any upstream gradient
        let mut rng = Xoshiro256::new(12);
        for &(b, cin, h, w, k, stride, pad) in &[
            (2usize, 3usize, 5usize, 6usize, 3usize, 1usize, 1usize),
            (1, 2, 7, 7, 3, 2, 0),
            (2, 1, 4, 4, 2, 2, 1),
        ] {
            let ho = (h + 2 * pad - k) / stride + 1;
            let wo = (w + 2 * pad - k) / stride + 1;
            let (t, dk) = (ho * wo, cin * k * k);
            let x = randv(&mut rng, b * h * w * cin);
            let y = randv(&mut rng, b * t * dk);
            let mut ux = vec![0f32; b * t * dk];
            unfold(&x, b, cin, h, w, k, stride, pad, &mut ux, 2);
            let mut fy = vec![0f32; b * h * w * cin];
            fold(&y, b, cin, h, w, k, stride, pad, &mut fy, 2);
            let lhs: f64 = ux.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
            let rhs: f64 = x.iter().zip(&fy).map(|(&a, &b)| a as f64 * b as f64).sum();
            assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "{lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn avgpool_roundtrip_and_transpose() {
        let mut rng = Xoshiro256::new(13);
        let (b, c, h, w, win) = (2usize, 3usize, 6usize, 4usize, 2usize);
        let (ho, wo) = (h / win, w / win);
        let x = randv(&mut rng, b * h * w * c);
        let mut out = vec![0f32; b * ho * wo * c];
        avgpool2d(&x, b, c, h, w, win, &mut out, 2);
        // spot check one window mean
        let want = (x[0] + x[1 * c] + x[w * c] + x[(w + 1) * c]) / 4.0;
        assert!((out[0] - want).abs() < 1e-5);
        // adjointness: <avg(x), g> == <x, avg_backward(g)>
        let g = randv(&mut rng, b * ho * wo * c);
        let mut dx = vec![0f32; b * h * w * c];
        avgpool2d_backward(&g, b, c, h, w, win, &mut dx, 2);
        let lhs: f64 = out.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = x.iter().zip(&dx).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn maxpool_routes_gradient_to_argmax() {
        let (b, c, h, w, win) = (1usize, 1usize, 4usize, 4usize, 2usize);
        #[rustfmt::skip]
        let x = vec![
            1.0f32, 5.0, 2.0, 2.0,
            3.0,    1.0, 2.0, 9.0,
            0.0,    0.0, 7.0, 7.0,
            0.0,    0.0, 7.0, 7.0,
        ];
        let mut out = vec![0f32; 4];
        maxpool2d(&x, b, c, h, w, win, &mut out, 1);
        assert_eq!(out, vec![5.0, 9.0, 0.0, 7.0]);
        let g = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut dx = vec![0f32; 16];
        maxpool2d_backward(&x, &g, b, c, h, w, win, &mut dx, 1);
        assert_eq!(dx[1], 1.0, "5.0 wins its window");
        assert_eq!(dx[7], 2.0, "9.0 wins its window");
        assert_eq!(dx[8], 3.0, "tie at 0.0: first in scan order wins");
        assert_eq!(dx[10], 4.0, "tie at 7.0: first in scan order wins");
        assert_eq!(dx.iter().filter(|&&v| v != 0.0).count(), 4);
    }

    #[test]
    fn conv_ghost_norm_matches_instantiated_reference() {
        // the im2col ghost-norm contract: unfold the input, then the
        // linear ghost kernel over (t = spatial positions, d = cin*k^2)
        // equals the materialized per-sample conv-grad norm
        let mut rng = Xoshiro256::new(14);
        let (b, cin, h, w, cout, k, stride, pad) = (3usize, 2, 5, 5, 4, 3, 1, 1);
        let ho = (h + 2 * pad - k) / stride + 1;
        let wo = (w + 2 * pad - k) / stride + 1;
        let (t, dk) = (ho * wo, cin * k * k);
        let x = randv(&mut rng, b * h * w * cin);
        let g = randv(&mut rng, b * t * cout);
        let mut patches = vec![0f32; b * t * dk];
        unfold(&x, b, cin, h, w, k, stride, pad, &mut patches, 2);
        let mut gram_a = vec![0f32; b * t * t];
        let mut gram_g = vec![0f32; b * t * t];
        let mut sq = vec![0f32; b];
        ghost_norm(&patches, &g, b, t, dk, cout, &mut gram_a, &mut gram_g, &mut sq, 2);
        for i in 0..b {
            // per-sample grad: patches_i^T g_i, norm in f64
            let mut want = 0f64;
            for j in 0..dk {
                for q in 0..cout {
                    let mut acc = 0f64;
                    for tt in 0..t {
                        acc += patches[(i * t + tt) * dk + j] as f64
                            * g[(i * t + tt) * cout + q] as f64;
                    }
                    want += acc * acc;
                }
            }
            assert!(
                (sq[i] as f64 - want).abs() < 1e-2 * want.max(1.0),
                "{} vs {}",
                sq[i],
                want
            );
        }
    }

    #[test]
    fn clip_factor_kinds() {
        let sq = vec![4.0f32, 0.25, 100.0];
        let mut c = vec![0f32; 3];
        clip_factors(&sq, 1.0, ClipKind::Abadi, &mut c);
        assert!((c[0] - 0.5).abs() < 1e-6);
        assert!((c[1] - 1.0).abs() < 1e-6);
        clip_factors(&sq, 1.0, ClipKind::Flat, &mut c);
        assert_eq!(c, vec![0.0, 1.0, 0.0]);
        clip_factors(&sq, 1.0, ClipKind::Automatic, &mut c);
        assert!((c[0] - 1.0 / 2.01).abs() < 1e-6);
        assert_eq!(ClipKind::parse("automatic"), Some(ClipKind::Automatic));
        assert_eq!(ClipKind::parse("bogus"), None);
    }

    #[test]
    fn layernorm_forward_normalizes() {
        let mut rng = Xoshiro256::new(7);
        let (rows, d) = (9usize, 12usize);
        let x = randv(&mut rng, rows * d);
        let gamma: Vec<f32> = (0..d).map(|j| 1.0 + 0.1 * j as f32).collect();
        let beta: Vec<f32> = (0..d).map(|j| 0.01 * j as f32).collect();
        let mut out = vec![0f32; rows * d];
        let mut xhat = vec![0f32; rows * d];
        let mut inv_std = vec![0f32; rows];
        layernorm_forward(&x, &gamma, &beta, &mut out, &mut xhat, &mut inv_std, rows, d);
        for r in 0..rows {
            let xh = &xhat[r * d..(r + 1) * d];
            let mean: f32 = xh.iter().sum::<f32>() / d as f32;
            let var: f32 = xh.iter().map(|v| v * v).sum::<f32>() / d as f32;
            assert!(mean.abs() < 1e-5, "xhat mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "xhat var {var}");
            for j in 0..d {
                let want = gamma[j] * xh[j] + beta[j];
                assert!((out[r * d + j] - want).abs() < 1e-5);
            }
            assert!(inv_std[r] > 0.0);
        }
    }

    #[test]
    fn layernorm_backward_matches_finite_difference() {
        let mut rng = Xoshiro256::new(8);
        let (rows, d) = (3usize, 7usize);
        let x = randv(&mut rng, rows * d);
        let gamma: Vec<f32> = (0..d).map(|j| 0.8 + 0.05 * j as f32).collect();
        let beta = vec![0.0f32; d];
        let g = randv(&mut rng, rows * d);
        let fwd = |x: &[f32]| -> Vec<f32> {
            let mut out = vec![0f32; rows * d];
            let mut xh = vec![0f32; rows * d];
            let mut is = vec![0f32; rows];
            layernorm_forward(x, &gamma, &beta, &mut out, &mut xh, &mut is, rows, d);
            out
        };
        let mut out = vec![0f32; rows * d];
        let mut xhat = vec![0f32; rows * d];
        let mut inv_std = vec![0f32; rows];
        layernorm_forward(&x, &gamma, &beta, &mut out, &mut xhat, &mut inv_std, rows, d);
        let mut da = vec![0f32; rows * d];
        layernorm_backward_data(&g, &gamma, &xhat, &inv_std, &mut da, rows, d);
        // scalar loss L = <g, LN(x)>; dL/dx[j] must match central diffs
        let h = 1e-3f32;
        for idx in [0usize, rows * d / 2, rows * d - 1] {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let lp: f32 = fwd(&xp).iter().zip(&g).map(|(o, gv)| o * gv).sum();
            let lm: f32 = fwd(&xm).iter().zip(&g).map(|(o, gv)| o * gv).sum();
            let numeric = (lp - lm) / (2.0 * h);
            assert!(
                (numeric - da[idx]).abs() < 5e-3 * da[idx].abs().max(1.0),
                "idx {idx}: numeric {numeric} vs analytic {}",
                da[idx]
            );
        }
    }

    #[test]
    fn ln_norms_and_sums_match_naive() {
        let mut rng = Xoshiro256::new(9);
        let (b, t, p) = (5usize, 3usize, 6usize);
        let g = randv(&mut rng, b * t * p);
        let xhat = randv(&mut rng, b * t * p);
        let c: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();
        // naive per-sample (gamma, beta) grads
        let mut want_sq = vec![0f64; b];
        let mut want_gg = vec![0f64; p];
        let mut want_gb = vec![0f64; p];
        for i in 0..b {
            let mut sg = vec![0f64; p];
            let mut sb = vec![0f64; p];
            for tt in 0..t {
                for j in 0..p {
                    let gv = g[(i * t + tt) * p + j] as f64;
                    sg[j] += gv * xhat[(i * t + tt) * p + j] as f64;
                    sb[j] += gv;
                }
            }
            want_sq[i] = sg.iter().map(|v| v * v).sum::<f64>() + sb.iter().map(|v| v * v).sum::<f64>();
            for j in 0..p {
                want_gg[j] += c[i] as f64 * sg[j];
                want_gb[j] += c[i] as f64 * sb[j];
            }
        }
        let workers = 2usize;
        let mut scratch = vec![0f32; workers * 2 * p];
        let mut sq = vec![0f32; b];
        ln_sq_norms(&g, &xhat, b, t, p, &mut scratch, &mut sq, 2);
        for i in 0..b {
            assert!(
                (sq[i] as f64 - want_sq[i]).abs() / want_sq[i].max(1e-6) < 1e-3,
                "{} vs {}",
                sq[i],
                want_sq[i]
            );
        }
        let mut gg = vec![0f32; p];
        let mut gb = vec![0f32; p];
        ln_weighted_grads(&g, &xhat, Some(&c), b, t, p, &mut gg, &mut gb);
        for j in 0..p {
            assert!((gg[j] as f64 - want_gg[j]).abs() < 1e-4, "{} vs {}", gg[j], want_gg[j]);
            assert!((gb[j] as f64 - want_gb[j]).abs() < 1e-4, "{} vs {}", gb[j], want_gb[j]);
        }
    }

    #[test]
    fn embedding_kernels_match_materialized_reference() {
        let mut rng = Xoshiro256::new(10);
        let (b, t, vocab, p) = (4usize, 5usize, 7usize, 3usize);
        // repeated tokens on purpose: the equality mask must fire
        let tokens: Vec<i32> = (0..b * t).map(|_| rng.next_below(vocab as u64) as i32).collect();
        let table = randv(&mut rng, vocab * p);
        let g = randv(&mut rng, b * t * p);
        let c: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();

        // forward
        let mut out = vec![0f32; b * t * p];
        embedding_forward(&tokens, &table, &mut out, b * t, p, 2);
        for r in 0..b * t {
            let tok = tokens[r] as usize;
            assert_eq!(&out[r * p..(r + 1) * p], &table[tok * p..(tok + 1) * p]);
        }

        // naive per-sample (vocab, p) gradient
        let mut naive = vec![0f64; b * vocab * p];
        for i in 0..b {
            for tt in 0..t {
                let tok = tokens[i * t + tt] as usize;
                for j in 0..p {
                    naive[i * vocab * p + tok * p + j] += g[(i * t + tt) * p + j] as f64;
                }
            }
        }
        let mut sq = vec![0f32; b];
        embedding_sq_norms(&tokens, &g, b, t, p, &mut sq, 2);
        for i in 0..b {
            let want: f64 = naive[i * vocab * p..(i + 1) * vocab * p].iter().map(|v| v * v).sum();
            assert!(
                (sq[i] as f64 - want).abs() / want.max(1e-6) < 1e-3,
                "sample {i}: {} vs {}",
                sq[i],
                want
            );
        }
        let mut summed = vec![0f32; vocab * p];
        embedding_weighted_grad(&tokens, &g, Some(&c), b, t, p, &mut summed);
        for k in 0..vocab * p {
            let want: f64 = (0..b).map(|i| c[i] as f64 * naive[i * vocab * p + k]).sum();
            assert!((summed[k] as f64 - want).abs() < 1e-4, "slot {k}: {} vs {}", summed[k], want);
        }
    }

    #[test]
    fn tied_cross_term_matches_materialized_reference() {
        let mut rng = Xoshiro256::new(14);
        let (b, t, vocab, d) = (4usize, 5usize, 6usize, 3usize);
        // narrow token band: the head column lookup must hit repeats
        let tokens: Vec<i32> = (0..b * t).map(|_| rng.next_below(4) as i32).collect();
        let g_emb = randv(&mut rng, b * t * d);
        let x_head = randv(&mut rng, b * t * d);
        let g_head = randv(&mut rng, b * t * vocab);

        // materialize G_emb_i and G_head_i in f64, take the inner product
        let mut want = vec![0f64; b];
        for i in 0..b {
            let mut ge = vec![0f64; vocab * d];
            let mut gh = vec![0f64; vocab * d];
            for tt in 0..t {
                let r = i * t + tt;
                let tok = tokens[r] as usize;
                for j in 0..d {
                    ge[tok * d + j] += g_emb[r * d + j] as f64;
                }
                for v in 0..vocab {
                    for j in 0..d {
                        gh[v * d + j] += g_head[r * vocab + v] as f64 * x_head[r * d + j] as f64;
                    }
                }
            }
            want[i] = 2.0 * ge.iter().zip(&gh).map(|(a, b)| a * b).sum::<f64>();
        }

        let mut sq = vec![0f32; b];
        tied_cross_sq_norms(&tokens, &g_emb, &x_head, &g_head, b, t, d, vocab, &mut sq, 2);
        for i in 0..b {
            assert!(
                (sq[i] as f64 - want[i]).abs() < 1e-3 * want[i].abs().max(1e-3),
                "sample {i}: {} vs {}",
                sq[i],
                want[i]
            );
        }
        // accumulation contract: a second call adds the same amount
        tied_cross_sq_norms(&tokens, &g_emb, &x_head, &g_head, b, t, d, vocab, &mut sq, 2);
        for i in 0..b {
            assert!((sq[i] as f64 - 2.0 * want[i]).abs() < 2e-3 * want[i].abs().max(1e-3));
        }
    }

    #[test]
    fn attention_forward_is_causal_and_normalized() {
        let mut rng = Xoshiro256::new(21);
        let (b, t, d, heads) = (3usize, 5usize, 6usize, 2usize);
        let qkv = randv(&mut rng, b * t * 3 * d);
        let mut probs = vec![0f32; b * heads * t * t];
        let mut ao = vec![0f32; b * t * d];
        attention_forward(&qkv, &mut probs, &mut ao, b, t, d, heads, 2);
        for i in 0..b {
            for h in 0..heads {
                let ph = &probs[(i * heads + h) * t * t..][..t * t];
                for t1 in 0..t {
                    let row = &ph[t1 * t..(t1 + 1) * t];
                    let s: f32 = row[..=t1].iter().sum();
                    assert!((s - 1.0).abs() < 1e-5, "row {t1} sums to {s}");
                    assert!(row[..=t1].iter().all(|&p| p > 0.0));
                    assert!(row[t1 + 1..].iter().all(|&p| p == 0.0), "causal mask leak");
                }
            }
        }
        // t = 1 degenerates to ao == v (prob 1 on the only token)
        let qkv1 = randv(&mut rng, b * 3 * d);
        let mut p1 = vec![0f32; b * heads];
        let mut ao1 = vec![0f32; b * d];
        attention_forward(&qkv1, &mut p1, &mut ao1, b, 1, d, heads, 1);
        assert!(p1.iter().all(|&p| (p - 1.0).abs() < 1e-6));
        for r in 0..b {
            for j in 0..d {
                assert!((ao1[r * d + j] - qkv1[r * 3 * d + 2 * d + j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn attention_backward_matches_finite_difference() {
        // scalar loss L = <g_ao, attention(qkv)>: dL/d qkv must match
        // central differences through the causal softmax.
        let mut rng = Xoshiro256::new(22);
        let (b, t, d, heads) = (2usize, 4usize, 4usize, 2usize);
        let qkv = randv(&mut rng, b * t * 3 * d);
        let g_ao = randv(&mut rng, b * t * d);
        let fwd = |qkv: &[f32]| -> Vec<f32> {
            let mut probs = vec![0f32; b * heads * t * t];
            let mut ao = vec![0f32; b * t * d];
            attention_forward(qkv, &mut probs, &mut ao, b, t, d, heads, 1);
            ao
        };
        let mut probs = vec![0f32; b * heads * t * t];
        let mut ao = vec![0f32; b * t * d];
        attention_forward(&qkv, &mut probs, &mut ao, b, t, d, heads, 1);
        let mut g_qkv = vec![0f32; b * t * 3 * d];
        attention_backward(&qkv, &probs, &g_ao, &mut g_qkv, b, t, d, heads, 1);
        let h = 1e-2f32;
        for idx in (0..qkv.len()).step_by(7) {
            let mut qp = qkv.clone();
            qp[idx] += h;
            let mut qm = qkv.clone();
            qm[idx] -= h;
            let lp: f32 = fwd(&qp).iter().zip(&g_ao).map(|(o, g)| o * g).sum();
            let lm: f32 = fwd(&qm).iter().zip(&g_ao).map(|(o, g)| o * g).sum();
            let numeric = (lp - lm) / (2.0 * h);
            assert!(
                (numeric - g_qkv[idx]).abs() < 2e-2 * g_qkv[idx].abs().max(0.5),
                "qkv[{idx}]: numeric {numeric} vs analytic {}",
                g_qkv[idx]
            );
        }
    }

    #[test]
    fn updates_match_scalar_math() {
        let mut w = vec![1.0f32];
        sgd_update(&mut w, &[2.0], Some(&[0.5]), 0.1, 2.0, 4.0);
        // w - 0.1*(2 + 2*0.5)/4 = 1 - 0.075
        assert!((w[0] - 0.925).abs() < 1e-6);

        let (mut w, mut m, mut v) = (vec![1.0f32], vec![0f32], vec![0f32]);
        adam_update(&mut w, &mut m, &mut v, &[4.0], None, 0.01, 0.0, 4.0, 1.0);
        // ghat = 1; mhat = 1; vhat = 1 => w -= 0.01 * 1/(1+eps)
        assert!((w[0] - 0.99).abs() < 1e-5);
        assert!((m[0] - 0.1).abs() < 1e-6);
        assert!((v[0] - 0.001).abs() < 1e-7);
    }
}
