//! Runtime: load AOT artifacts (HLO text + manifest.json) and execute
//! them on the PJRT CPU client. This is the only module that talks to
//! the `xla` crate; everything above it works with `Literal`s and
//! manifest metadata.
//!
//! Interchange contract (see python/compile/aot.py):
//!  * `<model>__init.hlo.txt`            — seed -> params
//!  * `<model>__eval.hlo.txt`            — params, x, y -> loss
//!  * `<model>__step_<strategy>.hlo.txt` — params, [m, v], x, y,
//!                                         [noise...], scalars -> params',
//!                                         [m', v'], metrics
//! All computations are lowered with return_tuple=True, so execution
//! yields one tuple literal that we decompose by the manifest's output
//! descriptors.

mod manifest;

pub use manifest::{ArtifactMeta, Dtype, LayerMeta, Manifest, ModelMeta, TensorDesc};

use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

/// A compiled-executable cache keyed by artifact file name.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative compile seconds (reported by the coordinator).
    pub compile_secs: RefCell<f64>,
}

impl Runtime {
    /// Load the manifest and create a CPU PJRT client.
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)
            .map_err(|e| anyhow!("loading manifest from {}: {e}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_secs: RefCell::new(0.0),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.manifest
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})",
                self.manifest.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact(&self, model: &str, kind: &str, strategy: Option<&str>)
        -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.model == model && a.kind == kind
                && a.strategy.as_deref() == strategy)
            .ok_or_else(|| anyhow!(
                "artifact model={model} kind={kind} strategy={strategy:?} not found \
                 (re-run `make artifacts`?)"))
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn executable(&self, art: &ArtifactMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&art.file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(&art.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", art.file))?,
        );
        *self.compile_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(art.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs (passed by reference so
    /// params can stay host-resident across steps); returns the
    /// decomposed output tuple, validated against the manifest.
    pub fn execute(&self, art: &ArtifactMeta, inputs: &[&xla::Literal])
        -> Result<Vec<xla::Literal>> {
        if inputs.len() != art.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                art.file,
                art.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.executable(art)?;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", art.file))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = tuple.to_tuple().context("decomposing result tuple")?;
        if outs.len() != art.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                art.file,
                art.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// Build a f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal_f32: {} elements for shape {:?}", data.len(), shape);
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal_i32: {} elements for shape {:?}", data.len(), shape);
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Scalar literals (0-d).
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Read back a f32 literal as a host vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read a scalar f32 output.
pub fn scalar_of(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
