//! Runtime layer: the [`Backend`] abstraction the coordinator trains
//! through, with two implementations.
//!
//! * [`native`] — the default. Runs the whole Book-Keeping DP step
//!   (forward, book-kept backward, ghost/per-sample norms, clipped
//!   weighted sum, noisy SGD/Adam) as fused Rust kernels. Zero external
//!   dependencies; builds and runs offline.
//! * [`pjrt`] — the original AOT-artifact executor (HLO text +
//!   manifest.json on the PJRT CPU client), demoted behind the
//!   `xla-runtime` cargo feature because the `xla` crate is not
//!   buildable in the offline environment. See DESIGN.md for the
//!   re-enable recipe.
//!
//! Everything above this module speaks [`ModelInfo`] + host tensors
//! (`Vec<f32>` / label vectors); no XLA types leak upward.

pub mod manifest;
pub mod native;
#[cfg(feature = "xla-runtime")]
pub mod pjrt;

pub use manifest::{ArtifactMeta, Dtype, LayerMeta, Manifest, ModelMeta, TensorDesc};

use crate::error::Result;
use crate::{anyhow, bail};
use std::collections::BTreeMap;

/// Backend-neutral model description: what the coordinator, noise
/// source, and checkpointing need to know about a model.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    /// "mlp" | "seqmlp" | "gpt" | ... (drives the data pipeline).
    pub kind: String,
    /// Samples per physical batch (the paper's B).
    pub batch: usize,
    /// Tokens per sample (the paper's T; 1 for flat inputs).
    pub seq: usize,
    /// Input feature width (vector models).
    pub d_in: usize,
    pub n_classes: usize,
    /// "sgd" | "adam".
    pub optimizer: String,
    /// "abadi" | "automatic" | "flat".
    pub clip_fn: String,
    /// Canonical tensors, in state/noise/checkpoint order.
    pub param_names: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub n_params: usize,
    /// Trainability flag per canonical tensor (`param_names` order).
    /// Frozen tensors keep full parameter storage (forward needs them)
    /// but carry zero-length gradient, noise, and optimizer-moment
    /// buffers — see DESIGN.md §9.
    pub trainable: Vec<bool>,
    /// Canonical trainability preset (`Trainable::canonical` form:
    /// "all", "bias-only", "lora:<rank>", "mask:<names>") — recorded in
    /// the checkpoint privacy fingerprint so a resume with a drifted
    /// mask is refused.
    pub trainable_preset: String,
}

impl ModelInfo {
    pub fn is_adam(&self) -> bool {
        self.optimizer == "adam"
    }

    pub fn param_shape(&self, name: &str) -> Result<&[usize]> {
        self.param_shapes
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("no shape for param '{name}'"))
    }

    /// Tensors in a full state snapshot (params [+ Adam m, v]).
    pub fn state_tensor_count(&self) -> usize {
        if self.is_adam() {
            3 * self.param_names.len()
        } else {
            self.param_names.len()
        }
    }

    /// Expected element count of each tensor in a full state snapshot,
    /// in snapshot order: params in `param_names` order, then (Adam
    /// only) the m moments, then the v moments — the layout `state()` /
    /// `load_state()` and the checkpoint format share. Checkpoint
    /// validation compares header lengths against this.
    pub fn state_tensor_lens(&self) -> Vec<usize> {
        let param_lens: Vec<usize> = self
            .param_names
            .iter()
            .map(|n| self.param_shapes[n].iter().product())
            .collect();
        let mut out = param_lens.clone();
        if self.is_adam() {
            // frozen tensors carry no optimizer state: their moment
            // slots are present (layout is positional) but empty
            let moment_lens: Vec<usize> = param_lens
                .iter()
                .zip(&self.trainable)
                .map(|(&len, &t)| if t { len } else { 0 })
                .collect();
            out.extend(moment_lens.iter().copied()); // m
            out.extend(moment_lens); // v
        }
        out
    }

    /// Element count of each tensor's gradient/noise buffer: the full
    /// parameter length for trainable tensors, zero for frozen ones.
    pub fn grad_lens(&self) -> Vec<usize> {
        self.param_names
            .iter()
            .zip(&self.trainable)
            .map(|(n, &t)| {
                if t {
                    self.param_shapes[n].iter().product()
                } else {
                    0
                }
            })
            .collect()
    }

    /// Parameters the trainability mask actually trains.
    pub fn n_trainable_params(&self) -> usize {
        self.param_names
            .iter()
            .zip(&self.trainable)
            .filter(|(_, &t)| t)
            .map(|(n, _)| self.param_shapes[n].iter().product::<usize>())
            .sum()
    }
}

/// Input features for one physical batch (labels travel separately).
#[derive(Clone, Debug)]
pub enum BatchX {
    /// Flat `(B*T*d)` feature rows.
    F32(Vec<f32>),
    /// Flat `(B*T)` token ids.
    I32(Vec<i32>),
}

/// Scalar hyperparameters of one optimizer step (the artifact scalar
/// tail, in order: lr, R, sigma*R, logical batch, 1-based step).
#[derive(Clone, Copy, Debug)]
pub struct StepHyper {
    pub lr: f32,
    pub clip: f32,
    /// sigma * R; 0 disables noise injection.
    pub sigma_r: f32,
    pub logical_batch: f32,
    pub step: f32,
}

/// Metrics of one step.
#[derive(Clone, Debug, Default)]
pub struct StepOut {
    /// Mean per-row loss.
    pub loss: f32,
    /// Mean per-sample clip factor across every clipping group
    /// (1.0 for nondp).
    pub mean_clip: f32,
    /// Mean clip factor per clipping group, in group order. One entry
    /// (equal to `mean_clip`) under all-layer clipping; one per layer /
    /// group under the layer-wise / group-wise styles.
    pub group_clip: Vec<f32>,
}

/// Arena / allocator telemetry (native backend).
#[derive(Clone, Copy, Debug, Default)]
pub struct AllocStats {
    /// Heap allocations the last step could not serve from the pool.
    /// Zero once warm — the paper's flat-memory claim as an invariant.
    pub fresh_allocs_last_step: usize,
    /// Total bytes ever handed out by the arena.
    pub arena_bytes: usize,
    /// High-water mark of arena floats checked out during the last
    /// step — the measured peak working set of the buffer schedule
    /// (lifetimes, not just sizes: the fused group-wise walk lowers
    /// this without changing the buffer set).
    pub arena_peak_floats: usize,
    /// Peak g-cache floats of the last fused BK walk (frontier
    /// gradient + live book-kept output gradients); 0 for two-pass /
    /// nondp / the unfused diagnostic schedule. Comparable to
    /// `complexity::bk_gcache_floats`.
    pub peak_gcache_floats: usize,
    /// Optimizer-moment floats actually allocated (Adam m + v over
    /// trainable tensors only; 0 for SGD). Drops under bias-only / LoRA
    /// presets — the measured side of the PEFT space claim.
    pub opt_state_floats: usize,
}

/// One trainable (model, strategy) pair the coordinator can drive.
///
/// A backend owns parameters and optimizer state; the trainer owns
/// data, privacy accounting, noise, and batching. `noise` slices are
/// standard-normal tensors in `param_names` order (empty = no noise).
pub trait Backend {
    fn info(&self) -> &ModelInfo;
    fn strategy(&self) -> &str;

    /// (Re-)initialize parameters from a seed.
    fn init(&mut self, seed: u64) -> Result<()>;

    /// Mean loss on one batch (no mutation).
    fn eval_loss(&mut self, x: &BatchX, y: &[i32]) -> Result<f32>;

    /// One fused optimizer step: clipped-gradient computation + noisy
    /// update (the fast path when logical batch == physical batch).
    fn step(&mut self, x: &BatchX, y: &[i32], noise: &[Vec<f32>], h: &StepHyper) -> Result<StepOut>;

    /// Gradient-accumulation half-step: per-sample-clipped gradient sums
    /// for one micro-batch, no update.
    fn clipped_grads(&mut self, x: &BatchX, y: &[i32], clip: f32)
        -> Result<(Vec<Vec<f32>>, StepOut)>;

    /// Per-sample-clipped gradient sums over one *logical* batch given
    /// as an ordered list of micro-batches, merged micro-batch by
    /// micro-batch in list order; metrics are averaged over the
    /// micro-batches (group clip factors included).
    ///
    /// The default is the single-worker tape: sequential
    /// `clipped_grads` per micro-batch, accumulated in a flat left
    /// fold — the reduction-order contract every parallel override
    /// (e.g. the native sharded driver) must reproduce bitwise.
    fn sharded_grads(
        &mut self,
        batches: &[(BatchX, Vec<i32>)],
        clip: f32,
    ) -> Result<(Vec<Vec<f32>>, StepOut)> {
        if batches.is_empty() {
            bail!("sharded_grads needs at least one micro-batch");
        }
        let mut acc_grads: Vec<Vec<f32>> = Vec::new();
        let mut out = StepOut::default();
        for (x, y) in batches {
            let (grads, micro) = self.clipped_grads(x, y, clip)?;
            merge_micro_batch(&mut acc_grads, &mut out, grads, micro);
        }
        finalize_step_out(&mut out, batches.len());
        Ok((acc_grads, out))
    }

    /// Apply an optimizer update from accumulated gradient sums.
    fn apply_update(&mut self, grads: &[Vec<f32>], noise: &[Vec<f32>], h: &StepHyper) -> Result<()>;

    /// Snapshot params (+ optimizer state) for checkpointing.
    fn state(&self) -> Result<Vec<Vec<f32>>>;

    /// Restore a snapshot (params only, or full state).
    fn load_state(&mut self, tensors: Vec<Vec<f32>>) -> Result<()>;

    /// Cumulative artifact-compile seconds (PJRT; 0 for native).
    fn compile_secs(&self) -> f64 {
        0.0
    }

    fn alloc_stats(&self) -> AllocStats {
        AllocStats::default()
    }
}

/// Fold one micro-batch's clipped gradient sums and metrics into the
/// logical-step accumulators, in arrival order. This is THE
/// reduction-order contract of gradient accumulation and sharding: a
/// flat left fold over micro-batches (`acc += g_k` element-wise, k
/// ascending), so any driver that merges in global micro-batch order —
/// sequential or sharded — produces bitwise-identical sums.
pub fn merge_micro_batch(
    acc_grads: &mut Vec<Vec<f32>>,
    acc_out: &mut StepOut,
    grads: Vec<Vec<f32>>,
    out: StepOut,
) {
    acc_out.loss += out.loss;
    acc_out.mean_clip += out.mean_clip;
    if acc_out.group_clip.is_empty() {
        acc_out.group_clip = out.group_clip;
    } else {
        for (a, g) in acc_out.group_clip.iter_mut().zip(out.group_clip.iter()) {
            *a += *g;
        }
    }
    if acc_grads.is_empty() {
        *acc_grads = grads;
    } else {
        for (a, g) in acc_grads.iter_mut().zip(grads.iter()) {
            for (av, gv) in a.iter_mut().zip(g.iter()) {
                *av += *gv;
            }
        }
    }
}

/// Turn micro-batch metric sums into per-logical-step means.
pub fn finalize_step_out(out: &mut StepOut, micro_batches: usize) {
    let k = micro_batches.max(1) as f32;
    out.loss /= k;
    out.mean_clip /= k;
    for g in out.group_clip.iter_mut() {
        *g /= k;
    }
}

/// Construct the backend selected by the config.
pub fn create_backend(cfg: &crate::config::TrainConfig) -> Result<Box<dyn Backend>> {
    let style = crate::complexity::ClippingStyle::parse(&cfg.clipping_style).ok_or_else(|| {
        anyhow!(
            "unknown clipping_style '{}' (expected all-layer, layer-wise, or group-wise[:k])",
            cfg.clipping_style
        )
    })?;
    match cfg.backend.as_str() {
        "native" => {
            let mut spec = native::model::NativeSpec::by_name(&cfg.model).ok_or_else(|| {
                anyhow!(
                    "model '{}' is not in the native registry (available: {})",
                    cfg.model,
                    native::model::registry_names().join(", ")
                )
            })?;
            if !cfg.trainable.is_empty() {
                // --trainable overrides the registry preset (e.g. run
                // gpt_nano_e2e bias-only without a registry twin)
                spec.trainable = cfg.trainable.clone();
            }
            spec.trainable_preset()?;
            let strategy = crate::complexity::Strategy::parse(&cfg.strategy)
                .ok_or_else(|| anyhow!("unknown strategy '{}'", cfg.strategy))?;
            let dispatch = native::autotune::resolve_dispatch(
                &cfg.dispatch,
                &cfg.dispatch_profile,
                cfg.threads,
            )?;
            if cfg.shards > 1 {
                Ok(Box::new(native::shard::ShardedRun::new(
                    spec,
                    strategy,
                    style,
                    cfg.threads,
                    &dispatch,
                    cfg.shards,
                )?))
            } else {
                Ok(Box::new(
                    native::NativeBackend::builder(spec, strategy)
                        .style(style)
                        .threads(cfg.threads)
                        .dispatch(dispatch)
                        .build()?,
                ))
            }
        }
        "pjrt" if style != crate::complexity::ClippingStyle::AllLayer => bail!(
            "clipping_style '{}' requires the native backend (pjrt artifacts are all-layer only)",
            cfg.clipping_style
        ),
        "pjrt" => {
            #[cfg(feature = "xla-runtime")]
            {
                Ok(Box::new(pjrt::PjrtBackend::load(cfg)?))
            }
            #[cfg(not(feature = "xla-runtime"))]
            {
                bail!(
                    "backend 'pjrt' requires building with --features xla-runtime \
                     (and a local `xla` crate; see DESIGN.md)"
                )
            }
        }
        other => bail!("unknown backend '{other}' (expected 'native' or 'pjrt')"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_backend_native_default() {
        let cfg = crate::config::TrainConfig::default();
        let be = create_backend(&cfg).unwrap();
        assert_eq!(be.info().name, cfg.model);
        assert_eq!(be.strategy(), cfg.strategy);
        assert_eq!(be.compile_secs(), 0.0);
    }

    #[test]
    fn create_backend_rejects_unknowns() {
        let mut cfg = crate::config::TrainConfig::default();
        cfg.model = "not_a_model".into();
        assert!(create_backend(&cfg).is_err());
        let mut cfg = crate::config::TrainConfig::default();
        cfg.backend = "tpu".into();
        assert!(create_backend(&cfg).is_err());
    }

    #[cfg(not(feature = "xla-runtime"))]
    #[test]
    fn pjrt_backend_gated_off_by_default() {
        let mut cfg = crate::config::TrainConfig::default();
        cfg.backend = "pjrt".into();
        let err = create_backend(&cfg).unwrap_err().to_string();
        assert!(err.contains("xla-runtime"), "{err}");
    }

    #[test]
    fn create_backend_honors_clipping_style() {
        let mut cfg = crate::config::TrainConfig::default();
        cfg.clipping_style = "layer-wise".into();
        assert!(create_backend(&cfg).is_ok());
        cfg.clipping_style = "group-wise:3".into();
        assert!(create_backend(&cfg).is_ok());
        cfg.clipping_style = "per-tensor".into();
        assert!(create_backend(&cfg).is_err());
        // pjrt artifacts only support flat clipping
        let mut cfg = crate::config::TrainConfig::default();
        cfg.backend = "pjrt".into();
        cfg.clipping_style = "layer-wise".into();
        let err = create_backend(&cfg).unwrap_err().to_string();
        assert!(err.contains("native"), "{err}");
    }

    #[test]
    fn create_backend_shards_selects_sharded_driver() {
        let mut cfg = crate::config::TrainConfig::default();
        cfg.shards = 3;
        let be = create_backend(&cfg).unwrap();
        // Same public surface as the single-worker backend.
        assert_eq!(be.info().name, cfg.model);
        assert_eq!(be.strategy(), cfg.strategy);
        // shards == 1 keeps the bare NativeBackend path working.
        cfg.shards = 1;
        assert!(create_backend(&cfg).is_ok());
    }

    #[test]
    fn model_info_helpers() {
        let info = native::model::NativeSpec::by_name("mlp_e2e").unwrap().info();
        assert!(!info.is_adam());
        assert_eq!(info.state_tensor_count(), info.param_names.len());
        assert_eq!(info.param_shape("w0").unwrap(), &[128, 256]);
        assert!(info.param_shape("nope").is_err());
        let seq = native::model::NativeSpec::by_name("seq_e2e").unwrap().info();
        assert!(seq.is_adam());
        assert_eq!(seq.state_tensor_count(), 3 * seq.param_names.len());
    }
}
