//! Privacy accounting for DP-SGD (Section 1.3 / Appendix A of the paper).
//!
//! Implements the Rényi-DP accountant for the Poisson-subsampled Gaussian
//! mechanism (Abadi et al. 2016 moments accountant; Mironov 2017; Mironov
//! et al. 2019 "RDP of the Sampled Gaussian Mechanism"), plus the
//! conversion to (epsilon, delta)-DP and noise calibration by binary
//! search. The coordinator consults this every step and enforces the
//! budget.
//!
//! RDP of the sampled Gaussian at integer order alpha >= 2 (q < 1):
//!
//!   RDP(alpha) = 1/(alpha-1) * log( sum_{j=0}^{alpha}
//!                  C(alpha, j) (1-q)^(alpha-j) q^j exp(j(j-1)/(2 sigma^2)) )
//!
//! For q = 1 this degenerates to the Gaussian mechanism: alpha/(2 sigma^2).
//! Fractional orders are handled by evaluating on an integer grid (the
//! standard practice in TF-Privacy / Opacus; the bound is an upper bound
//! so integer restriction stays valid).

use crate::util::math::{ln_binom, log_sum_exp};

/// Order grid used for the epsilon minimization (Opacus default-like).
pub fn default_orders() -> Vec<f64> {
    let mut v: Vec<f64> = (2..64).map(|x| x as f64).collect();
    v.extend([
        64.0, 80.0, 96.0, 128.0, 192.0, 256.0, 384.0, 512.0, 1024.0,
    ]);
    v
}

/// RDP of one sampled-Gaussian step at integer order `alpha`.
pub fn rdp_sampled_gaussian(q: f64, sigma: f64, alpha: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "q={q}");
    assert!(sigma > 0.0, "sigma={sigma}");
    assert!(alpha > 1.0, "alpha={alpha}");
    if q == 0.0 {
        return 0.0;
    }
    if (q - 1.0).abs() < 1e-12 {
        return alpha / (2.0 * sigma * sigma);
    }
    let a = alpha.round();
    let mut terms = Vec::with_capacity(a as usize + 1);
    for j in 0..=(a as u64) {
        let jf = j as f64;
        let log_term = ln_binom(a, jf)
            + jf * q.ln()
            + (a - jf) * (1.0 - q).ln()
            + jf * (jf - 1.0) / (2.0 * sigma * sigma);
        terms.push(log_term);
    }
    log_sum_exp(&terms) / (a - 1.0)
}

/// Convert accumulated RDP (per order) to epsilon at the given delta,
/// using the improved conversion of Balle et al. 2020 (also in Opacus):
///   eps = rdp - (ln delta + ln alpha)/(alpha-1) + ln((alpha-1)/alpha)
#[allow(clippy::needless_range_loop)]
pub fn rdp_to_epsilon(orders: &[f64], rdp: &[f64], delta: f64) -> f64 {
    assert_eq!(orders.len(), rdp.len());
    assert!(delta > 0.0 && delta < 1.0);
    let mut best = f64::INFINITY;
    for i in 0..orders.len() {
        let a = orders[i];
        let eps = rdp[i] - (delta.ln() + a.ln()) / (a - 1.0) + ((a - 1.0) / a).ln();
        if eps >= 0.0 && eps < best {
            best = eps;
        }
    }
    best
}

/// Stateful accountant: composes steps of the subsampled Gaussian.
#[derive(Clone, Debug)]
pub struct RdpAccountant {
    orders: Vec<f64>,
    rdp: Vec<f64>,
    pub steps: u64,
    pub q: f64,
    pub sigma: f64,
}

impl RdpAccountant {
    pub fn new(q: f64, sigma: f64) -> Self {
        let orders = default_orders();
        let rdp = vec![0.0; orders.len()];
        Self {
            orders,
            rdp,
            steps: 0,
            q,
            sigma,
        }
    }

    /// Account one optimizer step (RDP composes additively).
    pub fn step(&mut self) {
        for (i, &a) in self.orders.iter().enumerate() {
            self.rdp[i] += rdp_sampled_gaussian(self.q, self.sigma, a);
        }
        self.steps += 1;
    }

    /// Account `n` steps at once (same cost as one: scale by n).
    pub fn advance(&mut self, n: u64) {
        if n == 0 {
            return;
        }
        for (i, &a) in self.orders.iter().enumerate() {
            self.rdp[i] += n as f64 * rdp_sampled_gaussian(self.q, self.sigma, a);
        }
        self.steps += n;
    }

    pub fn epsilon(&self, delta: f64) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        rdp_to_epsilon(&self.orders, &self.rdp, delta)
    }
}

/// Epsilon after `steps` steps of sampled Gaussian (stateless helper).
pub fn epsilon_for(q: f64, sigma: f64, steps: u64, delta: f64) -> f64 {
    let orders = default_orders();
    let rdp: Vec<f64> = orders
        .iter()
        .map(|&a| steps as f64 * rdp_sampled_gaussian(q, sigma, a))
        .collect();
    rdp_to_epsilon(&orders, &rdp, delta)
}

/// Calibrate the noise multiplier sigma to hit `target_eps` at `delta`
/// after `steps` steps with sampling rate `q` (binary search; epsilon is
/// monotone decreasing in sigma).
pub fn calibrate_sigma(q: f64, steps: u64, target_eps: f64, delta: f64) -> f64 {
    assert!(target_eps > 0.0);
    let eps_at = |sigma: f64| epsilon_for(q, sigma, steps, delta);
    let mut lo = 0.05;
    let mut hi = 1.0;
    // grow hi until private enough, shrink lo until not
    while eps_at(hi) > target_eps {
        hi *= 2.0;
        assert!(hi < 1e6, "calibration diverged");
    }
    while eps_at(lo) < target_eps && lo > 1e-6 {
        lo /= 2.0;
    }
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if eps_at(mid) > target_eps {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi // conservative side: eps(hi) <= target
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_mechanism_q1_matches_rdp_formula() {
        for sigma in [0.5, 1.0, 4.0] {
            for alpha in [2.0, 8.0, 64.0] {
                let r = rdp_sampled_gaussian(1.0, sigma, alpha);
                assert!((r - alpha / (2.0 * sigma * sigma)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn subsampling_amplifies_privacy() {
        // smaller q => smaller RDP at fixed sigma/alpha
        let r_full = rdp_sampled_gaussian(1.0, 1.0, 8.0);
        let r_half = rdp_sampled_gaussian(0.5, 1.0, 8.0);
        let r_small = rdp_sampled_gaussian(0.01, 1.0, 8.0);
        assert!(r_small < r_half && r_half < r_full);
        // and q = 0 gives zero loss
        assert_eq!(rdp_sampled_gaussian(0.0, 1.0, 8.0), 0.0);
    }

    #[test]
    fn epsilon_monotonicity() {
        // more steps => more epsilon
        let e100 = epsilon_for(0.01, 1.0, 100, 1e-5);
        let e1000 = epsilon_for(0.01, 1.0, 1000, 1e-5);
        assert!(e1000 > e100);
        // more noise => less epsilon
        let e_lo = epsilon_for(0.01, 2.0, 1000, 1e-5);
        assert!(e_lo < e1000);
        // bigger delta => smaller epsilon
        let e_bigdelta = epsilon_for(0.01, 1.0, 1000, 1e-3);
        assert!(e_bigdelta < e1000);
    }

    #[test]
    fn matches_known_abadi_regime() {
        // The canonical DP-SGD MNIST setting (q=0.01, sigma=1.1, 10k steps,
        // delta=1e-5) is known to give eps in the low single digits via
        // the moments accountant (Abadi et al. report ~2-4 over epochs).
        let eps = epsilon_for(0.01, 1.1, 10_000, 1e-5);
        assert!(eps > 1.0 && eps < 6.0, "eps={eps}");
    }

    #[test]
    fn accountant_composes_like_stateless() {
        let mut acc = RdpAccountant::new(0.02, 1.2);
        for _ in 0..50 {
            acc.step();
        }
        let e_state = acc.epsilon(1e-5);
        let e_direct = epsilon_for(0.02, 1.2, 50, 1e-5);
        assert!((e_state - e_direct).abs() < 1e-9);
        let mut acc2 = RdpAccountant::new(0.02, 1.2);
        acc2.advance(50);
        assert!((acc2.epsilon(1e-5) - e_direct).abs() < 1e-9);
    }

    #[test]
    fn calibration_roundtrips() {
        for (q, steps, eps) in [(0.01, 1000, 3.0), (0.05, 500, 8.0), (0.001, 20_000, 1.0)] {
            let sigma = calibrate_sigma(q, steps, eps, 1e-5);
            let achieved = epsilon_for(q, sigma, steps, 1e-5);
            assert!(achieved <= eps * 1.001, "eps {achieved} > target {eps}");
            // and not over-noised by more than ~1%
            let eps_slightly_less_noise = epsilon_for(q, sigma * 0.98, steps, 1e-5);
            assert!(eps_slightly_less_noise > eps * 0.98);
        }
    }

    #[test]
    fn q1_single_step_close_to_analytic_gaussian() {
        // classic sufficient condition: sigma = sqrt(2 ln(1.25/delta))/eps
        // RDP conversion should land within ~35% of the classic bound.
        let delta = 1e-5;
        let eps_target = 1.0;
        let sigma_classic = (2.0 * (1.25f64 / delta).ln()).sqrt() / eps_target;
        let eps_rdp = epsilon_for(1.0, sigma_classic, 1, delta);
        assert!(
            eps_rdp < eps_target * 1.35 && eps_rdp > eps_target * 0.3,
            "eps_rdp={eps_rdp}"
        );
    }
}
