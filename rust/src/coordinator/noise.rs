//! DP noise generation — the privacy-critical sampling path.
//!
//! Kept in one auditable place at the coordinator (backends take noise
//! as an *input* and never sample it — neither the native kernels nor
//! the JAX artifacts own randomness). Streams are forked per
//! (step, tensor) so accumulation order can't correlate draws. Swap
//! `NoiseSource` for a DRBG-backed implementation for production
//! deployments; this interface is the only thing the trainer sees.

use crate::runtime::ModelInfo;
use crate::util::rng::{GaussianSource, Xoshiro256};

pub struct NoiseSource {
    root: Xoshiro256,
    step: u64,
}

impl NoiseSource {
    pub fn new(seed: u64) -> Self {
        Self {
            root: Xoshiro256::new(seed),
            step: 0,
        }
    }

    /// Draw sets consumed so far (persisted in checkpoints).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Fast-forward the step counter (checkpoint resume): the draws for
    /// steps 1..=step were already consumed by the pre-crash run and
    /// must never be replayed — reusing them would correlate the
    /// resumed noise with the released pre-crash parameters.
    pub fn skip_to(&mut self, step: u64) {
        self.step = self.step.max(step);
    }

    /// Standard-normal tensors, one per tensor in `param_names` order;
    /// frozen tensors get an empty draw (no gradient is released for
    /// them, so noising them would only waste privacy-neutral entropy).
    /// Streams stay forked per (step, slot index) — a trainable slot's
    /// draw is identical whatever the mask around it, so changing the
    /// mask between runs never re-correlates surviving streams. Each
    /// call advances the step counter (one logical batch = one draw
    /// set).
    pub fn tensors(&mut self, info: &ModelInfo) -> Vec<Vec<f32>> {
        self.step += 1;
        info.param_names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                if !info.trainable[i] {
                    return Vec::new();
                }
                let n: usize = info.param_shapes[name].iter().product();
                let mut gs =
                    GaussianSource::from_rng(self.root.fork(self.step * 1_000_003 + i as u64));
                let mut buf = vec![0f32; n];
                gs.fill_f32(&mut buf);
                buf
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::model::NativeSpec;

    fn two_tensor_info() -> ModelInfo {
        NativeSpec {
            name: "noise_t".into(),
            batch: 1,
            seq: 1,
            d_in: 16,
            hidden: vec![],
            n_classes: 16,
            optimizer: "sgd".into(),
            clip_fn: "abadi".into(),
            ..NativeSpec::default()
        }
        .info()
    }

    #[test]
    fn draws_differ_across_steps_and_tensors() {
        let info = two_tensor_info();
        assert_eq!(info.param_names.len(), 2); // w0 (16x16), b0 (16)
        let mut ns = NoiseSource::new(7);
        let t1 = ns.tensors(&info);
        let t2 = ns.tensors(&info);
        assert_eq!(t1[0].len(), 256);
        assert_eq!(t1[1].len(), 16);
        assert_ne!(t1[0][..16], t1[1][..], "tensor streams must differ");
        assert_ne!(t1[0], t2[0], "step streams must differ");
        // determinism under same seed
        let mut ns2 = NoiseSource::new(7);
        let t1b = ns2.tensors(&info);
        assert_eq!(t1[0], t1b[0]);
        assert_eq!(t1[1], t1b[1]);
    }

    #[test]
    fn frozen_slots_draw_nothing_without_shifting_streams() {
        let mut spec = NativeSpec {
            name: "noise_t".into(),
            batch: 1,
            seq: 1,
            d_in: 16,
            hidden: vec![],
            n_classes: 16,
            optimizer: "sgd".into(),
            clip_fn: "abadi".into(),
            ..NativeSpec::default()
        };
        let full = spec.info();
        spec.trainable = "bias-only".into();
        let masked = spec.info();
        assert_eq!(masked.trainable, vec![false, true]);
        let all = NoiseSource::new(11).tensors(&full);
        let some = NoiseSource::new(11).tensors(&masked);
        assert!(some[0].is_empty(), "frozen slot must draw nothing");
        // the trainable slot's stream is keyed by slot index, not by
        // its position among trainable slots: identical under any mask
        assert_eq!(some[1], all[1]);
    }

    #[test]
    fn skip_to_burns_consumed_draws() {
        let info = two_tensor_info();
        let mut pre_crash = NoiseSource::new(9);
        let step1 = pre_crash.tensors(&info);
        let step2 = pre_crash.tensors(&info);
        // resume after one completed step: must continue at step 2
        let mut resumed = NoiseSource::new(9);
        resumed.skip_to(1);
        let next = resumed.tensors(&info);
        assert_eq!(next[0], step2[0], "resume must continue the stream");
        assert_ne!(next[0], step1[0], "resume must not replay spent draws");
    }
}
