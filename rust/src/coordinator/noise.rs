//! DP noise generation — the privacy-critical sampling path.
//!
//! Kept in one auditable place at L3 (the JAX artifacts take noise as an
//! input and never sample it). Streams are forked per (step, tensor) so
//! accumulation order can't correlate draws. Swap `NoiseSource` for a
//! DRBG-backed implementation for production deployments; the interface
//! is the only thing the trainer sees.

use crate::runtime::{literal_f32, ModelMeta};
use crate::util::rng::{GaussianSource, Xoshiro256};
use anyhow::{anyhow, Result};

pub struct NoiseSource {
    root: Xoshiro256,
    step: u64,
}

impl NoiseSource {
    pub fn new(seed: u64) -> Self {
        Self {
            root: Xoshiro256::new(seed),
            step: 0,
        }
    }

    /// Standard-normal literals, one per trainable tensor. Each call
    /// advances the step counter (one logical batch = one draw set).
    pub fn tensors(&mut self, meta: &ModelMeta) -> Result<Vec<xla::Literal>> {
        self.step += 1;
        meta.param_names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let shape = meta.param_shape(name).map_err(|e| anyhow!(e))?;
                let n: usize = shape.iter().product();
                let mut gs =
                    GaussianSource::from_rng(self.root.fork(self.step * 1_000_003 + i as u64));
                let mut buf = vec![0f32; n];
                gs.fill_f32(&mut buf);
                literal_f32(&buf, shape)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_differ_across_steps_and_tensors() {
        // build a fake 2-tensor meta via the manifest parser
        let v = crate::json::parse(
            r#"{
          "models": {"m": {"spec": null, "batch": 1, "optimizer": "sgd",
            "clip_fn": "abadi", "group": "t", "param_names": ["a", "b"],
            "frozen_names": [], "param_shapes": {"a": [16], "b": [16]},
            "layer_meta": [], "n_params": 32}},
          "artifacts": []}"#,
        )
        .unwrap();
        let m = crate::runtime::Manifest::from_json(&v).unwrap();
        let meta = m.models["m"].clone();
        let mut ns = NoiseSource::new(7);
        let t1 = ns.tensors(&meta).unwrap();
        let t2 = ns.tensors(&meta).unwrap();
        let a1 = t1[0].to_vec::<f32>().unwrap();
        let b1 = t1[1].to_vec::<f32>().unwrap();
        let a2 = t2[0].to_vec::<f32>().unwrap();
        assert_ne!(a1, b1, "tensor streams must differ");
        assert_ne!(a1, a2, "step streams must differ");
        // determinism under same seed
        let mut ns2 = NoiseSource::new(7);
        let t1b = ns2.tensors(&meta).unwrap();
        assert_eq!(a1, t1b[0].to_vec::<f32>().unwrap());
    }
}
