//! Crash-safe checkpointing — format v2 (`FASTDP02`).
//!
//! Layout: `magic(8) | header_len u64 LE (8) | header_crc32 u32 LE (4) |
//! header JSON | payload` where the payload is the raw little-endian f32
//! tensor data (params, then Adam m / v moments) in `ModelInfo` state
//! order. The header carries:
//!
//!  * tensor `lengths` (validated against `ModelInfo::state_tensor_lens`
//!    on load — a malformed header is an error, never empty tensors),
//!  * `payload_crc`: CRC-32 of the payload (bit-flips and truncation are
//!    detected before any tensor reaches the backend),
//!  * a privacy [`Fingerprint`] (strategy, clipping style/fn, clip R,
//!    sigma, seed, logical batch) — resume refuses on mismatch instead
//!    of silently changing the DP semantics of already-spent budget,
//!  * stream [`Cursors`] (noise step, data draw cursor, accountant
//!    steps) so a resumed run continues every deterministic stream
//!    exactly where the killed run left it.
//!
//! Publishing is atomic: write to a `.ckpt_*.tmp`, fsync the file,
//! rename into place, fsync the directory. A crash at any point leaves
//! either the previous good checkpoint or a stale `.tmp` that
//! [`sweep_stale_tmps`] removes and [`latest`]/[`list_desc`] never
//! consider. v1 (`FASTDP01`) files remain loadable (no CRC or
//! fingerprint — the caller falls back to step-derived cursors).
//!
//! The [`fault`] submodule is a test-only injection hook (kill
//! mid-write, kill before rename, truncate, bit-flip) driving the
//! crash-recovery suite; it is a single mutex check per save and is
//! never armed outside tests.

use crate::error::{Context, Result};
use crate::json::Value;
use crate::runtime::ModelInfo;
use crate::util::crc::crc32;
use crate::{anyhow, bail};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC_V1: &[u8; 8] = b"FASTDP01";
const MAGIC_V2: &[u8; 8] = b"FASTDP02";
/// Header-length sanity cap: anything larger is a corrupt length field,
/// not a real header (headers are a few hundred bytes).
const MAX_HEADER_BYTES: u64 = 16 * 1024 * 1024;

/// Test-only fault injection for the crash-recovery suite.
///
/// Arm a fault before a save; the next [`save`] consumes it (one-shot).
/// `KillMidWrite` / `KillBeforeRename` make the save fail the way a
/// `kill -9` at that point would (partial or complete `.tmp`, nothing
/// published); `Truncate` / `BitFlip` publish normally and then damage
/// the published file, simulating media corruption the *next* load must
/// catch and fall back from.
pub mod fault {
    use std::sync::Mutex;

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum Fault {
        /// Die after writing the header and half the payload to `.tmp`.
        KillMidWrite,
        /// Die after a complete, fsynced `.tmp` but before the rename.
        KillBeforeRename,
        /// Publish, then chop N bytes off the end of the file.
        Truncate(usize),
        /// Publish, then XOR one bit at the given byte offset (clamped
        /// to the file; offsets past the header land in the payload).
        BitFlip(usize),
    }

    static ARMED: Mutex<Option<Fault>> = Mutex::new(None);

    /// Marker prefix of injected-kill error messages, so tests can tell
    /// a simulated crash from a real I/O failure.
    pub const INJECTED: &str = "injected fault";

    pub fn arm(f: Fault) {
        *ARMED.lock().unwrap() = Some(f);
    }

    pub fn disarm() {
        *ARMED.lock().unwrap() = None;
    }

    pub(super) fn take() -> Option<Fault> {
        ARMED.lock().unwrap().take()
    }
}

/// The config/privacy identity of a training run. Persisted in every v2
/// checkpoint; resume compares it field-by-field against the live run
/// and refuses on any mismatch — a checkpoint resumed under different
/// clipping, noise, seed, or batching would silently change what the
/// already-released steps meant for the privacy ledger.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    pub strategy: String,
    pub clipping_style: String,
    pub clip_fn: String,
    pub clip: f64,
    pub sigma: f64,
    pub seed: u64,
    pub logical_batch: usize,
    /// Canonical trainability preset (`Trainable::canonical`): which
    /// tensors the released gradients covered. Resuming under a
    /// different mask would splice two incompatible gradient streams
    /// into one ledger (and desynchronize the per-slot noise streams).
    /// v1 files and v2 files from before this field resume as "all".
    pub trainable: String,
}

impl Fingerprint {
    fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("strategy", Value::from(self.strategy.as_str()));
        v.set("clipping_style", Value::from(self.clipping_style.as_str()));
        v.set("clip_fn", Value::from(self.clip_fn.as_str()));
        v.set("clip", Value::from(self.clip));
        v.set("sigma", Value::from(self.sigma));
        // u64 seeds may exceed i64: store as a decimal string
        v.set("seed", Value::from(self.seed.to_string()));
        v.set("logical_batch", Value::from(self.logical_batch));
        v.set("trainable", Value::from(self.trainable.as_str()));
        v
    }

    fn from_json(v: &Value) -> Result<Fingerprint> {
        let seed: u64 = v
            .req_str("seed")
            .map_err(|e| anyhow!("fingerprint: {e}"))?
            .parse()
            .context("fingerprint: seed is not a u64")?;
        Ok(Fingerprint {
            strategy: v.req_str("strategy").map_err(|e| anyhow!("fingerprint: {e}"))?.to_string(),
            clipping_style: v
                .req_str("clipping_style")
                .map_err(|e| anyhow!("fingerprint: {e}"))?
                .to_string(),
            clip_fn: v.req_str("clip_fn").map_err(|e| anyhow!("fingerprint: {e}"))?.to_string(),
            clip: v.req_f64("clip").map_err(|e| anyhow!("fingerprint: {e}"))?,
            sigma: v.req_f64("sigma").map_err(|e| anyhow!("fingerprint: {e}"))?,
            seed,
            logical_batch: v
                .req_i64("logical_batch")
                .map_err(|e| anyhow!("fingerprint: {e}"))? as usize,
            // pre-trainability v2 checkpoints were always fully trainable
            trainable: v.opt_str("trainable", "all").to_string(),
        })
    }

    /// Refuse resume on any field drift, naming every mismatch.
    pub fn check(&self, run: &Fingerprint) -> Result<()> {
        let mut diffs: Vec<String> = Vec::new();
        if self.strategy != run.strategy {
            diffs.push(format!("strategy '{}' vs run '{}'", self.strategy, run.strategy));
        }
        if self.clipping_style != run.clipping_style {
            diffs.push(format!(
                "clipping_style '{}' vs run '{}'",
                self.clipping_style, run.clipping_style
            ));
        }
        if self.clip_fn != run.clip_fn {
            diffs.push(format!("clip_fn '{}' vs run '{}'", self.clip_fn, run.clip_fn));
        }
        if self.clip.to_bits() != run.clip.to_bits() {
            diffs.push(format!("clip R {} vs run {}", self.clip, run.clip));
        }
        if self.sigma.to_bits() != run.sigma.to_bits() {
            diffs.push(format!("sigma {} vs run {}", self.sigma, run.sigma));
        }
        if self.seed != run.seed {
            diffs.push(format!("seed {} vs run {}", self.seed, run.seed));
        }
        if self.logical_batch != run.logical_batch {
            diffs.push(format!(
                "logical_batch {} vs run {}",
                self.logical_batch, run.logical_batch
            ));
        }
        if self.trainable != run.trainable {
            diffs.push(format!(
                "trainable '{}' vs run '{}'",
                self.trainable, run.trainable
            ));
        }
        if !diffs.is_empty() {
            bail!(
                "checkpoint fingerprint mismatch ({}) — resuming would silently change the \
                 privacy semantics of budget already spent. Re-run with the original flags, \
                 or point --checkpoint-dir at a fresh directory to start over",
                diffs.join("; ")
            );
        }
        Ok(())
    }
}

/// Positions of every deterministic stream at checkpoint time. Restoring
/// them is what makes kill/resume bitwise: the noise draws and data
/// batches consumed before the crash are burned, never replayed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Cursors {
    /// `NoiseSource` step counter (draw sets consumed).
    pub noise_step: u64,
    /// `BatchSource` training-draw cursor (micro-batches consumed).
    pub data_cursor: u64,
    /// `RdpAccountant` composed steps.
    pub accountant_steps: u64,
}

impl Cursors {
    fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("noise_step", Value::from(self.noise_step as i64));
        v.set("data_cursor", Value::from(self.data_cursor as i64));
        v.set("accountant_steps", Value::from(self.accountant_steps as i64));
        v
    }

    fn from_json(v: &Value) -> Result<Cursors> {
        let get = |k: &str| -> Result<u64> {
            let x = v.req_i64(k).map_err(|e| anyhow!("cursors: {e}"))?;
            if x < 0 {
                bail!("cursors: '{k}' is negative ({x})");
            }
            Ok(x as u64)
        };
        Ok(Cursors {
            noise_step: get("noise_step")?,
            data_cursor: get("data_cursor")?,
            accountant_steps: get("accountant_steps")?,
        })
    }
}

/// Everything required to save one checkpoint (besides the tensors).
pub struct SaveMeta<'a> {
    pub step: usize,
    pub info: &'a ModelInfo,
    pub fingerprint: &'a Fingerprint,
    pub cursors: Cursors,
    /// Prune to this many newest checkpoints after a successful
    /// publish; 0 keeps everything.
    pub keep_last: usize,
}

/// A fully parsed, integrity-checked checkpoint file.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Format version: 1 (`FASTDP01`) or 2 (`FASTDP02`).
    pub version: u8,
    pub model: String,
    pub optimizer: String,
    pub step: usize,
    /// v2 only; `None` for v1 files (back-compat: accepted unchecked).
    pub fingerprint: Option<Fingerprint>,
    /// v2 only; v1 resumes derive cursors from `step`.
    pub cursors: Option<Cursors>,
    pub tensors: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Semantic validation against the live model: name, tensor count
    /// (params-only or full state), and every tensor length against
    /// `param_shapes`. Structural corruption is caught earlier, in
    /// [`read`]; failures here mean the checkpoint belongs to a
    /// different model and must not be loaded.
    pub fn validate(&self, info: &ModelInfo) -> Result<()> {
        if self.model != info.name {
            bail!("checkpoint is for model '{}', expected '{}'", self.model, info.name);
        }
        let full = info.state_tensor_lens();
        let n_params = info.param_names.len();
        let want: &[usize] = if self.tensors.len() == n_params {
            &full[..n_params]
        } else if self.tensors.len() == full.len() {
            &full[..]
        } else {
            bail!(
                "checkpoint for '{}' has {} tensors, expected {} (params only) or {} (full state)",
                info.name,
                self.tensors.len(),
                n_params,
                full.len()
            );
        };
        for (i, (t, w)) in self.tensors.iter().zip(want.iter()).enumerate() {
            if t.len() != *w {
                let name = &info.param_names[i % n_params];
                let part = match i / n_params {
                    0 => "param",
                    1 => "adam-m",
                    _ => "adam-v",
                };
                bail!(
                    "checkpoint tensor {i} ({part} '{name}') has {} elements, expected {w} \
                     from the model's param shapes",
                    t.len()
                );
            }
        }
        Ok(())
    }

    /// Total floats across all tensors.
    pub fn total_floats(&self) -> usize {
        self.tensors.iter().map(Vec::len).sum()
    }
}

/// Save a v2 checkpoint atomically. Refuses non-finite tensors — a
/// poisoned state must never be persisted (the non-finite step guards
/// keep it out of the backend; this is the last line of defense).
pub fn save(dir: &Path, meta: &SaveMeta, tensors: &[Vec<f32>]) -> Result<PathBuf> {
    for (i, t) in tensors.iter().enumerate() {
        if t.iter().any(|x| !x.is_finite()) {
            bail!(
                "refusing to checkpoint at step {}: tensor {i} contains non-finite values",
                meta.step
            );
        }
    }
    std::fs::create_dir_all(dir)?;

    let mut payload: Vec<u8> = Vec::with_capacity(tensors.iter().map(|t| t.len() * 4).sum());
    for t in tensors {
        for x in t {
            payload.extend_from_slice(&x.to_le_bytes());
        }
    }
    let payload_crc = crc32(&payload);

    let mut header = Value::obj();
    header.set("format", Value::from(2usize));
    header.set("model", Value::from(meta.info.name.as_str()));
    header.set("step", Value::from(meta.step));
    header.set("optimizer", Value::from(meta.info.optimizer.as_str()));
    header.set(
        "lengths",
        Value::Arr(tensors.iter().map(|t| Value::from(t.len())).collect()),
    );
    header.set("payload_crc", Value::from(payload_crc as i64));
    header.set("fingerprint", meta.fingerprint.to_json());
    header.set("cursors", meta.cursors.to_json());
    let htext = header.to_string();
    let hcrc = crc32(htext.as_bytes());

    let path = dir.join(format!("ckpt_{:08}.fdp", meta.step));
    let tmp = dir.join(format!(".ckpt_{:08}.tmp", meta.step));
    let injected = fault::take();
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(MAGIC_V2)?;
        f.write_all(&(htext.len() as u64).to_le_bytes())?;
        f.write_all(&hcrc.to_le_bytes())?;
        f.write_all(htext.as_bytes())?;
        if injected == Some(fault::Fault::KillMidWrite) {
            f.write_all(&payload[..payload.len() / 2])?;
            f.sync_all()?;
            bail!("{}: killed mid-write of {}", fault::INJECTED, tmp.display());
        }
        f.write_all(&payload)?;
        f.sync_all()?;
    }
    if injected == Some(fault::Fault::KillBeforeRename) {
        bail!("{}: killed before rename of {}", fault::INJECTED, tmp.display());
    }
    std::fs::rename(&tmp, &path)?; // atomic publish
    // fsync the directory so the rename itself survives power loss
    // (best-effort: not every platform lets you open a directory).
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    match injected {
        Some(fault::Fault::Truncate(n)) => {
            let len = std::fs::metadata(&path)?.len();
            let f = std::fs::OpenOptions::new().write(true).open(&path)?;
            f.set_len(len.saturating_sub(n as u64))?;
        }
        Some(fault::Fault::BitFlip(off)) => {
            let mut bytes = std::fs::read(&path)?;
            if !bytes.is_empty() {
                let i = off.min(bytes.len() - 1);
                bytes[i] ^= 0x08;
                std::fs::write(&path, bytes)?;
            }
        }
        _ => {}
    }
    if meta.keep_last > 0 {
        prune(dir, meta.keep_last)?;
    }
    Ok(path)
}

/// Legacy v1 writer (`FASTDP01`: JSON header, no CRC, no fingerprint).
/// Kept only so the back-compat suite can generate v1 files the way the
/// pre-v2 code did; production saves always write v2.
pub fn save_v1(dir: &Path, step: usize, info: &ModelInfo, tensors: &[Vec<f32>]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut header = Value::obj();
    header.set("model", Value::from(info.name.as_str()));
    header.set("step", Value::from(step));
    header.set("optimizer", Value::from(info.optimizer.as_str()));
    header.set(
        "lengths",
        Value::Arr(tensors.iter().map(|t| Value::from(t.len())).collect()),
    );
    let htext = header.to_string();
    let path = dir.join(format!("ckpt_{step:08}.fdp"));
    let tmp = dir.join(format!(".ckpt_{step:08}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(MAGIC_V1)?;
        f.write_all(&(htext.len() as u64).to_le_bytes())?;
        f.write_all(htext.as_bytes())?;
        for t in tensors {
            let mut bytes = Vec::with_capacity(t.len() * 4);
            for x in t {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            f.write_all(&bytes)?;
        }
        f.sync_all()?;
    }
    std::fs::rename(&tmp, &path)?;
    Ok(())
}

/// Structural read + integrity check of one checkpoint file (either
/// format version). Every way a file can be damaged — bad magic,
/// corrupt length field, header CRC mismatch, malformed JSON, invalid
/// lengths, truncated/overlong payload, payload CRC mismatch — is an
/// error here, so the resume loop can fall back to an older file.
/// Semantic checks against a model live in [`Checkpoint::validate`].
pub fn read(path: &Path) -> Result<Checkpoint> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)
        .with_context(|| format!("reading magic of {}", path.display()))?;
    let version: u8 = if &magic == MAGIC_V2 {
        2
    } else if &magic == MAGIC_V1 {
        1
    } else {
        bail!("bad checkpoint magic in {}", path.display());
    };
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb)
        .with_context(|| format!("reading header length of {}", path.display()))?;
    let hlen = u64::from_le_bytes(lenb);
    if hlen == 0 || hlen > MAX_HEADER_BYTES {
        bail!("malformed header length {hlen} in {}", path.display());
    }
    let header_crc = if version == 2 {
        let mut c = [0u8; 4];
        f.read_exact(&mut c)
            .with_context(|| format!("reading header CRC of {}", path.display()))?;
        Some(u32::from_le_bytes(c))
    } else {
        None
    };
    let mut hbytes = vec![0u8; hlen as usize];
    f.read_exact(&mut hbytes)
        .with_context(|| format!("truncated header in {}", path.display()))?;
    if let Some(want) = header_crc {
        let got = crc32(&hbytes);
        if got != want {
            bail!(
                "header CRC mismatch in {} (stored {want:08x}, computed {got:08x})",
                path.display()
            );
        }
    }
    let header = crate::json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow!("checkpoint header of {}: {e}", path.display()))?;
    let model = header.req_str("model").map_err(|e| anyhow!(e))?.to_string();
    let optimizer = header.opt_str("optimizer", "sgd").to_string();
    let step_raw = header.req_i64("step").map_err(|e| anyhow!(e))?;
    if step_raw < 0 {
        bail!("checkpoint header of {} has negative step {step_raw}", path.display());
    }
    let step = step_raw as usize;
    // Strict length parsing: a malformed entry is an error, never a
    // silent empty tensor.
    let raw_lengths = header.req_arr("lengths").map_err(|e| anyhow!(e))?;
    let mut lengths: Vec<usize> = Vec::with_capacity(raw_lengths.len());
    for (i, v) in raw_lengths.iter().enumerate() {
        match v.as_usize() {
            Some(n) => lengths.push(n),
            None => bail!(
                "malformed header in {}: lengths[{i}] = {v} is not a non-negative integer",
                path.display()
            ),
        }
    }
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)
        .with_context(|| format!("reading payload of {}", path.display()))?;
    let want_bytes: usize = lengths.iter().map(|n| n * 4).sum();
    if payload.len() != want_bytes {
        bail!(
            "payload of {} is {} bytes, header declares {want_bytes} — truncated or corrupt",
            path.display(),
            payload.len()
        );
    }
    if version == 2 {
        let want = header.req_i64("payload_crc").map_err(|e| anyhow!(e))? as u32;
        let got = crc32(&payload);
        if got != want {
            bail!(
                "payload CRC mismatch in {} (stored {want:08x}, computed {got:08x})",
                path.display()
            );
        }
    }
    let mut tensors = Vec::with_capacity(lengths.len());
    let mut off = 0usize;
    for n in lengths {
        let mut t = Vec::with_capacity(n);
        for c in payload[off..off + n * 4].chunks_exact(4) {
            t.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        off += n * 4;
        tensors.push(t);
    }
    let fingerprint = match header.get("fingerprint") {
        Some(v) => Some(Fingerprint::from_json(v)?),
        None => None,
    };
    let cursors = match header.get("cursors") {
        Some(v) => Some(Cursors::from_json(v)?),
        None => None,
    };
    Ok(Checkpoint {
        version,
        model,
        optimizer,
        step,
        fingerprint,
        cursors,
        tensors,
    })
}

/// Read + validate against a model: `(step, tensors)` on success.
pub fn load(path: &Path, info: &ModelInfo) -> Result<(usize, Vec<Vec<f32>>)> {
    let ck = read(path)?;
    ck.validate(info)?;
    Ok((ck.step, ck.tensors))
}

fn is_checkpoint_name(name: &str) -> bool {
    name.starts_with("ckpt_") && name.ends_with(".fdp")
}

/// All published checkpoints in `dir`, newest (highest step) first.
/// Stale `.tmp` files are never included.
pub fn list_desc(dir: &Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            let p = entry.path();
            if p.file_name()
                .and_then(|n| n.to_str())
                .map(is_checkpoint_name)
                .unwrap_or(false)
            {
                out.push(p);
            }
        }
    }
    // zero-padded step in the name => lexicographic == numeric order
    out.sort();
    out.reverse();
    out
}

/// Most recent checkpoint in `dir`, if any.
pub fn latest(dir: &Path) -> Option<PathBuf> {
    list_desc(dir).into_iter().next()
}

/// Remove `.ckpt_*.tmp` leftovers from crashed saves. Returns how many
/// were swept. Call at startup, before scanning for a resume point.
pub fn sweep_stale_tmps(dir: &Path) -> usize {
    let mut swept = 0;
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            let p = entry.path();
            let stale = p
                .file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with(".ckpt_") && n.ends_with(".tmp"))
                .unwrap_or(false);
            if stale && std::fs::remove_file(&p).is_ok() {
                swept += 1;
            }
        }
    }
    swept
}

/// Delete all but the newest `keep` checkpoints (`keep == 0` keeps
/// everything). Returns how many were removed.
pub fn prune(dir: &Path, keep: usize) -> Result<usize> {
    if keep == 0 {
        return Ok(0);
    }
    let all = list_desc(dir);
    let mut removed = 0;
    for p in all.iter().skip(keep) {
        std::fs::remove_file(p)
            .with_context(|| format!("pruning old checkpoint {}", p.display()))?;
        removed += 1;
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::model::NativeSpec;

    fn fake_info() -> ModelInfo {
        NativeSpec {
            name: "ck".into(),
            batch: 1,
            seq: 1,
            d_in: 2,
            hidden: vec![],
            n_classes: 2,
            optimizer: "sgd".into(),
            clip_fn: "abadi".into(),
            ..NativeSpec::default()
        }
        .info()
    }

    fn fp() -> Fingerprint {
        Fingerprint {
            strategy: "bk".into(),
            clipping_style: "all-layer".into(),
            clip_fn: "abadi".into(),
            clip: 1.0,
            sigma: 0.7310585786300049,
            seed: 42,
            logical_batch: 32,
            trainable: "all".into(),
        }
    }

    fn tensors_for(info: &ModelInfo) -> Vec<Vec<f32>> {
        info.state_tensor_lens()
            .iter()
            .enumerate()
            .map(|(i, &n)| (0..n).map(|j| (i * 1000 + j) as f32 * 0.25 - 3.0).collect())
            .collect()
    }

    fn meta<'a>(step: usize, info: &'a ModelInfo, f: &'a Fingerprint) -> SaveMeta<'a> {
        SaveMeta {
            step,
            info,
            fingerprint: f,
            cursors: Cursors {
                noise_step: step as u64,
                data_cursor: step as u64,
                accountant_steps: step as u64,
            },
            keep_last: 0,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fastdp_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// The fault hook is a process-global one-shot, and the test harness
    /// runs tests concurrently — serialize every test that calls save()
    /// so an armed fault is consumed by the save it was armed for.
    fn lock_faults() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn roundtrip_v2() {
        let _g = lock_faults();
        let dir = tmpdir("rt2");
        let info = fake_info();
        let f = fp();
        let tensors = tensors_for(&info);
        save(&dir, &meta(42, &info, &f), &tensors).unwrap();
        save(&dir, &meta(7, &info, &f), &tensors).unwrap();
        let latest_path = latest(&dir).unwrap();
        assert!(latest_path.to_str().unwrap().contains("00000042"));
        let ck = read(&latest_path).unwrap();
        assert_eq!(ck.version, 2);
        assert_eq!(ck.step, 42);
        assert_eq!(ck.tensors, tensors);
        assert_eq!(ck.fingerprint.as_ref().unwrap(), &f);
        assert_eq!(
            ck.cursors.unwrap(),
            Cursors { noise_step: 42, data_cursor: 42, accountant_steps: 42 }
        );
        ck.validate(&info).unwrap();
        // fingerprint round-trips bitwise (sigma is an awkward decimal)
        ck.fingerprint.unwrap().check(&f).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_files_still_load() {
        let dir = tmpdir("v1");
        let info = fake_info();
        let tensors = tensors_for(&info);
        save_v1(&dir, 9, &info, &tensors).unwrap();
        let ck = read(&latest(&dir).unwrap()).unwrap();
        assert_eq!(ck.version, 1);
        assert_eq!(ck.step, 9);
        assert_eq!(ck.tensors, tensors);
        assert!(ck.fingerprint.is_none());
        assert!(ck.cursors.is_none());
        ck.validate(&info).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_model_and_lengths() {
        let _g = lock_faults();
        let dir = tmpdir("wrong");
        let info = fake_info();
        let f = fp();
        save(&dir, &meta(1, &info, &f), &tensors_for(&info)).unwrap();
        let p = latest(&dir).unwrap();
        let ck = read(&p).unwrap();
        let mut other = info.clone();
        other.name = "different".into();
        assert!(ck.validate(&other).is_err());
        // tensor-length drift against param_shapes is rejected precisely
        let mut bad = ck.clone();
        bad.tensors[0].push(0.0);
        let err = bad.validate(&info).unwrap_err().to_string();
        assert!(err.contains("elements, expected"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corrupt_magic_and_header_len() {
        let dir = tmpdir("magic");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ckpt_00000001.fdp");
        std::fs::write(&p, b"NOTMAGIC????????????").unwrap();
        assert!(read(&p).is_err());
        // absurd header length (the old unwrap_or(0) class of bug)
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&p, bytes).unwrap();
        let err = read(&p).unwrap_err().to_string();
        assert!(err.contains("malformed header length"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_payload_bitflip_and_truncation() {
        let _g = lock_faults();
        let dir = tmpdir("flip");
        let info = fake_info();
        let f = fp();
        save(&dir, &meta(1, &info, &f), &tensors_for(&info)).unwrap();
        let p = latest(&dir).unwrap();
        let good = std::fs::read(&p).unwrap();
        // flip one payload bit
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 3] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();
        let err = read(&p).unwrap_err().to_string();
        assert!(err.contains("payload CRC mismatch"), "{err}");
        // truncate
        std::fs::write(&p, &good[..n - 5]).unwrap();
        let err = read(&p).unwrap_err().to_string();
        assert!(err.contains("truncated or corrupt"), "{err}");
        // header bit-flip is caught by the header CRC
        let mut badh = good.clone();
        badh[20] ^= 0x04;
        std::fs::write(&p, &badh).unwrap();
        assert!(read(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_mismatch_is_actionable() {
        let a = fp();
        let mut b = fp();
        b.clip = 2.0;
        b.strategy = "opacus".into();
        let err = a.check(&b).unwrap_err().to_string();
        assert!(err.contains("clip R"), "{err}");
        assert!(err.contains("strategy"), "{err}");
        assert!(err.contains("fingerprint mismatch"), "{err}");
        a.check(&fp()).unwrap();
    }

    #[test]
    fn trainability_drift_refuses_and_maskless_headers_default_to_all() {
        // drift: resuming a bias-only checkpoint under full fine-tuning
        // must be refused with the mask named
        let a = fp();
        let mut b = fp();
        b.trainable = "bias-only".into();
        let err = b.check(&a).unwrap_err().to_string();
        assert!(err.contains("trainable 'bias-only' vs run 'all'"), "{err}");
        // a pre-trainability v2 fingerprint (no "trainable" key) parses
        // as fully trainable and checks clean against an "all" run
        let mut v = Value::obj();
        v.set("strategy", Value::from("bk"));
        v.set("clipping_style", Value::from("all-layer"));
        v.set("clip_fn", Value::from("abadi"));
        v.set("clip", Value::from(1.0));
        v.set("sigma", Value::from(0.7310585786300049));
        v.set("seed", Value::from("42"));
        v.set("logical_batch", Value::from(32usize));
        let old = Fingerprint::from_json(&v).unwrap();
        assert_eq!(old.trainable, "all");
        old.check(&a).unwrap();
    }

    #[test]
    fn masked_state_roundtrips_zero_length_moments() {
        // bias-only + adam: frozen slots have 0-length m/v entries in
        // state order; the payload must round-trip them exactly
        let _g = lock_faults();
        let dir = tmpdir("mask");
        let info = {
            let mut s = NativeSpec {
                name: "ckm".into(),
                batch: 1,
                seq: 1,
                d_in: 2,
                hidden: vec![],
                n_classes: 2,
                optimizer: "adam".into(),
                clip_fn: "abadi".into(),
                ..NativeSpec::default()
            };
            s.trainable = "bias-only".into();
            s.info()
        };
        let lens = info.state_tensor_lens();
        // params full for every slot; moments zero for the frozen weight
        assert_eq!(lens.len(), 6);
        assert!(lens[0] > 0 && lens[1] > 0);
        assert_eq!(lens[2], 0, "frozen weight adam-m must be empty");
        assert_eq!(lens[4], 0, "frozen weight adam-v must be empty");
        assert!(lens[3] > 0 && lens[5] > 0);
        let tensors = tensors_for(&info);
        let mut f = fp();
        f.trainable = "bias-only".into();
        save(&dir, &meta(3, &info, &f), &tensors).unwrap();
        let ck = read(&latest(&dir).unwrap()).unwrap();
        assert_eq!(ck.tensors, tensors);
        ck.validate(&info).unwrap();
        assert_eq!(ck.fingerprint.unwrap().trainable, "bias-only");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn refuses_nonfinite_tensors() {
        let _g = lock_faults();
        let dir = tmpdir("nan");
        let info = fake_info();
        let f = fp();
        let mut tensors = tensors_for(&info);
        tensors[0][0] = f32::NAN;
        let err = save(&dir, &meta(3, &info, &f), &tensors).unwrap_err().to_string();
        assert!(err.contains("non-finite"), "{err}");
        assert!(latest(&dir).is_none(), "no file may be published");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retention_and_tmp_sweep() {
        let _g = lock_faults();
        let dir = tmpdir("keep");
        let info = fake_info();
        let f = fp();
        let tensors = tensors_for(&info);
        for step in 1..=5 {
            let mut m = meta(step, &info, &f);
            m.keep_last = 2;
            save(&dir, &m, &tensors).unwrap();
        }
        let kept = list_desc(&dir);
        assert_eq!(kept.len(), 2);
        assert!(kept[0].to_str().unwrap().contains("00000005"));
        assert!(kept[1].to_str().unwrap().contains("00000004"));
        // stale tmp from a crashed save: swept, and never listed
        std::fs::write(dir.join(".ckpt_00000009.tmp"), b"partial").unwrap();
        assert_eq!(list_desc(&dir).len(), 2);
        assert_eq!(sweep_stale_tmps(&dir), 1);
        assert!(!dir.join(".ckpt_00000009.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_injection_kills_leave_no_published_file() {
        let _g = lock_faults();
        let dir = tmpdir("fault");
        let info = fake_info();
        let f = fp();
        let tensors = tensors_for(&info);

        fault::arm(fault::Fault::KillMidWrite);
        let err = save(&dir, &meta(1, &info, &f), &tensors).unwrap_err().to_string();
        assert!(err.contains(fault::INJECTED), "{err}");
        assert!(latest(&dir).is_none());
        assert_eq!(sweep_stale_tmps(&dir), 1, "partial tmp left behind");

        fault::arm(fault::Fault::KillBeforeRename);
        let err = save(&dir, &meta(1, &info, &f), &tensors).unwrap_err().to_string();
        assert!(err.contains(fault::INJECTED), "{err}");
        assert!(latest(&dir).is_none());
        assert_eq!(sweep_stale_tmps(&dir), 1, "complete tmp left behind");

        // one-shot: the next save is clean
        save(&dir, &meta(2, &info, &f), &tensors).unwrap();
        assert!(latest(&dir).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_injection_corruption_is_caught_on_read() {
        let _g = lock_faults();
        let dir = tmpdir("faultc");
        let info = fake_info();
        let f = fp();
        let tensors = tensors_for(&info);
        fault::arm(fault::Fault::Truncate(6));
        save(&dir, &meta(1, &info, &f), &tensors).unwrap();
        assert!(read(&latest(&dir).unwrap()).is_err());

        fault::arm(fault::Fault::BitFlip(1_000_000));
        save(&dir, &meta(2, &info, &f), &tensors).unwrap();
        assert!(read(&latest(&dir).unwrap()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
