//! Checkpointing: params (+ optimizer state) to a simple self-describing
//! binary format — a JSON header (model name, step, tensor count/lengths)
//! followed by raw little-endian f32 data.

use crate::json::Value;
use crate::runtime::ModelInfo;
use crate::{anyhow, bail};
use crate::error::{Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"FASTDP01";

pub fn save(dir: &Path, step: usize, info: &ModelInfo, tensors: &[Vec<f32>]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut header = Value::obj();
    header.set("model", Value::from(info.name.as_str()));
    header.set("step", Value::from(step));
    header.set("optimizer", Value::from(info.optimizer.as_str()));
    header.set(
        "lengths",
        Value::Arr(tensors.iter().map(|t| Value::from(t.len())).collect()),
    );
    let htext = header.to_string();
    let path = dir.join(format!("ckpt_{step:08}.fdp"));
    let tmp = dir.join(format!(".ckpt_{step:08}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(MAGIC)?;
        f.write_all(&(htext.len() as u64).to_le_bytes())?;
        f.write_all(htext.as_bytes())?;
        for t in tensors {
            // SAFETY-free little-endian write
            let mut bytes = Vec::with_capacity(t.len() * 4);
            for x in t {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            f.write_all(&bytes)?;
        }
        f.sync_all()?;
    }
    std::fs::rename(&tmp, &path)?; // atomic publish
    Ok(())
}

pub fn load(path: &Path, info: &ModelInfo) -> Result<(usize, Vec<Vec<f32>>)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad checkpoint magic in {}", path.display());
    }
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb)?;
    let hlen = u64::from_le_bytes(lenb) as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = crate::json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow!("checkpoint header: {e}"))?;
    let model = header.req_str("model").map_err(|e| anyhow!(e))?;
    if model != info.name {
        bail!("checkpoint is for model '{model}', expected '{}'", info.name);
    }
    let step = header.req_i64("step").map_err(|e| anyhow!(e))? as usize;
    let lengths: Vec<usize> = header
        .req_arr("lengths")
        .map_err(|e| anyhow!(e))?
        .iter()
        .map(|v| v.as_usize().unwrap_or(0))
        .collect();
    let mut tensors = Vec::with_capacity(lengths.len());
    for n in lengths {
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        let mut t = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            t.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        tensors.push(t);
    }
    Ok((step, tensors))
}

/// Most recent checkpoint in `dir`, if any.
pub fn latest(dir: &Path) -> Option<PathBuf> {
    let mut best: Option<PathBuf> = None;
    for entry in std::fs::read_dir(dir).ok()? {
        let p = entry.ok()?.path();
        let name = p.file_name()?.to_str()?;
        if name.starts_with("ckpt_")
            && name.ends_with(".fdp")
            && best.as_ref().map(|b| p > *b).unwrap_or(true)
        {
            best = Some(p.clone());
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::model::NativeSpec;

    fn fake_info() -> ModelInfo {
        NativeSpec {
            name: "ck".into(),
            batch: 1,
            seq: 1,
            d_in: 2,
            hidden: vec![],
            n_classes: 2,
            optimizer: "sgd".into(),
            clip_fn: "abadi".into(),
            ..NativeSpec::default()
        }
        .info()
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("fastdp_ckpt_{}", std::process::id()));
        let info = fake_info();
        let tensors = vec![vec![1.0f32, -2.5, 3.25, 0.0], vec![9.0f32; 7]];
        save(&dir, 42, &info, &tensors).unwrap();
        save(&dir, 7, &info, &tensors).unwrap();
        let latest_path = latest(&dir).unwrap();
        assert!(latest_path.to_str().unwrap().contains("00000042"));
        let (step, loaded) = load(&latest_path, &info).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded, tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_model() {
        let dir = std::env::temp_dir().join(format!("fastdp_ckpt2_{}", std::process::id()));
        let info = fake_info();
        save(&dir, 1, &info, &[vec![0.0]]).unwrap();
        let mut other = info.clone();
        other.name = "different".into();
        assert!(load(&latest(&dir).unwrap(), &other).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corrupt_magic() {
        let dir = std::env::temp_dir().join(format!("fastdp_ckpt3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ckpt_00000001.fdp");
        std::fs::write(&p, b"NOTMAGIC????").unwrap();
        assert!(load(&p, &fake_info()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
