//! Layer-3 training coordinator — the runtime half of the paper's
//! `PrivacyEngine.attach(optimizer)` workflow (Section 4).
//!
//! Responsibilities:
//!  * noise calibration via the RDP accountant (sigma from (eps, delta))
//!  * Poisson subsampling + physical batching of the synthetic corpus
//!  * strategy dispatch: fused `step_<strategy>` executables on the fast
//!    path, `clipgrad + apply` pairs when gradient accumulation is on
//!  * DP noise generation (L3 owns the privacy-critical DRBG; JAX never
//!    samples noise)
//!  * budget enforcement, metrics, checkpointing
//!
//! Python is never on this path: everything executes through the PJRT
//! runtime on AOT artifacts.

pub mod checkpoint;
pub mod noise;

use crate::config::TrainConfig;
use crate::privacy::{calibrate_sigma, RdpAccountant};
use crate::runtime::{literal_f32, literal_i32, scalar_f32, scalar_i32, scalar_of, Runtime};
use crate::util::rng::Xoshiro256;
use crate::util::stats::{peak_rss_bytes, Summary};
use crate::{data, info};
use anyhow::{anyhow, bail, Context, Result};
use std::time::Instant;

/// One logged training step.
#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub mean_clip: f32,
    pub epsilon: f64,
    pub step_secs: f64,
}

/// Final report of a training run (consumed by examples / benches /
/// EXPERIMENTS.md).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub model: String,
    pub strategy: String,
    pub steps: usize,
    pub sigma: f64,
    pub final_loss: f32,
    pub initial_loss: f32,
    pub final_epsilon: f64,
    pub logs: Vec<StepLog>,
    pub throughput_samples_per_sec: f64,
    pub mean_step_secs: f64,
    pub compile_secs: f64,
    pub peak_rss_bytes: u64,
}

/// Batch source abstraction so the trainer drives either token or vector
/// workloads through one loop.
pub enum BatchSource {
    Tokens(data::TokenCorpus),
    Vectors { ds: data::VectorDataset, image_hw: Option<(usize, usize)> },
}

impl BatchSource {
    /// Produce (x, y) literals for a physical batch of size b.
    fn sample(&mut self, b: usize, x_shape: &[usize], y_shape: &[usize])
        -> Result<(xla::Literal, xla::Literal)> {
        match self {
            BatchSource::Tokens(c) => {
                let (xs, ys) = c.sample_batch(b);
                Ok((literal_i32(&xs, x_shape)?, literal_i32(&ys, y_shape)?))
            }
            BatchSource::Vectors { ds, .. } => {
                let (xs, ys) = ds.sample_batch(b);
                Ok((literal_f32(&xs, x_shape)?, literal_i32(&ys, y_shape)?))
            }
        }
    }
}

pub struct Trainer {
    pub rt: Runtime,
    pub cfg: TrainConfig,
    pub meta: crate::runtime::ModelMeta,
    pub accountant: Option<RdpAccountant>,
    pub sigma: f64,
    source: BatchSource,
    params: Vec<xla::Literal>,
    frozen: Vec<xla::Literal>,
    opt_m: Vec<xla::Literal>,
    opt_v: Vec<xla::Literal>,
    noise: noise::NoiseSource,
    rng: Xoshiro256,
    step_no: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let rt = Runtime::load(cfg.artifacts_dir.clone())?;
        let meta = rt.model(&cfg.model)?.clone();
        let b_phys = meta.batch;
        let logical = if cfg.logical_batch == 0 { b_phys } else { cfg.logical_batch };
        if logical % b_phys != 0 {
            bail!(
                "logical batch {} must be a multiple of the physical batch {}",
                logical,
                b_phys
            );
        }

        // privacy calibration
        let dp = cfg.strategy != "nondp" && !cfg.disable_dp;
        let q = logical as f64 / cfg.privacy.dataset_size as f64;
        let sigma = if !dp {
            0.0
        } else if cfg.privacy.sigma > 0.0 {
            cfg.privacy.sigma
        } else {
            let s = calibrate_sigma(
                q,
                cfg.steps as u64,
                cfg.privacy.target_epsilon,
                cfg.privacy.target_delta,
            );
            info!(
                "calibrated sigma={s:.4} for (eps={}, delta={}) at q={q:.5} over {} steps",
                cfg.privacy.target_epsilon, cfg.privacy.target_delta, cfg.steps
            );
            s
        };
        let accountant = dp.then(|| RdpAccountant::new(q, sigma));

        // data source from the model spec
        let spec = &meta.spec;
        let source = match spec.opt_str("kind", "") {
            "gpt" | "gptlora" => BatchSource::Tokens(data::TokenCorpus::new(
                spec.req_i64("vocab").map_err(|e| anyhow!(e))? as usize,
                spec.req_i64("seq").map_err(|e| anyhow!(e))? as usize,
                cfg.seed ^ 0xDA7A,
            )),
            "mlp" => BatchSource::Vectors {
                ds: data::VectorDataset::new(
                    spec.req_i64("d_in").map_err(|e| anyhow!(e))? as usize,
                    spec.opt_i64("n_classes", 10) as usize,
                    2.0,
                    cfg.seed ^ 0xDA7A,
                ),
                image_hw: None,
            },
            "conv" => {
                let hw = spec.opt_i64("hw", 32) as usize;
                let c = spec.opt_i64("c_in", 3) as usize;
                BatchSource::Vectors {
                    ds: data::VectorDataset::new(
                        hw * hw * c,
                        spec.opt_i64("n_classes", 10) as usize,
                        1.0,
                        cfg.seed ^ 0xDA7A,
                    ),
                    image_hw: Some((hw, c)),
                }
            }
            other => bail!("unknown model kind '{other}' in manifest"),
        };

        Ok(Self {
            rt,
            meta,
            accountant,
            sigma,
            source,
            params: Vec::new(),
            frozen: Vec::new(),
            opt_m: Vec::new(),
            opt_v: Vec::new(),
            noise: noise::NoiseSource::new(cfg.seed ^ 0x0153),
            rng: Xoshiro256::new(cfg.seed),
            step_no: 0,
            cfg,
        })
    }

    /// Initialize parameters via the init artifact (or a checkpoint).
    pub fn init(&mut self) -> Result<()> {
        if let (Some(dir), true) = (&self.cfg.checkpoint_dir, self.cfg.checkpoint_every > 0) {
            let latest = checkpoint::latest(dir);
            if let Some(path) = latest {
                info!("resuming from checkpoint {}", path.display());
                let (step, tensors) = checkpoint::load(&path, &self.meta)?;
                self.step_no = step;
                self.set_flat_state(tensors)?;
                return Ok(());
            }
        }
        let init = self.rt.artifact(&self.cfg.model, "init", None)?.clone();
        let seed = scalar_i32(self.cfg.seed as i32);
        let outs = self.rt.execute(&init, &[&seed])?;
        let n_tr = self.meta.param_names.len();
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n_tr).collect();
        self.frozen = it.collect();
        if self.meta.is_adam() {
            self.opt_m = self.zeros_like_params()?;
            self.opt_v = self.zeros_like_params()?;
        }
        Ok(())
    }

    fn zeros_like_params(&self) -> Result<Vec<xla::Literal>> {
        self.meta
            .param_names
            .iter()
            .map(|name| {
                let shape = self.meta.param_shape(name).map_err(|e| anyhow!(e))?;
                let n: usize = shape.iter().product();
                literal_f32(&vec![0f32; n], shape)
            })
            .collect()
    }

    fn set_flat_state(&mut self, tensors: Vec<Vec<f32>>) -> Result<()> {
        let n_tr = self.meta.param_names.len();
        let mut out = Vec::with_capacity(tensors.len());
        for (i, data) in tensors.iter().enumerate() {
            let name = &self.meta.param_names[i % n_tr];
            out.push(literal_f32(data, self.meta.param_shape(name).map_err(|e| anyhow!(e))?)?);
        }
        let mut it = out.into_iter();
        self.params = (&mut it).take(n_tr).collect();
        if self.meta.is_adam() {
            self.opt_m = (&mut it).take(n_tr).collect();
            self.opt_v = (&mut it).take(n_tr).collect();
        }
        Ok(())
    }

    fn data_shapes(&self, art: &crate::runtime::ArtifactMeta) -> Result<(Vec<usize>, Vec<usize>)> {
        let xi = art
            .input_index("x")
            .ok_or_else(|| anyhow!("artifact missing x input"))?;
        let yi = art
            .input_index("y")
            .ok_or_else(|| anyhow!("artifact missing y input"))?;
        Ok((art.inputs[xi].shape.clone(), art.inputs[yi].shape.clone()))
    }

    /// Evaluate mean loss on `batches` fresh batches.
    pub fn eval(&mut self, batches: usize) -> Result<f32> {
        let eval = self.rt.artifact(&self.cfg.model, "eval", None)?.clone();
        let (xs, ys) = self.data_shapes(&eval)?;
        let b = self.meta.batch;
        let mut total = 0.0f32;
        for _ in 0..batches {
            let (xl, yl) = self.source.sample(b, &xs, &ys)?;
            let mut args: Vec<&xla::Literal> = self.params.iter().collect();
            args.extend(self.frozen.iter());
            args.push(&xl);
            args.push(&yl);
            total += scalar_of(&self.rt.execute(&eval, &args)?[0])?;
        }
        Ok(total / batches as f32)
    }

    /// One *logical* training step (possibly several physical batches).
    pub fn train_step(&mut self) -> Result<StepLog> {
        let b_phys = self.meta.batch;
        let logical = if self.cfg.logical_batch == 0 { b_phys } else { self.cfg.logical_batch };
        let accum = logical / b_phys;
        let t0 = Instant::now();

        let (loss, mean_clip) = if accum == 1 {
            self.fused_step(logical)?
        } else {
            self.accumulated_step(accum, logical)?
        };

        if let Some(acc) = &mut self.accountant {
            acc.step();
        }
        self.step_no += 1;

        if self.cfg.checkpoint_every > 0 && self.step_no % self.cfg.checkpoint_every == 0 {
            if let Some(dir) = self.cfg.checkpoint_dir.clone() {
                self.save_checkpoint(&dir)?;
            }
        }

        Ok(StepLog {
            step: self.step_no,
            loss,
            mean_clip,
            epsilon: self.epsilon(),
            step_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Fast path: the fused step artifact (one physical == one logical).
    fn fused_step(&mut self, logical: usize) -> Result<(f32, f32)> {
        let art = self
            .rt
            .artifact(&self.cfg.model, "step", Some(&self.cfg.strategy))?
            .clone();
        let (xs, ys) = self.data_shapes(&art)?;
        let (xl, yl) = self.source.sample(self.meta.batch, &xs, &ys)?;
        let with_noise = self.cfg.strategy != "nondp";

        let noise = if with_noise {
            self.noise.tensors(&self.meta)?
        } else {
            Vec::new()
        };
        let scalars = [
            scalar_f32(self.cfg.lr as f32),
            scalar_f32(self.cfg.clip as f32),
            scalar_f32((self.sigma * self.cfg.clip) as f32),
            scalar_f32(logical as f32),
            scalar_f32((self.step_no + 1) as f32),
        ];
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.extend(self.frozen.iter());
        if self.meta.is_adam() {
            args.extend(self.opt_m.iter());
            args.extend(self.opt_v.iter());
        }
        args.push(&xl);
        args.push(&yl);
        args.extend(noise.iter());
        args.extend(scalars.iter());

        let outs = self.rt.execute(&art, &args)?;
        let loss = scalar_of(&outs[art.output_index("metric:loss").unwrap()])?;
        let clip = art
            .output_index("metric:mean_clip")
            .map(|i| scalar_of(&outs[i]).unwrap_or(1.0))
            .unwrap_or(1.0);
        let n_tr = self.meta.param_names.len();
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n_tr).collect();
        if self.meta.is_adam() {
            self.opt_m = (&mut it).take(n_tr).collect();
            self.opt_v = (&mut it).take(n_tr).collect();
        }
        Ok((loss, clip))
    }

    /// Gradient accumulation: k clipgrad micro-steps summed host-side,
    /// then one apply with a single noise draw (DP-correct: per-sample
    /// clipping is per micro-batch, noise is per logical batch).
    fn accumulated_step(&mut self, accum: usize, logical: usize) -> Result<(f32, f32)> {
        let cg = self
            .rt
            .artifact(&self.cfg.model, "clipgrad", Some(&self.cfg.strategy))?
            .clone();
        let (xs, ys) = self.data_shapes(&cg)?;
        let n_tr = self.meta.param_names.len();
        let mut acc_grads: Vec<Vec<f32>> = Vec::new();
        let mut loss_sum = 0.0f32;
        let mut clip_sum = 0.0f32;
        let clip_lit = scalar_f32(self.cfg.clip as f32);
        for _ in 0..accum {
            let (xl, yl) = self.source.sample(self.meta.batch, &xs, &ys)?;
            let mut args: Vec<&xla::Literal> = self.params.iter().collect();
            args.extend(self.frozen.iter());
            args.push(&xl);
            args.push(&yl);
            args.push(&clip_lit);
            let outs = self.rt.execute(&cg, &args)?;
            loss_sum += scalar_of(&outs[cg.output_index("metric:loss").unwrap()])?;
            clip_sum += scalar_of(&outs[cg.output_index("metric:mean_clip").unwrap()])?;
            for (i, lit) in outs[..n_tr].iter().enumerate() {
                let v = lit.to_vec::<f32>()?;
                if acc_grads.len() <= i {
                    acc_grads.push(v);
                } else {
                    for (a, x) in acc_grads[i].iter_mut().zip(v.iter()) {
                        *a += *x;
                    }
                }
            }
        }

        // apply: params' = opt(params, sum_grads + sigma*R*noise)
        let apply = self.rt.artifact(&self.cfg.model, "apply", None)?.clone();
        let grads: Vec<xla::Literal> = acc_grads
            .iter()
            .enumerate()
            .map(|(i, g)| {
                literal_f32(g, self.meta.param_shape(&self.meta.param_names[i]).unwrap())
            })
            .collect::<Result<_>>()?;
        let with_noise = self.cfg.strategy != "nondp";
        let noise = if with_noise {
            self.noise.tensors(&self.meta)?
        } else {
            self.zeros_like_params()?
        };
        let scalars = [
            scalar_f32(self.cfg.lr as f32),
            scalar_f32(if with_noise { (self.sigma * self.cfg.clip) as f32 } else { 0.0 }),
            scalar_f32(logical as f32),
            scalar_f32((self.step_no + 1) as f32),
        ];
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        if self.meta.is_adam() {
            args.extend(self.opt_m.iter());
            args.extend(self.opt_v.iter());
        }
        args.extend(grads.iter());
        args.extend(noise.iter());
        args.extend(scalars.iter());
        let outs = self.rt.execute(&apply, &args)?;
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n_tr).collect();
        if self.meta.is_adam() {
            self.opt_m = (&mut it).take(n_tr).collect();
            self.opt_v = (&mut it).take(n_tr).collect();
        }
        Ok((loss_sum / accum as f32, clip_sum / accum as f32))
    }

    pub fn epsilon(&self) -> f64 {
        self.accountant
            .as_ref()
            .map(|a| a.epsilon(self.cfg.privacy.target_delta))
            .unwrap_or(0.0)
    }

    pub fn save_checkpoint(&self, dir: &std::path::Path) -> Result<()> {
        let mut tensors: Vec<Vec<f32>> = Vec::new();
        for p in self.params.iter().chain(self.opt_m.iter()).chain(self.opt_v.iter()) {
            tensors.push(p.to_vec::<f32>()?);
        }
        checkpoint::save(dir, self.step_no, &self.meta, &tensors)
            .context("saving checkpoint")
    }

    /// Full training run per the config; logs every `log_every` steps.
    pub fn run(&mut self) -> Result<TrainReport> {
        self.init()?;
        let initial_loss = self.eval(2)?;
        info!(
            "model={} strategy={} params={:.2}M B={} sigma={:.3} initial_loss={initial_loss:.4}",
            self.cfg.model,
            self.cfg.strategy,
            self.meta.n_params as f64 / 1e6,
            self.meta.batch,
            self.sigma
        );
        let mut report = TrainReport {
            model: self.cfg.model.clone(),
            strategy: self.cfg.strategy.clone(),
            sigma: self.sigma,
            initial_loss,
            ..Default::default()
        };
        let mut times = Summary::new();
        let logical = if self.cfg.logical_batch == 0 { self.meta.batch } else { self.cfg.logical_batch };
        let run_t0 = Instant::now();
        let mut last_loss = initial_loss;
        for s in 0..self.cfg.steps {
            if self.cfg.privacy.strict_budget
                && self.accountant.is_some()
                && self.epsilon() >= self.cfg.privacy.target_epsilon
                && self.cfg.privacy.sigma > 0.0
            {
                info!("privacy budget exhausted at step {s}; stopping");
                break;
            }
            let log = self.train_step()?;
            times.push(log.step_secs);
            last_loss = log.loss;
            if self.cfg.log_every > 0 && (s + 1) % self.cfg.log_every == 0 {
                info!(
                    "step {:>5} loss {:.4} clip {:.3} eps {:.3} ({:.0} samples/s)",
                    log.step,
                    log.loss,
                    log.mean_clip,
                    log.epsilon,
                    logical as f64 / log.step_secs
                );
                report.logs.push(log);
            }
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                let ev = self.eval(2)?;
                info!("eval loss {ev:.4}");
            }
        }
        let elapsed = run_t0.elapsed().as_secs_f64();
        report.steps = self.step_no;
        report.final_loss = last_loss;
        report.final_epsilon = self.epsilon();
        report.mean_step_secs = times.mean();
        report.throughput_samples_per_sec =
            (self.step_no * logical) as f64 / elapsed.max(1e-9);
        report.compile_secs = *self.rt.compile_secs.borrow();
        report.peak_rss_bytes = peak_rss_bytes();
        // deterministic tiny perturbation consumers to silence unused warnings
        let _ = &self.rng;
        Ok(report)
    }
}
