//! Training coordinator — the runtime half of the paper's
//! `PrivacyEngine.attach(optimizer)` workflow (Section 4), rewired to
//! drive any [`Backend`](crate::runtime::Backend).
//!
//! Responsibilities:
//!  * backend selection by config (native kernels by default, PJRT
//!    artifacts behind the `xla-runtime` feature)
//!  * noise calibration via the RDP accountant (sigma from (eps, delta))
//!  * synthetic data pipeline + physical batching
//!  * strategy dispatch: fused `step` on the fast path, `clipped_grads +
//!    apply_update` pairs when gradient accumulation is on
//!  * DP noise generation (the coordinator owns the privacy-critical
//!    DRBG; backends take noise as input and never sample)
//!  * budget enforcement, metrics, checkpointing
//!
//! Neither Python nor XLA is on this path in the default build.

pub mod checkpoint;
pub mod noise;

use crate::config::TrainConfig;
use crate::error::{Context, Result};
use crate::privacy::{calibrate_sigma, RdpAccountant};
use crate::runtime::{create_backend, Backend, BatchX, ModelInfo, StepHyper, StepOut};
use crate::util::stats::{peak_rss_bytes, Summary};
use crate::{bail, data, info, warn_};
use std::time::Instant;

/// One logged training step.
#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub mean_clip: f32,
    /// Mean clip factor per clipping group (one entry for all-layer;
    /// one per layer/group under layer-wise/group-wise styles).
    pub group_clip: Vec<f32>,
    pub epsilon: f64,
    pub step_secs: f64,
}

/// Final report of a training run (consumed by examples / benches /
/// EXPERIMENTS.md).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub model: String,
    pub strategy: String,
    pub backend: String,
    pub steps: usize,
    pub sigma: f64,
    pub final_loss: f32,
    pub initial_loss: f32,
    pub final_epsilon: f64,
    pub logs: Vec<StepLog>,
    pub throughput_samples_per_sec: f64,
    pub mean_step_secs: f64,
    pub compile_secs: f64,
    pub peak_rss_bytes: u64,
}

/// Batch source abstraction so the trainer drives token and vector
/// workloads through one loop.
pub enum BatchSource {
    Tokens(data::TokenCorpus),
    Vectors(data::VectorDataset),
}

impl BatchSource {
    /// Build the source matching a model description.
    fn for_model(info: &ModelInfo, seed: u64) -> Result<Self> {
        match info.kind.as_str() {
            // natively executed token models (seqtok) are next-token
            // predictors like the gpt artifacts: vocab == n_classes
            "gpt" | "gptlora" | "seqtok" => Ok(BatchSource::Tokens(data::TokenCorpus::new(
                info.n_classes,
                info.seq,
                seed,
            ))),
            "mlp" | "seqmlp" | "conv" => {
                // class separation as in the seed pipeline: conv images
                // are lower-contrast than flat vectors
                let sep = if info.kind == "conv" { 1.0 } else { 2.0 };
                Ok(BatchSource::Vectors(data::VectorDataset::new(
                    info.d_in,
                    info.n_classes,
                    sep,
                    seed,
                )))
            }
            other => bail!("unknown model kind '{other}'"),
        }
    }

    /// Produce (x, y) for one physical batch (`b` samples of `t` rows).
    fn sample(&mut self, b: usize, t: usize) -> (BatchX, Vec<i32>) {
        match self {
            BatchSource::Tokens(c) => {
                let (xs, ys) = c.sample_batch(b);
                (BatchX::I32(xs), ys)
            }
            BatchSource::Vectors(ds) => {
                // one labeled feature row per token: B*T rows per batch
                let (xs, ys) = ds.sample_batch(b * t);
                (BatchX::F32(xs), ys)
            }
        }
    }

    /// Eval batch from the disjoint eval stream — never advances the
    /// training cursor, so evaluation cannot perturb which training
    /// batches a (resumed) run sees.
    fn sample_eval(&mut self, b: usize, t: usize) -> (BatchX, Vec<i32>) {
        match self {
            BatchSource::Tokens(c) => {
                let (xs, ys) = c.sample_eval_batch(b);
                (BatchX::I32(xs), ys)
            }
            BatchSource::Vectors(ds) => {
                let (xs, ys) = ds.sample_eval_batch(b * t);
                (BatchX::F32(xs), ys)
            }
        }
    }

    /// Training draws consumed so far (persisted in checkpoints).
    fn cursor(&self) -> u64 {
        match self {
            BatchSource::Tokens(c) => c.cursor(),
            BatchSource::Vectors(ds) => ds.cursor(),
        }
    }

    /// Position the training stream (checkpoint resume).
    fn skip_to(&mut self, cursor: u64) {
        match self {
            BatchSource::Tokens(c) => c.skip_to(cursor),
            BatchSource::Vectors(ds) => ds.skip_to(cursor),
        }
    }

    /// Positioned clone of the training stream starting at absolute
    /// draw `start`. Sharded steps give each shard a sub-stream at its
    /// first global micro-batch index; the per-shard draws concatenate
    /// to exactly this stream's 1-shard order.
    fn sub_stream(&self, start: u64) -> Self {
        match self {
            BatchSource::Tokens(c) => BatchSource::Tokens(c.sub_stream(start)),
            BatchSource::Vectors(ds) => BatchSource::Vectors(ds.sub_stream(start)),
        }
    }
}

pub struct Trainer {
    pub backend: Box<dyn Backend>,
    pub cfg: TrainConfig,
    pub info: ModelInfo,
    pub accountant: Option<RdpAccountant>,
    pub sigma: f64,
    source: BatchSource,
    noise: noise::NoiseSource,
    step_no: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let backend = create_backend(&cfg)?;
        let info = backend.info().clone();
        let b_phys = info.batch;
        let logical = if cfg.logical_batch == 0 { b_phys } else { cfg.logical_batch };
        if logical % b_phys != 0 {
            bail!(
                "logical batch {} must be a multiple of the physical batch {}",
                logical,
                b_phys
            );
        }

        // privacy calibration
        let dp = cfg.strategy != "nondp" && !cfg.disable_dp;
        let q = logical as f64 / cfg.privacy.dataset_size as f64;
        let sigma = if !dp {
            0.0
        } else if cfg.privacy.sigma > 0.0 {
            cfg.privacy.sigma
        } else {
            let s = calibrate_sigma(
                q,
                cfg.steps as u64,
                cfg.privacy.target_epsilon,
                cfg.privacy.target_delta,
            );
            info!(
                "calibrated sigma={s:.4} for (eps={}, delta={}) at q={q:.5} over {} steps",
                cfg.privacy.target_epsilon, cfg.privacy.target_delta, cfg.steps
            );
            s
        };
        let accountant = dp.then(|| RdpAccountant::new(q, sigma));
        let source = BatchSource::for_model(&info, cfg.seed ^ 0xDA7A)?;

        Ok(Self {
            backend,
            info,
            accountant,
            sigma,
            source,
            noise: noise::NoiseSource::new(cfg.seed ^ 0x0153),
            step_no: 0,
            cfg,
        })
    }

    fn logical_batch(&self) -> usize {
        if self.cfg.logical_batch == 0 {
            self.info.batch
        } else {
            self.cfg.logical_batch
        }
    }

    /// Whether the step consumes noise tensors. Keyed on the strategy
    /// alone (not `disable_dp`): DP-strategy executables take noise as
    /// an input regardless, and sigma_r is 0 when DP is disabled, so
    /// the draw is a no-op numerically but keeps the arity contract.
    fn wants_noise(&self) -> bool {
        self.cfg.strategy != "nondp"
    }

    /// Micro-batches per logical step.
    fn accum(&self) -> usize {
        self.logical_batch() / self.info.batch
    }

    /// The run's config/privacy identity, persisted in every checkpoint
    /// header and compared on resume.
    fn fingerprint(&self) -> checkpoint::Fingerprint {
        checkpoint::Fingerprint {
            strategy: self.cfg.strategy.clone(),
            clipping_style: self.cfg.clipping_style.clone(),
            clip_fn: self.info.clip_fn.clone(),
            clip: self.cfg.clip,
            sigma: self.sigma,
            seed: self.cfg.seed,
            logical_batch: self.logical_batch(),
            trainable: self.info.trainable_preset.clone(),
        }
    }

    /// Initialize parameters via the backend, or resume from the newest
    /// usable checkpoint whenever `checkpoint_dir` holds one (resume is
    /// *not* gated on `checkpoint_every`: a dir with a checkpoint and
    /// periodic saving off still resumes).
    ///
    /// Corrupt files (bad magic/CRC, malformed header, truncation) are
    /// logged and skipped — the scan falls back to the next-older
    /// checkpoint. Semantic mismatches (different model, fingerprint
    /// drift) are hard errors: the directory belongs to a different run
    /// and silently ignoring it would change privacy semantics.
    pub fn init(&mut self) -> Result<()> {
        if let Some(dir) = self.cfg.checkpoint_dir.clone() {
            let swept = checkpoint::sweep_stale_tmps(&dir);
            if swept > 0 {
                info!("swept {swept} stale .tmp file(s) from {}", dir.display());
            }
            for path in checkpoint::list_desc(&dir) {
                let ck = match checkpoint::read(&path) {
                    Ok(ck) => ck,
                    Err(e) => {
                        warn_!("ignoring corrupt checkpoint: {e}");
                        continue;
                    }
                };
                ck.validate(&self.info)
                    .with_context(|| format!("cannot resume from {}", path.display()))?;
                if let Some(fp) = &ck.fingerprint {
                    fp.check(&self.fingerprint())
                        .with_context(|| format!("cannot resume from {}", path.display()))?;
                }
                return self.resume_from(ck, &path);
            }
            if self.cfg.resume {
                bail!(
                    "--resume: no usable checkpoint found in {}",
                    dir.display()
                );
            }
        } else if self.cfg.resume {
            bail!("--resume requires --checkpoint-dir");
        }
        self.backend.init(self.cfg.seed)
    }

    /// Restore backend state and every stream cursor from a validated
    /// checkpoint. After this, the run continues exactly where the
    /// killed run left off: same upcoming noise draws, same upcoming
    /// data batches, same privacy ledger.
    fn resume_from(&mut self, ck: checkpoint::Checkpoint, path: &std::path::Path) -> Result<()> {
        // v1 files predate cursor persistence: derive positions from the
        // step counter (one noise draw set + one accountant step per
        // logical step; one data draw per micro-batch).
        let cursors = ck.cursors.unwrap_or(checkpoint::Cursors {
            noise_step: ck.step as u64,
            data_cursor: (ck.step * self.accum()) as u64,
            accountant_steps: ck.step as u64,
        });
        info!(
            "resuming from checkpoint {} (v{}, step {})",
            path.display(),
            ck.version,
            ck.step
        );
        self.step_no = ck.step;
        if let Some(acc) = &mut self.accountant {
            // Replay the ledger with sequential step() calls: n
            // sequential compositions are bitwise-identical to the
            // original accumulation (advance(n) computes n*x, which is
            // not, in floating point).
            for _ in 0..cursors.accountant_steps {
                acc.step();
            }
        }
        // Burn the consumed stream positions: the pre-crash steps used
        // draws 1..=k, and a resumed run must never replay them —
        // reusing a spent noise draw would correlate fresh noise with
        // already-released parameters.
        self.noise.skip_to(cursors.noise_step);
        self.source.skip_to(cursors.data_cursor);
        self.backend.load_state(ck.tensors)
    }

    /// Evaluate mean loss on `batches` batches from the eval stream.
    pub fn eval(&mut self, batches: usize) -> Result<f32> {
        let mut total = 0.0f32;
        for _ in 0..batches.max(1) {
            let (x, y) = self.source.sample_eval(self.info.batch, self.info.seq);
            total += self.backend.eval_loss(&x, &y)?;
        }
        Ok(total / batches.max(1) as f32)
    }

    fn hyper(&self, logical: usize) -> StepHyper {
        StepHyper {
            lr: self.cfg.lr as f32,
            clip: self.cfg.clip as f32,
            sigma_r: (self.sigma * self.cfg.clip) as f32,
            logical_batch: logical as f32,
            step: (self.step_no + 1) as f32,
        }
    }

    /// One *logical* training step (possibly several physical batches).
    ///
    /// Under `on_nonfinite=abort` (default) the fused fast path is used
    /// and a non-finite loss is a hard error. `skip` / `rollback` run
    /// the two-phase guarded path: gradients are checked before the
    /// apply and parameters after it, so a poisoned tensor never
    /// survives the step — but the noise draw and accountant step are
    /// burned regardless (the data was touched; the budget is spent).
    pub fn train_step(&mut self) -> Result<StepLog> {
        let b_phys = self.info.batch;
        let logical = self.logical_batch();
        let accum = logical / b_phys;
        let t0 = Instant::now();

        let out = if self.cfg.on_nonfinite == "abort" {
            let out = if accum == 1 {
                self.fused_step(logical)?
            } else {
                self.accumulated_step(accum, logical)?
            };
            if !out.loss.is_finite() {
                bail!(
                    "non-finite loss {} at step {} (on_nonfinite=abort; use \
                     --on-nonfinite skip|rollback to continue past bad steps)",
                    out.loss,
                    self.step_no + 1
                );
            }
            out
        } else {
            self.guarded_step(accum, logical)?
        };

        if let Some(acc) = &mut self.accountant {
            acc.step();
        }
        self.step_no += 1;

        if self.cfg.checkpoint_every > 0 && self.step_no % self.cfg.checkpoint_every == 0 {
            if let Some(dir) = self.cfg.checkpoint_dir.clone() {
                self.save_checkpoint(&dir)?;
            }
        }

        Ok(StepLog {
            step: self.step_no,
            loss: out.loss,
            mean_clip: out.mean_clip,
            group_clip: out.group_clip,
            epsilon: self.epsilon(),
            step_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Fast path: one fused backend step (one physical == one logical).
    fn fused_step(&mut self, logical: usize) -> Result<StepOut> {
        let (x, y) = self.source.sample(self.info.batch, self.info.seq);
        let noise = if self.wants_noise() {
            self.noise.tensors(&self.info)
        } else {
            Vec::new()
        };
        let h = self.hyper(logical);
        self.backend.step(&x, &y, &noise, &h)
    }

    /// Accumulate per-sample-clipped gradient sums over `accum`
    /// micro-batches (no update). Returns the summed grads plus the
    /// step metrics averaged over the micro-batches.
    ///
    /// Data is drawn through per-shard sub-streams: shard `s` owns the
    /// contiguous micro-batch range the balanced split assigns it and
    /// reads from a stream clone positioned at its first global draw
    /// index, so the per-shard draws concatenate to exactly the
    /// 1-shard order (the parent cursor advances by `accum` either
    /// way, keeping checkpoint cursors shard-count-independent). The
    /// reduction is the backend's [`Backend::sharded_grads`], whose
    /// contract is a flat left fold in global micro-batch order —
    /// bitwise the sequential accumulation regardless of shard count.
    fn accumulate_grads(&mut self, accum: usize) -> Result<(Vec<Vec<f32>>, StepOut)> {
        let cursor = self.source.cursor();
        let shards = self.cfg.shards.max(1);
        let mut batches = Vec::with_capacity(accum);
        let mut start = 0u64;
        for n in crate::runtime::native::par::split_sizes(accum, shards) {
            let mut sub = self.source.sub_stream(cursor + start);
            for _ in 0..n {
                batches.push(sub.sample(self.info.batch, self.info.seq));
            }
            start += n as u64;
        }
        self.source.skip_to(cursor + accum as u64);
        self.backend.sharded_grads(&batches, self.cfg.clip as f32)
    }

    /// Gradient accumulation: k clipped-grad micro-steps summed
    /// host-side, then one apply with a single noise draw (DP-correct:
    /// per-sample clipping is per micro-batch, noise is per logical
    /// batch).
    fn accumulated_step(&mut self, accum: usize, logical: usize) -> Result<StepOut> {
        let (acc_grads, out) = self.accumulate_grads(accum)?;
        let noise = if self.wants_noise() {
            self.noise.tensors(&self.info)
        } else {
            Vec::new()
        };
        let h = self.hyper(logical);
        self.backend.apply_update(&acc_grads, &noise, &h)?;
        Ok(out)
    }

    /// Two-phase guarded step for `on_nonfinite=skip|rollback`: compute
    /// clipped grads, check them and the loss, snapshot, apply, then
    /// scan the updated parameters. The same kernels run as on the
    /// fused path (clipped sums + apply into zeroed buffers), so the
    /// guard changes robustness, not arithmetic.
    fn guarded_step(&mut self, accum: usize, logical: usize) -> Result<StepOut> {
        let (grads, out) = self.accumulate_grads(accum)?;
        let noise = if self.wants_noise() {
            self.noise.tensors(&self.info)
        } else {
            Vec::new()
        };
        let h = self.hyper(logical);
        let grads_poisoned = !out.loss.is_finite()
            || grads.iter().any(|g| g.iter().any(|x| !x.is_finite()));
        let mut update_poisoned = false;
        let mut snapshot = None;
        if !grads_poisoned {
            snapshot = Some(self.backend.state()?);
            self.backend.apply_update(&grads, &noise, &h)?;
            update_poisoned = self
                .backend
                .state()?
                .iter()
                .any(|t| t.iter().any(|x| !x.is_finite()));
        }
        if grads_poisoned || update_poisoned {
            match self.cfg.on_nonfinite.as_str() {
                // Skip: discard the poisoned update. If nothing was
                // applied (grads caught first) the parameters are
                // already clean; otherwise restore the pre-apply
                // snapshot.
                "skip" => {
                    if update_poisoned {
                        self.backend.load_state(snapshot.unwrap())?;
                    }
                    warn_!(
                        "step {}: non-finite {} — update skipped; the noise draw and \
                         accountant step are burned (budget is spent)",
                        self.step_no + 1,
                        if grads_poisoned { "loss/gradients" } else { "parameter update" }
                    );
                }
                // Rollback: restore the last good checkpoint's params +
                // optimizer state. Only needed when the apply itself
                // overflowed; a pre-apply catch leaves params clean.
                _ => {
                    if update_poisoned {
                        self.rollback_to_checkpoint()?;
                    } else {
                        warn_!(
                            "step {}: non-finite loss/gradients caught before the apply — \
                             update dropped (parameters untouched); the noise draw and \
                             accountant step are burned",
                            self.step_no + 1
                        );
                    }
                }
            }
        }
        Ok(out)
    }

    /// Restore parameters (+ optimizer state) from the newest usable
    /// checkpoint. Streams and the privacy ledger are *not* rewound:
    /// the consumed draws and spent budget stay consumed and spent.
    fn rollback_to_checkpoint(&mut self) -> Result<()> {
        let dir = self
            .cfg
            .checkpoint_dir
            .clone()
            .context("on_nonfinite=rollback requires checkpoint_dir")?;
        for path in checkpoint::list_desc(&dir) {
            let ck = match checkpoint::read(&path) {
                Ok(ck) => ck,
                Err(e) => {
                    warn_!("rollback: ignoring corrupt checkpoint: {e}");
                    continue;
                }
            };
            ck.validate(&self.info)?;
            if let Some(fp) = &ck.fingerprint {
                fp.check(&self.fingerprint())?;
            }
            warn_!(
                "step {}: non-finite parameter update — rolled back to checkpoint {} \
                 (step {}); streams and the privacy ledger continue forward",
                self.step_no + 1,
                path.display(),
                ck.step
            );
            return self.backend.load_state(ck.tensors);
        }
        bail!(
            "on_nonfinite=rollback: non-finite update at step {} but no usable checkpoint \
             in {}",
            self.step_no + 1,
            dir.display()
        )
    }

    pub fn epsilon(&self) -> f64 {
        self.accountant
            .as_ref()
            .map(|a| a.epsilon(self.cfg.privacy.target_delta))
            .unwrap_or(0.0)
    }

    pub fn save_checkpoint(&self, dir: &std::path::Path) -> Result<()> {
        let tensors = self.backend.state()?;
        let fp = self.fingerprint();
        let meta = checkpoint::SaveMeta {
            step: self.step_no,
            info: &self.info,
            fingerprint: &fp,
            cursors: checkpoint::Cursors {
                noise_step: self.noise.step(),
                data_cursor: self.source.cursor(),
                accountant_steps: self
                    .accountant
                    .as_ref()
                    .map(|a| a.steps)
                    .unwrap_or(self.step_no as u64),
            },
            keep_last: self.cfg.checkpoint_keep_last,
        };
        checkpoint::save(dir, &meta, &tensors)
            .context("saving checkpoint")
            .map(|_| ())
    }

    /// Full training run per the config; logs every `log_every` steps.
    pub fn run(&mut self) -> Result<TrainReport> {
        self.init()?;
        let initial_loss = self.eval(2)?;
        info!(
            "model={} strategy={} backend={} params={:.2}M B={} sigma={:.3} initial_loss={initial_loss:.4}",
            self.cfg.model,
            self.cfg.strategy,
            self.cfg.backend,
            self.info.n_params as f64 / 1e6,
            self.info.batch,
            self.sigma
        );
        let mut report = TrainReport {
            model: self.cfg.model.clone(),
            strategy: self.cfg.strategy.clone(),
            backend: self.cfg.backend.clone(),
            sigma: self.sigma,
            initial_loss,
            ..Default::default()
        };
        let mut times = Summary::new();
        let logical = self.logical_batch();
        let run_t0 = Instant::now();
        let mut last_loss = initial_loss;
        // `steps` is the *total* step target: a resumed run picks up at
        // the checkpointed step_no and stops at the same total as the
        // uninterrupted run would.
        let start_step = self.step_no;
        while self.step_no < self.cfg.steps {
            if self.cfg.privacy.strict_budget
                && self.accountant.is_some()
                && self.epsilon() >= self.cfg.privacy.target_epsilon
                && self.cfg.privacy.sigma > 0.0
            {
                info!("privacy budget exhausted at step {}; stopping", self.step_no);
                break;
            }
            let log = self.train_step()?;
            times.push(log.step_secs);
            last_loss = log.loss;
            if self.cfg.log_every > 0 && self.step_no % self.cfg.log_every == 0 {
                info!(
                    "step {:>5} loss {:.4} clip {:.3} eps {:.3} ({:.0} samples/s)",
                    log.step,
                    log.loss,
                    log.mean_clip,
                    log.epsilon,
                    logical as f64 / log.step_secs
                );
                if log.group_clip.len() > 1 {
                    let per: Vec<String> =
                        log.group_clip.iter().map(|c| format!("{c:.3}")).collect();
                    info!("      group clip [{}]", per.join(" "));
                }
                report.logs.push(log);
            }
            if self.cfg.eval_every > 0 && self.step_no % self.cfg.eval_every == 0 {
                let ev = self.eval(2)?;
                info!("eval loss {ev:.4}");
            }
        }
        let elapsed = run_t0.elapsed().as_secs_f64();
        report.steps = self.step_no;
        report.final_loss = last_loss;
        report.final_epsilon = self.epsilon();
        report.mean_step_secs = times.mean();
        report.throughput_samples_per_sec =
            ((self.step_no - start_step) * logical) as f64 / elapsed.max(1e-9);
        report.compile_secs = self.backend.compile_secs();
        report.peak_rss_bytes = peak_rss_bytes();
        Ok(report)
    }
}
