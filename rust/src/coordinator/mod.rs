//! Training coordinator — the runtime half of the paper's
//! `PrivacyEngine.attach(optimizer)` workflow (Section 4), rewired to
//! drive any [`Backend`](crate::runtime::Backend).
//!
//! Responsibilities:
//!  * backend selection by config (native kernels by default, PJRT
//!    artifacts behind the `xla-runtime` feature)
//!  * noise calibration via the RDP accountant (sigma from (eps, delta))
//!  * synthetic data pipeline + physical batching
//!  * strategy dispatch: fused `step` on the fast path, `clipped_grads +
//!    apply_update` pairs when gradient accumulation is on
//!  * DP noise generation (the coordinator owns the privacy-critical
//!    DRBG; backends take noise as input and never sample)
//!  * budget enforcement, metrics, checkpointing
//!
//! Neither Python nor XLA is on this path in the default build.

pub mod checkpoint;
pub mod noise;

use crate::config::TrainConfig;
use crate::error::{Context, Result};
use crate::privacy::{calibrate_sigma, RdpAccountant};
use crate::runtime::{create_backend, Backend, BatchX, ModelInfo, StepHyper, StepOut};
use crate::util::stats::{peak_rss_bytes, Summary};
use crate::{bail, data, info};
use std::time::Instant;

/// One logged training step.
#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub mean_clip: f32,
    /// Mean clip factor per clipping group (one entry for all-layer;
    /// one per layer/group under layer-wise/group-wise styles).
    pub group_clip: Vec<f32>,
    pub epsilon: f64,
    pub step_secs: f64,
}

/// Final report of a training run (consumed by examples / benches /
/// EXPERIMENTS.md).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub model: String,
    pub strategy: String,
    pub backend: String,
    pub steps: usize,
    pub sigma: f64,
    pub final_loss: f32,
    pub initial_loss: f32,
    pub final_epsilon: f64,
    pub logs: Vec<StepLog>,
    pub throughput_samples_per_sec: f64,
    pub mean_step_secs: f64,
    pub compile_secs: f64,
    pub peak_rss_bytes: u64,
}

/// Batch source abstraction so the trainer drives token and vector
/// workloads through one loop.
pub enum BatchSource {
    Tokens(data::TokenCorpus),
    Vectors(data::VectorDataset),
}

impl BatchSource {
    /// Build the source matching a model description.
    fn for_model(info: &ModelInfo, seed: u64) -> Result<Self> {
        match info.kind.as_str() {
            // natively executed token models (seqtok) are next-token
            // predictors like the gpt artifacts: vocab == n_classes
            "gpt" | "gptlora" | "seqtok" => Ok(BatchSource::Tokens(data::TokenCorpus::new(
                info.n_classes,
                info.seq,
                seed,
            ))),
            "mlp" | "seqmlp" | "conv" => {
                // class separation as in the seed pipeline: conv images
                // are lower-contrast than flat vectors
                let sep = if info.kind == "conv" { 1.0 } else { 2.0 };
                Ok(BatchSource::Vectors(data::VectorDataset::new(
                    info.d_in,
                    info.n_classes,
                    sep,
                    seed,
                )))
            }
            other => bail!("unknown model kind '{other}'"),
        }
    }

    /// Produce (x, y) for one physical batch (`b` samples of `t` rows).
    fn sample(&mut self, b: usize, t: usize) -> (BatchX, Vec<i32>) {
        match self {
            BatchSource::Tokens(c) => {
                let (xs, ys) = c.sample_batch(b);
                (BatchX::I32(xs), ys)
            }
            BatchSource::Vectors(ds) => {
                // one labeled feature row per token: B*T rows per batch
                let (xs, ys) = ds.sample_batch(b * t);
                (BatchX::F32(xs), ys)
            }
        }
    }
}

pub struct Trainer {
    pub backend: Box<dyn Backend>,
    pub cfg: TrainConfig,
    pub info: ModelInfo,
    pub accountant: Option<RdpAccountant>,
    pub sigma: f64,
    source: BatchSource,
    noise: noise::NoiseSource,
    step_no: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let backend = create_backend(&cfg)?;
        let info = backend.info().clone();
        let b_phys = info.batch;
        let logical = if cfg.logical_batch == 0 { b_phys } else { cfg.logical_batch };
        if logical % b_phys != 0 {
            bail!(
                "logical batch {} must be a multiple of the physical batch {}",
                logical,
                b_phys
            );
        }

        // privacy calibration
        let dp = cfg.strategy != "nondp" && !cfg.disable_dp;
        let q = logical as f64 / cfg.privacy.dataset_size as f64;
        let sigma = if !dp {
            0.0
        } else if cfg.privacy.sigma > 0.0 {
            cfg.privacy.sigma
        } else {
            let s = calibrate_sigma(
                q,
                cfg.steps as u64,
                cfg.privacy.target_epsilon,
                cfg.privacy.target_delta,
            );
            info!(
                "calibrated sigma={s:.4} for (eps={}, delta={}) at q={q:.5} over {} steps",
                cfg.privacy.target_epsilon, cfg.privacy.target_delta, cfg.steps
            );
            s
        };
        let accountant = dp.then(|| RdpAccountant::new(q, sigma));
        let source = BatchSource::for_model(&info, cfg.seed ^ 0xDA7A)?;

        Ok(Self {
            backend,
            info,
            accountant,
            sigma,
            source,
            noise: noise::NoiseSource::new(cfg.seed ^ 0x0153),
            step_no: 0,
            cfg,
        })
    }

    fn logical_batch(&self) -> usize {
        if self.cfg.logical_batch == 0 {
            self.info.batch
        } else {
            self.cfg.logical_batch
        }
    }

    /// Whether the step consumes noise tensors. Keyed on the strategy
    /// alone (not `disable_dp`): DP-strategy executables take noise as
    /// an input regardless, and sigma_r is 0 when DP is disabled, so
    /// the draw is a no-op numerically but keeps the arity contract.
    fn wants_noise(&self) -> bool {
        self.cfg.strategy != "nondp"
    }

    /// Initialize parameters via the backend (or resume a checkpoint).
    pub fn init(&mut self) -> Result<()> {
        if let (Some(dir), true) = (&self.cfg.checkpoint_dir, self.cfg.checkpoint_every > 0) {
            if let Some(path) = checkpoint::latest(dir) {
                info!("resuming from checkpoint {}", path.display());
                let (step, tensors) = checkpoint::load(&path, &self.info)?;
                self.step_no = step;
                // Replay the privacy ledger and burn the consumed noise
                // draws: the pre-crash steps spent budget and used the
                // deterministic streams for steps 1..=step, so a resumed
                // run must account for them and never redraw them.
                if let Some(acc) = &mut self.accountant {
                    for _ in 0..step {
                        acc.step();
                    }
                }
                self.noise.skip_to(step as u64);
                self.backend.load_state(tensors)?;
                return Ok(());
            }
        }
        self.backend.init(self.cfg.seed)
    }

    /// Evaluate mean loss on `batches` fresh batches.
    pub fn eval(&mut self, batches: usize) -> Result<f32> {
        let mut total = 0.0f32;
        for _ in 0..batches.max(1) {
            let (x, y) = self.source.sample(self.info.batch, self.info.seq);
            total += self.backend.eval_loss(&x, &y)?;
        }
        Ok(total / batches.max(1) as f32)
    }

    fn hyper(&self, logical: usize) -> StepHyper {
        StepHyper {
            lr: self.cfg.lr as f32,
            clip: self.cfg.clip as f32,
            sigma_r: (self.sigma * self.cfg.clip) as f32,
            logical_batch: logical as f32,
            step: (self.step_no + 1) as f32,
        }
    }

    /// One *logical* training step (possibly several physical batches).
    pub fn train_step(&mut self) -> Result<StepLog> {
        let b_phys = self.info.batch;
        let logical = self.logical_batch();
        let accum = logical / b_phys;
        let t0 = Instant::now();

        let out = if accum == 1 {
            self.fused_step(logical)?
        } else {
            self.accumulated_step(accum, logical)?
        };

        if let Some(acc) = &mut self.accountant {
            acc.step();
        }
        self.step_no += 1;

        if self.cfg.checkpoint_every > 0 && self.step_no % self.cfg.checkpoint_every == 0 {
            if let Some(dir) = self.cfg.checkpoint_dir.clone() {
                self.save_checkpoint(&dir)?;
            }
        }

        Ok(StepLog {
            step: self.step_no,
            loss: out.loss,
            mean_clip: out.mean_clip,
            group_clip: out.group_clip,
            epsilon: self.epsilon(),
            step_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Fast path: one fused backend step (one physical == one logical).
    fn fused_step(&mut self, logical: usize) -> Result<StepOut> {
        let (x, y) = self.source.sample(self.info.batch, self.info.seq);
        let noise = if self.wants_noise() {
            self.noise.tensors(&self.info)
        } else {
            Vec::new()
        };
        let h = self.hyper(logical);
        self.backend.step(&x, &y, &noise, &h)
    }

    /// Gradient accumulation: k clipped-grad micro-steps summed
    /// host-side, then one apply with a single noise draw (DP-correct:
    /// per-sample clipping is per micro-batch, noise is per logical
    /// batch).
    fn accumulated_step(&mut self, accum: usize, logical: usize) -> Result<StepOut> {
        let mut acc_grads: Vec<Vec<f32>> = Vec::new();
        let mut loss_sum = 0.0f32;
        let mut clip_sum = 0.0f32;
        let mut group_sum: Vec<f32> = Vec::new();
        for _ in 0..accum {
            let (x, y) = self.source.sample(self.info.batch, self.info.seq);
            let (grads, out) = self.backend.clipped_grads(&x, &y, self.cfg.clip as f32)?;
            loss_sum += out.loss;
            clip_sum += out.mean_clip;
            if group_sum.is_empty() {
                group_sum = out.group_clip;
            } else {
                for (a, g) in group_sum.iter_mut().zip(out.group_clip.iter()) {
                    *a += *g;
                }
            }
            if acc_grads.is_empty() {
                acc_grads = grads;
            } else {
                for (a, g) in acc_grads.iter_mut().zip(grads.iter()) {
                    for (av, gv) in a.iter_mut().zip(g.iter()) {
                        *av += *gv;
                    }
                }
            }
        }
        let noise = if self.wants_noise() {
            self.noise.tensors(&self.info)
        } else {
            Vec::new()
        };
        let h = self.hyper(logical);
        self.backend.apply_update(&acc_grads, &noise, &h)?;
        for g in group_sum.iter_mut() {
            *g /= accum as f32;
        }
        Ok(StepOut {
            loss: loss_sum / accum as f32,
            mean_clip: clip_sum / accum as f32,
            group_clip: group_sum,
        })
    }

    pub fn epsilon(&self) -> f64 {
        self.accountant
            .as_ref()
            .map(|a| a.epsilon(self.cfg.privacy.target_delta))
            .unwrap_or(0.0)
    }

    pub fn save_checkpoint(&self, dir: &std::path::Path) -> Result<()> {
        let tensors = self.backend.state()?;
        checkpoint::save(dir, self.step_no, &self.info, &tensors).context("saving checkpoint")
    }

    /// Full training run per the config; logs every `log_every` steps.
    pub fn run(&mut self) -> Result<TrainReport> {
        self.init()?;
        let initial_loss = self.eval(2)?;
        info!(
            "model={} strategy={} backend={} params={:.2}M B={} sigma={:.3} initial_loss={initial_loss:.4}",
            self.cfg.model,
            self.cfg.strategy,
            self.cfg.backend,
            self.info.n_params as f64 / 1e6,
            self.info.batch,
            self.sigma
        );
        let mut report = TrainReport {
            model: self.cfg.model.clone(),
            strategy: self.cfg.strategy.clone(),
            backend: self.cfg.backend.clone(),
            sigma: self.sigma,
            initial_loss,
            ..Default::default()
        };
        let mut times = Summary::new();
        let logical = self.logical_batch();
        let run_t0 = Instant::now();
        let mut last_loss = initial_loss;
        for s in 0..self.cfg.steps {
            if self.cfg.privacy.strict_budget
                && self.accountant.is_some()
                && self.epsilon() >= self.cfg.privacy.target_epsilon
                && self.cfg.privacy.sigma > 0.0
            {
                info!("privacy budget exhausted at step {s}; stopping");
                break;
            }
            let log = self.train_step()?;
            times.push(log.step_secs);
            last_loss = log.loss;
            if self.cfg.log_every > 0 && (s + 1) % self.cfg.log_every == 0 {
                info!(
                    "step {:>5} loss {:.4} clip {:.3} eps {:.3} ({:.0} samples/s)",
                    log.step,
                    log.loss,
                    log.mean_clip,
                    log.epsilon,
                    logical as f64 / log.step_secs
                );
                if log.group_clip.len() > 1 {
                    let per: Vec<String> =
                        log.group_clip.iter().map(|c| format!("{c:.3}")).collect();
                    info!("      group clip [{}]", per.join(" "));
                }
                report.logs.push(log);
            }
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                let ev = self.eval(2)?;
                info!("eval loss {ev:.4}");
            }
        }
        let elapsed = run_t0.elapsed().as_secs_f64();
        report.steps = self.step_no;
        report.final_loss = last_loss;
        report.final_epsilon = self.epsilon();
        report.mean_step_secs = times.mean();
        report.throughput_samples_per_sec = (self.step_no * logical) as f64 / elapsed.max(1e-9);
        report.compile_secs = self.backend.compile_secs();
        report.peak_rss_bytes = peak_rss_bytes();
        Ok(report)
    }
}
