//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `fastdp <subcommand> [--key value] [--flag] [positional...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be a number")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare `--flag` followed by a non-option token is read as
        // `--flag <value>` (documented ambiguity); use `--flag` last or
        // with `=`.
        let a = parse("train pos1 --config cfg.json --steps 100 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.json"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --filter=table1 --n=3");
        assert_eq!(a.get("filter"), Some("table1"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("lr", 0.1), 0.1);
    }
}
