//! # fastdp — Book-Keeping differentially private optimization
//!
//! Reproduction of *"Differentially Private Optimization on Large Model at
//! Small Cost"* (Bu, Wang, Zha, Karypis — ICML 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — ghost-norm / clipped-sum /
//!   per-sample-gradient kernels (`python/compile/kernels/`).
//! * **Layer 2 (JAX, build time)** — transformer / MLP / CNN forward +
//!   book-keeping backward, one AOT-lowered HLO artifact per
//!   (model, DP implementation) pair (`python/compile/`).
//! * **Layer 3 (this crate, run time)** — training coordinator, privacy
//!   accountant, complexity engine, data pipeline and PJRT runtime.
//!   Python is never on the training path.
//!
//! See DESIGN.md for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a bench target.

pub mod arch;
pub mod bench;
pub mod cli;
pub mod complexity;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod privacy;
pub mod runtime;
pub mod util;
