//! # fastdp — Book-Keeping differentially private optimization
//!
//! Reproduction of *"Differentially Private Optimization on Large Model at
//! Small Cost"* (Bu, Wang, Zha, Karypis — ICML 2023).
//!
//! The run-time stack is pure Rust and self-contained:
//!
//! * **runtime::native (default)** — the BK step end-to-end as fused
//!   native kernels over a composable per-layer module system
//!   (`runtime::native::layers`: Linear, ReLU, Embedding, LayerNorm):
//!   ghost-norm / per-sample-instantiation norms with the paper's mixed
//!   layerwise dispatch, all-layer / layer-wise / group-wise clipping
//!   styles, the clipped weighted sum, and noisy SGD/Adam —
//!   register-tiled wide-lane kernels (runtime-detected SIMD with a
//!   portable fallback), thread-fanned over the batch, and
//!   allocation-free in steady state (step-scoped buffer arena).
//! * **runtime::pjrt (feature `xla-runtime`)** — the original AOT
//!   artifact executor (HLO text + manifest from `python/compile/`,
//!   executed on the PJRT CPU client). Off by default because the `xla`
//!   crate is not buildable offline.
//! * **coordinator** — training loop, RDP accountant, DP noise DRBG,
//!   Poisson batching, checkpointing; drives either backend through the
//!   `runtime::Backend` trait.
//!
//! The build-time Python layers (`python/compile/`: Pallas kernels + JAX
//! AOT lowering) only matter for the PJRT path; the native path needs no
//! Python at all.
//!
//! See DESIGN.md for the backend contract, the native kernel
//! memory/threading model, and the per-experiment index mapping paper
//! tables/figures to bench targets.

// Config structs are built as `default() + field edits` throughout (the
// seed codebase's idiom); keep clippy's -D warnings CI green on it.
#![allow(clippy::field_reassign_with_default)]

pub mod arch;
pub mod bench;
pub mod cli;
pub mod complexity;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod json;
pub mod privacy;
pub mod runtime;
pub mod util;
