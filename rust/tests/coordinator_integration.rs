//! Coordinator-level integration over the native backend: full Trainer
//! runs — training reduces loss, the accountant tracks epsilon,
//! accumulation matches the fused path semantically, and checkpoints
//! round-trip. No artifacts, no XLA: runs offline.

#![allow(clippy::field_reassign_with_default)]

use fastdp::config::TrainConfig;
use fastdp::coordinator::Trainer;

fn base_cfg(model: &str, strategy: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = model.into();
    cfg.strategy = strategy.into();
    cfg.steps = steps;
    cfg.lr = 0.5;
    cfg.clip = 1.0;
    cfg.log_every = 0;
    cfg.privacy.sigma = 0.8;
    cfg.privacy.dataset_size = 50_000;
    cfg.privacy.strict_budget = false;
    cfg
}

#[test]
fn bk_training_reduces_loss_and_tracks_epsilon() {
    let mut t = Trainer::new(base_cfg("mlp_e2e", "bk", 15)).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps, 15);
    assert!(
        report.final_loss < report.initial_loss * 0.8,
        "loss {} -> {}",
        report.initial_loss,
        report.final_loss
    );
    assert!(report.final_epsilon > 0.0 && report.final_epsilon.is_finite());
    assert!(report.throughput_samples_per_sec > 0.0);
    assert_eq!(report.backend, "native");
}

#[test]
fn nondp_has_zero_epsilon() {
    let mut cfg = base_cfg("mlp_e2e", "nondp", 5);
    cfg.lr = 0.05; // unclipped gradients: keep the step size sane
    let mut t = Trainer::new(cfg).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.final_epsilon, 0.0);
    assert!(report.final_loss < report.initial_loss);
}

#[test]
fn accumulated_matches_fused_with_zero_noise() {
    // With sigma ~ 0, both the fused path and the clipgrad+apply path
    // must learn; we check both end well below the initial loss.
    let mut fused_cfg = base_cfg("mlp_e2e", "bk", 10);
    fused_cfg.privacy.sigma = 1e-9; // effectively zero noise
    let mut fused = Trainer::new(fused_cfg).unwrap();
    let fr = fused.run().unwrap();

    let mut acc_cfg = base_cfg("mlp_e2e", "bk", 10);
    acc_cfg.privacy.sigma = 1e-9;
    acc_cfg.logical_batch = 64; // 2 x physical 32 -> accumulation path
    let mut acc = Trainer::new(acc_cfg).unwrap();
    let ar = acc.run().unwrap();

    assert!(fr.final_loss < fr.initial_loss * 0.6, "{} -> {}", fr.initial_loss, fr.final_loss);
    assert!(ar.final_loss < ar.initial_loss * 0.6, "{} -> {}", ar.initial_loss, ar.final_loss);
}

#[test]
fn accumulation_sees_more_data_per_step() {
    // Larger sampling rate q must spend more budget at fixed sigma/steps.
    let mut small = Trainer::new(base_cfg("mlp_e2e", "bk", 5)).unwrap();
    let rs = small.run().unwrap();

    let mut big_cfg = base_cfg("mlp_e2e", "bk", 5);
    big_cfg.logical_batch = 128;
    let mut big = Trainer::new(big_cfg).unwrap();
    let rb = big.run().unwrap();
    assert!(
        rb.final_epsilon > rs.final_epsilon,
        "bigger sampling rate must spend more budget: {} vs {}",
        rb.final_epsilon,
        rs.final_epsilon
    );
}

#[test]
fn adam_seq_strategies_all_learn() {
    // The sequential model (T = 32, Adam) exercises the Gram-matrix ghost
    // norms and the mixed dispatch end-to-end.
    for strategy in ["bk", "bk_mixopt", "ghostclip", "nondp"] {
        let mut cfg = base_cfg("seq_e2e", strategy, 3);
        cfg.lr = 1e-3;
        let mut t = Trainer::new(cfg).unwrap();
        let r = t.run().unwrap();
        assert!(
            r.final_loss.is_finite() && r.final_loss < r.initial_loss * 1.05,
            "{strategy}: {} -> {}",
            r.initial_loss,
            r.final_loss
        );
    }
}

#[test]
fn token_sequence_model_trains_natively_all_styles() {
    // The acceptance case of the DpLayer refactor: an Embedding +
    // Linear + LayerNorm stack trains end-to-end under --backend native
    // with every clipping style (next-token over the Markov corpus).
    for style in ["all-layer", "layer-wise", "group-wise:2"] {
        let mut cfg = base_cfg("seq_tok_e2e", "bk", 20);
        cfg.lr = 1e-2;
        cfg.clipping_style = style.into();
        cfg.log_every = 5;
        let mut t = Trainer::new(cfg).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.backend, "native");
        assert!(
            r.final_loss.is_finite() && r.final_loss < r.initial_loss,
            "{style}: {} -> {}",
            r.initial_loss,
            r.final_loss
        );
        // the logged group means match the configured granularity
        let want_groups = match style {
            "all-layer" => 1,
            "group-wise:2" => 2,
            // seq_tok_e2e: emb + ln0 + fc0 + ln1 + fc1 trainable layers
            _ => 5,
        };
        let log = r.logs.last().expect("logged step");
        assert_eq!(log.group_clip.len(), want_groups, "{style}");
        assert!(log.group_clip.iter().all(|c| c.is_finite() && *c > 0.0));
    }
}

#[test]
fn gpt_nano_trains_natively_with_epsilon_accounting() {
    // The transformer acceptance path: `fastdp train --model
    // gpt_nano_e2e --backend native` runs a full DP step loop offline
    // with finite loss and a growing epsilon ledger, through causal
    // attention and the residual tape.
    let mut cfg = base_cfg("gpt_nano_e2e", "bk", 20);
    cfg.lr = 1e-2; // Adam
    cfg.log_every = 5;
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    assert_eq!(r.backend, "native");
    assert_eq!(r.steps, 20);
    assert!(r.initial_loss.is_finite() && r.final_loss.is_finite());
    assert!(
        r.final_loss < r.initial_loss,
        "gpt_nano loss should fall: {} -> {}",
        r.initial_loss,
        r.final_loss
    );
    assert!(r.final_epsilon > 0.0 && r.final_epsilon.is_finite());
    // clipping-style variant: layer-wise clip factors per trainable layer
    let mut cfg = base_cfg("gpt_nano_e2e", "bk_mixopt", 5);
    cfg.lr = 1e-2;
    cfg.clipping_style = "layer-wise".into();
    cfg.log_every = 5;
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.final_loss.is_finite());
    let log = r.logs.last().expect("logged step");
    // emb + 2*(ln,attn,ln,fc1,fc2) + lnf + head = 13 trainable layers
    assert_eq!(log.group_clip.len(), 13);
    assert!(log.group_clip.iter().all(|c| c.is_finite() && *c > 0.0));
}

#[test]
fn tied_gpt_nano_trains_natively_end_to_end() {
    // The weight-tied acceptance path: `fastdp train --model
    // gpt_nano_tied_e2e --backend native` — the vocab head is a shared
    // view of the embedding table, clipped as one unit (own ghost norms
    // + the O(T^2 d) cross term), with the epsilon ledger intact.
    let mut cfg = base_cfg("gpt_nano_tied_e2e", "bk", 20);
    cfg.lr = 1e-2; // Adam
    cfg.log_every = 5;
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    assert_eq!(r.backend, "native");
    assert_eq!(r.steps, 20);
    assert!(
        r.final_loss.is_finite() && r.final_loss < r.initial_loss,
        "tied gpt_nano loss should fall: {} -> {}",
        r.initial_loss,
        r.final_loss
    );
    assert!(r.final_epsilon > 0.0 && r.final_epsilon.is_finite());
    // layer-wise: groups follow canonical tensors, so the tied model
    // has one group fewer than untied gpt_nano_e2e (12, not 13)
    let mut cfg = base_cfg("gpt_nano_tied_e2e", "bk", 5);
    cfg.lr = 1e-2;
    cfg.clipping_style = "layer-wise".into();
    cfg.log_every = 5;
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.final_loss.is_finite());
    let log = r.logs.last().expect("logged step");
    assert_eq!(log.group_clip.len(), 12, "tied head shares the embedding's group");
    assert!(log.group_clip.iter().all(|c| c.is_finite() && *c > 0.0));
}

#[test]
fn clipping_style_works_through_accumulation() {
    let mut cfg = base_cfg("mlp_e2e", "bk", 4);
    cfg.clipping_style = "layer-wise".into();
    cfg.logical_batch = 64; // 2 micro-batches per logical step
    cfg.log_every = 2;
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.final_loss.is_finite() && r.final_loss < r.initial_loss);
    let log = r.logs.last().expect("logged step");
    assert_eq!(log.group_clip.len(), 3, "mlp_e2e has 3 trainable layers");
}

#[test]
fn rejects_unknown_clipping_style() {
    let mut cfg = base_cfg("mlp_e2e", "bk", 3);
    cfg.clipping_style = "per-tensor".into();
    assert!(cfg.validate().is_err());
}

#[test]
fn strict_budget_stops_training() {
    let mut cfg = base_cfg("mlp_e2e", "bk", 500);
    cfg.privacy.sigma = 0.4; // noisy => epsilon grows fast
    cfg.privacy.target_epsilon = 0.3;
    cfg.privacy.strict_budget = true;
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    assert!(
        r.steps < 500,
        "training should stop early on budget, ran {} steps",
        r.steps
    );
}

#[test]
fn checkpoint_resume_preserves_progress() {
    let dir = std::env::temp_dir().join(format!("fastdp_ci_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = base_cfg("mlp_e2e", "bk", 10);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.checkpoint_every = 5;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    let r = t.run().unwrap();

    let mut resumed = Trainer::new(cfg).unwrap();
    resumed.init().unwrap();
    // The resumed accountant must already carry the pre-crash budget —
    // silently resetting epsilon on resume would break the guarantee.
    assert!(
        (resumed.epsilon() - r.final_epsilon).abs() < 1e-9,
        "resumed epsilon {} vs pre-crash {}",
        resumed.epsilon(),
        r.final_epsilon
    );
    let loss = resumed.eval(4).unwrap();
    assert!(
        loss < r.initial_loss * 0.9,
        "resumed eval {loss} vs initial {}",
        r.initial_loss
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_works_with_periodic_saving_off() {
    // The old gate `checkpoint_every > 0` silently started from scratch
    // when a dir held a checkpoint but periodic saving was off. Resume
    // must key on the directory contents alone.
    let dir = std::env::temp_dir().join(format!("fastdp_ci_ckpt_nogate_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = base_cfg("mlp_e2e", "bk", 6);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.checkpoint_every = 3;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    let r = t.run().unwrap();
    assert!(r.final_epsilon > 0.0);

    cfg.checkpoint_every = 0; // periodic saving off; resume must still happen
    let mut resumed = Trainer::new(cfg).unwrap();
    resumed.init().unwrap();
    assert!(
        (resumed.epsilon() - r.final_epsilon).abs() < 1e-12,
        "resume ignored with checkpoint_every=0: epsilon {} vs {}",
        resumed.epsilon(),
        r.final_epsilon
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_flag_requires_a_checkpoint() {
    let dir = std::env::temp_dir().join(format!("fastdp_ci_ckpt_empty_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = base_cfg("mlp_e2e", "bk", 3);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.resume = true;
    let mut t = Trainer::new(cfg).unwrap();
    let err = t.init().unwrap_err().to_string();
    assert!(err.contains("no usable checkpoint"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rejects_bad_logical_batch() {
    let mut cfg = base_cfg("mlp_e2e", "bk", 5);
    cfg.logical_batch = 33; // not a multiple of physical 32
    assert!(Trainer::new(cfg).is_err());
}

#[test]
fn rejects_unknown_native_model() {
    let cfg = base_cfg("gpt_e2e", "bk", 3); // GPT needs the pjrt backend
    let err = Trainer::new(cfg).unwrap_err().to_string();
    assert!(err.contains("native registry"), "{err}");
}

#[cfg(not(feature = "xla-runtime"))]
#[test]
fn pjrt_backend_requires_feature() {
    let mut cfg = base_cfg("mlp_e2e", "bk", 3);
    cfg.backend = "pjrt".into();
    let err = Trainer::new(cfg).unwrap_err().to_string();
    assert!(err.contains("xla-runtime"), "{err}");
}
