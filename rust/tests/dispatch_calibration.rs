//! Measured ghost-vs-instantiation dispatch: calibration, the profile
//! cache file, the corrupt/stale fallback policy, and end-to-end
//! equivalence of the two routes on a real model.
//!
//! The dispatch decision only changes *which kernel computes the
//! per-sample norms* — never the math those norms feed — so flipping a
//! layer's route must leave a training step equivalent within float
//! tolerance. That is the safety property that makes measured dispatch
//! shippable: a bad profile can cost time, not correctness.

use fastdp::complexity::dispatch::{Dispatch, DispatchProfile, PROFILE_VERSION};
use fastdp::complexity::{self, ClippingStyle, Strategy};
use fastdp::runtime::native::autotune::{
    calibrate, load_profile, resolve_dispatch, save_profile,
};
use fastdp::runtime::native::model::NativeSpec;
use fastdp::runtime::native::NativeBackend;
use fastdp::runtime::{Backend, BatchX, StepHyper};
use std::path::PathBuf;

fn temp_path(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("fastdp_dispatch_{}_{name}", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// A profile that makes ghost norms look catastrophically slow, so any
/// layer the formula routes to ghost flips to instantiation.
fn inst_biased_profile() -> DispatchProfile {
    DispatchProfile {
        ghost_secs_per_flop: 1e-6,
        inst_secs_per_flop: 1e-12,
        threads: 1,
        isa: "synthetic".to_string(),
    }
}

#[test]
fn profile_round_trips_through_cache_file() {
    let path = temp_path("roundtrip.json");
    let p = calibrate(1);
    save_profile(&path, &p).unwrap();
    let loaded = load_profile(&path).unwrap();
    assert_eq!(loaded.ghost_secs_per_flop, p.ghost_secs_per_flop);
    assert_eq!(loaded.inst_secs_per_flop, p.inst_secs_per_flop);
    assert_eq!(loaded.threads, p.threads);
    assert_eq!(loaded.isa, p.isa);
    // and resolve() picks the cached profile up as measured dispatch
    match resolve_dispatch("measured", &path, 1).unwrap() {
        Dispatch::Measured(m) => assert_eq!(m.threads, p.threads),
        d => panic!("expected measured dispatch, got {}", d.name()),
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn missing_profile_calibrates_and_caches() {
    let path = temp_path("fresh.json");
    assert!(!path.exists());
    let d = resolve_dispatch("measured", &path, 1).unwrap();
    assert_eq!(d.name(), "measured");
    assert!(path.exists(), "resolve must write the calibrated profile");
    let p = load_profile(&path).unwrap();
    assert!(p.ghost_secs_per_flop > 0.0 && p.inst_secs_per_flop > 0.0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_or_stale_profiles_fall_back_to_formula() {
    // corrupt: unparseable JSON is a warning + formula, never an error
    let path = temp_path("corrupt.json");
    std::fs::write(&path, "{this is not json").unwrap();
    let d = resolve_dispatch("measured", &path, 1).unwrap();
    assert_eq!(d.name(), "formula", "corrupt cache must fall back");
    // stale: wrong version, same policy
    let path2 = temp_path("stale.json");
    let mut p = inst_biased_profile().to_json();
    p.set("version", fastdp::json::Value::Int(PROFILE_VERSION + 1));
    std::fs::write(&path2, p.to_string()).unwrap();
    let d = resolve_dispatch("measured", &path2, 1).unwrap();
    assert_eq!(d.name(), "formula", "stale cache must fall back");
    // non-finite coefficients are corrupt too
    let path3 = temp_path("nan.json");
    let mut p = inst_biased_profile();
    p.ghost_secs_per_flop = -1.0;
    std::fs::write(&path3, p.to_json().to_string()).unwrap();
    assert_eq!(resolve_dispatch("measured", &path3, 1).unwrap().name(), "formula");
    for p in [path, path2, path3] {
        let _ = std::fs::remove_file(&p);
    }
}

#[test]
fn measured_profile_flips_registry_layer_routes() {
    // seq_tok_e2e's linear layers are formula-ghost (2T^2 << pd); the
    // inst-biased profile must reroute them while the forced routes
    // (embedding -> ghost, norm -> inst) stay put.
    let spec = NativeSpec::by_name("seq_tok_e2e").unwrap();
    let layers = spec.arch_layers();
    let measured = Dispatch::Measured(inst_biased_profile());
    let mut flipped = 0;
    for l in &layers {
        let f = complexity::ghost_preferred(l);
        let m = measured.ghost_preferred(l);
        match l.kind {
            fastdp::arch::LayerKind::Embedding => assert!(m, "embedding stays ghost"),
            fastdp::arch::LayerKind::Norm => assert!(!m, "norm stays instantiation"),
            _ => {
                if f != m {
                    flipped += 1;
                }
            }
        }
    }
    assert!(flipped >= 1, "the synthetic profile must change at least one route");
}

#[test]
fn flipped_routes_train_equivalently() {
    // One BkMixOpt step under formula dispatch vs under the route-
    // flipping measured profile: per-sample norms come from different
    // kernels (Gram-based ghost vs instantiated gradients), but the
    // clipped update must agree within float tolerance. mlp_ln is an
    // SGD model whose linear layers are all formula-ghost (T = 1), so
    // the synthetic profile reroutes every one of them.
    let spec = NativeSpec::by_name("mlp_ln").unwrap();
    let measured_d = Dispatch::Measured(inst_biased_profile());
    // precondition: the synthetic profile really flips linear routes
    assert!(
        spec.arch_layers()
            .iter()
            .any(|l| complexity::ghost_preferred(l) != measured_d.ghost_preferred(l)),
        "test precondition: the synthetic profile must flip a route"
    );
    let step_state = |dispatch: &Dispatch| -> Vec<f32> {
        let spec = NativeSpec::by_name("mlp_ln").unwrap();
        let mut be = NativeBackend::builder(spec.clone(), Strategy::BkMixOpt)
            .style(ClippingStyle::AllLayer)
            .threads(2)
            .dispatch(dispatch.clone())
            .build()
            .unwrap();
        be.init(3).unwrap();
        let mut ds = fastdp::data::VectorDataset::new(spec.d_in, spec.n_classes, 2.0, 17);
        let (xs, ys) = ds.sample_batch(spec.batch * spec.seq);
        let h = StepHyper {
            lr: 1e-2,
            clip: 1.0,
            sigma_r: 0.0,
            logical_batch: spec.batch as f32,
            step: 1.0,
        };
        be.step(&BatchX::F32(xs), &ys, &[], &h).unwrap();
        be.state().unwrap().concat()
    };
    let formula = step_state(&Dispatch::Formula);
    let measured = step_state(&measured_d);
    assert_eq!(formula.len(), measured.len());
    let mut max_rel = 0.0f64;
    for (&a, &b) in formula.iter().zip(&measured) {
        let rel = (a as f64 - b as f64).abs() / (a as f64).abs().max(1.0);
        max_rel = max_rel.max(rel);
    }
    assert!(
        max_rel < 1e-4,
        "route flip changed the step beyond float tolerance: max rel diff {max_rel}"
    );
}
